"""Mula model configuration family.

Paper Table 1 configs are kept verbatim (used by the Rust cluster/perf model
for projections); runnable analogs scale hidden/layers down while preserving
the architecture family (OLMo dense / OLMoE MoE), expert ratios and
active/total parameter ratios. See DESIGN.md §3.
"""

from dataclasses import dataclass, field, asdict
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    hidden: int
    n_heads: int
    head_dim: int
    intermediate: int          # dense MLP intermediate, or per-expert intermediate
    n_experts: int             # 0 => dense model
    top_k: int
    vocab_size: int
    context: int
    aux_coef: float = 0.01     # expert load-balancing auxiliary loss coefficient
    rope_theta: float = 10000.0
    # Artifact shapes (micro-batch x sequence the AOT module is lowered for).
    batch: int = 8
    seq: int = 128
    # FastSparseMoE kernel blocking (paper TBS; stage-4 row tile)
    tbs: int = 8
    tile: int = 8

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Total parameter count (matches the flat layout in model.py)."""
        h, v = self.hidden, self.vocab_size
        emb = v * h
        attn = 4 * h * h  # q,k,v,o (n_heads*head_dim == hidden by construction)
        norms = 2 * h  # two RMSNorm gains per layer
        if self.is_moe:
            mlp = self.n_experts * 3 * h * self.intermediate + self.n_experts * h  # experts + router
        else:
            mlp = 3 * h * self.intermediate
        final = h  # final norm
        head = v * h
        return emb + self.n_layers * (attn + norms + mlp) + final + head

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts instead of all)."""
        if not self.is_moe:
            return self.param_count()
        h = self.hidden
        inactive = (self.n_experts - self.top_k) * 3 * h * self.intermediate
        return self.param_count() - self.n_layers * inactive


def _cfg(**kw) -> ModelConfig:
    kw.setdefault("head_dim", kw["hidden"] // kw["n_heads"])
    return ModelConfig(**kw)


# ---------------------------------------------------------------------------
# Runnable analogs (lowered to HLO artifacts; see DESIGN.md §3)
# ---------------------------------------------------------------------------

MULA_TINY = _cfg(
    name="mula-tiny", n_layers=2, hidden=64, n_heads=2, intermediate=32,
    n_experts=8, top_k=2, vocab_size=256, context=64, batch=4, seq=32,
)
MULA_TINY_DENSE = _cfg(
    name="mula-tiny-dense", n_layers=2, hidden=64, n_heads=2, intermediate=256,
    n_experts=0, top_k=0, vocab_size=256, context=64, batch=4, seq=32,
)
MULA_MINI = _cfg(
    name="mula-mini", n_layers=4, hidden=128, n_heads=4, intermediate=64,
    n_experts=16, top_k=4, vocab_size=1024, context=128, batch=8, seq=128,
)
MULA_MINI_DENSE = _cfg(
    name="mula-mini-dense", n_layers=4, hidden=128, n_heads=4, intermediate=512,
    n_experts=0, top_k=0, vocab_size=1024, context=128, batch=8, seq=128,
)
MULA_SMALL = _cfg(
    name="mula-small", n_layers=6, hidden=192, n_heads=6, intermediate=96,
    n_experts=24, top_k=4, vocab_size=1024, context=128, batch=8, seq=128,
)
MULA_MED = _cfg(
    name="mula-med", n_layers=8, hidden=256, n_heads=8, intermediate=128,
    n_experts=32, top_k=4, vocab_size=1024, context=128, batch=8, seq=128,
    tbs=32, tile=32,
)
MULA_100M = _cfg(
    name="mula-100m", n_layers=10, hidden=640, n_heads=10, intermediate=320,
    n_experts=16, top_k=4, vocab_size=8192, context=256, batch=2, seq=256,
    tbs=64, tile=64,
)

RUNNABLE = [
    MULA_TINY, MULA_TINY_DENSE, MULA_MINI, MULA_MINI_DENSE,
    MULA_SMALL, MULA_MED, MULA_100M,
]

# ---------------------------------------------------------------------------
# Paper Table 1 configs (projection-only; never lowered)
# ---------------------------------------------------------------------------

PAPER = [
    _cfg(name="mula-1b", n_layers=16, hidden=2048, n_heads=16, head_dim=128,
         intermediate=8192, n_experts=0, top_k=0, vocab_size=50304, context=2048),
    _cfg(name="mula-7b-a1b", n_layers=16, hidden=2048, n_heads=16, head_dim=128,
         intermediate=1024, n_experts=64, top_k=8, vocab_size=50304, context=2048),
    _cfg(name="mula-20b-a2b", n_layers=32, hidden=2048, n_heads=16, head_dim=128,
         intermediate=1024, n_experts=96, top_k=8, vocab_size=50304, context=2048),
    _cfg(name="mula-100b-a7b", n_layers=48, hidden=3072, n_heads=24, head_dim=128,
         intermediate=1536, n_experts=144, top_k=8, vocab_size=50304, context=2048),
    _cfg(name="mula-220b-a10b", n_layers=64, hidden=3072, n_heads=24, head_dim=128,
         intermediate=1536, n_experts=240, top_k=8, vocab_size=50304, context=2048),
]

BY_NAME = {c.name: c for c in RUNNABLE + PAPER}


def get(name: str) -> ModelConfig:
    return BY_NAME[name]
