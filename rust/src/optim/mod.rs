//! Optimizers: fused AdamW on flat shards, the standard sharded optimizer
//! (SO, ZeRO-1-style) and the paper's EP-Aware Sharded Optimizer (EPSO,
//! §3.2).

pub mod adamw;
pub mod sharded;

pub use adamw::{AdamParams, AdamState};
pub use sharded::{SegmentLayout, SegmentState, ShardedOptimizer, ShardingMode};
