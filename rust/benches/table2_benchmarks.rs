//! Table 2: final benchmark scores, dense vs iso-compute MoE. The paper's
//! lm-eval rows are substituted by the synthetic probe suite (DESIGN.md
//! §1); the claim reproduced is the *ordering*: at iso-compute the MoE
//! model matches or beats the dense one on the suite average.


use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec};
use optimus::data::{corpus, preprocess};
use optimus::eval;
use optimus::runtime::Engine;
use optimus::util::bench::Report;

fn main() -> optimus::Result<()> {
    let m = Manifest::load(&optimus::artifacts_dir())?;
    let data_dir = std::env::temp_dir().join("optimus-table2-data");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 8, 64), 64, 7, &data_dir, 2048)?;
    }
    let engine = Engine::new_pool(2)?;
    let steps = 36;

    let mut results = Vec::new();
    for model in ["mula-tiny-dense", "mula-tiny"] {
        let spec = JobSpec::new(model)
            .data_dir(data_dir.clone())
            .topology(2, 1, 1)
            .steps(steps)
            .warmup_steps(6)
            .peak_lr(3e-3)
            .min_lr(3e-4)
            .build()?;
        let r = coordinator::train(&m, &spec)?;
        let mm = m.config(model)?;
        results.push((model, eval::run_suite(&engine, mm, &r.final_params, 24)?));
    }

    let mut t = Report::new(
        "Table 2: benchmark scores after training (dense vs MoE, iso-compute)",
        &["benchmark", results[0].0, results[1].0],
    );
    for task in eval::TASKS {
        t.row(&[
            task.into(),
            format!("{:.1}", results[0].1[task]),
            format!("{:.1}", results[1].1[task]),
        ]);
    }
    t.row(&[
        "average".into(),
        format!("{:.1}", eval::average(&results[0].1)),
        format!("{:.1}", eval::average(&results[1].1)),
    ]);
    t.print();
    t.write_csv("table2_benchmarks").ok();
    Ok(())
}
