//! The `Checkpointer`: sharded, asynchronous, two-phase-committed
//! checkpoints (paper §4's reliability story, redesigned as a subsystem).
//!
//! Each rank submits its [`TrainState`] — `Arc` handles captured in O(1)
//! at a step boundary — and either a background writer thread (async, the
//! default) or the submitting thread (sync) serializes the owned shards
//! into a *staging* directory. When the last of the `world` ranks lands,
//! the checkpoint **commits**:
//!
//! ```text
//!   .tmp-<step>/r*.{part}.bin      phase 1: shard files, fsynced
//!   .tmp-<step>/manifest.json      phase 2a: manifest written LAST, fsynced
//!   ckpt-<step>/                   phase 2b: atomic directory rename
//! ```
//!
//! A crash at any point leaves either the previously committed
//! checkpoints intact or an ignorable `.tmp-*` dir (cleaned on the next
//! attach) — the paper's "a valid checkpoint to resume training always
//! exists", generalized from two slots to a keep-`k` ring.
//!
//! The save API **requires a plan fingerprint**: untagged checkpoints can
//! no longer be written (reads of legacy untagged files still pass
//! through the legacy [`super::Checkpoint`] path).

use super::state::{PartPayload, TrainState};
use super::{bytes_to_f32s, bytes_to_u16s, checksum};
use crate::util::json::Json;
use crate::Result;
use anyhow::{anyhow, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

/// Checkpoint policy knobs, carried by the
/// [`ParallelismPlan`](crate::coordinator::ParallelismPlan) and set
/// through the `JobSpecBuilder` (`--ckpt-dir` / `--ckpt-every` /
/// `--ckpt-sync` / `--ckpt-keep` on the CLI). The policy never shapes
/// the plan fingerprint — like `--overlap`, it is an execution knob.
#[derive(Clone, Debug, PartialEq)]
pub struct CkptPolicy {
    /// checkpoint root directory; `None` disables checkpointing *and*
    /// auto-resume
    pub dir: Option<PathBuf>,
    /// snapshot interval in optimizer steps
    pub every: usize,
    /// serialize snapshots on a background writer thread, so the training
    /// step only blocks for the O(1) handle capture
    pub asynchronous: bool,
    /// committed checkpoints retained (≥ 2 — the dual guarantee)
    pub keep: usize,
}

impl Default for CkptPolicy {
    fn default() -> CkptPolicy {
        CkptPolicy { dir: None, every: 10, asynchronous: true, keep: 2 }
    }
}

impl CkptPolicy {
    pub fn enabled(&self) -> bool {
        self.dir.is_some()
    }

    /// Should a snapshot be captured after `step`?
    pub fn due(&self, step: usize) -> bool {
        self.enabled() && self.every > 0 && step > 0 && step % self.every == 0
    }

    /// Validation message for the plan's `[checkpoint]` spec check.
    pub fn invalid_reason(&self) -> Option<String> {
        if !self.enabled() {
            return None;
        }
        if self.every == 0 {
            return Some("checkpoint interval must be >= 1 step".to_string());
        }
        if self.keep < 2 {
            return Some(format!(
                "keep must be >= 2 (the dual guarantee needs a second slot \
                 so a failed write never destroys the only valid checkpoint); got {}",
                self.keep
            ));
        }
        None
    }
}

struct Job {
    step: usize,
    rank: usize,
    state: TrainState,
}

struct PendingStep {
    dir: PathBuf,
    parts: Vec<Json>,
    scalars: BTreeMap<String, Json>,
    ranks_done: usize,
}

/// Liveness/accounting counters for tests and `StepBreakdown` folding.
#[derive(Clone, Copy, Debug)]
pub struct CkptStats {
    /// committed checkpoints this run
    pub commits: u64,
    pub last_commit_step: Option<usize>,
    /// serialization time spent on the background writer (0 in sync mode
    /// — there the write time is the submitting thread's stall)
    pub write_secs: f64,
    /// shard payload bytes serialized to disk (at storage width: bf16
    /// param shards count 2 bytes/elem) — the per-dtype checkpoint-size
    /// column of the perf gate
    pub bytes_written: u64,
}

/// Sharded checkpoint writer shared by every rank of a run.
pub struct Checkpointer {
    root: PathBuf,
    fingerprint: String,
    world: usize,
    keep: usize,
    pending: Mutex<BTreeMap<usize, PendingStep>>,
    tx: Mutex<Option<SyncSender<Job>>>,
    writer: Mutex<Option<std::thread::JoinHandle<()>>>,
    commits: AtomicU64,
    /// committed step + 1; 0 = none yet
    last_commit: AtomicU64,
    write_micros: AtomicU64,
    part_bytes: AtomicU64,
    error: Mutex<Option<String>>,
}

impl Checkpointer {
    /// Attach at `root`. The fingerprint
    /// ([`JobSpec::fingerprint`](crate::coordinator::JobSpec::fingerprint))
    /// is required — the new save API cannot write untagged checkpoints.
    /// Stale `.tmp-*` staging dirs from a previous crash are removed;
    /// committed `ckpt-*` dirs are never touched.
    pub fn new(
        root: &Path,
        fingerprint: &str,
        world: usize,
        policy: &CkptPolicy,
    ) -> Result<Arc<Checkpointer>> {
        if fingerprint.is_empty() {
            return Err(anyhow!("Checkpointer requires a plan fingerprint"));
        }
        if world == 0 {
            return Err(anyhow!("Checkpointer requires world >= 1"));
        }
        std::fs::create_dir_all(root)?;
        if let Ok(rd) = std::fs::read_dir(root) {
            for e in rd.flatten() {
                if e.file_name().to_string_lossy().starts_with(".tmp-") {
                    let _ = std::fs::remove_dir_all(e.path());
                }
            }
        }
        let ck = Arc::new(Checkpointer {
            root: root.to_path_buf(),
            fingerprint: fingerprint.to_string(),
            world,
            keep: policy.keep.max(2),
            pending: Mutex::new(BTreeMap::new()),
            tx: Mutex::new(None),
            writer: Mutex::new(None),
            commits: AtomicU64::new(0),
            last_commit: AtomicU64::new(0),
            write_micros: AtomicU64::new(0),
            part_bytes: AtomicU64::new(0),
            error: Mutex::new(None),
        });
        if policy.asynchronous {
            // bounded queue: at most two full snapshot rounds in flight,
            // so a writer slower than the snapshot cadence backpressures
            // the training threads (the stall lands in `snapshot_secs`)
            // instead of pinning an unbounded pile of COW'd state
            let (tx, rx) = sync_channel::<Job>(world * 2);
            // the writer holds a Weak so dropping the last external Arc
            // (even without drain) closes the channel and ends the thread
            let me: Weak<Checkpointer> = Arc::downgrade(&ck);
            let h = std::thread::Builder::new()
                .name("ckpt-writer".to_string())
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        let Some(ck) = me.upgrade() else { break };
                        let t = Instant::now();
                        if let Err(e) = ck.write_snapshot(job.step, job.rank, &job.state) {
                            let mut err = ck.error.lock().unwrap();
                            if err.is_none() {
                                *err = Some(format!("{e:#}"));
                            }
                        }
                        ck.write_micros
                            .fetch_add(t.elapsed().as_micros() as u64, Ordering::Relaxed);
                    }
                })
                .expect("spawn ckpt-writer");
            *ck.tx.lock().unwrap() = Some(tx);
            *ck.writer.lock().unwrap() = Some(h);
        }
        Ok(ck)
    }

    /// Submit one rank's snapshot for `step`. Async mode: an O(1)
    /// enqueue onto the bounded writer queue (blocking only when the
    /// writer has fallen two snapshot rounds behind — honest
    /// backpressure). Sync mode: writes inline (the stall the perf gate
    /// measures). Either way the checkpoint commits when the last of the
    /// `world` ranks lands.
    pub fn submit(&self, step: usize, rank: usize, state: TrainState) -> Result<()> {
        if let Some(e) = self.error.lock().unwrap().clone() {
            return Err(anyhow!("checkpoint writer failed earlier: {e}"));
        }
        let tx = self.tx.lock().unwrap().clone();
        match tx {
            Some(tx) => tx
                .send(Job { step, rank, state })
                .map_err(|_| anyhow!("checkpoint writer thread is gone")),
            None => self.write_snapshot(step, rank, &state),
        }
    }

    fn staging_dir(&self, step: usize) -> PathBuf {
        self.root.join(format!(".tmp-{step:08}"))
    }

    fn slot_dir(&self, step: usize) -> PathBuf {
        self.root.join(format!("ckpt-{step:08}"))
    }

    /// Phase 1 for one rank: serialize its owned shard runs into the
    /// staging dir; trigger phase 2 (commit) when every rank has landed.
    fn write_snapshot(&self, step: usize, rank: usize, state: &TrainState) -> Result<()> {
        let dir = self.staging_dir(step);
        {
            let mut p = self.pending.lock().unwrap();
            if !p.contains_key(&step) {
                std::fs::create_dir_all(&dir)?;
                p.insert(
                    step,
                    PendingStep {
                        dir: dir.clone(),
                        parts: Vec::new(),
                        scalars: BTreeMap::new(),
                        ranks_done: 0,
                    },
                );
            }
        }
        let mut entries: Vec<Json> = Vec::new();
        let mut scalars: Vec<(String, Json)> = Vec::new();
        for part in &state.parts {
            match &part.payload {
                PartPayload::U64(v) => {
                    scalars.push((format!("r{rank}.{}", part.name), Json::Num(*v as f64)));
                }
                PartPayload::F64(v) => {
                    scalars.push((format!("r{rank}.{}", part.name), Json::Num(*v)));
                }
                PartPayload::F32 { tensor, runs } => {
                    let data = tensor.as_f32()?;
                    let mut bytes =
                        Vec::with_capacity(runs.iter().map(|r| r.len * 4).sum::<usize>());
                    let mut run_json = Vec::new();
                    for r in runs {
                        let slice = data
                            .get(r.local_start..r.local_start + r.len)
                            .ok_or_else(|| {
                                anyhow!("snapshot part `{}` run out of bounds", part.name)
                            })?;
                        for x in slice {
                            bytes.extend_from_slice(&x.to_le_bytes());
                        }
                        run_json.push(Json::Arr(vec![
                            Json::Num(r.global_start as f64),
                            Json::Num(r.len as f64),
                        ]));
                    }
                    entries.push(self.part_entry(&dir, rank, &part.name, "f32", bytes, run_json)?);
                }
                PartPayload::Bf16 { tensor, runs } => {
                    // half-width payload: raw 2-byte storage words
                    let data = tensor.as_bf16()?;
                    let mut bytes =
                        Vec::with_capacity(runs.iter().map(|r| r.len * 2).sum::<usize>());
                    let mut run_json = Vec::new();
                    for r in runs {
                        let slice = data
                            .get(r.local_start..r.local_start + r.len)
                            .ok_or_else(|| {
                                anyhow!("snapshot part `{}` run out of bounds", part.name)
                            })?;
                        for x in slice {
                            bytes.extend_from_slice(&x.to_le_bytes());
                        }
                        run_json.push(Json::Arr(vec![
                            Json::Num(r.global_start as f64),
                            Json::Num(r.len as f64),
                        ]));
                    }
                    entries.push(self.part_entry(&dir, rank, &part.name, "bf16", bytes, run_json)?);
                }
            }
        }
        let commit = {
            let mut p = self.pending.lock().unwrap();
            let ps = p.get_mut(&step).expect("pending step created above");
            ps.parts.extend(entries);
            for (k, v) in scalars {
                ps.scalars.insert(k, v);
            }
            ps.ranks_done += 1;
            if ps.ranks_done == self.world {
                p.remove(&step)
            } else {
                None
            }
        };
        if let Some(ps) = commit {
            self.commit(step, ps)?;
        }
        Ok(())
    }

    /// Serialize one part's bytes into the staging dir and build its
    /// manifest entry. `dtype` is recorded per part so resume validates
    /// it (legacy manifests without the field read back as `"f32"`).
    fn part_entry(
        &self,
        dir: &Path,
        rank: usize,
        name: &str,
        dtype: &str,
        bytes: Vec<u8>,
        run_json: Vec<Json>,
    ) -> Result<Json> {
        let file = format!("r{rank}.{name}.bin");
        write_synced(&dir.join(&file), &bytes)?;
        self.part_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        let mut e = BTreeMap::new();
        e.insert("file".to_string(), Json::Str(file));
        e.insert("rank".to_string(), Json::Num(rank as f64));
        e.insert("name".to_string(), Json::Str(name.to_string()));
        e.insert("dtype".to_string(), Json::Str(dtype.to_string()));
        e.insert("runs".to_string(), Json::Arr(run_json));
        e.insert(
            "checksum".to_string(),
            Json::Str(format!("{:016x}", checksum(&bytes))),
        );
        Ok(Json::Obj(e))
    }

    /// Phase 2: manifest written **last** inside the staging dir, fsynced,
    /// then the whole dir renamed into its final `ckpt-<step>` name.
    fn commit(&self, step: usize, ps: PendingStep) -> Result<()> {
        let mut meta = BTreeMap::new();
        meta.insert("step".to_string(), Json::Num(step as f64));
        meta.insert("plan".to_string(), Json::Str(self.fingerprint.clone()));
        meta.insert("world".to_string(), Json::Num(self.world as f64));
        meta.insert("parts".to_string(), Json::Arr(ps.parts));
        meta.insert("scalars".to_string(), Json::Obj(ps.scalars));
        write_synced(&ps.dir.join("manifest.json"), Json::Obj(meta).to_string().as_bytes())?;
        sync_dir(&ps.dir);
        let slot = self.slot_dir(step);
        let _ = std::fs::remove_dir_all(&slot);
        std::fs::rename(&ps.dir, &slot)
            .with_context(|| format!("committing checkpoint {slot:?}"))?;
        sync_dir(&self.root);
        self.commits.fetch_add(1, Ordering::Relaxed);
        self.last_commit.store(step as u64 + 1, Ordering::Relaxed);
        self.prune();
        Ok(())
    }

    /// Keep the newest `keep` committed checkpoints.
    fn prune(&self) {
        let mut steps = committed_steps(&self.root);
        steps.sort_unstable();
        while steps.len() > self.keep {
            let s = steps.remove(0);
            let _ = std::fs::remove_dir_all(self.slot_dir(s));
        }
    }

    /// Drain the background writer: close the queue, join the thread (so
    /// trailing snapshots commit), and surface the first write error if
    /// any occurred. The harness calls this after the rank threads have
    /// joined, so a committed checkpoint is on disk when `train` returns.
    pub fn drain(&self) -> Result<()> {
        *self.tx.lock().unwrap() = None;
        if let Some(h) = self.writer.lock().unwrap().take() {
            let _ = h.join();
        }
        if let Some(e) = self.error.lock().unwrap().clone() {
            return Err(anyhow!("checkpoint write failed: {e}"));
        }
        Ok(())
    }

    pub fn stats(&self) -> CkptStats {
        let lc = self.last_commit.load(Ordering::Relaxed);
        CkptStats {
            commits: self.commits.load(Ordering::Relaxed),
            last_commit_step: if lc == 0 { None } else { Some(lc as usize - 1) },
            write_secs: self.write_micros.load(Ordering::Relaxed) as f64 / 1e6,
            bytes_written: self.part_bytes.load(Ordering::Relaxed),
        }
    }
}

impl Drop for Checkpointer {
    fn drop(&mut self) {
        // belt-and-suspenders: the harness drains explicitly; this keeps
        // a forgotten drain from leaking the writer thread. Never join
        // from the writer itself (it can briefly own the last upgraded
        // Arc while finishing a job).
        *self.tx.lock().unwrap() = None;
        if let Some(h) = self.writer.lock().unwrap().take() {
            if h.thread().id() != std::thread::current().id() {
                let _ = h.join();
            }
        }
    }
}

fn write_synced(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    Ok(())
}

/// Best-effort directory fsync (not every platform allows opening dirs).
fn sync_dir(dir: &Path) {
    if let Ok(f) = std::fs::File::open(dir) {
        let _ = f.sync_all();
    }
}

/// Steps of the committed (`ckpt-<step>` with a manifest) checkpoints.
fn committed_steps(root: &Path) -> Vec<usize> {
    let Ok(rd) = std::fs::read_dir(root) else { return Vec::new() };
    rd.flatten()
        .filter_map(|e| {
            let name = e.file_name().to_string_lossy().to_string();
            let step: usize = name.strip_prefix("ckpt-")?.parse().ok()?;
            e.path().join("manifest.json").exists().then_some(step)
        })
        .collect()
}

/// One shard file recorded in a committed manifest.
#[derive(Clone, Debug)]
pub struct SavedPart {
    pub rank: usize,
    pub name: String,
    pub file: String,
    /// element dtype of the payload (`"f32"` / `"bf16"`); manifests
    /// written before the mixed-precision PR read back as `"f32"`
    pub dtype: String,
    /// (global_start, len) per run, in file order
    pub runs: Vec<(usize, usize)>,
    pub checksum: String,
}

/// A committed checkpoint's manifest, loaded back.
#[derive(Clone, Debug)]
pub struct SavedCheckpoint {
    pub dir: PathBuf,
    pub step: usize,
    /// plan fingerprint recorded at save time (never absent — the save
    /// API requires it)
    pub plan: String,
    pub world: usize,
    pub parts: Vec<SavedPart>,
    pub scalars: BTreeMap<String, f64>,
}

impl SavedCheckpoint {
    pub fn load_dir(dir: &Path) -> Result<SavedCheckpoint> {
        let bad = |what: &str| {
            crate::ft::checks::err(
                crate::ft::checks::RESUME,
                "manifest",
                format!("{what} in {dir:?}"),
            )
        };
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|_| bad("no manifest.json"))?;
        let j = Json::parse(&text).map_err(|e| bad(&format!("unparseable manifest ({e})")))?;
        let step = j
            .get("step")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("missing `step`"))?;
        let plan = j
            .get("plan")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing `plan`"))?
            .to_string();
        let world = j
            .get("world")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("missing `world`"))?;
        let mut parts = Vec::new();
        for p in j.get("parts").and_then(Json::as_arr).unwrap_or(&[]) {
            let runs = p
                .get("runs")
                .and_then(Json::as_arr)
                .ok_or_else(|| bad("part without runs"))?
                .iter()
                .map(|r| {
                    let a = r.as_arr().and_then(|a| {
                        Some((a.first()?.as_usize()?, a.get(1)?.as_usize()?))
                    });
                    a.ok_or_else(|| bad("malformed run"))
                })
                .collect::<Result<Vec<(usize, usize)>>>()?;
            parts.push(SavedPart {
                rank: p.get("rank").and_then(Json::as_usize).unwrap_or(0),
                name: p
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("part without name"))?
                    .to_string(),
                file: p
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("part without file"))?
                    .to_string(),
                dtype: p
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("f32")
                    .to_string(),
                runs,
                checksum: p
                    .get("checksum")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            });
        }
        let scalars = j
            .get("scalars")
            .and_then(Json::as_obj)
            .map(|m| {
                m.iter()
                    .filter_map(|(k, v)| Some((k.clone(), v.as_f64()?)))
                    .collect()
            })
            .unwrap_or_default();
        Ok(SavedCheckpoint { dir: dir.to_path_buf(), step, plan, world, parts, scalars })
    }

    /// Every committed checkpoint under `root`, newest first, skipping
    /// slots whose manifest fails to parse. The resume path walks this
    /// list so a slot with a corrupt *shard* also falls back to the next
    /// older checkpoint — the dual guarantee: a failed or damaged write
    /// never masks an older valid checkpoint.
    pub fn load_all(root: &Path) -> Vec<SavedCheckpoint> {
        let mut steps = committed_steps(root);
        steps.sort_unstable_by(|a, b| b.cmp(a));
        steps
            .into_iter()
            .filter_map(|s| {
                SavedCheckpoint::load_dir(&root.join(format!("ckpt-{s:08}"))).ok()
            })
            .collect()
    }

    /// Newest committed checkpoint under `root`, if any.
    pub fn load_latest(root: &Path) -> Option<SavedCheckpoint> {
        SavedCheckpoint::load_all(root).into_iter().next()
    }
}

/// Human-readable dump for `optimus ckpt inspect <dir>`: every slot's
/// validity, step, recorded plan, shard inventory and checksum status.
pub fn inspect(root: &Path) -> Result<String> {
    let mut out = format!("checkpoint root {}\n", root.display());
    let mut names: Vec<String> = std::fs::read_dir(root)
        .with_context(|| format!("cannot read {root:?}"))?
        .flatten()
        .map(|e| e.file_name().to_string_lossy().to_string())
        .filter(|n| n.starts_with("ckpt-") || n.starts_with(".tmp-"))
        .collect();
    names.sort();
    if names.is_empty() {
        out.push_str("  (no checkpoints)\n");
        return Ok(out);
    }
    for name in names {
        let dir = root.join(&name);
        if name.starts_with(".tmp-") {
            out.push_str(&format!("  {name}  UNCOMMITTED staging dir (ignored on resume)\n"));
            continue;
        }
        match SavedCheckpoint::load_dir(&dir) {
            Err(e) => out.push_str(&format!("  {name}  INVALID: {e:#}\n")),
            Ok(c) => {
                let mut all_ok = true;
                let mut lines = String::new();
                for p in &c.parts {
                    let elems: usize = p.runs.iter().map(|r| r.1).sum();
                    let status = match std::fs::read(c.dir.join(&p.file)) {
                        Err(_) => {
                            all_ok = false;
                            "MISSING"
                        }
                        Ok(b) if format!("{:016x}", checksum(&b)) != p.checksum => {
                            all_ok = false;
                            "CHECKSUM MISMATCH"
                        }
                        Ok(b)
                            if (p.dtype == "bf16" && bytes_to_u16s(&b).is_err())
                                || (p.dtype != "bf16" && bytes_to_f32s(&b).is_err()) =>
                        {
                            all_ok = false;
                            "TRUNCATED"
                        }
                        Ok(_) => "ok",
                    };
                    lines.push_str(&format!(
                        "      {:<28} rank {:<3} {:<5} runs {:<3} elems {:<8} fnv {}  {status}\n",
                        p.file,
                        p.rank,
                        p.dtype,
                        p.runs.len(),
                        elems,
                        p.checksum
                    ));
                }
                out.push_str(&format!(
                    "  {name}  {}  step {}  world {}  plan {}\n{lines}",
                    if all_ok { "VALID" } else { "INVALID" },
                    c.step,
                    c.world,
                    c.plan
                ));
            }
        }
    }
    Ok(out)
}
