//! Paged KV-cache allocator for the serving engine.
//!
//! The decode artifacts are fixed-shape and recompute attention over the
//! full token window every step (no incremental K/V tensors cross steps
//! on the host), so the state a request must keep alive between decode
//! steps is exactly its token prefix — prompt plus everything generated
//! so far. That prefix is what gets paged: fixed-size `Arc`-backed i32
//! [`Tensor`] blocks owned by a [`KvPool`], recycled through a free list,
//! with each in-flight request holding a [`PageTable`] that maps its
//! logical token positions onto pool pages. The decode engine reads a
//! request's window back out of its pages every step, so the pages are
//! load-bearing, not bookkeeping.
//!
//! Exhaustion is a scheduling signal, never an abort: [`PageTable::reserve`]
//! is all-or-nothing and simply returns `false` when the free list cannot
//! cover the span, leaving the pool untouched — the scheduler responds by
//! keeping the request queued (admission backpressure, which the bounded
//! arrival queue propagates back to the traffic source). The scheduler
//! reserves a request's *entire* window (prompt + max generation) at
//! admission, so a request that starts decoding can never die — or stall
//! its EP lockstep siblings — on a mid-flight allocation.

use crate::runtime::Tensor;

/// Fixed-size page pool. Pages are `Arc`-backed i32 tensors; writes go
/// through [`Tensor::as_i32_mut`], so a page some snapshot still holds is
/// copied on write instead of racing it.
pub struct KvPool {
    page_size: usize,
    pages: Vec<Tensor>,
    /// LIFO free list: the page released last is re-issued first, keeping
    /// reuse hot and making leak accounting trivial (`total - free`)
    free: Vec<usize>,
    /// fewest free pages ever observed → peak occupancy for reports
    min_free: usize,
}

impl KvPool {
    pub fn new(n_pages: usize, page_size: usize) -> KvPool {
        assert!(n_pages > 0 && page_size > 0, "kv pool needs non-zero geometry");
        KvPool {
            page_size,
            pages: (0..n_pages).map(|_| Tensor::i32(vec![0; page_size], vec![page_size])).collect(),
            free: (0..n_pages).rev().collect(),
            min_free: n_pages,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn total_pages(&self) -> usize {
        self.pages.len()
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently held by live page tables.
    pub fn pages_in_use(&self) -> usize {
        self.pages.len() - self.free.len()
    }

    /// Most pages ever simultaneously in use.
    pub fn peak_pages_used(&self) -> usize {
        self.pages.len() - self.min_free
    }

    /// Pages needed to hold `tokens` tokens.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    fn alloc_page(&mut self) -> Option<usize> {
        let p = self.free.pop()?;
        self.min_free = self.min_free.min(self.free.len());
        Some(p)
    }

    fn free_page(&mut self, page: usize) {
        debug_assert!(!self.free.contains(&page), "double free of kv page {page}");
        self.free.push(page);
    }

    fn write(&mut self, page: usize, slot: usize, tok: i32) {
        self.pages[page].as_i32_mut().expect("kv pages are i32")[slot] = tok;
    }

    fn read(&self, page: usize, slot: usize) -> i32 {
        self.pages[page].as_i32().expect("kv pages are i32")[slot]
    }
}

/// Per-request mapping from logical token positions onto pool pages.
/// Dropping a table without [`PageTable::release`] leaks its pages — the
/// serve report surfaces that as `kv_pages_leaked`, and the tests pin it
/// at zero.
#[derive(Default)]
pub struct PageTable {
    pages: Vec<usize>,
    len: usize,
}

impl PageTable {
    pub fn new() -> PageTable {
        PageTable::default()
    }

    /// Tokens currently stored.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Token capacity of the pages held so far.
    pub fn capacity(&self, pool: &KvPool) -> usize {
        self.pages.len() * pool.page_size()
    }

    /// Grow the table to hold `total_tokens` tokens. All-or-nothing:
    /// returns `false` (pool untouched) when the free list cannot cover
    /// the growth — the caller's backpressure signal.
    pub fn reserve(&mut self, pool: &mut KvPool, total_tokens: usize) -> bool {
        let need = pool.pages_for(total_tokens).saturating_sub(self.pages.len());
        if need > pool.free_pages() {
            return false;
        }
        for _ in 0..need {
            self.pages.push(pool.alloc_page().expect("free count was just checked"));
        }
        true
    }

    /// Append one token, allocating a page on demand if the reserved
    /// capacity is exhausted. Returns `false` on pool exhaustion.
    pub fn append(&mut self, pool: &mut KvPool, tok: i32) -> bool {
        if self.len == self.capacity(pool) && !self.reserve(pool, self.len + 1) {
            return false;
        }
        let ps = pool.page_size();
        pool.write(self.pages[self.len / ps], self.len % ps, tok);
        self.len += 1;
        true
    }

    /// Append a run of tokens (reserving up front so a mid-run failure
    /// cannot leave a half-written suffix).
    pub fn extend(&mut self, pool: &mut KvPool, toks: &[i32]) -> bool {
        if !self.reserve(pool, self.len + toks.len()) {
            return false;
        }
        for &t in toks {
            let ok = self.append(pool, t);
            debug_assert!(ok, "capacity was reserved");
        }
        true
    }

    /// Reassemble the stored token window in logical order — what the
    /// decode engine feeds the artifacts each step.
    pub fn tokens(&self, pool: &KvPool) -> Vec<i32> {
        let ps = pool.page_size();
        (0..self.len).map(|i| pool.read(self.pages[i / ps], i % ps)).collect()
    }

    /// Return every held page to the pool's free list.
    pub fn release(&mut self, pool: &mut KvPool) {
        for p in self.pages.drain(..) {
            pool.free_page(p);
        }
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_spans_pages_and_reads_back_in_order() {
        let mut pool = KvPool::new(4, 3);
        let mut t = PageTable::new();
        for i in 0..10 {
            assert!(t.append(&mut pool, i));
        }
        assert_eq!(t.len(), 10);
        assert_eq!(t.tokens(&pool), (0..10).collect::<Vec<i32>>());
        // 10 tokens at 3 per page = 4 pages
        assert_eq!(pool.pages_in_use(), 4);
        assert_eq!(pool.pages_for(10), 4);
        t.release(&mut pool);
        assert_eq!(pool.free_pages(), 4);
    }

    #[test]
    fn reserve_is_all_or_nothing() {
        let mut pool = KvPool::new(2, 4);
        let mut t = PageTable::new();
        // 3 pages worth on a 2-page pool: refused, nothing allocated
        assert!(!t.reserve(&mut pool, 9));
        assert_eq!(pool.free_pages(), 2);
        assert!(t.reserve(&mut pool, 8));
        assert_eq!(pool.free_pages(), 0);
        t.release(&mut pool);
        assert_eq!(pool.free_pages(), 2);
    }

    #[test]
    fn exhaustion_backpressures_and_release_unblocks() {
        let mut pool = KvPool::new(2, 4);
        let mut a = PageTable::new();
        assert!(a.extend(&mut pool, &[1, 2, 3, 4, 5])); // 2 pages
        let mut b = PageTable::new();
        // pool exhausted: admission of b must wait
        assert!(!b.reserve(&mut pool, 1));
        assert!(!b.append(&mut pool, 9));
        assert!(b.is_empty());
        a.release(&mut pool);
        // freed pages are reused (LIFO) — same physical pages, new owner
        assert!(b.extend(&mut pool, &[9, 9]));
        assert_eq!(b.tokens(&pool), vec![9, 9]);
        // a's release wiped its mapping, not the data path
        assert_eq!(a.len(), 0);
        b.release(&mut pool);
        assert_eq!(pool.free_pages(), pool.total_pages());
        assert_eq!(pool.peak_pages_used(), 2);
    }

    #[test]
    fn pages_are_isolated_between_tables() {
        let mut pool = KvPool::new(4, 2);
        let mut a = PageTable::new();
        let mut b = PageTable::new();
        assert!(a.extend(&mut pool, &[1, 2, 3]));
        assert!(b.extend(&mut pool, &[7, 8, 9]));
        assert_eq!(a.tokens(&pool), vec![1, 2, 3]);
        assert_eq!(b.tokens(&pool), vec![7, 8, 9]);
        a.release(&mut pool);
        b.release(&mut pool);
        assert_eq!(pool.free_pages(), 4);
    }
}
