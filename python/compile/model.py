"""L2 — OLMo/OLMoE-family transformer in JAX (build-time only).

Mirrors the paper's reference models (allenai/OLMo-1B-hf dense,
allenai/OLMoE-1B-7B-0924 MoE): RMSNorm, rotary attention, SwiGLU MLP /
SparseMoE with softmax-then-topk routing (no renorm) and the switch-style
load-balancing auxiliary loss.

Parameters live in a single flat f32 vector whose layout is described by
``param_specs`` — the same layout the Rust coordinator sees through
``manifest.json`` (offset, shape, is_expert, layer). The is_expert flag is
what EPSO (paper §3.2) keys its two-group sharding on.

Three MoE execution paths:
  * ``moe_impl="fsmoe"``  — the FastSparseMoE Pallas path (Algorithm 1
     stages 2-5), used in the fused train_step and the EP artifacts;
  * ``moe_impl="naive"``  — the HuggingFace-style all-experts loop, the
     paper's baseline side of Table 3;
  * dense configs skip routing entirely.
"""

import functools
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import configs
from .kernels import fast_moe, ref as kref


# ===========================================================================
# Flat parameter layout
# ===========================================================================

def param_specs(cfg: configs.ModelConfig) -> List[dict]:
    """Ordered parameter spec: name, shape, offset, is_expert, layer."""
    specs = []
    off = 0

    def add(name, shape, is_expert=False, layer=-1):
        nonlocal off
        n = int(np.prod(shape))
        specs.append(dict(name=name, shape=tuple(shape), offset=off,
                          numel=n, is_expert=is_expert, layer=layer))
        off += n

    h, v, i = cfg.hidden, cfg.vocab_size, cfg.intermediate
    add("embed", (v, h))
    for l in range(cfg.n_layers):
        add(f"layer{l}.wq", (h, h), layer=l)
        add(f"layer{l}.wk", (h, h), layer=l)
        add(f"layer{l}.wv", (h, h), layer=l)
        add(f"layer{l}.wo", (h, h), layer=l)
        add(f"layer{l}.norm1", (h,), layer=l)
        add(f"layer{l}.norm2", (h,), layer=l)
        if cfg.is_moe:
            add(f"layer{l}.router", (h, cfg.n_experts), layer=l)
            add(f"layer{l}.gate", (cfg.n_experts, h, i), True, l)
            add(f"layer{l}.up", (cfg.n_experts, h, i), True, l)
            add(f"layer{l}.down", (cfg.n_experts, i, h), True, l)
        else:
            add(f"layer{l}.gate", (h, i), layer=l)
            add(f"layer{l}.up", (h, i), layer=l)
            add(f"layer{l}.down", (i, h), layer=l)
    add("final_norm", (h,))
    add("head", (h, v))
    return specs


def param_count(cfg) -> int:
    s = param_specs(cfg)
    return s[-1]["offset"] + s[-1]["numel"]


def unflatten(cfg, flat) -> Dict[str, jnp.ndarray]:
    out = {}
    for s in param_specs(cfg):
        seg = jax.lax.dynamic_slice(flat, (s["offset"],), (s["numel"],))
        out[s["name"]] = seg.reshape(s["shape"])
    return out


def init_params(cfg, seed=0) -> np.ndarray:
    """Reference initializer (tests / python-side experiments). The Rust
    coordinator re-implements the same scheme with its own PRNG; value
    parity is not required, only distribution parity."""
    rng = np.random.default_rng(seed)
    flat = np.empty(param_count(cfg), dtype=np.float32)
    for s in param_specs(cfg):
        o, n = s["offset"], s["numel"]
        if "norm" in s["name"]:
            flat[o:o + n] = 1.0
        else:
            flat[o:o + n] = rng.standard_normal(n).astype(np.float32) * 0.02
    return flat


# ===========================================================================
# Model pieces
# ===========================================================================

def rms_norm(x, gain, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * gain.astype(jnp.float32)).astype(x.dtype)


def rope(q, k, theta):
    """Rotary embeddings. q,k [B,S,NH,HD]."""
    b, s, nh, hd = q.shape
    pos = jnp.arange(s, dtype=jnp.float32)
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = pos[:, None] * freqs[None, :]                  # [S, HD/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)

    def rot(x):
        x1, x2 = x[..., 0::2], x[..., 1::2]
        xr1 = x1 * cos[None, :, None, :] - x2 * sin[None, :, None, :]
        xr2 = x1 * sin[None, :, None, :] + x2 * cos[None, :, None, :]
        return jnp.stack([xr1, xr2], axis=-1).reshape(x.shape)

    return rot(q), rot(k)


def attention(p, prefix, x, cfg):
    """Causal multi-head attention with RoPE. x [B,S,H]."""
    b, s, h = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p[f"{prefix}.wq"]).reshape(b, s, nh, hd)
    k = (x @ p[f"{prefix}.wk"]).reshape(b, s, nh, hd)
    v = (x @ p[f"{prefix}.wv"]).reshape(b, s, nh, hd)
    q, k = rope(q, k, cfg.rope_theta)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, h)
    return o @ p[f"{prefix}.wo"]


def aux_loss(probs, indices, n_experts):
    """Switch-transformer load-balancing loss: N * sum_i f_i * P_i.

    probs [T,N] softmax router probabilities, indices [T,K] chosen ids.
    """
    t = probs.shape[0]
    k = indices.shape[1]
    onehot = jax.nn.one_hot(indices, n_experts, dtype=jnp.float32)  # [T,K,N]
    f = jnp.sum(onehot, axis=(0, 1)) / (t * k)          # fraction per expert
    p_mean = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p_mean)


def moe_layer(p, prefix, x2d, cfg, moe_impl):
    """SparseMoE over flattened tokens x2d [T,H]. Returns (out, aux).
    Kernel blocking (tbs, tile) comes from the config."""
    w, idx, probs = kref.router_topk(x2d, p[f"{prefix}.router"], cfg.top_k)
    a = aux_loss(probs, idx, cfg.n_experts)
    gate, up, down = p[f"{prefix}.gate"], p[f"{prefix}.up"], p[f"{prefix}.down"]
    if moe_impl == "fsmoe":
        out = fast_moe.fast_sparse_moe_partial(
            x2d, w, idx, gate, up, down, 0,
            tbs=cfg.tbs, tile=cfg.tile)
    elif moe_impl == "naive":
        out = kref.naive_sparse_moe(x2d, w, idx, gate, up, down, 0)
    else:
        raise ValueError(moe_impl)
    return out, a


def dense_mlp(p, prefix, x):
    return (kref.silu(x @ p[f"{prefix}.gate"]) * (x @ p[f"{prefix}.up"])) \
        @ p[f"{prefix}.down"]


def decoder_layer(p, l, h, cfg, moe_impl):
    """One decoder block. h [B,S,H] -> (h', aux)."""
    b, s, hd = h.shape
    prefix = f"layer{l}"
    a = h + attention(p, prefix, rms_norm(h, p[f"{prefix}.norm1"]), cfg)
    moe_in = rms_norm(a, p[f"{prefix}.norm2"])
    if cfg.is_moe:
        out2d, aux = moe_layer(p, prefix, moe_in.reshape(b * s, hd), cfg,
                               moe_impl)
        return a + out2d.reshape(b, s, hd), aux
    return a + dense_mlp(p, prefix, moe_in), jnp.float32(0.0)


def forward(cfg, flat, tokens, moe_impl="fsmoe"):
    """Full forward. tokens [B, S+1] (inputs || shifted targets).

    Returns (lm_loss, aux_total, logits).
    """
    p = unflatten(cfg, flat)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    h = p["embed"][inp]                                  # [B,S,H]
    aux_total = jnp.float32(0.0)
    for l in range(cfg.n_layers):
        h, aux = decoder_layer(p, l, h, cfg, moe_impl)
        aux_total = aux_total + aux
    h = rms_norm(h, p["final_norm"])
    logits = h @ p["head"]                               # [B,S,V]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll), aux_total, logits


# ===========================================================================
# Artifact entry points (lowered by aot.py)
# ===========================================================================

def make_train_step(cfg, moe_impl="fsmoe"):
    """(params_flat [P], tokens [B,S+1] i32) ->
       (loss_total, lm_loss, aux_loss, grads_flat [P])"""

    def train_step(flat, tokens):
        def loss_fn(f):
            lm, aux, _ = forward(cfg, f, tokens, moe_impl)
            return lm + cfg.aux_coef * aux, (lm, aux)
        (total, (lm, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(flat)
        return total, lm, aux, grads

    return train_step


def make_eval_step(cfg, moe_impl="fsmoe"):
    """(params_flat, tokens [B,S+1]) -> (nll [B,S], preds [B,S] i32)"""

    def eval_step(flat, tokens):
        p = unflatten(cfg, flat)
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        h = p["embed"][inp]
        for l in range(cfg.n_layers):
            h, _ = decoder_layer(p, l, h, cfg, moe_impl)
        h = rms_norm(h, p["final_norm"])
        logits = h @ p["head"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nll, preds

    return eval_step


def make_moe_block_step(cfg, moe_impl):
    """Single SparseMoE block fwd+bwd — the Table 3 (FSMOE) benchmark unit.

    (block_params [Pb], x [T,H], dy [T,H]) -> (y, dx, dparams)
    block_params layout: router || gate || up || down of layer 0.
    """
    h, n, i, k = cfg.hidden, cfg.n_experts, cfg.intermediate, cfg.top_k
    sizes = [h * n, n * h * i, n * h * i, n * i * h]
    offs = np.cumsum([0] + sizes)

    def block(bp, x):
        p = {
            "blk.router": jax.lax.dynamic_slice(bp, (int(offs[0]),), (sizes[0],)).reshape(h, n),
            "blk.gate": jax.lax.dynamic_slice(bp, (int(offs[1]),), (sizes[1],)).reshape(n, h, i),
            "blk.up": jax.lax.dynamic_slice(bp, (int(offs[2]),), (sizes[2],)).reshape(n, h, i),
            "blk.down": jax.lax.dynamic_slice(bp, (int(offs[3]),), (sizes[3],)).reshape(n, i, h),
        }
        out, aux = moe_layer(p, "blk", x, cfg, moe_impl)
        return out, aux

    def step(bp, x, dy):
        def obj(bp_, x_):
            out, aux = block(bp_, x_)
            return jnp.sum(out * dy) + cfg.aux_coef * aux, out
        (_, y), (dbp, dx) = jax.value_and_grad(
            obj, argnums=(0, 1), has_aux=True)(bp, x)
        return y, dx, dbp

    return step, int(offs[-1])


# ---------------------------------------------------------------------------
# Pipeline-parallel stage functions (SAC-native: bwd recomputes from the
# stashed stage input — paper §1 "Selective Activation Checkpointing")
# ---------------------------------------------------------------------------

def stage_layers(cfg, pp, stage):
    lps = cfg.n_layers // pp
    return range(stage * lps, (stage + 1) * lps)


def stage_param_specs(cfg, pp, stage) -> List[dict]:
    """Specs (with stage-local offsets) owned by a pipeline stage.
    Stage 0 additionally owns the embedding; the last stage owns the final
    norm + head."""
    layers = set(stage_layers(cfg, pp, stage))
    out, off = [], 0
    for s in param_specs(cfg):
        owned = (s["layer"] in layers
                 or (stage == 0 and s["name"] == "embed")
                 or (stage == pp - 1 and s["name"] in ("final_norm", "head")))
        if owned:
            t = dict(s)
            t["offset"] = off
            off += s["numel"]
            out.append(t)
    return out


def _stage_unflatten(cfg, pp, stage, flat):
    return {s["name"]: jax.lax.dynamic_slice(
        flat, (s["offset"],), (s["numel"],)).reshape(s["shape"])
        for s in stage_param_specs(cfg, pp, stage)}


def _stage_forward(cfg, pp, stage, p, x, tokens, moe_impl):
    """x: stage input activations ([B,S,H]) or None for stage 0 (tokens)."""
    aux_total = jnp.float32(0.0)
    if stage == 0:
        h = p["embed"][tokens[:, :-1]]
    else:
        h = x
    for l in stage_layers(cfg, pp, stage):
        h, aux = decoder_layer(p, l, h, cfg, moe_impl)
        aux_total = aux_total + aux
    if stage == pp - 1:
        h = rms_norm(h, p["final_norm"])
        logits = h @ p["head"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(nll), aux_total
    return h, aux_total


def make_stage_fwd(cfg, pp, stage, moe_impl="fsmoe"):
    """Forward-only stage pass.
    stage 0:        (p_stage, tokens) -> (h_out, aux)
    middle stages:  (p_stage, h_in)   -> (h_out, aux)
    last stage:     (p_stage, h_in, tokens) -> (loss, aux)
    """
    def fwd(p_flat, *args):
        p = _stage_unflatten(cfg, pp, stage, p_flat)
        if stage == 0:
            tokens, x = args[0], None
        elif stage == pp - 1:
            x, tokens = args
        else:
            x, tokens = args[0], None
        return _stage_forward(cfg, pp, stage, p, x, tokens, moe_impl)
    return fwd


def make_stage_fwdbwd(cfg, pp, stage, moe_impl="fsmoe"):
    """Recompute-forward + backward for one stage (1F1B unit of work).

    stage 0:  (p, tokens, d_out)      -> (dp,)           [no dx]
    middle:   (p, h_in, d_out)        -> (dx, dp)
    last:     (p, h_in, tokens)       -> (loss, aux, dx, dp)
    d_out is the cotangent of h_out; the aux-loss cotangent is folded in
    with coefficient cfg.aux_coef (DESIGN.md §6).
    """
    def fwdbwd(p_flat, *args):
        if stage == pp - 1:
            x, tokens = args

            def obj(pf, x_):
                loss, aux = make_stage_fwd(cfg, pp, stage, moe_impl)(pf, x_, tokens)
                return loss + cfg.aux_coef * aux, (loss, aux)
            (_, (loss, aux)), (dp, dx) = jax.value_and_grad(
                obj, argnums=(0, 1), has_aux=True)(p_flat, x)
            return loss, aux, dx, dp
        if stage == 0:
            tokens, d_out = args

            def obj(pf):
                h, aux = make_stage_fwd(cfg, pp, stage, moe_impl)(pf, tokens)
                return jnp.sum(h * d_out) + cfg.aux_coef * aux
            dp = jax.grad(obj)(p_flat)
            return (dp,)
        x, d_out = args

        def obj(pf, x_):
            h, aux = make_stage_fwd(cfg, pp, stage, moe_impl)(pf, x_)
            return jnp.sum(h * d_out) + cfg.aux_coef * aux
        dp, dx = jax.grad(obj, argnums=(0, 1))(p_flat, x)
        return dx, dp

    return fwdbwd


# ---------------------------------------------------------------------------
# Expert-parallel per-layer functions (Algorithm 1 split at Stage 1):
# rust does allgather / reduce-scatter between these artifacts.
# ---------------------------------------------------------------------------

def layer_nonexpert_specs(cfg) -> List[dict]:
    """Per-layer non-expert params (attn + norms + router), layer 0 offsets
    — all layers share shapes, so one artifact serves every layer."""
    out, off = [], 0
    for s in param_specs(cfg):
        if s["layer"] == 0 and not s["is_expert"]:
            t = dict(s); t["offset"] = off
            off += s["numel"]
            out.append(t)
    return out


def layer_expert_numel(cfg, ep) -> int:
    nr = cfg.n_experts // ep
    return 3 * nr * cfg.hidden * cfg.intermediate


def make_ep_embed_fwd(cfg):
    def f(emb_flat, tokens):
        emb = emb_flat.reshape(cfg.vocab_size, cfg.hidden)
        return emb[tokens[:, :-1]]
    return f


def make_ep_embed_bwd(cfg):
    def f(emb_flat, tokens, dh):
        def obj(e):
            return jnp.sum(make_ep_embed_fwd(cfg)(e, tokens) * dh)
        return jax.grad(obj)(emb_flat)
    return f


def _layer_pre(cfg, p_flat, h, moe_impl):
    """Attention half + router of one MoE layer (pre-Stage-1)."""
    specs = layer_nonexpert_specs(cfg)
    p = {s["name"].replace("layer0.", ""): jax.lax.dynamic_slice(
        p_flat, (s["offset"],), (s["numel"],)).reshape(s["shape"])
        for s in specs}
    b, s_, hd = h.shape
    pp_ = {f"layer0.{k}": v for k, v in p.items()}
    a = h + attention(pp_, "layer0", rms_norm(h, p["norm1"]), cfg)
    moe_in = rms_norm(a, p["norm2"])
    x2d = moe_in.reshape(b * s_, hd)
    w, idx, probs = kref.router_topk(x2d, p["router"], cfg.top_k)
    aux = aux_loss(probs, idx, cfg.n_experts)
    return a, x2d, w, idx, aux


def make_ep_layer_pre_fwd(cfg, moe_impl="fsmoe"):
    """(p_layer_ne, h [B,S,H]) -> (a, moe_in2d, w, idx, aux)."""
    def f(p_flat, h):
        a, x2d, w, idx, aux = _layer_pre(cfg, p_flat, h, moe_impl)
        return a, x2d, w, idx.astype(jnp.int32), aux
    return f


def make_ep_layer_pre_bwd(cfg, moe_impl="fsmoe"):
    """Recompute+backward of the pre half.
    (p, h, d_a_total, d_moe_in, d_w) -> (dh, dp)
    d_a_total already includes the residual path cotangent of `a`.
    """
    def f(p_flat, h, d_a, d_x2d, d_w):
        def obj(pf, h_):
            a, x2d, w, idx, aux = _layer_pre(cfg, pf, h_, moe_impl)
            return (jnp.sum(a * d_a) + jnp.sum(x2d * d_x2d)
                    + jnp.sum(w * d_w) + cfg.aux_coef * aux)
        dp, dh = jax.grad(obj, argnums=(0, 1))(p_flat, h)
        return dh, dp
    return f


def _expert_partial(cfg, ep, pe_flat, x_all, w_all, idx_all, tile=None):
    nr = cfg.n_experts // ep
    h, i = cfg.hidden, cfg.intermediate
    sz = nr * h * i
    gate = jax.lax.dynamic_slice(pe_flat, (0,), (sz,)).reshape(nr, h, i)
    up = jax.lax.dynamic_slice(pe_flat, (sz,), (sz,)).reshape(nr, h, i)
    down = jax.lax.dynamic_slice(pe_flat, (2 * sz,), (sz,)).reshape(nr, i, h)
    # n_start is rank-dependent: shift global expert ids so that local
    # experts occupy [0, NR) — the coordinator passes pre-shifted indices.
    return fast_moe.fast_sparse_moe_partial(
        x_all, w_all, idx_all, gate, up, down, 0,
        tbs=cfg.tbs, tile=tile if tile is not None else cfg.tile)


def make_ep_expert_fwd(cfg, ep, tile=None):
    """(p_experts_local, x_all [T,H], w_all [T,K], idx_local [T,K])
       -> partial_out [T,H].
    idx_local = global_idx - n_start (coordinator shifts; non-local ids
    fall outside [0,NR) and are ignored by the kernels)."""
    def f(pe, x, w, idx):
        return _expert_partial(cfg, ep, pe, x, w, idx, tile)
    return f


def make_ep_expert_bwd(cfg, ep, tile=None):
    """(p_experts, x_all, w_all, idx_local, d_partial_full)
       -> (dx_partial, dw_partial, dp_experts)"""
    def f(pe, x, w, idx, dy):
        def obj(pe_, x_, w_):
            out = _expert_partial(cfg, ep, pe_, x_, w_, idx, tile)
            return jnp.sum(out * dy)
        dpe, dx, dw = jax.grad(obj, argnums=(0, 1, 2))(pe, x, w)
        return dx, dw, dpe
    return f


def make_ep_head_fwdbwd(cfg):
    """(p_head_flat [H + H*V], h [B,S,H], tokens) -> (loss, dh, dp)."""
    h_, v = cfg.hidden, cfg.vocab_size

    def f(p_flat, h, tokens):
        def obj(pf, h_in):
            fn = jax.lax.dynamic_slice(pf, (0,), (h_,))
            head = jax.lax.dynamic_slice(pf, (h_,), (h_ * v,)).reshape(h_, v)
            x = rms_norm(h_in, fn)
            logits = x @ head
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            tgt = tokens[:, 1:]
            nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
            return jnp.mean(nll)
        loss, (dp, dh) = jax.value_and_grad(obj, argnums=(0, 1))(p_flat, h)
        return loss, dh, dp
    return f


def make_ep_head_fwd(cfg):
    """(p_head_flat [H + H*V], h [B,S,H]) -> preds [B,S] i32.

    Serve-only forward head: the same final-norm + head math as
    ``make_ep_head_fwdbwd``'s objective, but returning the per-position
    argmax instead of loss/cotangents — what the `optimus serve` EP
    decoder needs to pick the next token.
    """
    h_, v = cfg.hidden, cfg.vocab_size

    def f(p_flat, h):
        fn = jax.lax.dynamic_slice(p_flat, (0,), (h_,))
        head = jax.lax.dynamic_slice(p_flat, (h_,), (h_ * v,)).reshape(h_, v)
        x = rms_norm(h, fn)
        logits = x @ head
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return f
