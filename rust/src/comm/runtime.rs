//! Async collective submission: a per-rank comm worker that executes
//! collectives off the rank thread so communication overlaps compute.
//!
//! [`CommRuntime`] owns one dedicated worker thread draining a FIFO job
//! queue (mutex + condvar — model-checked under `--cfg loom`, see
//! `tests/loom_models.rs`). The nonblocking collective variants on
//! [`super::Group`] (`allreduce_start` / `reduce_scatter_start` /
//! `allgather_start`) submit a closure and return a [`CommHandle`]
//! future; `wait()` blocks until the worker has finished that collective.
//!
//! FIFO submission is the correctness contract: rendezvous rounds on a
//! [`super::Group`] are strictly ordered, so every member must issue its
//! collectives on a group in the same program order — exactly what one
//! lane per rank preserves. Comm-on-comm serialization within a rank
//! mirrors a real NIC anyway; the win is communication running
//! concurrently with the rank thread's *compute* (the pipelined sharded
//! optimizer of DESIGN.md §6, paper §3.2).
//!
//! Failure semantics:
//!
//! * a collective that panics on the worker (e.g. a poisoned group after
//!   a peer death) is captured and re-thrown from `wait()` on the
//!   submitting rank thread, so the harness's poison-guard still
//!   classifies the root cause;
//! * a job that can never run (its lane died or was [`CommRuntime::abort`]ed)
//!   resolves its handle to an **orphaned** state — `wait()` panics with
//!   the lane label and op counter (`comm lane 'comm-dp0' dropped
//!   in-flight collective #17`), so a dropped-lane failure is
//!   attributable to a rank instead of an anonymous hang.

use super::lsync::{self, Condvar, Mutex};
use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

type Job = Box<dyn FnOnce() + Send>;

enum SlotState<T> {
    /// submitted, not yet executed
    Pending,
    /// executed: the job's return value or its captured panic
    Done(std::thread::Result<T>),
    /// the job was dropped without running (lane aborted or died)
    Orphaned,
}

/// Shared completion slot between one job and its handle.
struct Slot<T> {
    state: Mutex<SlotState<T>>,
    cv: Condvar,
}

/// Drop bomb carried by every queued job closure: if the closure is
/// destroyed without running (queue cleared, worker gone), the slot flips
/// to `Orphaned` and waiters wake — an in-flight collective can be
/// *failed* but never silently lost.
struct OrphanGuard<T> {
    slot: Arc<Slot<T>>,
}

impl<T> Drop for OrphanGuard<T> {
    fn drop(&mut self) {
        let mut st = self.slot.state.lock().unwrap();
        if matches!(*st, SlotState::Pending) {
            *st = SlotState::Orphaned;
            self.slot.cv.notify_all();
        }
    }
}

/// A submitted collective that will never complete: its lane dropped it
/// before execution. Carries the lane label and per-lane op counter so
/// the failure is attributable.
#[derive(Debug)]
pub struct LaneDropped {
    pub lane: String,
    pub op: u64,
}

impl fmt::Display for LaneDropped {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "comm lane `{}` dropped in-flight collective #{} before it ran",
            self.lane, self.op
        )
    }
}

impl std::error::Error for LaneDropped {}

/// Future for one in-flight collective submitted to a [`CommRuntime`].
pub struct CommHandle<T = Vec<f32>> {
    slot: Arc<Slot<T>>,
    lane: String,
    /// 1-based submission index on this lane
    op: u64,
}

impl<T> CommHandle<T> {
    /// Block until the collective completes. A panic on the worker
    /// (poisoned group) is re-thrown here, on the submitting thread; an
    /// orphaned job panics with the lane label and op counter.
    pub fn wait(self) -> T {
        match self.try_wait() {
            Ok(v) => v,
            Err(dropped) => panic!("{dropped}"),
        }
    }

    /// Block until the collective completes, surfacing an orphaned job
    /// as an error instead of a panic. A worker-side panic is still
    /// re-thrown.
    pub fn try_wait(self) -> Result<T, LaneDropped> {
        let mut st = self.slot.state.lock().unwrap();
        loop {
            match &*st {
                SlotState::Pending => st = self.slot.cv.wait(st).unwrap(),
                SlotState::Orphaned => {
                    return Err(LaneDropped { lane: self.lane, op: self.op })
                }
                SlotState::Done(_) => break,
            }
        }
        // take the result out; the slot is consumed with the handle
        let SlotState::Done(r) = std::mem::replace(&mut *st, SlotState::Orphaned) else {
            unreachable!("checked Done above")
        };
        drop(st);
        match r {
            Ok(v) => Ok(v),
            Err(p) => resume_unwind(p),
        }
    }
}

struct LaneQ {
    jobs: VecDeque<Job>,
    closed: bool,
}

struct LaneShared {
    q: Mutex<LaneQ>,
    cv: Condvar,
    busy_nanos: AtomicU64,
    ops: AtomicU64,
}

/// A single-worker comm lane: FIFO execution plus busy-time accounting
/// (the overlap numerator behind
/// [`StepBreakdown::overlap_secs`](crate::metrics::StepBreakdown)).
/// Dropping the runtime drains the queue, shuts the worker down and
/// joins it.
pub struct CommRuntime {
    shared: Arc<LaneShared>,
    label: String,
    /// per-lane submission counter — the op number in orphan reports
    submitted: AtomicU64,
    worker: Option<lsync::JoinHandle<()>>,
}

fn worker_loop(shared: Arc<LaneShared>) {
    loop {
        let job = {
            let mut q = shared.q.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.closed {
                    return;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        // jobs never unwind (submit wraps them in catch_unwind),
        // so one poisoned collective doesn't kill the lane
        #[cfg(not(loom))]
        let t = std::time::Instant::now();
        job();
        #[cfg(not(loom))]
        shared
            .busy_nanos
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        shared.ops.fetch_add(1, Ordering::Relaxed);
    }
}

impl CommRuntime {
    /// Spawn the worker thread (named `comm-<label>`).
    pub fn new(label: &str) -> CommRuntime {
        let shared = Arc::new(LaneShared {
            q: Mutex::new(LaneQ { jobs: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            busy_nanos: AtomicU64::new(0),
            ops: AtomicU64::new(0),
        });
        let w = Arc::clone(&shared);
        let worker = lsync::spawn_named(&format!("comm-{label}"), move || worker_loop(w));
        CommRuntime {
            shared,
            label: label.to_string(),
            submitted: AtomicU64::new(0),
            worker: Some(worker),
        }
    }

    /// Enqueue `f`. Jobs run FIFO on the worker; the handle resolves when
    /// `f` returns (or re-throws its panic at `wait`).
    pub fn submit<T, F>(&self, f: F) -> CommHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let op = self.submitted.fetch_add(1, Ordering::Relaxed) + 1;
        let slot = Arc::new(Slot {
            state: Mutex::new(SlotState::Pending),
            cv: Condvar::new(),
        });
        let guard = OrphanGuard { slot: Arc::clone(&slot) };
        let job: Job = Box::new(move || {
            let r = catch_unwind(AssertUnwindSafe(f));
            let mut st = guard.slot.state.lock().unwrap();
            *st = SlotState::Done(r);
            guard.slot.cv.notify_all();
            // `guard` drops after the state is Done — its bomb is inert
        });
        let lane = format!("comm-{}", self.label);
        {
            let mut q = self.shared.q.lock().unwrap();
            assert!(
                !q.closed,
                "comm lane `{lane}` is closed; cannot submit collective #{op}"
            );
            q.jobs.push_back(job);
        }
        self.shared.cv.notify_one();
        CommHandle { slot, lane, op }
    }

    /// Drop every queued-but-unstarted job. Their handles resolve to the
    /// orphaned state (`wait()` panics with lane + op, `try_wait()`
    /// errors); a job already executing completes normally. The failure
    /// path for a rank tearing down its lane mid-step.
    pub fn abort(&self) {
        let dropped: Vec<Job> = {
            let mut q = self.shared.q.lock().unwrap();
            q.jobs.drain(..).collect()
        };
        // dropping the closures fires their orphan guards — outside the
        // lane lock, so waiters wake without lock-order entanglement
        drop(dropped);
    }

    /// Total seconds the worker has spent inside collectives. The counter
    /// is bumped *after* a job's handle resolves, so a reading taken right
    /// after `wait()` may trail by one job — accounting only.
    pub fn busy_secs(&self) -> f64 {
        self.shared.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Number of jobs the worker has completed.
    pub fn completed_ops(&self) -> u64 {
        self.shared.ops.load(Ordering::Relaxed)
    }
}

impl Drop for CommRuntime {
    fn drop(&mut self) {
        // close the queue; the worker drains whatever is already queued,
        // then exits — and is always joined, so no lane thread leaks
        {
            let mut q = self.shared.q.lock().unwrap();
            q.closed = true;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_resolves_in_fifo_order() {
        let rt = CommRuntime::new("test-fifo");
        let handles: Vec<CommHandle<usize>> =
            (0..16).map(|i| rt.submit(move || i * 2)).collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.wait(), i * 2);
        }
        assert_eq!(rt.completed_ops(), 16);
    }

    #[test]
    fn worker_survives_a_panicking_job() {
        let rt = CommRuntime::new("test-panic");
        let bad: CommHandle<()> = rt.submit(|| panic!("boom"));
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| bad.wait()));
        assert!(caught.is_err(), "wait must re-throw the job panic");
        // lane still alive afterwards
        let ok = rt.submit(|| 7usize);
        assert_eq!(ok.wait(), 7);
    }

    #[test]
    fn busy_time_accumulates() {
        let rt = CommRuntime::new("test-busy");
        rt.submit(|| std::thread::sleep(std::time::Duration::from_millis(5)))
            .wait();
        // flush: a second job guarantees the first's busy add landed
        rt.submit(|| ()).wait();
        assert!(rt.busy_secs() >= 0.004, "{}", rt.busy_secs());
    }

    #[test]
    fn orphaned_collective_is_attributable_to_lane_and_op() {
        let rt = CommRuntime::new("t-orphan");
        // park the worker inside job #1 so #2 and #3 are queued for sure
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let h1 = rt.submit(move || {
            let _ = rx.recv();
            1usize
        });
        let h2: CommHandle<usize> = rt.submit(|| 2);
        let h3: CommHandle<usize> = rt.submit(|| 3);
        rt.abort();
        tx.send(()).unwrap();
        assert_eq!(h1.wait(), 1, "the running job completes through an abort");
        let e = h2.try_wait().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("comm lane `comm-t-orphan`"), "{msg}");
        assert!(msg.contains("collective #2"), "{msg}");
        // wait() on an orphan panics with the same attributable message
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| h3.wait()))
            .expect_err("orphaned wait must panic");
        let pmsg = caught
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(pmsg.contains("comm-t-orphan") && pmsg.contains("#3"), "{pmsg}");
        // the lane survives an abort: later submissions run normally
        assert_eq!(rt.submit(|| 4usize).wait(), 4);
    }
}
