//! Pipeline-parallel training over stage artifacts.
//!
//! Stage ranks execute the schedule's op list; activations/cotangents move
//! over point-to-point channels. The backward artifacts recompute their
//! stage forward from the stashed stage *input* (tokens for stage 0,
//! received activations otherwise) — i.e. selective activation
//! checkpointing is the engine's native execution mode (paper §1, used
//! for Mula-100B/220B).
//!
//! Gradients accumulate over microbatches and are averaged before the
//! sharded optimizer step (per-stage DP group).

use super::pipeline::{PipeOp, Schedule};
use super::{clip_now, init_global_params, TrainOptions, TrainReport};
use crate::comm::{Mesh, P2p, ReduceDtype};
use crate::config::{ModelManifest, ParamSpec};
use crate::data::{BatchPlan, Dataset};
use crate::metrics::{Curve, Scoped, StepBreakdown};
use crate::optim::sharded::{SegmentSpec, ShardedOptimizer};
use crate::runtime::{Engine, Tensor};
use crate::Result;
use anyhow::anyhow;
use std::sync::Arc;

/// Stage-owned parameter specs (mirrors python model.stage_param_specs:
/// same filter, same order, local offsets).
pub fn stage_specs(mm: &ModelManifest, pp: usize, stage: usize) -> Vec<ParamSpec> {
    let lps = mm.hyper.n_layers / pp;
    let lo = (stage * lps) as i64;
    let hi = ((stage + 1) * lps) as i64;
    let mut out = Vec::new();
    let mut off = 0usize;
    for p in &mm.params {
        let owned = (p.layer >= lo && p.layer < hi)
            || (stage == 0 && p.name == "embed")
            || (stage == pp - 1 && (p.name == "final_norm" || p.name == "head"));
        if owned {
            let mut q = p.clone();
            let goff = p.offset;
            q.offset = off;
            off += p.numel;
            out.push(ParamSpec { name: format!("{}@{goff}", q.name), ..q });
        }
    }
    out
}

fn stage_len(specs: &[ParamSpec]) -> usize {
    specs.iter().map(|s| s.numel).sum()
}

fn extract_stage(global: &[f32], specs: &[ParamSpec]) -> Vec<f32> {
    let mut out = Vec::with_capacity(stage_len(specs));
    for s in specs {
        let goff: usize = s
            .name
            .rsplit('@')
            .next()
            .unwrap()
            .parse()
            .expect("stage spec global offset");
        out.extend_from_slice(&global[goff..goff + s.numel]);
    }
    out
}

fn scatter_stage(local: &[f32], specs: &[ParamSpec], global: &mut [f32]) {
    let mut off = 0usize;
    for s in specs {
        let goff: usize = s.name.rsplit('@').next().unwrap().parse().unwrap();
        global[goff..goff + s.numel].copy_from_slice(&local[off..off + s.numel]);
        off += s.numel;
    }
}

pub fn run(
    mm: &ModelManifest,
    ds: Arc<Dataset>,
    engine: Engine,
    mesh: Arc<Mesh>,
    opts: &TrainOptions,
) -> Result<TrainReport> {
    let pp = opts.topo.pp;
    if !mm.pp_degrees.contains(&pp) {
        return Err(anyhow!(
            "no PP={pp} artifacts for {} (built: {:?})",
            mm.name,
            mm.pp_degrees
        ));
    }
    if matches!(opts.schedule, Schedule::Interleaved1F1B { .. }) {
        return Err(anyhow!(
            "interleaved-1f1b needs multi-chunk artifacts; runnable engine \
             supports gpipe/1f1b (interleaved is covered by the schedule \
             property tests and the cluster model)"
        ));
    }
    let world_n = opts.topo.world();
    let p2p = P2p::new(world_n, 2); // tag 0 = fwd activations, 1 = cotangents
    let plan = BatchPlan {
        dp: opts.topo.dp,
        micro_batch: mm.hyper.batch,
        micro_batches: opts.micro_batches,
    };

    let handles: Vec<_> = (0..world_n)
        .map(|rank| {
            let mm = mm.clone();
            let ds = Arc::clone(&ds);
            let engine = engine.clone();
            let mesh = Arc::clone(&mesh);
            let opts = opts.clone();
            let p2p = Arc::clone(&p2p);
            std::thread::Builder::new()
                .name(format!("pp-rank-{rank}"))
                .spawn(move || {
                    let m2 = Arc::clone(&mesh);
                    let r = rank_main(rank, &mm, ds, engine, mesh, p2p, &opts, plan);
                    if r.is_err() {
                        m2.poison_all();
                    }
                    r
                })
                .expect("spawn rank")
        })
        .collect();

    let mut report: Option<TrainReport> = None;
    let mut stage0_params: Option<Vec<f32>> = None;
    let mut first_err: Option<anyhow::Error> = None;
    let mut panic_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(RankOut::Last(r))) => report = Some(r),
            Ok(Ok(RankOut::Stage { stage: 0, params })) => stage0_params = Some(params),
            Ok(Ok(_)) => {}
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => panic_err = panic_err.or(Some(anyhow!("pp rank panicked"))),
        }
    }
    if let Some(e) = first_err.or(panic_err) {
        return Err(e);
    }
    let mut rep = report.ok_or_else(|| anyhow!("last stage produced no report"))?;
    // assemble a full parameter vector from stage segments (pp=2 case:
    // stage 0 params + the last stage's own, already scattered into rep)
    if let Some(p0) = stage0_params {
        let specs0 = stage_specs(mm, pp, 0);
        let mut global = rep.final_params.clone();
        scatter_stage(&p0, &specs0, &mut global);
        rep.final_params = global;
    }
    Ok(rep)
}

enum RankOut {
    Last(TrainReport),
    Stage { stage: usize, params: Vec<f32> },
    None,
}

#[allow(clippy::too_many_arguments)]
fn rank_main(
    rank: usize,
    mm: &ModelManifest,
    ds: Arc<Dataset>,
    engine: Engine,
    mesh: Arc<Mesh>,
    p2p: Arc<P2p>,
    opts: &TrainOptions,
    plan: BatchPlan,
) -> Result<RankOut> {
    let h = &mm.hyper;
    let pp = opts.topo.pp;
    let c = mesh.coord(rank);
    let stage = c.pp;
    let last = stage == pp - 1;
    let specs = stage_specs(mm, pp, stage);
    let my_len = stage_len(&specs);
    let world = mesh.world_group();
    let (dp_group, dp_rank) = mesh.dp_group(rank);
    let (prev, next) = mesh.pp_neighbours(rank);

    // model broadcasting, then stage extraction
    let global0 = if rank == 0 {
        let p = init_global_params(mm, opts.run.seed);
        world.broadcast(rank, 0, p.clone());
        p
    } else {
        world.broadcast(rank, 0, Vec::new())
    };
    let mut params = extract_stage(&global0, &specs);
    drop(global0);

    let segs = vec![SegmentSpec {
        local_offset: 0,
        len: my_len,
        group: Arc::clone(dp_group),
        group_rank: dp_rank,
        norm_weight: 1.0,
    }];
    let mut opt = ShardedOptimizer::new(
        segs,
        Arc::clone(dp_group),
        dp_rank,
        opts.adam(),
        opts.reduce_dtype(),
        opts.run.grad_clip,
    );

    let art_fwd = if last {
        None
    } else {
        Some(mm.artifact_path(&format!("pp{pp}_stage{stage}_fwd"))?)
    };
    let art_fwdbwd = mm.artifact_path(&format!("pp{pp}_stage{stage}_fwdbwd"))?;

    let (b, s) = (h.batch, h.seq);
    let _act_len = b * s * h.hidden;
    let ops = opts.schedule.ops(stage, pp, opts.micro_batches);
    let exec = |key: &str, path: &std::path::Path, inputs: Vec<Tensor>| {
        engine.exec(
            &format!("{}:pp{pp}s{stage}:{key}", mm.name),
            path.to_path_buf(),
            inputs,
        )
    };

    let mut loss_curve = Curve::new("loss");
    let mut gn_curve = Curve::new("grad_norm");
    let mut breakdown = StepBreakdown::default();
    let mut step_secs = Vec::with_capacity(opts.run.steps);

    for step in 0..opts.run.steps {
        let t_step = std::time::Instant::now();
        let mut grads = vec![0.0f32; my_len];
        let mut step_loss = 0.0f32;
        // stashed stage inputs per microbatch (SAC)
        let mut stash: Vec<Option<Tensor>> = vec![None; opts.micro_batches];

        for op in &ops {
            match *op {
                PipeOp::Fwd { mb, .. } => {
                    let tokens = {
                        let _t = Scoped::new(&mut breakdown.data_secs);
                        ds.batch_i32(plan.start(step, c.dp, mb), b, s)
                    };
                    let tokens_t = Tensor::i32(tokens, vec![b, s + 1]);
                    if stage == 0 {
                        let outs = {
                            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                            exec("fwd", art_fwd.as_ref().unwrap(), vec![
                                Tensor::f32(params.clone(), vec![my_len]),
                                tokens_t.clone(),
                            ])?
                        };
                        let hout = outs[0].as_f32()?.to_vec();
                        stash[mb] = Some(tokens_t);
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.send(rank, next.unwrap(), 0, (step * 64 + mb) as u64, hout);
                    } else if last {
                        // recv + fused fwdbwd + send cotangent immediately
                        let hin = {
                            let _t = Scoped::new(&mut breakdown.comm_secs);
                            p2p.recv(prev.unwrap(), rank, 0, (step * 64 + mb) as u64)
                        };
                        let outs = {
                            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                            exec("fwdbwd", &art_fwdbwd, vec![
                                Tensor::f32(params.clone(), vec![my_len]),
                                Tensor::f32(hin, vec![b, s, h.hidden]),
                                tokens_t,
                            ])?
                        };
                        let loss = outs[0].scalar()?;
                        if !loss.is_finite() {
                            return Err(anyhow!(
                                "rank {rank}: non-finite loss at step {step}"
                            ));
                        }
                        step_loss += loss;
                        let dx = outs[2].as_f32()?.to_vec();
                        for (g, d) in grads.iter_mut().zip(outs[3].as_f32()?) {
                            *g += d;
                        }
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.send(rank, prev.unwrap(), 1, (step * 64 + mb) as u64, dx);
                    } else {
                        let hin = {
                            let _t = Scoped::new(&mut breakdown.comm_secs);
                            p2p.recv(prev.unwrap(), rank, 0, (step * 64 + mb) as u64)
                        };
                        let hin_t = Tensor::f32(hin, vec![b, s, h.hidden]);
                        let outs = {
                            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                            exec("fwd", art_fwd.as_ref().unwrap(), vec![
                                Tensor::f32(params.clone(), vec![my_len]),
                                hin_t.clone(),
                            ])?
                        };
                        stash[mb] = Some(hin_t);
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.send(
                            rank,
                            next.unwrap(),
                            0,
                            (step * 64 + mb) as u64,
                            outs[0].as_f32()?.to_vec(),
                        );
                    }
                }
                PipeOp::Bwd { mb, .. } => {
                    if last {
                        continue; // fused into Fwd above
                    }
                    let d_out = {
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.recv(next.unwrap(), rank, 1, (step * 64 + mb) as u64)
                    };
                    let d_out_t = Tensor::f32(d_out, vec![b, s, h.hidden]);
                    let input = stash[mb].take().expect("bwd before fwd");
                    let outs = {
                        let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                        exec("fwdbwd", &art_fwdbwd, vec![
                            Tensor::f32(params.clone(), vec![my_len]),
                            input,
                            d_out_t,
                        ])?
                    };
                    if stage == 0 {
                        for (g, d) in grads.iter_mut().zip(outs[0].as_f32()?) {
                            *g += d;
                        }
                    } else {
                        let dx = outs[0].as_f32()?.to_vec();
                        for (g, d) in grads.iter_mut().zip(outs[1].as_f32()?) {
                            *g += d;
                        }
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.send(rank, prev.unwrap(), 1, (step * 64 + mb) as u64, dx);
                    }
                }
            }
        }

        // average gradient over microbatches
        let inv = 1.0 / opts.micro_batches as f32;
        for g in grads.iter_mut() {
            *g *= inv;
        }
        let lr = opts.run.lr_at(step) as f32;
        let gn = {
            let _t = Scoped::new(&mut breakdown.optimizer_secs);
            opt.step(&mut params, &grads, lr, clip_now(&opts.run, step))
        };
        opts.hook.on_step(rank, step, step_loss / opts.micro_batches as f32, &mut params)?;

        // loss lives on the last stage; average over its DP replicas
        if last {
            let mean = dp_group.allreduce_mean(
                dp_rank,
                vec![step_loss / opts.micro_batches as f32],
                ReduceDtype::F32,
            )[0];
            if c.dp == 0 {
                loss_curve.push(step, mean as f64);
                gn_curve.push(step, gn);
            }
        }
        step_secs.push(t_step.elapsed().as_secs_f64());
    }

    if last && c.dp == 0 {
        let mut final_params = vec![0.0f32; mm.param_count];
        scatter_stage(&params, &specs, &mut final_params);
        breakdown.comm_secs += opt.comm_secs;
        return Ok(RankOut::Last(TrainReport {
            loss: loss_curve,
            grad_norm: gn_curve,
            breakdown,
            step_secs,
            tokens_per_step: plan.instances_per_step() * s,
            final_params,
            opt_state_bytes: opt.state_bytes(),
            optimizer_update_secs: opt.update_secs,
            optimizer_comm_secs: opt.comm_secs,
        }));
    }
    if stage == 0 && c.dp == 0 {
        return Ok(RankOut::Stage { stage, params });
    }
    Ok(RankOut::None)
}
