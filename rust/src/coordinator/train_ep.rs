//! Expert-parallel training: Algorithm 1 with Stage 1 in Rust.
//!
//! Per layer and step, each EP rank:
//!   1. runs `ep_layer_pre_fwd` (attention + router) on its local tokens,
//!   2. exchanges tokens/weights/indices across the EP group (allgather —
//!      the paper's choice — or all2all, ablation),
//!   3. runs `ep_expert_fwd` (Pallas stages 2-5) over its local experts,
//!   4. reduce-scatters the partial outputs (line 116) and adds the
//!      residual.
//! The backward pass mirrors it: allgather d(moe_out) (line "allgather on
//! the gradients"), `ep_expert_bwd`, reduce-scatter dx/dw, then
//! `ep_layer_pre_bwd` recomputes the attention half from the stashed layer
//! input (SAC).
//!
//! Gradient/optimizer sharding is where SO vs EPSO differ (§3.2):
//! * SO: NE grads allreduced over EP (to stay correct), then sharded over
//!   DP only — NE optimizer states replicated EP times;
//! * EPSO: NE grads reduce-scattered over the whole DP×EP group.

use super::ep::{exchange_all2all, exchange_allgather, fur_indices, EpComm};
use super::ep_layout::EpLayout;
use super::{clip_now, init_global_params, TrainOptions, TrainReport};
use crate::comm::{Mesh, ReduceDtype};
use crate::config::ModelManifest;
use crate::data::{BatchPlan, Dataset};
use crate::metrics::{Curve, Scoped, StepBreakdown};
use crate::optim::sharded::{build_segments, ShardedOptimizer};
use crate::runtime::{Engine, Tensor};
use crate::Result;
use anyhow::anyhow;
use std::sync::Arc;

pub fn run(
    mm: &ModelManifest,
    ds: Arc<Dataset>,
    engine: Engine,
    mesh: Arc<Mesh>,
    opts: &TrainOptions,
) -> Result<TrainReport> {
    let ep = opts.topo.ep;
    if !mm.ep_degrees.contains(&ep) {
        return Err(anyhow!(
            "no EP={ep} artifacts for {} (built: {:?})",
            mm.name,
            mm.ep_degrees
        ));
    }
    let world_n = opts.topo.world();
    // EP scales the global batch like DP (paper §1): data-rank = dp*EP+ep
    let plan = BatchPlan {
        dp: world_n,
        micro_batch: mm.hyper.batch,
        micro_batches: 1,
    };

    let handles: Vec<_> = (0..world_n)
        .map(|rank| {
            let mm = mm.clone();
            let ds = Arc::clone(&ds);
            let engine = engine.clone();
            let mesh = Arc::clone(&mesh);
            let opts = opts.clone();
            std::thread::Builder::new()
                .name(format!("ep-rank-{rank}"))
                .spawn(move || {
                    let m2 = Arc::clone(&mesh);
                    let r = rank_main(rank, &mm, ds, engine, mesh, &opts, plan);
                    if r.is_err() {
                        m2.poison_all();
                    }
                    r
                })
                .expect("spawn rank")
        })
        .collect();

    let mut report = None;
    let mut first_err: Option<anyhow::Error> = None;
    let mut panic_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(Some(r))) => report = Some(r),
            Ok(Ok(None)) => {}
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => panic_err = panic_err.or(Some(anyhow!("ep rank panicked"))),
        }
    }
    if let Some(e) = first_err.or(panic_err) {
        return Err(e);
    }
    report.ok_or_else(|| anyhow!("rank 0 produced no report"))
}

struct Arts {
    embed_fwd: std::path::PathBuf,
    embed_bwd: std::path::PathBuf,
    pre_fwd: std::path::PathBuf,
    pre_bwd: std::path::PathBuf,
    expert_fwd: std::path::PathBuf,
    expert_bwd: std::path::PathBuf,
    head: std::path::PathBuf,
}

impl Arts {
    fn load(mm: &ModelManifest, ep: usize) -> Result<Arts> {
        let p = |n: &str| mm.artifact_path(&format!("ep{ep}_{n}"));
        Ok(Arts {
            embed_fwd: p("embed_fwd")?,
            embed_bwd: p("embed_bwd")?,
            pre_fwd: p("layer_pre_fwd")?,
            pre_bwd: p("layer_pre_bwd")?,
            expert_fwd: p("expert_fwd")?,
            expert_bwd: p("expert_bwd")?,
            head: p("head_fwdbwd")?,
        })
    }
}

#[allow(clippy::too_many_arguments)]
fn rank_main(
    rank: usize,
    mm: &ModelManifest,
    ds: Arc<Dataset>,
    engine: Engine,
    mesh: Arc<Mesh>,
    opts: &TrainOptions,
    plan: BatchPlan,
) -> Result<Option<TrainReport>> {
    let h = &mm.hyper;
    let ep = opts.topo.ep;
    let c = mesh.coord(rank);
    let layout = EpLayout::new(mm, ep, c.ep);
    let arts = Arts::load(mm, ep)?;
    let world = mesh.world_group();
    let (ep_group, ep_rank) = mesh.ep_group(rank);
    let (dp_group, dp_rank) = mesh.dp_group(rank);
    let (dpep_group, dpep_rank) = mesh.dpep_group(rank);
    let nr = layout.n_local_experts;

    // model broadcasting: rank 0 initializes the *global* vector, all
    // ranks extract their local layout from the broadcast copy.
    let global0 = if rank == 0 {
        let p = init_global_params(mm, opts.run.seed);
        world.broadcast(rank, 0, p.clone());
        p
    } else {
        world.broadcast(rank, 0, Vec::new())
    };
    let mut params = layout.extract(&global0);
    drop(global0);

    let segs = build_segments(
        opts.mode,
        layout.ne_len,
        layout.e_len,
        dp_group,
        dp_rank,
        dpep_group,
        dpep_rank,
        ep,
    );
    let mut opt = ShardedOptimizer::new(
        segs,
        Arc::clone(dpep_group),
        dpep_rank,
        opts.adam(),
        opts.reduce_dtype(),
        opts.run.grad_clip,
    );

    let (b, s) = (h.batch, h.seq);
    let t_local = b * s;
    let t_all = ep * t_local;
    let k = h.top_k;
    let hid = h.hidden;
    let data_rank = c.dp * ep + c.ep;

    let exec = |key: &str, path: &std::path::Path, inputs: Vec<Tensor>| {
        engine.exec(&format!("{}:{key}", mm.name), path.to_path_buf(), inputs)
    };
    let pslice = |params: &[f32], r: &std::ops::Range<usize>| {
        Tensor::f32(params[r.clone()].to_vec(), vec![r.len()])
    };

    let mut loss_curve = Curve::new("loss");
    let mut gn_curve = Curve::new("grad_norm");
    let mut breakdown = StepBreakdown::default();
    let mut step_secs = Vec::with_capacity(opts.run.steps);

    for step in 0..opts.run.steps {
        let t_step = std::time::Instant::now();
        let tokens = {
            let _t = Scoped::new(&mut breakdown.data_secs);
            ds.batch_i32(plan.start(step, data_rank, 0), b, s)
        };
        let tokens_t = Tensor::i32(tokens, vec![b, s + 1]);

        // ---------------- forward ----------------
        let mut hcur = {
            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
            exec("embed_fwd", &arts.embed_fwd,
                 vec![pslice(&params, &layout.emb), tokens_t.clone()])?
                .remove(0)
        };
        // stashes for backward (SAC: inputs only)
        let mut stash_h: Vec<Tensor> = Vec::with_capacity(h.n_layers);
        let mut stash_x: Vec<Vec<f32>> = Vec::with_capacity(h.n_layers);
        let mut stash_w: Vec<Vec<f32>> = Vec::with_capacity(h.n_layers);
        let mut stash_i: Vec<Vec<i32>> = Vec::with_capacity(h.n_layers);
        let mut aux_total = 0.0f32;

        for l in 0..h.n_layers {
            stash_h.push(hcur.clone());
            let outs = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                exec("pre_fwd", &arts.pre_fwd,
                     vec![pslice(&params, &layout.layer_ne[l]), hcur])?
            };
            let mut it = outs.into_iter();
            let a = it.next().unwrap();
            let x2d = it.next().unwrap().into_f32()?;
            let w2d = it.next().unwrap().into_f32()?;
            let idx = it.next().unwrap();
            let aux = it.next().unwrap().scalar()?;
            aux_total += aux;
            let mut idx = idx.as_i32()?.to_vec();
            if opts.fur {
                idx = fur_indices(t_local, k, h.n_experts);
            }
            // ---- Stage 1: token exchange across EP ----
            let (x_all, w_all, idx_all) = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                match opts.ep_comm {
                    EpComm::Allgather => {
                        exchange_allgather(ep_group, ep_rank, x2d, w2d, &idx)
                    }
                    EpComm::All2All => exchange_all2all(
                        ep_group, ep_rank, ep, nr, hid, x2d, w2d, &idx,
                    ),
                }
            };
            // shift indices so local experts occupy [0, NR)
            let idx_shift: Vec<i32> = idx_all
                .iter()
                .map(|&v| v - (ep_rank * nr) as i32)
                .collect();
            // ---- Stages 2-5 (Pallas) ----
            let partial = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                exec("expert_fwd", &arts.expert_fwd, vec![
                    pslice(&params, &layout.layer_e[l]),
                    Tensor::f32(x_all.clone(), vec![t_all, hid]),
                    Tensor::f32(w_all.clone(), vec![t_all, k]),
                    Tensor::i32(idx_shift.clone(), vec![t_all, k]),
                ])?
                .remove(0)
                .into_f32()?
            };
            // ---- line 116: reduce-scatter of partial outputs ----
            let moe_local = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                ep_group.reduce_scatter_sum_even(ep_rank, partial, ReduceDtype::F32)
            };
            // residual: h = a + moe_out
            let mut a_data = a.into_f32()?;
            for (av, mv) in a_data.iter_mut().zip(moe_local.iter()) {
                *av += *mv;
            }
            hcur = Tensor::f32(a_data, vec![b, s, hid]);
            stash_x.push(x_all);
            stash_w.push(w_all);
            stash_i.push(idx_shift);
        }

        // ---- head + loss ----
        let outs = {
            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
            exec("head", &arts.head,
                 vec![pslice(&params, &layout.head), hcur, tokens_t.clone()])?
        };
        let loss = outs[0].scalar()?;
        let mut dh = outs[1].clone().into_f32()?;
        let dp_head = outs[2].as_f32()?.to_vec();
        if !loss.is_finite() {
            return Err(anyhow!("rank {rank}: non-finite loss at step {step}"));
        }

        // ---------------- backward ----------------
        let mut grads = vec![0.0f32; layout.local_len()];
        grads[layout.head.clone()].copy_from_slice(&dp_head);

        for l in (0..h.n_layers).rev() {
            // d(out) = dh: residual gives d_a = dh and d(moe_out) = dh
            let d_moe_full = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                ep_group.allgather(ep_rank, dh.clone())
            };
            let outs = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                exec("expert_bwd", &arts.expert_bwd, vec![
                    pslice(&params, &layout.layer_e[l]),
                    Tensor::f32(stash_x[l].clone(), vec![t_all, hid]),
                    Tensor::f32(stash_w[l].clone(), vec![t_all, k]),
                    Tensor::i32(stash_i[l].clone(), vec![t_all, k]),
                    Tensor::f32(d_moe_full, vec![t_all, hid]),
                ])?
            };
            let dx_partial = outs[0].as_f32()?.to_vec();
            let dw_partial = outs[1].as_f32()?.to_vec();
            let dpe = outs[2].as_f32()?;
            grads[layout.layer_e[l].clone()].copy_from_slice(dpe);
            let (dx_local, dw_local) = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                (
                    ep_group.reduce_scatter_sum_even(ep_rank, dx_partial, ReduceDtype::F32),
                    ep_group.reduce_scatter_sum_even(ep_rank, dw_partial, ReduceDtype::F32),
                )
            };
            let outs = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                exec("pre_bwd", &arts.pre_bwd, vec![
                    pslice(&params, &layout.layer_ne[l]),
                    stash_h[l].clone(),
                    Tensor::f32(dh.clone(), vec![b, s, hid]),
                    Tensor::f32(dx_local, vec![t_local, hid]),
                    Tensor::f32(dw_local, vec![t_local, k]),
                ])?
            };
            dh = outs[0].as_f32()?.to_vec();
            grads[layout.layer_ne[l].clone()].copy_from_slice(outs[1].as_f32()?);
        }
        // embedding backward
        let outs = {
            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
            exec("embed_bwd", &arts.embed_bwd, vec![
                pslice(&params, &layout.emb),
                tokens_t.clone(),
                Tensor::f32(dh.clone(), vec![b, s, hid]),
            ])?
        };
        grads[layout.emb.clone()].copy_from_slice(outs[0].as_f32()?);

        // ---- SO correctness step: NE grads must average over EP too ----
        if opts.mode == crate::optim::ShardingMode::So && ep > 1 {
            let _t = Scoped::new(&mut breakdown.comm_secs);
            let ne = grads[..layout.ne_len].to_vec();
            let avg = ep_group.allreduce_mean(ep_rank, ne, opts.reduce_dtype());
            grads[..layout.ne_len].copy_from_slice(&avg);
        }

        let lr = opts.run.lr_at(step) as f32;
        let gn = opt.step(&mut params, &grads, lr, clip_now(&opts.run, step));
        opts.hook.on_step(rank, step, loss, &mut params)?;

        // loss averaged over all ranks (each saw distinct tokens)
        let mean_loss =
            world.allreduce_mean(rank, vec![loss], ReduceDtype::F32)[0];
        if rank == 0 {
            loss_curve.push(step, mean_loss as f64);
            gn_curve.push(step, gn);
        }
        step_secs.push(t_step.elapsed().as_secs_f64());
        let _ = aux_total;
    }

    // reassemble rank 0's global view (rank 0 holds ep=0 experts; other
    // experts live on sibling ep ranks: gather via dpep allgather of local
    // vectors is overkill — scatter local and gather expert blocks)
    if rank == 0 {
        let mut final_params = vec![0.0f32; mm.param_count];
        // collect every ep rank's local vector via the ep group
        let all_locals = ep_group.allgather(ep_rank, params.clone());
        for (r, chunk) in all_locals.chunks(layout.local_len()).enumerate() {
            let lay_r = EpLayout::new(mm, ep, r);
            lay_r.scatter(chunk, &mut final_params);
        }
        breakdown.comm_secs += opt.comm_secs;
        return Ok(Some(TrainReport {
            loss: loss_curve,
            grad_norm: gn_curve,
            breakdown,
            step_secs,
            tokens_per_step: plan.instances_per_step() * s,
            final_params,
            opt_state_bytes: opt.state_bytes(),
            optimizer_update_secs: opt.update_secs,
            optimizer_comm_secs: opt.comm_secs,
        }));
    }
    // non-zero ranks must still participate in the final gather above
    if mesh.coord(rank).dp == 0 {
        ep_group.allgather(ep_rank, params.clone());
    }
    Ok(None)
}
