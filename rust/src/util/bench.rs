//! Micro-benchmark harness (`criterion` is unavailable offline).
//!
//! Warmup + timed iterations, robust stats (median / MAD), and a tabular
//! reporter the `rust/benches/*` binaries share. Each paper table/figure
//! bench prints the same rows/series the paper reports and appends CSV to
//! `bench_out/` for EXPERIMENTS.md.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_samples(mut s: Vec<Duration>) -> Self {
        assert!(!s.is_empty());
        s.sort();
        let sum: Duration = s.iter().sum();
        Stats {
            iters: s.len(),
            mean: sum / s.len() as u32,
            median: s[s.len() / 2],
            min: s[0],
            max: s[s.len() - 1],
        }
    }

    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Time `f` with `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<Duration> = (0..iters.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect();
    Stats::from_samples(samples)
}

/// Time a fallible op, propagating the first error.
pub fn bench_result<E, F: FnMut() -> Result<(), E>>(
    warmup: usize,
    iters: usize,
    mut f: F,
) -> Result<Stats, E> {
    for _ in 0..warmup {
        f()?;
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f()?;
        samples.push(t.elapsed());
    }
    Ok(Stats::from_samples(samples))
}

/// Simple fixed-width table printer + CSV sink for bench reports.
pub struct Report {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Report {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        line(&w.iter().map(|n| "-".repeat(*n)).collect::<Vec<_>>());
        for r in &self.rows {
            line(r);
        }
    }

    /// Append as CSV under `bench_out/<name>.csv` (created on demand).
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_out");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        let mut s = self.headers.join(",") + "\n";
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        std::fs::write(&path, s)?;
        Ok(path)
    }
}

/// Human-friendly duration formatting for report cells.
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let st = bench(1, 5, || std::thread::sleep(Duration::from_micros(200)));
        assert!(st.min <= st.median && st.median <= st.max);
        assert!(st.median >= Duration::from_micros(150));
    }

    #[test]
    fn report_prints_and_writes() {
        let mut r = Report::new("t", &["a", "b"]);
        r.row(&["1".into(), "x".into()]);
        r.print();
    }
}
