//! The training job specification: a validated, builder-constructed
//! replacement for the old flat `TrainOptions`.
//!
//! ```no_run
//! use optimus::coordinator::JobSpec;
//! use optimus::coordinator::pipeline::Schedule;
//! use optimus::optim::ShardingMode;
//!
//! let spec = JobSpec::new("mula-tiny")
//!     .data_dir("data/shards")
//!     .topology(4, 2, 2)
//!     .sharding(ShardingMode::Epso)
//!     .schedule(Schedule::OneFOneB)
//!     .micro_batches(4)
//!     .build()?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! `build()` runs the plan-level subset of the validation table (axis
//! sanity, micro-batch bounds, explicit-EPSO feasibility, world-size
//! consistency); `coordinator::train` then runs the full
//! [`ParallelismPlan::validate`] preflight against the model manifest and
//! dataset before any rank thread spawns.

use super::ep::EpComm;
use super::pipeline::Schedule;
use super::plan::{DEFAULT_OVERLAP_CHUNK, ParallelismPlan};
use super::{NoHook, StepHook};
use crate::ckpt::CkptPolicy;
use crate::comm::{ReduceDtype, Topology};
use crate::config::RunConfig;
use crate::optim::{AdamParams, ShardingMode};
use crate::runtime::Dtype;
use crate::Result;
use anyhow::anyhow;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Test/diagnostic sink recording every `(stream position, instance id)`
/// a run consumes through the harness batch fetch — the recorded-id hook
/// behind the elastic-resume data-order tests. Positions are unique per
/// consumption on DP/EP topologies; under PP both the first and the last
/// stage of a pipeline column fetch the same batch, so positions repeat
/// once per extra fetching stage.
pub type DataTrace = Arc<Mutex<Vec<(u64, u64)>>>;

/// A validated training job: model + run recipe + [`ParallelismPlan`].
/// Constructed through [`JobSpec::new`] (the builder); the fields stay
/// readable everywhere the old `TrainOptions` fields were.
#[derive(Clone)]
pub struct JobSpec {
    pub model: String,
    pub plan: ParallelismPlan,
    pub run: RunConfig,
    /// forced uniform routing (paper §2.3)
    pub fur: bool,
    /// PJRT executor threads
    pub engine_pool: usize,
    /// preprocessed shard directory
    pub data_dir: PathBuf,
    pub hook: Arc<dyn StepHook>,
    /// true when a caller installed a real [`StepHook`] — the harness
    /// only materializes the mutable f32 parameter view (which bf16
    /// engines cannot provide) when a hook will actually observe it
    pub hooked: bool,
    /// optional recorded-id sink for data-order tests (see [`DataTrace`])
    pub data_trace: Option<DataTrace>,
    /// private marker: construction goes through the builder (or the
    /// deprecated `TrainOptions` shim), never a struct literal
    _built: (),
}

impl JobSpec {
    /// Start building a job for `model`. Finish with
    /// [`JobSpecBuilder::build`].
    #[allow(clippy::new_ret_no_self)]
    pub fn new(model: &str) -> JobSpecBuilder {
        JobSpecBuilder {
            model: model.to_string(),
            topo: Topology::dp_only(2),
            mode: None,
            run: RunConfig::default(),
            fur: false,
            ep_comm: EpComm::Allgather,
            schedule: Schedule::OneFOneB,
            micro_batches: 2,
            engine_pool: 2,
            data_dir: None,
            hook: Arc::new(NoHook),
            hooked: false,
            expected_world: None,
            overlap: false,
            overlap_chunk: DEFAULT_OVERLAP_CHUNK,
            ckpt: CkptPolicy::default(),
            dtype: Dtype::F32,
            prefetch: true,
            data_epochs: 0,
            data_trace: None,
        }
    }

    pub fn topo(&self) -> Topology {
        self.plan.topo
    }

    pub fn adam(&self) -> AdamParams {
        AdamParams {
            beta1: self.run.beta1 as f32,
            beta2: self.run.beta2 as f32,
            eps: self.run.eps as f32,
            weight_decay: self.run.weight_decay as f32,
        }
    }

    /// Gradient-reduction wire dtype: bf16 when the plan runs mixed
    /// precision (paper §2.1 — bf16 wires come with the dtype) or when
    /// the standalone `--bf16-grad-reduce` ablation knob asks for it on
    /// an otherwise-f32 run.
    pub fn reduce_dtype(&self) -> ReduceDtype {
        if self.plan.dtype == Dtype::Bf16 || self.run.bf16_grad_reduce {
            ReduceDtype::Bf16
        } else {
            ReduceDtype::F32
        }
    }

    /// Stable identity recorded in checkpoints and compared on resume.
    pub fn fingerprint(&self) -> String {
        format!("{}/{}", self.model, self.plan.fingerprint())
    }
}

/// Fluent builder for [`JobSpec`].
pub struct JobSpecBuilder {
    model: String,
    topo: Topology,
    mode: Option<ShardingMode>,
    run: RunConfig,
    fur: bool,
    ep_comm: EpComm,
    schedule: Schedule,
    micro_batches: usize,
    engine_pool: usize,
    data_dir: Option<PathBuf>,
    hook: Arc<dyn StepHook>,
    hooked: bool,
    expected_world: Option<usize>,
    overlap: bool,
    overlap_chunk: usize,
    ckpt: CkptPolicy,
    dtype: Dtype,
    prefetch: bool,
    data_epochs: usize,
    data_trace: Option<DataTrace>,
}

impl JobSpecBuilder {
    /// Mesh axes: data-, expert- and pipeline-parallel degrees. Keeps a
    /// previously set [`JobSpecBuilder::node_size`].
    pub fn topology(mut self, dp: usize, ep: usize, pp: usize) -> Self {
        self.topo = Topology::grid(dp, ep, pp).with_node_size(self.topo.node_size);
        self
    }

    /// Ranks per node (`--node-size`): >1 places rank r on node
    /// `r / node_size` and runs node-spanning collectives hierarchically
    /// (intra-node → leaders → intra-node). The world size must divide
    /// by it (the `[topology]` check); 1 (the default) is the flat
    /// baseline, bit-identical to every pre-hierarchy run.
    pub fn node_size(mut self, n: usize) -> Self {
        self.topo.node_size = n;
        self
    }

    /// Mesh axes from an existing [`Topology`] value.
    pub fn topo(mut self, t: Topology) -> Self {
        self.topo = t;
        self
    }

    /// Assert the mesh matches a launcher-provided world size
    /// (`dp*ep*pp == n` is then part of validation).
    pub fn world_size(mut self, n: usize) -> Self {
        self.expected_world = Some(n);
        self
    }

    /// Explicit optimizer sharding mode. Without this, the plan defaults
    /// to EPSO when ep > 1 and SO otherwise; an explicit EPSO at ep = 1
    /// fails validation.
    pub fn sharding(mut self, mode: ShardingMode) -> Self {
        self.mode = Some(mode);
        self
    }

    /// Stage-1 token-exchange policy (paper §3.1).
    pub fn ep_comm(mut self, c: EpComm) -> Self {
        self.ep_comm = c;
        self
    }

    /// Pipeline microbatch schedule.
    pub fn schedule(mut self, s: Schedule) -> Self {
        self.schedule = s;
        self
    }

    /// Microbatches per optimizer step (pipeline topologies).
    pub fn micro_batches(mut self, n: usize) -> Self {
        self.micro_batches = n;
        self
    }

    /// Forced uniform routing (paper §2.3).
    pub fn fur(mut self, on: bool) -> Self {
        self.fur = on;
        self
    }

    /// Overlap the sharded optimizer's collectives with its compute (the
    /// pipelined step over the async comm runtime, paper §3.2). A pure
    /// scheduling change: final parameters are bit-identical to a serial
    /// run.
    pub fn overlap(mut self, on: bool) -> Self {
        self.overlap = on;
        self
    }

    /// Pipeline chunk length in elements for the overlapped optimizer
    /// (default [`DEFAULT_OVERLAP_CHUNK`]).
    pub fn overlap_chunk(mut self, n: usize) -> Self {
        self.overlap_chunk = n;
        self
    }

    /// Parameter/gradient-wire element dtype (`--dtype {f32,bf16}`).
    /// `F32` (the default) is bit-identical to every pre-dtype run;
    /// `Bf16` runs the paper's mixed-precision recipe — bf16 params and
    /// half-width collective/checkpoint payloads over f32 master weights
    /// and moments in the sharded optimizer.
    pub fn dtype(mut self, dt: Dtype) -> Self {
        self.dtype = dt;
        self
    }

    /// Enable sharded checkpointing — and **auto-resume**: when `dir`
    /// already holds a committed checkpoint of the same *model*,
    /// `coordinator::train` resumes from it, resharding the saved state
    /// onto this plan's topology if they differ (paper §4; see
    /// [`crate::ckpt`]).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.ckpt.dir = Some(dir.into());
        self
    }

    /// Snapshot interval in optimizer steps (default 10).
    pub fn ckpt_every(mut self, n: usize) -> Self {
        self.ckpt.every = n;
        self
    }

    /// Asynchronous snapshot serialization (default `true`): the training
    /// step blocks only for the O(1) `Arc` capture; a background writer
    /// serializes. `false` writes inline (the ablation the perf gate
    /// measures).
    pub fn ckpt_async(mut self, on: bool) -> Self {
        self.ckpt.asynchronous = on;
        self
    }

    /// Committed checkpoints retained (default 2 — the dual guarantee).
    pub fn ckpt_keep(mut self, k: usize) -> Self {
        self.ckpt.keep = k;
        self
    }

    /// PJRT executor pool size.
    pub fn engine_pool(mut self, n: usize) -> Self {
        self.engine_pool = n;
        self
    }

    /// Preprocessed shard directory (required).
    pub fn data_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.data_dir = Some(dir.into());
        self
    }

    /// Seed of the epoch-aware blockwise data shuffle (`--data-seed`).
    /// The shuffled instance order is reproducible from this value alone
    /// — independent of `seed`, which drives parameter init.
    pub fn data_seed(mut self, seed: u64) -> Self {
        self.run.data_seed = seed;
        self
    }

    /// Per-rank background batch prefetch (default on; `--no-prefetch`
    /// disables). A pure execution knob: the consumed batches are
    /// identical either way.
    pub fn data_prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Epoch budget for the `[data]` validation check: the run may
    /// consume at most `n` passes over the dataset (`steps ×
    /// instances_per_step ≤ dataset × n`). `0` (the default) leaves the
    /// budget unbounded.
    pub fn data_epochs(mut self, n: usize) -> Self {
        self.data_epochs = n;
        self
    }

    /// Attach a recorded-id sink: every `(stream position, instance id)`
    /// the run consumes is pushed into it (data-order tests).
    pub fn data_trace(mut self, trace: DataTrace) -> Self {
        self.data_trace = Some(trace);
        self
    }

    /// Per-step hook (checkpointing, fault injection, snapshots).
    /// Installing one requires the engines to expose a mutable f32
    /// parameter view, which the bf16 engines do not — a hooked
    /// `--dtype bf16` run fails at the first step hook invocation.
    pub fn hook(mut self, h: Arc<dyn StepHook>) -> Self {
        self.hook = h;
        self.hooked = true;
        self
    }

    /// Replace the whole run recipe.
    pub fn run(mut self, run: RunConfig) -> Self {
        self.run = run;
        self
    }

    // -- run-recipe conveniences (the commonly tuned knobs) --

    pub fn steps(mut self, n: usize) -> Self {
        self.run.steps = n;
        self
    }

    pub fn warmup_steps(mut self, n: usize) -> Self {
        self.run.warmup_steps = n;
        self
    }

    pub fn peak_lr(mut self, lr: f64) -> Self {
        self.run.peak_lr = lr;
        self
    }

    pub fn min_lr(mut self, lr: f64) -> Self {
        self.run.min_lr = lr;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.run.seed = seed;
        self
    }

    pub fn bf16_grad_reduce(mut self, on: bool) -> Self {
        self.run.bf16_grad_reduce = on;
        self
    }

    /// Validate the plan-level invariants and produce the spec.
    pub fn build(self) -> Result<JobSpec> {
        let data_dir = self
            .data_dir
            .ok_or_else(|| anyhow!("JobSpec for `{}` needs .data_dir(..)", self.model))?;
        let mut plan = ParallelismPlan::new(self.topo);
        if let Some(mode) = self.mode {
            plan.mode = mode;
            plan.mode_explicit = true;
        }
        plan.schedule = self.schedule;
        plan.micro_batches = self.micro_batches;
        plan.ep_comm = self.ep_comm;
        plan.expected_world = self.expected_world;
        plan.overlap = self.overlap;
        plan.overlap_chunk = self.overlap_chunk;
        plan.ckpt = self.ckpt;
        plan.dtype = self.dtype;
        plan.prefetch = self.prefetch;
        plan.data_epochs = self.data_epochs;
        plan.validate_spec()?;
        Ok(JobSpec {
            model: self.model,
            plan,
            run: self.run,
            fur: self.fur,
            engine_pool: self.engine_pool,
            data_dir,
            hook: self.hook,
            hooked: self.hooked,
            data_trace: self.data_trace,
            _built: (),
        })
    }
}

// ---------------------------------------------------------------------
// Deprecated flat-options shim (one release of source compatibility)
// ---------------------------------------------------------------------

/// The old flat, unvalidated options bag. Superseded by [`JobSpec`].
#[deprecated(
    since = "0.2.0",
    note = "use `JobSpec::new(model).data_dir(..).topology(dp, ep, pp)...build()?`"
)]
#[derive(Clone)]
pub struct TrainOptions {
    pub model: String,
    pub topo: Topology,
    pub mode: ShardingMode,
    pub run: RunConfig,
    pub fur: bool,
    pub ep_comm: EpComm,
    pub schedule: Schedule,
    pub micro_batches: usize,
    pub engine_pool: usize,
    pub data_dir: PathBuf,
    pub hook: Arc<dyn StepHook>,
}

#[allow(deprecated)]
impl TrainOptions {
    pub fn new(model: &str, topo: Topology, data_dir: PathBuf) -> TrainOptions {
        TrainOptions {
            model: model.into(),
            topo,
            mode: ShardingMode::Epso,
            run: RunConfig::default(),
            fur: false,
            ep_comm: EpComm::Allgather,
            schedule: Schedule::OneFOneB,
            micro_batches: 2,
            engine_pool: 2,
            data_dir,
            hook: Arc::new(NoHook),
        }
    }
}

#[allow(deprecated)]
impl From<TrainOptions> for JobSpec {
    fn from(o: TrainOptions) -> JobSpec {
        let mut plan = ParallelismPlan::new(o.topo);
        // the old default mode was EPSO everywhere; at ep = 1 that is
        // numerically identical to SO, so resolve it implicitly instead
        // of tripping the explicit-EPSO check
        plan.mode = if o.topo.ep > 1 { o.mode } else { ShardingMode::So };
        plan.mode_explicit = false;
        plan.schedule = o.schedule;
        plan.micro_batches = o.micro_batches;
        plan.ep_comm = o.ep_comm;
        JobSpec {
            model: o.model,
            plan,
            run: o.run,
            fur: o.fur,
            engine_pool: o.engine_pool,
            data_dir: o.data_dir,
            // the legacy bag cannot distinguish a default NoHook from an
            // installed one; it predates bf16, so always invoking is safe
            hooked: true,
            hook: o.hook,
            data_trace: None,
            _built: (),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_plan_level_invariants() {
        let base = || JobSpec::new("mula-tiny").data_dir("/tmp/x");
        assert!(base().topology(1, 2, 2).micro_batches(4).build().is_ok());

        let e = base().topology(1, 2, 2).micro_batches(0).build().unwrap_err();
        assert!(e.to_string().contains("[micro-batches]"), "{e}");

        let e = base().topology(2, 2, 1).world_size(8).build().unwrap_err();
        assert!(e.to_string().contains("[world-size]"), "{e}");

        let e = base()
            .topology(2, 1, 1)
            .sharding(ShardingMode::Epso)
            .build()
            .unwrap_err();
        assert!(e.to_string().contains("[sharding]"), "{e}");

        let e = JobSpec::new("m").topology(2, 1, 1).build().unwrap_err();
        assert!(e.to_string().contains("data_dir"), "{e}");

        let e = base()
            .topology(2, 1, 1)
            .overlap(true)
            .overlap_chunk(0)
            .build()
            .unwrap_err();
        assert!(e.to_string().contains("[overlap]"), "{e}");
        let ok = base().topology(2, 1, 1).overlap(true).build().unwrap();
        assert!(ok.plan.overlap && ok.plan.overlap_chunk > 0);

        let e = base()
            .topology(2, 1, 1)
            .checkpoint_dir("/tmp/ck")
            .ckpt_keep(1)
            .build()
            .unwrap_err();
        assert!(e.to_string().contains("[checkpoint]"), "{e}");
        let ok = base()
            .topology(2, 1, 1)
            .checkpoint_dir("/tmp/ck")
            .ckpt_every(5)
            .ckpt_async(false)
            .build()
            .unwrap();
        assert!(ok.plan.ckpt.enabled() && !ok.plan.ckpt.asynchronous);
        assert_eq!(ok.plan.ckpt.every, 5);
    }

    #[test]
    fn dtype_knob_threads_through() {
        let base = || JobSpec::new("m").data_dir("/tmp/x").topology(2, 1, 1);
        let s = base().dtype(Dtype::Bf16).build().unwrap();
        assert_eq!(s.plan.dtype, Dtype::Bf16);
        assert_eq!(s.reduce_dtype(), ReduceDtype::Bf16, "bf16 plans reduce in bf16");
        assert!(s.fingerprint().ends_with("/bf16"), "{}", s.fingerprint());
        // the default stays f32 with legacy fingerprints
        let d = base().build().unwrap();
        assert_eq!(d.plan.dtype, Dtype::F32);
        assert_eq!(d.reduce_dtype(), ReduceDtype::F32);
        assert!(!d.fingerprint().contains("bf16"));
        // bf16 + overlap is rejected at build time
        let e = base().dtype(Dtype::Bf16).overlap(true).build().unwrap_err();
        assert!(e.to_string().contains("[dtype]"), "{e}");
    }

    #[test]
    fn data_pipeline_knobs_thread_through() {
        let s = JobSpec::new("m")
            .data_dir("/tmp/x")
            .topology(2, 1, 1)
            .data_seed(99)
            .data_prefetch(false)
            .data_epochs(3)
            .build()
            .unwrap();
        assert_eq!(s.run.data_seed, 99);
        assert!(!s.plan.prefetch);
        assert_eq!(s.plan.data_epochs, 3);
        // defaults: prefetch on, unbounded epoch budget, stable data seed
        let d = JobSpec::new("m").data_dir("/tmp/x").topology(2, 1, 1).build().unwrap();
        assert!(d.plan.prefetch);
        assert_eq!(d.plan.data_epochs, 0);
        assert_eq!(d.run.data_seed, 7);
        assert!(d.data_trace.is_none());
    }

    #[test]
    fn node_size_knob_threads_through_and_is_validated() {
        let base = || JobSpec::new("m").data_dir("/tmp/x");
        // order-independent with .topology(): the axes keep the knob
        let s = base().node_size(2).topology(4, 1, 1).build().unwrap();
        assert_eq!(s.topo().node_size, 2);
        assert!(s.fingerprint().ends_with("/nodes2"), "{}", s.fingerprint());
        // default: flat placement, legacy fingerprint
        let d = base().topology(4, 1, 1).build().unwrap();
        assert_eq!(d.topo().node_size, 1);
        assert!(!d.fingerprint().contains("nodes"), "{}", d.fingerprint());
        // world not divisible by node size → [topology]
        let e = base().topology(4, 1, 1).node_size(3).build().unwrap_err();
        assert!(e.to_string().contains("[topology]"), "{e}");
        // zero is an axis-sanity failure, not a divide-by-zero
        let e = base().topology(4, 1, 1).node_size(0).build().unwrap_err();
        assert!(e.to_string().contains("[topology]"), "{e}");
    }

    #[test]
    fn default_sharding_tracks_ep_degree() {
        let d = |dp, ep, pp| {
            JobSpec::new("m")
                .data_dir("/tmp/x")
                .topology(dp, ep, pp)
                .build()
                .unwrap()
                .plan
                .mode
        };
        assert_eq!(d(2, 1, 1), ShardingMode::So);
        assert_eq!(d(1, 2, 1), ShardingMode::Epso);
        assert_eq!(d(1, 2, 2), ShardingMode::Epso);
    }

    #[test]
    #[allow(deprecated)]
    fn train_options_shim_converts() {
        let o = TrainOptions::new(
            "mula-tiny",
            Topology::grid(1, 2, 1),
            PathBuf::from("/tmp/x"),
        );
        let spec: JobSpec = o.into();
        assert_eq!(spec.topo(), Topology::grid(1, 2, 1));
        assert_eq!(spec.plan.mode, ShardingMode::Epso);
        // at ep = 1 the legacy EPSO default resolves to SO
        let o = TrainOptions::new("mula-tiny", Topology::dp_only(2), PathBuf::from("/tmp/x"));
        let spec: JobSpec = o.into();
        assert_eq!(spec.plan.mode, ShardingMode::So);
        assert!(spec.plan.validate_spec().is_ok());
    }
}
