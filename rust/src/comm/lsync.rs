//! Loom-aware synchronization shim for the comm fabric.
//!
//! Everything that participates in the rendezvous / lane protocols —
//! mutexes, condvars, the poison flag, thread spawns — goes through this
//! module so the `--cfg loom` build swaps in [loom]'s model-checked
//! primitives while release builds compile to the plain `std` types with
//! zero overhead. Pure *accounting* atomics (byte counters, op counters)
//! deliberately stay `std::sync::atomic` even under loom: they carry no
//! happens-before edges the protocol relies on, and keeping them out of
//! the model keeps the interleaving state space tractable.
//!
//! This is the **one** place in the crate allowed to call a bare
//! `thread::spawn` (loom's spawn has no named builder) — `optimus lint`
//! exempts exactly this file from the named-spawn rule.
//!
//! [loom]: https://docs.rs/loom

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::atomic::AtomicBool;
#[cfg(not(loom))]
pub use std::sync::atomic::AtomicBool;

#[cfg(loom)]
pub use loom::thread::JoinHandle;
#[cfg(not(loom))]
pub use std::thread::JoinHandle;

/// Spawn a worker thread. Release builds use a **named** builder (thread
/// names are load bearing: stall dumps and panic reports attribute work
/// by thread name); loom models have no thread names, so the label is
/// accepted and dropped there.
#[cfg(not(loom))]
pub fn spawn_named<F, T>(name: &str, f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .unwrap_or_else(|e| panic!("spawning thread `{name}`: {e}"))
}

#[cfg(loom)]
pub fn spawn_named<F, T>(_name: &str, f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    loom::thread::spawn(f)
}
