//! Seeded, epoch-aware deterministic **blockwise shuffle** of the
//! instance stream.
//!
//! The offline pipeline already writes shards in one fixed shuffled
//! order (paper §4). Training additionally needs *epoch-aware* shuffling
//! — a fresh order every pass over the data — without giving up the
//! paper's contiguous-read property (mmap'd shard reads that walk
//! forward through memory). [`ShuffledIndex`] reconciles the two:
//!
//! * the epoch's instances are grouped into fixed-size **blocks** of
//!   [`SHUFFLE_BLOCK`] consecutive raw instances;
//! * each epoch draws an independent permutation of the *blocks* from
//!   [`crate::util::prng::Prng`] (`Prng::new(seed).fork(epoch)`), so the
//!   whole order is reproducible from the seed alone;
//! * *within* a block, stream order equals raw order — consecutive
//!   stream positions read consecutive mmap'd instances.
//!
//! The map is a pure function `(seed, n, block) × cursor → (epoch,
//! instance)`: any rank, on any topology, at any point in the run, maps
//! a global stream position to the same instance — the property the
//! elastic-resume token cursor (DESIGN.md §7) relies on.

use crate::util::prng::Prng;
use std::sync::{Arc, Mutex};

/// Default shuffle-block length in instances. Large enough that shard
/// reads stay effectively sequential, small enough that the block
/// permutation decorrelates neighbouring corpus regions even on small
/// datasets.
pub const SHUFFLE_BLOCK: usize = 64;

/// One epoch's materialized block permutation.
struct EpochPerm {
    epoch: u64,
    /// block ids in stream order
    perm: Vec<u64>,
    /// position of the (possibly short) last block id within `perm`
    short_pos: usize,
}

/// Deterministic cursor → (epoch, instance) map. Cheap to share
/// (`Send + Sync`); the per-epoch block permutation is cached behind a
/// mutex and rebuilt only when the epoch advances.
pub struct ShuffledIndex {
    /// instances per epoch
    n: u64,
    block: u64,
    seed: u64,
    /// two-slot permutation cache: a step whose positions straddle an
    /// epoch boundary has rank threads and prefetch producers mapping
    /// both epochs concurrently — one slot per epoch keeps the boundary
    /// from thrashing O(n_blocks) rebuilds under the lock
    cache: Mutex<[Option<Arc<EpochPerm>>; 2]>,
}

impl ShuffledIndex {
    /// Index over `n` instances with the given shuffle `seed` and the
    /// default block length.
    pub fn new(n: usize, seed: u64) -> ShuffledIndex {
        ShuffledIndex::with_block(n, seed, SHUFFLE_BLOCK)
    }

    /// Index with an explicit block length (tests; `block >= 1`).
    pub fn with_block(n: usize, seed: u64, block: usize) -> ShuffledIndex {
        assert!(n > 0, "ShuffledIndex needs a non-empty dataset");
        assert!(block > 0, "ShuffledIndex needs a positive block length");
        ShuffledIndex {
            n: n as u64,
            block: block as u64,
            seed,
            cache: Mutex::new([None, None]),
        }
    }

    /// Instances per epoch.
    pub fn epoch_len(&self) -> u64 {
        self.n
    }

    fn blocks(&self) -> u64 {
        self.n.div_ceil(self.block)
    }

    /// Length of the last block (short when `block` does not divide `n`).
    fn short_len(&self) -> u64 {
        self.n - (self.blocks() - 1) * self.block
    }

    fn epoch_perm(&self, epoch: u64) -> Arc<EpochPerm> {
        let mut cache = crate::util::lock(&self.cache);
        for slot in cache.iter().flatten() {
            if slot.epoch == epoch {
                return Arc::clone(slot);
            }
        }
        let nb = self.blocks();
        let perm = Prng::new(self.seed).fork(epoch).permutation(nb as usize);
        let short_id = nb - 1;
        let short_pos = perm.iter().position(|&b| b == short_id).unwrap();
        let p = Arc::new(EpochPerm { epoch, perm, short_pos });
        // keep the previous epoch around: boundary steps map both
        cache[1] = cache[0].take();
        cache[0] = Some(Arc::clone(&p));
        p
    }

    /// Start of `perm[j]`'s run within the epoch's stream: `j` full
    /// blocks, minus the short block's deficit once it has passed.
    fn run_start(&self, p: &EpochPerm, j: u64) -> u64 {
        let deficit = if j > p.short_pos as u64 { self.block - self.short_len() } else { 0 };
        j * self.block - deficit
    }

    /// Map a global stream cursor to `(epoch, instance id)`. Total over
    /// all of `u64` — budget enforcement lives in
    /// [`TokenStream`](super::TokenStream), not here.
    pub fn map(&self, cursor: u64) -> (u64, usize) {
        let epoch = cursor / self.n;
        let pos = cursor % self.n;
        let p = self.epoch_perm(epoch);
        // largest j with run_start(j) <= pos (run starts are strictly
        // increasing, so binary search over the closed form)
        let (mut lo, mut hi) = (0u64, self.blocks() - 1);
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if self.run_start(&p, mid) <= pos {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let inst = p.perm[lo as usize] * self.block + (pos - self.run_start(&p, lo));
        (epoch, inst as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn epoch_order(idx: &ShuffledIndex, epoch: u64) -> Vec<usize> {
        let n = idx.epoch_len();
        (0..n)
            .map(|p| {
                let (e, i) = idx.map(epoch * n + p);
                assert_eq!(e, epoch);
                i
            })
            .collect()
    }

    #[test]
    fn each_epoch_is_a_permutation() {
        for (n, block) in [(10usize, 4usize), (64, 64), (65, 64), (128, 16), (7, 64), (1, 1)] {
            let idx = ShuffledIndex::with_block(n, 42, block);
            for epoch in 0..3u64 {
                let mut order = epoch_order(&idx, epoch);
                order.sort_unstable();
                assert_eq!(order, (0..n).collect::<Vec<_>>(), "n={n} block={block} epoch={epoch}");
            }
        }
    }

    #[test]
    fn blocks_stay_contiguous() {
        // consecutive positions inside a block read consecutive raw
        // instances — the contiguous mmap-read property
        let idx = ShuffledIndex::with_block(130, 5, 16);
        let order = epoch_order(&idx, 0);
        let mut breaks = 0;
        for w in order.windows(2) {
            if w[1] != w[0] + 1 {
                breaks += 1;
            }
        }
        // at most one discontinuity per block boundary
        assert!(breaks <= 130usize.div_ceil(16), "{breaks} breaks in {order:?}");
    }

    #[test]
    fn reproducible_from_seed_alone_and_epochs_differ() {
        let a = ShuffledIndex::with_block(200, 11, 16);
        let b = ShuffledIndex::with_block(200, 11, 16);
        let c = ShuffledIndex::with_block(200, 12, 16);
        assert_eq!(epoch_order(&a, 0), epoch_order(&b, 0));
        assert_eq!(epoch_order(&a, 5), epoch_order(&b, 5));
        assert_ne!(epoch_order(&a, 0), epoch_order(&c, 0), "seed must reorder");
        assert_ne!(epoch_order(&a, 0), epoch_order(&a, 1), "epochs must reshuffle");
    }

    #[test]
    fn cache_follows_epoch_hops() {
        // alternate between epochs (the boundary-step access pattern):
        // the two-slot cache must serve both without staleness, and a
        // third epoch must evict cleanly
        let idx = ShuffledIndex::with_block(50, 3, 8);
        let e0 = epoch_order(&idx, 0);
        let e1 = epoch_order(&idx, 1);
        let e2 = epoch_order(&idx, 2);
        for p in 0..50u64 {
            assert_eq!(idx.map(p).1, e0[p as usize]);
            assert_eq!(idx.map(50 + p).1, e1[p as usize]);
            assert_eq!(idx.map(100 + p).1, e2[p as usize]);
        }
    }
}
