//! Offline preprocessing: **Tokenization, Shuffling, Sharding** (paper §4).
//!
//! 1. *Tokenization*: each data file Dᵢ becomes a token array Tᵢ
//!    (documents joined with EOS). With context size C, Dᵢ yields
//!    Nᵢ = |Tᵢ|/C training instances.
//! 2. *Shuffling*: a global permutation P over all N = ΣNᵢ instances.
//! 3. *Sharding*: instances are gathered in permutation order and written
//!    to `.oshard` files that the Dataset mmaps lazily — so training reads
//!    are contiguous.
//!
//! Shard format (little-endian):
//! `magic "OSHD" | u32 version | u32 context | u64 n_instances |
//!  u32 tokens[n_instances * context]`

use super::tokenizer::Tokenizer;
use crate::util::prng::Prng;
use crate::Result;
use anyhow::{anyhow, Context};
use std::io::Write;
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"OSHD";
pub const VERSION: u32 = 1;

#[derive(Debug, Clone)]
pub struct PreprocessStats {
    pub n_files: usize,
    pub total_tokens: usize,
    pub n_instances: usize,
    pub n_shards: usize,
}

/// Run the full pipeline over in-memory data files, writing shards into
/// `out_dir`. `instances_per_shard` bounds shard size.
pub fn preprocess(
    files: &[Vec<String>],
    context: usize,
    seed: u64,
    out_dir: &Path,
    instances_per_shard: usize,
) -> Result<PreprocessStats> {
    std::fs::create_dir_all(out_dir)?;
    let tok = Tokenizer::new();

    // 1. tokenization: per-file token arrays
    let token_arrays: Vec<Vec<u32>> =
        files.iter().map(|docs| tok.tokenize_file(docs)).collect();
    let total_tokens: usize = token_arrays.iter().map(|t| t.len()).sum();

    // instance index: (file, start) for each contiguous C-token window
    let mut instances = Vec::new();
    for (fi, t) in token_arrays.iter().enumerate() {
        let n_i = t.len() / context; // Ni = Ti / C
        for j in 0..n_i {
            instances.push((fi, j * context));
        }
    }
    let n = instances.len();
    if n == 0 {
        return Err(anyhow!("corpus too small for context {context}"));
    }

    // 2. shuffling: permutation P of size N
    let mut rng = Prng::new(seed);
    let perm = rng.permutation(n);

    // 3. sharding: gather in permutation order, write shard files
    let mut shard_id = 0usize;
    let mut written = 0usize;
    while written < n {
        let count = (n - written).min(instances_per_shard);
        let path = out_dir.join(format!("shard-{shard_id:05}.oshard"));
        let mut buf = Vec::with_capacity(24 + count * context * 4);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(context as u32).to_le_bytes());
        buf.extend_from_slice(&(count as u64).to_le_bytes());
        for k in 0..count {
            let (fi, start) = instances[perm[written + k] as usize];
            let window = &token_arrays[fi][start..start + context];
            for t in window {
                buf.extend_from_slice(&t.to_le_bytes());
            }
        }
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("creating {path:?}"))?;
        f.write_all(&buf)?;
        written += count;
        shard_id += 1;
    }

    Ok(PreprocessStats {
        n_files: files.len(),
        total_tokens,
        n_instances: n,
        n_shards: shard_id,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("optimus-pp-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn pipeline_writes_shards() {
        let dir = tmpdir("basic");
        let files = corpus::data_files(3, 4, 6);
        let st = preprocess(&files, 64, 7, &dir, 32).unwrap();
        assert!(st.n_instances > 32, "{st:?}");
        assert!(st.n_shards >= 2);
        let entries: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(entries.len(), st.n_shards);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shuffling_changes_order_but_not_content() {
        let dir_a = tmpdir("sa");
        let dir_b = tmpdir("sb");
        let files = corpus::data_files(3, 2, 4);
        preprocess(&files, 32, 1, &dir_a, 1_000_000).unwrap();
        preprocess(&files, 32, 2, &dir_b, 1_000_000).unwrap();
        let a = std::fs::read(dir_a.join("shard-00000.oshard")).unwrap();
        let b = std::fs::read(dir_b.join("shard-00000.oshard")).unwrap();
        assert_eq!(a.len(), b.len());
        assert_ne!(a, b, "different shuffle seeds must reorder instances");
        std::fs::remove_dir_all(&dir_a).unwrap();
        std::fs::remove_dir_all(&dir_b).unwrap();
    }
}
