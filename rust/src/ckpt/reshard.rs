//! Topology-elastic resume: re-slice a saved sharded checkpoint onto a
//! (possibly different) `ParallelismPlan`.
//!
//! Every shard in a committed checkpoint records its `(global_start,
//! len)` runs in the global flat parameter coordinate system, so a
//! resume does not need the saving topology at all: a dp2×ep2 EPSO
//! checkpoint resumes under dp4 (and vice versa) by gathering each new
//! rank's segment shards out of the saved run union. This replaces the
//! old `ensure_plan` hard rejection ("resharding is out of scope") with
//! a validated reshard path.
//!
//! True state mismatches still fail loudly, with stable
//! `checkpoint resume failed [<check>]` strings that
//! [`crate::ft::classify`] maps to a non-relaunchable `Config` failure:
//! `[model]` (different model), `[param-count]` (saved shards don't tile
//! the model's parameter space), `[coverage]` (a requested range has no
//! saved shard), `[checksum]`/`[manifest]` (corrupt files), `[data-seed]`
//! (the harness refuses a resume whose `--data-seed` differs from the
//! one the saved token cursor was consumed under). A checkpoint
//! at or past the step budget is *not* an error — the resumed run simply
//! has zero steps left (so a relaunch after a final-step crash, or a
//! re-run of a completed command, still loads cleanly).

use super::checkpointer::SavedCheckpoint;
use super::state::{GlobalRun, StatePart};
use super::{bytes_to_f32s, bytes_to_u16s, checksum};
use crate::ft::checks;
use crate::util::bf16s_to_f32s;
use crate::Result;
use std::collections::BTreeMap;

/// One loaded shard run: a global interval and its data.
struct LoadedRun {
    global_start: usize,
    data: Vec<f32>,
}

/// A fully loaded, checksum-verified checkpoint, indexed by component
/// (`"params"`, `"adam_m"`, `"adam_v"`) in global coordinates — the
/// object every resuming rank gathers its re-sliced state from.
pub struct ResumeState {
    step: usize,
    plan: String,
    /// element dtype the `params` shards were saved in ("f32"/"bf16");
    /// legacy manifests without the field read back as "f32"
    param_dtype: String,
    comps: BTreeMap<String, Vec<LoadedRun>>,
    pub scalars: BTreeMap<String, f64>,
}

impl ResumeState {
    /// Load and verify every shard of `saved`.
    pub fn open(saved: &SavedCheckpoint) -> Result<ResumeState> {
        let mut comps: BTreeMap<String, Vec<LoadedRun>> = BTreeMap::new();
        let mut param_dtype: Option<String> = None;
        for p in &saved.parts {
            let bytes = std::fs::read(saved.dir.join(&p.file)).map_err(|_| {
                checks::err(
                    checks::RESUME,
                    "manifest",
                    format!("shard file `{}` is missing from {:?}", p.file, saved.dir),
                )
            })?;
            if format!("{:016x}", checksum(&bytes)) != p.checksum {
                return Err(checks::err(
                    checks::RESUME,
                    "checksum",
                    format!("shard `{}` is corrupt", p.file),
                ));
            }
            // decode at the part's recorded storage width; bf16 shards
            // decode exactly into the f32 working representation
            let vals = match p.dtype.as_str() {
                "bf16" => bytes_to_u16s(&bytes).map(|w| bf16s_to_f32s(&w)),
                _ => bytes_to_f32s(&bytes),
            }
            .map_err(|e| {
                checks::err(checks::RESUME, "checksum", format!("shard `{}`: {e}", p.file))
            })?;
            if StatePart::component(&p.name) == "params" {
                match &param_dtype {
                    None => param_dtype = Some(p.dtype.clone()),
                    Some(d) if d != &p.dtype => {
                        return Err(checks::err(
                            checks::RESUME,
                            "dtype",
                            format!("parameter shards mix dtypes `{d}` and `{}`", p.dtype),
                        ))
                    }
                    Some(_) => {}
                }
            }
            let total: usize = p.runs.iter().map(|r| r.1).sum();
            if vals.len() != total {
                return Err(checks::err(
                    checks::RESUME,
                    "manifest",
                    format!(
                        "shard `{}` holds {} values, its manifest runs describe {total}",
                        p.file,
                        vals.len()
                    ),
                ));
            }
            let comp = StatePart::component(&p.name).to_string();
            let runs = comps.entry(comp).or_default();
            let mut off = 0usize;
            for &(g, n) in &p.runs {
                runs.push(LoadedRun { global_start: g, data: vals[off..off + n].to_vec() });
                off += n;
            }
        }
        for runs in comps.values_mut() {
            runs.sort_by_key(|r| r.global_start);
        }
        Ok(ResumeState {
            step: saved.step,
            plan: saved.plan.clone(),
            param_dtype: param_dtype.unwrap_or_else(|| "f32".to_string()),
            comps,
            scalars: saved.scalars.clone(),
        })
    }

    /// Element dtype the parameter shards were saved in (`"f32"` /
    /// `"bf16"`).
    pub fn param_dtype(&self) -> &str {
        &self.param_dtype
    }

    /// `[dtype]` preflight: the resuming plan must run the dtype the
    /// parameter shards were saved in — silently up- or down-converting
    /// params at resume would shift the loss trajectory without any
    /// record of it.
    pub fn validate_dtype(&self, plan_dtype: &str) -> Result<()> {
        if self.param_dtype != plan_dtype {
            return Err(checks::err(
                checks::RESUME,
                "dtype",
                format!(
                    "checkpoint holds `{}` parameter shards, the resuming plan is \
                     --dtype {plan_dtype}",
                    self.param_dtype
                ),
            ));
        }
        Ok(())
    }

    /// Step the checkpoint was captured after; resume continues at
    /// `step() + 1`.
    pub fn step(&self) -> usize {
        self.step
    }

    /// Plan fingerprint recorded at save time.
    pub fn plan(&self) -> &str {
        &self.plan
    }

    /// Model name recorded in the fingerprint (its first segment).
    pub fn model(&self) -> &str {
        self.plan.split('/').next().unwrap_or("")
    }

    /// Elastic-resume preflight: the checkpoint must describe the same
    /// *model* (the same global parameter space); topology, sharding
    /// mode, schedule, step budget and every other execution knob may
    /// differ freely.
    pub fn validate(&self, model: &str, param_count: usize) -> Result<()> {
        if self.model() != model {
            return Err(checks::err(
                checks::RESUME,
                "model",
                format!(
                    "checkpoint was written for `{}` (plan `{}`), this job trains \
                     `{model}` — a different model cannot be resharded",
                    self.model(),
                    self.plan
                ),
            ));
        }
        let cov = self.coverage("params");
        if cov != vec![(0, param_count)] {
            return Err(checks::err(
                checks::RESUME,
                "param-count",
                format!(
                    "saved parameter shards cover {cov:?}, the model needs exactly \
                     [(0, {param_count})]"
                ),
            ));
        }
        Ok(())
    }

    /// The saved AdamW bias-correction counter, if recorded (every rank
    /// and segment records the same value; `max` is defensive). Restores
    /// use it instead of re-deriving from the step index, so a future
    /// optimizer-step/train-step decoupling (gradient accumulation)
    /// cannot silently resume with a wrong counter.
    pub fn adam_step(&self) -> Option<u64> {
        self.scalars
            .iter()
            .filter(|(k, _)| k.contains(".adam_t"))
            .map(|(_, v)| *v as u64)
            .max()
    }

    /// The data-shuffle seed the saved cursor was consumed under, if
    /// recorded. The cursor is only meaningful under the same shuffle:
    /// the harness refuses a resume whose `--data-seed` differs
    /// (`checkpoint resume failed [data-seed]`) instead of silently
    /// re-reading/skipping instances. Legacy checkpoints return `None`
    /// (unchecked).
    pub fn data_seed(&self) -> Option<u64> {
        self.scalars
            .iter()
            .filter(|(k, _)| k.ends_with(".data.seed"))
            .map(|(_, v)| *v as u64)
            .max()
    }

    /// The saved global token cursor — instances consumed when the
    /// snapshot was taken — if recorded (every rank records the same
    /// value; `max` is defensive, like [`ResumeState::adam_step`]). A
    /// resumed run continues the data stream at exactly this position
    /// under any topology; checkpoints predating the cursor return
    /// `None` and the harness falls back to the legacy step-derived
    /// position. (Scalars ride the manifest as f64 — exact for cursors
    /// below 2^53 instances, far past any run this crate drives.)
    pub fn data_cursor(&self) -> Option<u64> {
        self.scalars
            .iter()
            .filter(|(k, _)| k.ends_with(".data.cursor"))
            .map(|(_, v)| *v as u64)
            .max()
    }

    /// Merged `[start, end)` global coverage of a component's shards.
    fn coverage(&self, comp: &str) -> Vec<(usize, usize)> {
        let Some(runs) = self.comps.get(comp) else { return Vec::new() };
        let mut out: Vec<(usize, usize)> = Vec::new();
        for r in runs {
            // runs are sorted by global_start; overlaps (SO-replicated
            // segments) merge away
            let (s, e) = (r.global_start, r.global_start + r.data.len());
            match out.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => out.push((s, e)),
            }
        }
        out
    }

    /// Re-slice: fill a local buffer of `local_len` elements, where each
    /// of `runs` maps a local range onto a global interval. The saved
    /// shards may come from any topology; overlapping saved runs
    /// (SO-replicated segments) hold identical bytes, so any cover wins.
    pub fn gather(&self, comp: &str, runs: &[GlobalRun], local_len: usize) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; local_len];
        let saved = self.comps.get(comp).ok_or_else(|| {
            checks::err(checks::RESUME, "coverage", format!("checkpoint has no `{comp}` shards"))
        })?;
        for want in runs {
            let mut pos = want.global_start;
            let end = want.global_start + want.len;
            while pos < end {
                let r = saved
                    .iter()
                    .find(|r| r.global_start <= pos && pos < r.global_start + r.data.len())
                    .ok_or_else(|| {
                        checks::err(
                            checks::RESUME,
                            "coverage",
                            format!(
                                "`{comp}` global range [{pos}, {end}) is not covered \
                                 by any saved shard"
                            ),
                        )
                    })?;
                let take = (end - pos).min(r.global_start + r.data.len() - pos);
                let src = &r.data[pos - r.global_start..pos - r.global_start + take];
                let dst = want.local_start + (pos - want.global_start);
                out[dst..dst + take].copy_from_slice(src);
                pos += take;
            }
        }
        Ok(out)
    }

    /// The full global parameter vector — the broadcast seed on resume
    /// (every rank then extracts its local view exactly as on a fresh
    /// start, which is what makes resume plan-agnostic).
    pub fn assemble_params(&self, param_count: usize) -> Result<Vec<f32>> {
        self.gather(
            "params",
            &[GlobalRun { local_start: 0, global_start: 0, len: param_count }],
            param_count,
        )
    }
}
