//! Pipeline-parallel microbatch schedules: GPipe, 1F1B, interleaved-1F1B
//! (paper §1: "We implemented gpipe, 1f1b, and interleaved-1f1b
//! schedules").
//!
//! A schedule is pure data — `Vec<PipeOp>` per stage — so correctness
//! (every microbatch forwarded before its backward, bounded in-flight
//! count, chunk ordering) is property-tested without running any HLO.
//! The runnable PP engine executes GPipe and 1F1B; interleaved-1F1B
//! (which requires ≥2 model chunks per rank) is exercised by the cluster
//! performance model.

/// One unit of work for a stage. `chunk` is the model-chunk index
/// (always 0 except interleaved schedules).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipeOp {
    Fwd { mb: usize, chunk: usize },
    Bwd { mb: usize, chunk: usize },
}

/// P2p sequence-id slots reserved per step. This single constant pins
/// the cross-cutting invariant together: [`seq_id`] strides by it (used
/// by every pipeline engine) and the plan's `[micro-batches]` validation
/// bounds `micro_batches` by it — so ids can never collide across steps.
pub const SEQ_SLOTS: usize = 64;

/// The p2p sequence id for (step, microbatch) on any tag.
pub fn seq_id(step: usize, mb: usize) -> u64 {
    debug_assert!(mb < SEQ_SLOTS);
    (step * SEQ_SLOTS + mb) as u64
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    GPipe,
    OneFOneB,
    Interleaved1F1B { chunks: usize },
}

impl Schedule {
    pub fn name(&self) -> &'static str {
        match self {
            Schedule::GPipe => "gpipe",
            Schedule::OneFOneB => "1f1b",
            Schedule::Interleaved1F1B { .. } => "interleaved-1f1b",
        }
    }

    /// Parse a CLI schedule name (the runnable choices).
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "gpipe" => Some(Schedule::GPipe),
            "1f1b" => Some(Schedule::OneFOneB),
            _ => None,
        }
    }

    /// Op list for `stage` of `stages`, with `micro` microbatches.
    pub fn ops(&self, stage: usize, stages: usize, micro: usize) -> Vec<PipeOp> {
        match *self {
            Schedule::GPipe => gpipe(micro),
            Schedule::OneFOneB => one_f_one_b(stage, stages, micro),
            Schedule::Interleaved1F1B { chunks } => {
                interleaved(stage, stages, micro, chunks)
            }
        }
    }

    /// Peak number of stashed forward activations for `stage` — the
    /// memory the schedule trades (GPipe stashes all M, 1F1B at most
    /// `stages - stage`).
    pub fn peak_in_flight(&self, stage: usize, stages: usize, micro: usize) -> usize {
        let mut live = 0usize;
        let mut peak = 0usize;
        for op in self.ops(stage, stages, micro) {
            match op {
                PipeOp::Fwd { .. } => {
                    live += 1;
                    peak = peak.max(live);
                }
                PipeOp::Bwd { .. } => live = live.saturating_sub(1),
            }
        }
        peak
    }
}

/// GPipe: all forwards, then all backwards in reverse microbatch order.
fn gpipe(micro: usize) -> Vec<PipeOp> {
    let mut v: Vec<PipeOp> =
        (0..micro).map(|mb| PipeOp::Fwd { mb, chunk: 0 }).collect();
    v.extend((0..micro).rev().map(|mb| PipeOp::Bwd { mb, chunk: 0 }));
    v
}

/// Non-interleaved 1F1B (PipeDream-flush): `stages - stage - 1` warmup
/// forwards, steady 1F1B phase, cooldown backwards. Backwards retire in
/// forward order (FIFO).
fn one_f_one_b(stage: usize, stages: usize, micro: usize) -> Vec<PipeOp> {
    let warmup = (stages - stage - 1).min(micro);
    let mut v = Vec::with_capacity(2 * micro);
    let mut next_f = 0usize;
    let mut next_b = 0usize;
    for _ in 0..warmup {
        v.push(PipeOp::Fwd { mb: next_f, chunk: 0 });
        next_f += 1;
    }
    while next_f < micro {
        v.push(PipeOp::Fwd { mb: next_f, chunk: 0 });
        next_f += 1;
        v.push(PipeOp::Bwd { mb: next_b, chunk: 0 });
        next_b += 1;
    }
    while next_b < micro {
        v.push(PipeOp::Bwd { mb: next_b, chunk: 0 });
        next_b += 1;
    }
    v
}

/// Interleaved 1F1B (Megatron-LM): each stage owns `chunks` model chunks;
/// microbatches are processed in groups of `stages`, cycling chunks on a
/// "virtual pipeline". Simplified faithful variant: warmup
/// `(chunks-1)*stages + stages-stage-1` forwards.
fn interleaved(stage: usize, stages: usize, micro: usize, chunks: usize) -> Vec<PipeOp> {
    assert!(chunks >= 1);
    let total = micro * chunks;
    // forward order: rounds of `stages` microbatches per chunk
    let mut fwd_order = Vec::with_capacity(total);
    let groups = (micro + stages - 1) / stages;
    for g in 0..groups {
        for c in 0..chunks {
            for m in 0..stages {
                let mb = g * stages + m;
                if mb < micro {
                    fwd_order.push((mb, c));
                }
            }
        }
    }
    // backward order mirrors forward order with chunks reversed
    let mut bwd_order = Vec::with_capacity(total);
    for g in 0..groups {
        for c in (0..chunks).rev() {
            for m in 0..stages {
                let mb = g * stages + m;
                if mb < micro {
                    bwd_order.push((mb, c));
                }
            }
        }
    }
    let warmup = ((chunks - 1) * stages + stages - stage - 1).min(total);
    let mut v = Vec::with_capacity(2 * total);
    let mut fi = 0usize;
    let mut bi = 0usize;
    for _ in 0..warmup {
        let (mb, c) = fwd_order[fi];
        v.push(PipeOp::Fwd { mb, chunk: c });
        fi += 1;
    }
    while fi < total {
        let (mb, c) = fwd_order[fi];
        v.push(PipeOp::Fwd { mb, chunk: c });
        fi += 1;
        let (mb, c) = bwd_order[bi];
        v.push(PipeOp::Bwd { mb, chunk: c });
        bi += 1;
    }
    while bi < total {
        let (mb, c) = bwd_order[bi];
        v.push(PipeOp::Bwd { mb, chunk: c });
        bi += 1;
    }
    v
}

/// Pipeline bubble fraction for the analytic model:
/// (stages-1)/(micro + stages - 1) for GPipe/1F1B; interleaving divides
/// the bubble by the chunk count (Megatron-LM eq. 2).
pub fn bubble_fraction(s: Schedule, stages: usize, micro: usize) -> f64 {
    let base = (stages - 1) as f64 / (micro as f64 + stages as f64 - 1.0);
    match s {
        Schedule::Interleaved1F1B { chunks } => base / chunks as f64,
        _ => base,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;

    fn check_complete(s: Schedule, stages: usize, micro: usize) {
        let chunks = match s {
            Schedule::Interleaved1F1B { chunks } => chunks,
            _ => 1,
        };
        for stage in 0..stages {
            let ops = s.ops(stage, stages, micro);
            assert_eq!(ops.len(), 2 * micro * chunks, "{s:?} st{stage}");
            for mb in 0..micro {
                for c in 0..chunks {
                    let f = ops
                        .iter()
                        .position(|o| *o == PipeOp::Fwd { mb, chunk: c })
                        .expect("missing fwd");
                    let b = ops
                        .iter()
                        .position(|o| *o == PipeOp::Bwd { mb, chunk: c })
                        .expect("missing bwd");
                    assert!(f < b, "{s:?} stage {stage}: bwd before fwd for mb {mb}");
                }
            }
        }
    }

    #[test]
    fn all_schedules_complete() {
        for stages in [1usize, 2, 4] {
            for micro in [1usize, 2, 4, 8] {
                check_complete(Schedule::GPipe, stages, micro);
                check_complete(Schedule::OneFOneB, stages, micro);
                check_complete(Schedule::Interleaved1F1B { chunks: 2 }, stages, micro);
            }
        }
    }

    #[test]
    fn one_f_one_b_bounds_activation_memory() {
        // 1F1B peak in-flight <= stages - stage; GPipe peaks at M
        for stages in [2usize, 4] {
            for micro in [4usize, 8, 16] {
                for stage in 0..stages {
                    let p1 = Schedule::OneFOneB.peak_in_flight(stage, stages, micro);
                    let pg = Schedule::GPipe.peak_in_flight(stage, stages, micro);
                    assert_eq!(pg, micro);
                    assert!(p1 <= stages - stage, "{p1} > {}", stages - stage);
                    if micro > stages - stage {
                        assert!(p1 < pg, "1f1b should beat gpipe memory");
                    }
                }
            }
        }
    }

    #[test]
    fn first_stage_warmup_is_longest() {
        let ops0 = Schedule::OneFOneB.ops(0, 4, 8);
        let leading_fwds =
            ops0.iter().take_while(|o| matches!(o, PipeOp::Fwd { .. })).count();
        assert_eq!(leading_fwds, 4); // warmup (stages-1) + first steady F
        let last = Schedule::OneFOneB.ops(3, 4, 8);
        assert!(matches!(last[0], PipeOp::Fwd { .. }));
        assert!(matches!(last[1], PipeOp::Bwd { .. }), "last stage strict 1F1B");
    }

    #[test]
    fn bubble_shrinks_with_interleaving() {
        let b1 = bubble_fraction(Schedule::OneFOneB, 8, 16);
        let b2 = bubble_fraction(Schedule::Interleaved1F1B { chunks: 4 }, 8, 16);
        assert!(b2 < b1 / 3.0);
    }

    #[test]
    fn property_schedules_valid_under_random_shapes() {
        run_cases(60, |g| {
            let stages = *g.choose(&[1usize, 2, 3, 4, 6]);
            let micro = g.range(1, 17);
            let sched = match g.below(3) {
                0 => Schedule::GPipe,
                1 => Schedule::OneFOneB,
                _ => Schedule::Interleaved1F1B { chunks: g.range(1, 4) },
            };
            check_complete(sched, stages, micro);
        });
    }
}
