//! CI perf gate: mula-tiny DP and PP×EP micro-benches, serial vs
//! `--overlap` (the pipelined EPSO path), the checkpoint snapshot
//! stall (sync vs async sharded checkpointing), the data pipeline
//! (prefetch-on vs prefetch-off steps/sec + `data_wait_secs`), the
//! mixed-precision lanes (`--dtype f32` vs `bf16`: steps/sec, collective
//! bytes at wire width, checkpoint param-shard bytes), and the
//! hierarchical-collective lanes (flat vs `--node-size 3` on a 6-rank DP
//! mesh: steps/sec plus intra-node vs inter-node bytes), written to
//! `BENCH_PR8.json` at the repo root and gated against the committed
//! `ci/bench_baseline.json` — a steps/sec regression beyond the
//! baseline's tolerance (default 10%) exits nonzero so the `perf-gate`
//! workflow job fails. The byte accounting is deterministic, so those
//! gates are unconditional: bf16 collective traffic and checkpoint
//! param shards must land at ≤ 55% of the f32 lane's, and the
//! hierarchical lane's inter-node bytes at ≤ (n−1)/n of the flat
//! lane's (n = node size).
//!
//! The serving lane replays one seeded open-loop workload through
//! `optimus serve` under continuous and static batching and writes
//! `BENCH_SERVE.json` (p50/p99 TTFT, p50/p99 per-token latency,
//! tokens/sec, decode steps per mode). Greedy decode makes the
//! completion sets and decode-step counts deterministic, so those gates
//! are unconditional: both modes must produce identical completions,
//! leak zero KV pages, and continuous batching must finish in strictly
//! fewer decode steps — and at strictly higher tokens/sec — than static.
//!
//! Baseline entries that are absent, null or zero are *record-only*: the
//! run prints the measured value and passes, so the gate bootstraps on
//! the first CI run and tightens once a measured baseline is committed.
//!
//! Run locally from `rust/`: `cargo bench --bench perf_gate` (requires
//! built HLO artifacts; prints a SKIP note and exits 0 otherwise).
//! Overrides: `PERF_GATE_OUT` (output path), `PERF_GATE_BASELINE`.

use optimus::comm::Topology;
use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec, TrainReport};
use optimus::data::{corpus, preprocess};
use optimus::runtime::Dtype;
use optimus::serve::{self, BatchMode, ServeConfig, TrafficConfig};
use optimus::util::bench::Report;
use optimus::util::json::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;

struct Case {
    name: &'static str,
    topo: Topology,
}

const STEPS: usize = 14;

fn repo_root() -> PathBuf {
    let mut p = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.pop();
    p
}

fn out_path() -> PathBuf {
    std::env::var("PERF_GATE_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| repo_root().join("BENCH_PR8.json"))
}

fn baseline_path() -> PathBuf {
    std::env::var("PERF_GATE_BASELINE")
        .map(PathBuf::from)
        .unwrap_or_else(|_| repo_root().join("ci/bench_baseline.json"))
}

fn run_case(
    man: &Manifest,
    data: &std::path::Path,
    c: &Case,
    overlap: bool,
) -> optimus::Result<(f64, TrainReport)> {
    let spec = JobSpec::new("mula-tiny")
        .data_dir(data.to_path_buf())
        .topo(c.topo)
        .steps(STEPS)
        .warmup_steps(2)
        .micro_batches(2)
        .engine_pool(2)
        .overlap(overlap)
        .overlap_chunk(4096)
        .build()?;
    let r = coordinator::train(man, &spec)?;
    let sps = 1.0 / r.mean_step_secs().max(1e-9);
    Ok((sps, r))
}

fn breakdown_json(r: &TrainReport) -> Json {
    let mut m = BTreeMap::new();
    m.insert("fwd_bwd_secs".to_string(), Json::Num(r.breakdown.fwd_bwd_secs));
    m.insert("optimizer_secs".to_string(), Json::Num(r.breakdown.optimizer_secs));
    m.insert("comm_secs".to_string(), Json::Num(r.breakdown.comm_secs));
    m.insert("data_secs".to_string(), Json::Num(r.breakdown.data_secs));
    m.insert(
        "data_wait_secs".to_string(),
        Json::Num(r.breakdown.data_wait_secs),
    );
    m.insert(
        "data_prefetch_secs".to_string(),
        Json::Num(r.breakdown.data_prefetch_secs),
    );
    m.insert("queue_secs".to_string(), Json::Num(r.breakdown.queue_secs));
    m.insert("overlap_secs".to_string(), Json::Num(r.breakdown.overlap_secs));
    m.insert(
        "snapshot_secs".to_string(),
        Json::Num(r.breakdown.snapshot_secs),
    );
    m.insert(
        "snapshot_write_secs".to_string(),
        Json::Num(r.breakdown.snapshot_write_secs),
    );
    m.insert(
        "optimizer_comm_secs".to_string(),
        Json::Num(r.optimizer_comm_secs),
    );
    m.insert(
        "optimizer_overlap_secs".to_string(),
        Json::Num(r.optimizer_overlap_secs),
    );
    m.insert("mean_step_secs".to_string(), Json::Num(r.mean_step_secs()));
    m.insert(
        "optimizer_lane_ops".to_string(),
        Json::Num(r.optimizer_lane_ops as f64),
    );
    Json::Obj(m)
}

fn main() -> optimus::Result<()> {
    let Some(man) = optimus::manifest_or_skip("perf_gate") else {
        println!("perf-gate: SKIP (HLO artifacts not built)");
        return Ok(());
    };
    // pid-suffixed + rebuilt every run: a killed earlier run must never
    // leave half-written shards that poison later measurements
    let data = std::env::temp_dir().join(format!("optimus-perf-gate-data-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&data);
    preprocess::preprocess(&corpus::data_files(42, 4, 32), 64, 7, &data, 512)?;

    let baseline = std::fs::read_to_string(baseline_path())
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let tolerance = baseline
        .as_ref()
        .and_then(|b| b.get("tolerance"))
        .and_then(Json::as_f64)
        .unwrap_or(0.10);

    let cases = [
        Case { name: "dp", topo: Topology::dp_only(2) },
        Case { name: "ppep", topo: Topology::grid(1, 2, 2) },
    ];

    let mut out = BTreeMap::new();
    out.insert(
        "bench".to_string(),
        Json::Str(
            "perf-gate PR8: mula-tiny serial vs --overlap + ckpt snapshot stall \
             + data prefetch on/off + --dtype f32 vs bf16 + flat vs --node-size \
             hierarchical collectives"
                .to_string(),
        ),
    );
    out.insert("model".to_string(), Json::Str("mula-tiny".to_string()));
    out.insert("steps".to_string(), Json::Num(STEPS as f64));
    out.insert("tolerance".to_string(), Json::Num(tolerance));

    let mut table = Report::new(
        "perf-gate — steps/sec, serial vs --overlap (mula-tiny)",
        &["case", "serial", "overlap", "speedup"],
    );
    let mut failures: Vec<String> = Vec::new();

    for c in &cases {
        let (sps_serial, r_serial) = run_case(&man, &data, c, false)?;
        let (sps_overlap, r_overlap) = run_case(&man, &data, c, true)?;
        let speedup = sps_overlap / sps_serial.max(1e-9);
        table.row(&[
            c.name.to_string(),
            format!("{sps_serial:.2}"),
            format!("{sps_overlap:.2}"),
            format!("{speedup:.2}x"),
        ]);
        out.insert(
            format!("{}_serial_steps_per_sec", c.name),
            Json::Num(sps_serial),
        );
        out.insert(
            format!("{}_overlap_steps_per_sec", c.name),
            Json::Num(sps_overlap),
        );
        out.insert(format!("{}_overlap_speedup", c.name), Json::Num(speedup));
        out.insert(format!("{}_serial_breakdown", c.name), breakdown_json(&r_serial));
        out.insert(
            format!("{}_overlap_breakdown", c.name),
            breakdown_json(&r_overlap),
        );

        // regression gate vs the committed baseline
        for (key, sps) in [
            (format!("{}_serial_steps_per_sec", c.name), sps_serial),
            (format!("{}_overlap_steps_per_sec", c.name), sps_overlap),
        ] {
            match baseline
                .as_ref()
                .and_then(|b| b.get(&key))
                .and_then(Json::as_f64)
            {
                Some(base) if base > 0.0 => {
                    let floor = base * (1.0 - tolerance);
                    if sps < floor {
                        failures.push(format!(
                            "{key}: {sps:.2} steps/sec regressed more than \
                             {:.0}% below baseline {base:.2} (floor {floor:.2})",
                            tolerance * 100.0
                        ));
                    } else {
                        println!("perf-gate: {key} {sps:.2} vs baseline {base:.2} — ok");
                    }
                }
                _ => println!("perf-gate: {key} {sps:.2} — no baseline yet, record-only"),
            }
        }
    }

    table.print();

    // --- checkpoint snapshot stall: sync (inline write) vs async (O(1)
    // capture + background writer), on the DP case ---
    let mut ck_table = Report::new(
        "perf-gate — checkpoint snapshot stall per run (mula-tiny DP, 14 steps, every 4)",
        &["mode", "stall", "hidden write", "commits"],
    );
    for (mode, asynchronous) in [("sync", false), ("async", true)] {
        let ckdir = std::env::temp_dir().join(format!(
            "optimus-perf-gate-ck-{mode}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&ckdir);
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data.clone())
            .topo(Topology::dp_only(2))
            .steps(STEPS)
            .warmup_steps(2)
            .engine_pool(2)
            .checkpoint_dir(&ckdir)
            .ckpt_every(4)
            .ckpt_async(asynchronous)
            .build()?;
        let r = coordinator::train(&man, &spec)?;
        ck_table.row(&[
            mode.to_string(),
            format!("{:.4}s", r.breakdown.snapshot_secs),
            format!("{:.4}s", r.breakdown.snapshot_write_secs),
            format!("{}", r.ckpt_commits),
        ]);
        out.insert(
            format!("dp_ckpt_{mode}_snapshot_stall_secs"),
            Json::Num(r.breakdown.snapshot_secs),
        );
        out.insert(
            format!("dp_ckpt_{mode}_hidden_write_secs"),
            Json::Num(r.breakdown.snapshot_write_secs),
        );
        out.insert(
            format!("dp_ckpt_{mode}_steps_per_sec"),
            Json::Num(1.0 / r.mean_step_secs().max(1e-9)),
        );
        out.insert(format!("dp_ckpt_{mode}_commits"), Json::Num(r.ckpt_commits as f64));
        let _ = std::fs::remove_dir_all(&ckdir);
    }
    ck_table.print();

    // --- data pipeline: prefetch on (background producer, queue-pop
    // stall) vs off (synchronous batch assembly on the rank thread), on
    // the DP case ---
    let mut data_table = Report::new(
        "perf-gate — data pipeline, prefetch on vs off (mula-tiny DP, 14 steps)",
        &["mode", "steps/sec", "data stall", "hidden prefetch"],
    );
    for (mode, on) in [("on", true), ("off", false)] {
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data.clone())
            .topo(Topology::dp_only(2))
            .steps(STEPS)
            .warmup_steps(2)
            .engine_pool(2)
            .data_prefetch(on)
            .build()?;
        let r = coordinator::train(&man, &spec)?;
        let sps = 1.0 / r.mean_step_secs().max(1e-9);
        // the exposed data stall: queue-pop wait when prefetching,
        // synchronous assembly otherwise
        let stall = r.breakdown.data_wait_secs + r.breakdown.data_secs;
        data_table.row(&[
            mode.to_string(),
            format!("{sps:.2}"),
            format!("{stall:.4}s"),
            format!("{:.4}s", r.breakdown.data_prefetch_secs),
        ]);
        out.insert(format!("dp_prefetch_{mode}_steps_per_sec"), Json::Num(sps));
        out.insert(
            format!("dp_prefetch_{mode}_data_wait_secs"),
            Json::Num(r.breakdown.data_wait_secs),
        );
        out.insert(
            format!("dp_prefetch_{mode}_data_secs"),
            Json::Num(r.breakdown.data_secs),
        );
        out.insert(
            format!("dp_prefetch_{mode}_data_prefetch_secs"),
            Json::Num(r.breakdown.data_prefetch_secs),
        );
        out.insert(
            format!("dp_prefetch_{mode}_epochs_consumed"),
            Json::Num(r.epochs_consumed),
        );
    }
    data_table.print();

    // --- mixed precision: --dtype f32 vs bf16 on the checkpointed DP
    // case. Steps/sec gates like the other lanes (record-only until a
    // baseline is committed); the byte columns are deterministic
    // accounting, so their halving gate is unconditional. ---
    let mut dt_table = Report::new(
        "perf-gate — mixed precision, --dtype f32 vs bf16 (mula-tiny DP, ckpt every 4)",
        &["dtype", "steps/sec", "comm MiB", "ckpt param MiB"],
    );
    let mut lanes: BTreeMap<&'static str, (u64, u64)> = BTreeMap::new();
    for dt in [Dtype::F32, Dtype::Bf16] {
        let ckdir = std::env::temp_dir().join(format!(
            "optimus-perf-gate-dt-{dt}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&ckdir);
        let mut b = JobSpec::new("mula-tiny")
            .data_dir(data.clone())
            .topo(Topology::dp_only(2))
            .steps(STEPS)
            .warmup_steps(2)
            .engine_pool(2)
            .dtype(dt)
            .checkpoint_dir(&ckdir)
            .ckpt_every(4);
        if dt == Dtype::F32 {
            // a clean all-f32 wire baseline: the paper's bf16
            // gradient-reduction default would blur the comparison
            b = b.bf16_grad_reduce(false);
        }
        let r = coordinator::train(&man, &b.build()?)?;
        let sps = 1.0 / r.mean_step_secs().max(1e-9);
        let comm = r.comm_bytes_in + r.comm_bytes_out;
        // checkpoint size per dtype, on the param shards (the payload the
        // dtype changes; AdamW moments stay f32 by design)
        let saved = optimus::ckpt::SavedCheckpoint::load_latest(&ckdir)
            .expect("checkpointed run left no committed checkpoint");
        let ckpt_param_bytes: u64 = saved
            .parts
            .iter()
            .filter(|p| p.name.starts_with("params."))
            .map(|p| std::fs::metadata(saved.dir.join(&p.file)).map(|m| m.len()).unwrap_or(0))
            .sum();
        let key = dt.as_str();
        dt_table.row(&[
            key.to_string(),
            format!("{sps:.2}"),
            format!("{:.2}", comm as f64 / (1 << 20) as f64),
            format!("{:.4}", ckpt_param_bytes as f64 / (1 << 20) as f64),
        ]);
        out.insert(format!("dp_{key}_steps_per_sec"), Json::Num(sps));
        out.insert(format!("dp_{key}_comm_bytes"), Json::Num(comm as f64));
        out.insert(
            format!("dp_{key}_ckpt_param_bytes"),
            Json::Num(ckpt_param_bytes as f64),
        );
        out.insert(format!("dp_{key}_ckpt_bytes"), Json::Num(r.ckpt_bytes as f64));
        lanes.insert(key, (comm, ckpt_param_bytes));
        let gate_key = format!("dp_{key}_steps_per_sec");
        match baseline
            .as_ref()
            .and_then(|bl| bl.get(&gate_key))
            .and_then(Json::as_f64)
        {
            Some(base) if base > 0.0 => {
                let floor = base * (1.0 - tolerance);
                if sps < floor {
                    failures.push(format!(
                        "{gate_key}: {sps:.2} steps/sec regressed more than \
                         {:.0}% below baseline {base:.2} (floor {floor:.2})",
                        tolerance * 100.0
                    ));
                } else {
                    println!("perf-gate: {gate_key} {sps:.2} vs baseline {base:.2} — ok");
                }
            }
            _ => println!("perf-gate: {gate_key} {sps:.2} — no baseline yet, record-only"),
        }
        let _ = std::fs::remove_dir_all(&ckdir);
    }
    dt_table.print();
    let (f32_comm, f32_ckpt) = lanes["f32"];
    let (bf16_comm, bf16_ckpt) = lanes["bf16"];
    for (what, f, b) in [
        ("collective bytes", f32_comm, bf16_comm),
        ("checkpoint param bytes", f32_ckpt, bf16_ckpt),
    ] {
        if f == 0 || b as f64 > f as f64 * 0.55 {
            failures.push(format!(
                "bf16 {what} {b} exceed 55% of f32 {f} — half-width wire or \
                 checkpoint payload regressed"
            ));
        } else {
            println!(
                "perf-gate: bf16 {what} {b} = {:.1}% of f32 {f} — ok",
                100.0 * b as f64 / f as f64
            );
        }
    }

    // --- hierarchical collectives: flat vs --node-size 3 on a 6-rank DP
    // mesh. Steps/sec gates like the other lanes (record-only until a
    // baseline is committed); the intra/inter byte split is deterministic
    // accounting, so the (n−1)/n inter-node reduction gate is
    // unconditional. ---
    const NODE_SIZE: usize = 3;
    let mut hier_table = Report::new(
        "perf-gate — hierarchical collectives, flat vs --node-size 3 (mula-tiny DP world 6)",
        &["lane", "steps/sec", "intra MiB", "inter MiB"],
    );
    let mut hier_lanes: BTreeMap<&'static str, (u64, u64)> = BTreeMap::new();
    for (lane, ns) in [("flat", 1usize), ("hier", NODE_SIZE)] {
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data.clone())
            .topo(Topology::dp_only(6).with_node_size(ns))
            .steps(STEPS)
            .warmup_steps(2)
            .engine_pool(2)
            .build()?;
        let r = coordinator::train(&man, &spec)?;
        let sps = 1.0 / r.mean_step_secs().max(1e-9);
        hier_table.row(&[
            lane.to_string(),
            format!("{sps:.2}"),
            format!("{:.2}", r.comm_intra_bytes as f64 / (1 << 20) as f64),
            format!("{:.2}", r.comm_inter_bytes as f64 / (1 << 20) as f64),
        ]);
        out.insert(format!("dp_{lane}_steps_per_sec"), Json::Num(sps));
        out.insert(
            format!("dp_{lane}_intra_bytes"),
            Json::Num(r.comm_intra_bytes as f64),
        );
        out.insert(
            format!("dp_{lane}_inter_bytes"),
            Json::Num(r.comm_inter_bytes as f64),
        );
        hier_lanes.insert(lane, (r.comm_intra_bytes, r.comm_inter_bytes));
        let gate_key = format!("dp_{lane}_steps_per_sec");
        match baseline
            .as_ref()
            .and_then(|bl| bl.get(&gate_key))
            .and_then(Json::as_f64)
        {
            Some(base) if base > 0.0 => {
                let floor = base * (1.0 - tolerance);
                if sps < floor {
                    failures.push(format!(
                        "{gate_key}: {sps:.2} steps/sec regressed more than \
                         {:.0}% below baseline {base:.2} (floor {floor:.2})",
                        tolerance * 100.0
                    ));
                } else {
                    println!("perf-gate: {gate_key} {sps:.2} vs baseline {base:.2} — ok");
                }
            }
            _ => println!("perf-gate: {gate_key} {sps:.2} — no baseline yet, record-only"),
        }
    }
    out.insert("hier_node_size".to_string(), Json::Num(NODE_SIZE as f64));
    hier_table.print();
    let (_flat_intra, flat_inter) = hier_lanes["flat"];
    let (hier_intra, hier_inter) = hier_lanes["hier"];
    // the whole point of the hierarchy: at node size n the inter-node
    // fabric carries at most (n−1)/n of the flat lane's bytes, with the
    // remainder moved onto the intra-node legs
    let cap = flat_inter as f64 * (NODE_SIZE as f64 - 1.0) / NODE_SIZE as f64;
    if flat_inter == 0 || hier_intra == 0 || hier_inter as f64 > cap {
        failures.push(format!(
            "hier inter-node bytes {hier_inter} exceed (n-1)/n of flat {flat_inter} \
             (cap {cap:.0}, intra-node {hier_intra}) — the --node-size hierarchy is \
             not keeping reduction traffic on the intra-node legs"
        ));
    } else {
        println!(
            "perf-gate: hier inter-node bytes {hier_inter} = {:.1}% of flat \
             {flat_inter} (cap {:.1}%) — ok",
            100.0 * hier_inter as f64 / flat_inter as f64,
            100.0 * (NODE_SIZE as f64 - 1.0) / NODE_SIZE as f64
        );
    }

    // --- serving lane: continuous vs static batching over one seeded
    // open-loop workload, into its own BENCH_SERVE.json. The completion
    // sets, KV accounting and decode-step counts are deterministic, so
    // those gates are unconditional; tokens/sec gates against the
    // baseline like the training lanes (record-only until committed). ---
    let serve_ck = std::env::temp_dir().join(format!(
        "optimus-perf-gate-serve-ck-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&serve_ck);
    let spec = JobSpec::new("mula-tiny")
        .data_dir(data.clone())
        .topo(Topology::dp_only(1))
        .steps(5)
        .warmup_steps(2)
        .engine_pool(2)
        .checkpoint_dir(&serve_ck)
        .ckpt_every(3)
        .build()?;
    coordinator::train(&man, &spec)?;

    let traffic = TrafficConfig {
        seed: 7,
        requests: 24,
        rate_rps: 0.0,
        prompt_len: (4, 8),
        // a wide generation spread is what continuous batching exploits:
        // static lanes idle finished slots until the longest request
        // in the batch drains
        gen_len: (4, 16),
        queue_depth: 4,
    };
    let mut serve_table = Report::new(
        "perf-gate — serving, continuous vs static batching (mula-tiny, 24 requests)",
        &["mode", "tok/s", "ttft p50/p99", "per-tok p50/p99", "steps"],
    );
    let mut serve_out = BTreeMap::new();
    serve_out.insert(
        "bench".to_string(),
        Json::Str(
            "serve perf-gate: optimus serve continuous vs static batching on one \
             seeded open-loop workload (mula-tiny)"
                .to_string(),
        ),
    );
    serve_out.insert("model".to_string(), Json::Str("mula-tiny".to_string()));
    serve_out.insert("requests".to_string(), Json::Num(traffic.requests as f64));
    let mut reports = Vec::new();
    for (mode_name, mode) in [("continuous", BatchMode::Continuous), ("static", BatchMode::Static)]
    {
        let mut cfg = ServeConfig::new("mula-tiny", &serve_ck);
        cfg.mode = mode;
        cfg.traffic = traffic.clone();
        let r = serve::serve(&man, &cfg)?;
        serve_table.row(&[
            mode_name.to_string(),
            format!("{:.1}", r.tokens_per_sec()),
            format!("{:.4}/{:.4}s", r.ttft.p50(), r.ttft.p99()),
            format!("{:.4}/{:.4}s", r.per_token.p50(), r.per_token.p99()),
            format!("{}", r.decode_steps),
        ]);
        serve_out.insert(
            format!("serve_{mode_name}_tokens_per_sec"),
            Json::Num(r.tokens_per_sec()),
        );
        serve_out.insert(format!("serve_{mode_name}_ttft_p50_secs"), Json::Num(r.ttft.p50()));
        serve_out.insert(format!("serve_{mode_name}_ttft_p99_secs"), Json::Num(r.ttft.p99()));
        serve_out.insert(
            format!("serve_{mode_name}_per_token_p50_secs"),
            Json::Num(r.per_token.p50()),
        );
        serve_out.insert(
            format!("serve_{mode_name}_per_token_p99_secs"),
            Json::Num(r.per_token.p99()),
        );
        serve_out.insert(
            format!("serve_{mode_name}_decode_steps"),
            Json::Num(r.decode_steps as f64),
        );
        serve_out.insert(
            format!("serve_{mode_name}_tokens_generated"),
            Json::Num(r.tokens_generated as f64),
        );
        if r.completions.len() != r.submitted {
            failures.push(format!(
                "serve {mode_name}: only {} of {} requests completed",
                r.completions.len(),
                r.submitted
            ));
        }
        if r.kv_pages_leaked != 0 {
            failures.push(format!(
                "serve {mode_name}: {} KV page(s) leaked",
                r.kv_pages_leaked
            ));
        }
        let gate_key = format!("serve_{mode_name}_tokens_per_sec");
        let tps = r.tokens_per_sec();
        match baseline
            .as_ref()
            .and_then(|bl| bl.get(&gate_key))
            .and_then(Json::as_f64)
        {
            Some(base) if base > 0.0 => {
                let floor = base * (1.0 - tolerance);
                if tps < floor {
                    failures.push(format!(
                        "{gate_key}: {tps:.1} tokens/sec regressed more than \
                         {:.0}% below baseline {base:.1} (floor {floor:.1})",
                        tolerance * 100.0
                    ));
                } else {
                    println!("perf-gate: {gate_key} {tps:.1} vs baseline {base:.1} — ok");
                }
            }
            _ => println!("perf-gate: {gate_key} {tps:.1} — no baseline yet, record-only"),
        }
        reports.push(r);
    }
    serve_table.print();
    let (cont, stat) = (&reports[0], &reports[1]);
    if cont.completions != stat.completions {
        failures.push(
            "serve: continuous and static batching produced different completion \
             sets from the same seeded workload"
                .to_string(),
        );
    }
    // the continuous scheduler's whole claim, in deterministic units:
    // refilling evicted slots mid-flight finishes the same workload in
    // strictly fewer fixed-shape decode steps ...
    if cont.decode_steps >= stat.decode_steps {
        failures.push(format!(
            "serve: continuous batching took {} decode steps vs static {} — \
             slot refill is not raising occupancy",
            cont.decode_steps, stat.decode_steps
        ));
    }
    // ... and per-step cost is constant (fixed-shape recompute), so the
    // step advantage must show up as wall-clock throughput too
    if cont.tokens_per_sec() <= stat.tokens_per_sec() {
        failures.push(format!(
            "serve: continuous batching {:.1} tokens/sec is not above static {:.1}",
            cont.tokens_per_sec(),
            stat.tokens_per_sec()
        ));
    } else {
        println!(
            "perf-gate: serve continuous {:.1} tokens/sec vs static {:.1} \
             ({} vs {} decode steps) — ok",
            cont.tokens_per_sec(),
            stat.tokens_per_sec(),
            cont.decode_steps,
            stat.decode_steps
        );
    }
    serve_out.insert(
        "serve_continuous_over_static_speedup".to_string(),
        Json::Num(cont.tokens_per_sec() / stat.tokens_per_sec().max(1e-9)),
    );
    let _ = std::fs::remove_dir_all(&serve_ck);
    let serve_path = std::env::var("PERF_GATE_SERVE_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| repo_root().join("BENCH_SERVE.json"));
    std::fs::write(&serve_path, Json::Obj(serve_out).to_string())?;
    println!("perf-gate: wrote {}", serve_path.display());

    let path = out_path();
    std::fs::write(&path, Json::Obj(out).to_string())?;
    println!("perf-gate: wrote {}", path.display());

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("perf-gate FAIL: {f}");
        }
        std::process::exit(1);
    }
    Ok(())
}
