//! Integration: train dense vs MoE at iso-compute and check Table 2's
//! qualitative claim — training improves probe scores, and the synthetic
//! suite produces a full table (Figs 2-3 machinery).

use optimus::coordinator::{self, JobSpec};
use optimus::data::{corpus, preprocess};
use optimus::eval;
use optimus::runtime::{Engine, Tensor};
use std::path::PathBuf;

fn data_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("optimus-eval-data-{}", std::process::id()));
    if !dir.exists() {
        let files = corpus::data_files(42, 6, 40);
        preprocess::preprocess(&files, 64, 7, &dir, 512).unwrap();
    }
    dir
}

#[test]
fn training_improves_probe_scores() {
    let Some(m) = optimus::manifest_or_skip("eval_suite::training_improves_probe_scores")
    else {
        return;
    };
    let mm = m.config("mula-tiny").unwrap();
    let engine = Engine::new_pool(2).unwrap();

    let base_params = Tensor::f32(
        coordinator::init_global_params(mm, 1234),
        vec![mm.param_count],
    );
    let before = eval::run_suite(&engine, mm, &base_params, 16).unwrap();

    let spec = JobSpec::new("mula-tiny")
        .data_dir(data_dir())
        .topology(2, 1, 1)
        .steps(60)
        .warmup_steps(6)
        .peak_lr(3e-3)
        .min_lr(3e-4)
        .engine_pool(2)
        .build()
        .unwrap();
    let r = coordinator::train(&m, &spec).unwrap();
    let after = eval::run_suite(&engine, mm, &r.final_params, 16).unwrap();

    assert_eq!(before.len(), eval::TASKS.len());
    // the held-out score (bounded ppl transform) must improve with
    // training; probe accuracies must not regress on average
    assert!(
        after["held_out_ppl"] > before["held_out_ppl"] + 1.0,
        "no ppl gain: {before:?} -> {after:?}"
    );
    assert!(
        eval::average(&after) >= eval::average(&before) - 1.0,
        "suite regressed: {before:?} -> {after:?}"
    );
}
