//! Integration: the three runnable engines (DP-fused, EP, PP) implement
//! the *same* training semantics — first-step losses agree across
//! decompositions on identical data, and every mode learns.

use optimus::comm::Topology;
use optimus::coordinator::{self, ep::EpComm, pipeline::Schedule, TrainOptions};
use optimus::data::{corpus, preprocess};
use optimus::optim::ShardingMode;
use std::path::PathBuf;
use std::sync::OnceLock;

fn data_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir()
            .join(format!("optimus-it-data-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let files = corpus::data_files(42, 4, 24);
        preprocess::preprocess(&files, 64, 7, &dir, 256).unwrap();
        dir
    })
    .clone()
}

fn base_opts(topo: Topology, steps: usize) -> TrainOptions {
    let mut o = TrainOptions::new("mula-tiny", topo, data_dir());
    o.run.steps = steps;
    o.run.warmup_steps = 4;
    o.run.peak_lr = 2e-3;
    o.run.min_lr = 2e-4;
    o.engine_pool = 2;
    o
}

#[test]
fn dp_ep_pp_first_step_losses_agree() {
    let Some(m) = optimus::manifest_or_skip("train_modes::dp_ep_pp_first_step_losses_agree") else {
        return;
    };

    let dp = coordinator::train(&m, &base_opts(Topology::dp_only(2), 2)).unwrap();
    let mut ep_opts = base_opts(Topology { dp: 1, ep: 2, pp: 1 }, 2);
    ep_opts.mode = ShardingMode::Epso;
    let ep = coordinator::train(&m, &ep_opts).unwrap();
    let mut pp_opts = base_opts(Topology { dp: 1, ep: 1, pp: 2 }, 2);
    pp_opts.micro_batches = 2;
    pp_opts.schedule = Schedule::OneFOneB;
    let pp = coordinator::train(&m, &pp_opts).unwrap();

    let l_dp = dp.loss.points[0].1;
    let l_ep = ep.loss.points[0].1;
    let l_pp = pp.loss.points[0].1;
    // identical params + identical data: decompositions must agree
    assert!((l_dp - l_ep).abs() < 5e-4, "DP {l_dp} vs EP {l_ep}");
    assert!((l_dp - l_pp).abs() < 5e-4, "DP {l_dp} vs PP {l_pp}");
    // random init on vocab 256 -> ~ln(256)
    assert!((l_dp - 256f64.ln()).abs() < 0.5, "{l_dp}");
}

#[test]
fn every_mode_learns() {
    let Some(m) = optimus::manifest_or_skip("train_modes::every_mode_learns") else {
        return;
    };
    let steps = 25;

    let dp = coordinator::train(&m, &base_opts(Topology::dp_only(2), steps)).unwrap();
    assert!(
        dp.loss.tail_mean(3) < dp.loss.points[0].1 - 0.5,
        "DP no learning: {:?}",
        dp.loss.points
    );

    let mut ep_opts = base_opts(Topology { dp: 1, ep: 2, pp: 1 }, steps);
    ep_opts.mode = ShardingMode::Epso;
    let ep = coordinator::train(&m, &ep_opts).unwrap();
    assert!(
        ep.loss.tail_mean(3) < ep.loss.points[0].1 - 0.5,
        "EP no learning: {:?}",
        ep.loss.points
    );

    let mut pp_opts = base_opts(Topology { dp: 1, ep: 1, pp: 2 }, steps);
    pp_opts.micro_batches = 2;
    let pp = coordinator::train(&m, &pp_opts).unwrap();
    assert!(
        pp.loss.tail_mean(3) < pp.loss.points[0].1 - 0.5,
        "PP no learning: {:?}",
        pp.loss.points
    );
}

#[test]
fn ep_so_and_epso_trajectories_match() {
    // EPSO is a resharding, not a different optimizer: loss curves must
    // coincide while EPSO holds strictly less optimizer state.
    let Some(m) = optimus::manifest_or_skip("train_modes::ep_so_and_epso_trajectories_match") else {
        return;
    };
    let mk = |mode| {
        let mut o = base_opts(Topology { dp: 2, ep: 2, pp: 1 }, 6);
        o.mode = mode;
        o.run.bf16_grad_reduce = false; // keep reductions exactly associative-ish
        coordinator::train(&m, &o).unwrap()
    };
    let so = mk(ShardingMode::So);
    let epso = mk(ShardingMode::Epso);
    for ((s1, a), (s2, b)) in so.loss.points.iter().zip(epso.loss.points.iter()) {
        assert_eq!(s1, s2);
        assert!((a - b).abs() < 2e-3, "step {s1}: SO {a} vs EPSO {b}");
    }
    assert!(
        epso.opt_state_bytes < so.opt_state_bytes,
        "EPSO must hold less state: {} vs {}",
        epso.opt_state_bytes,
        so.opt_state_bytes
    );
}

#[test]
fn ep_allgather_and_all2all_agree() {
    // paper §3.1 Stage 1: the two exchange policies are numerically
    // identical (they differ in communication volume only).
    let Some(m) = optimus::manifest_or_skip("train_modes::ep_allgather_and_all2all_agree") else {
        return;
    };
    let mk = |policy| {
        let mut o = base_opts(Topology { dp: 1, ep: 2, pp: 1 }, 3);
        o.ep_comm = policy;
        o.run.bf16_grad_reduce = false;
        coordinator::train(&m, &o).unwrap()
    };
    let ag = mk(EpComm::Allgather);
    let aa = mk(EpComm::All2All);
    for ((_, a), (_, b)) in ag.loss.points.iter().zip(aa.loss.points.iter()) {
        assert!((a - b).abs() < 1e-4, "allgather {a} vs all2all {b}");
    }
}

#[test]
fn gpipe_and_1f1b_agree() {
    let Some(m) = optimus::manifest_or_skip("train_modes::gpipe_and_1f1b_agree") else {
        return;
    };
    let mk = |sched| {
        let mut o = base_opts(Topology { dp: 1, ep: 1, pp: 2 }, 3);
        o.schedule = sched;
        o.micro_batches = 4;
        o.run.bf16_grad_reduce = false;
        coordinator::train(&m, &o).unwrap()
    };
    let g = mk(Schedule::GPipe);
    let f = mk(Schedule::OneFOneB);
    for ((_, a), (_, b)) in g.loss.points.iter().zip(f.loss.points.iter()) {
        assert!((a - b).abs() < 1e-4, "gpipe {a} vs 1f1b {b}");
    }
}

#[test]
fn fur_runs_and_stays_finite() {
    let Some(m) = optimus::manifest_or_skip("train_modes::fur_runs_and_stays_finite") else {
        return;
    };
    let mut o = base_opts(Topology { dp: 1, ep: 2, pp: 1 }, 4);
    o.fur = true;
    let r = coordinator::train(&m, &o).unwrap();
    for (_, l) in &r.loss.points {
        assert!(l.is_finite());
    }
}
