"""Pure-jnp / numpy correctness oracles for the FastSparseMoE kernels.

Two references live here:

1. ``naive_sparse_moe`` — the HuggingFace-OLMoE-style implementation the
   paper uses as its baseline (a python loop over experts, each expert
   gathering its tokens through a dense mask). This is both the pytest
   oracle for the Pallas path and the **baseline side of Table 3 (FSMOE)**.

2. ``ref_token_counts`` / ``ref_index_generation`` — plain-numpy transcripts
   of Algorithm 1 stages 2-3, used to check the Pallas integer kernels
   entry-by-entry (including the exact base+offset layout of
   ``input_indices`` / ``output_indices`` from the paper's Figure 5).
"""

import numpy as np
import jax
import jax.numpy as jnp


def silu(x):
    return x * jax.nn.sigmoid(x)


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def topk_sorted(probs, k):
    """top-k via stable argsort (ties -> lowest index, matching
    jax.lax.top_k). Lowers to HLO `sort`, which the xla_extension 0.5.1
    text parser accepts — jax 0.8's native `topk` op does not exist in
    that parser (version-skew shim, see aot.py). The VJP is a one-hot
    scatter: take_along_axis's native VJP emits gathers with
    operand_batching_dims, which the legacy HLO converter rejects."""
    order = jnp.argsort(-probs, axis=-1, stable=True)[..., :k]
    vals = jnp.take_along_axis(probs, order, axis=-1)
    return vals, order.astype(jnp.int32)


def _topk_fwd(probs, k):
    vals, order = topk_sorted(probs, k)
    return (vals, order), (order, probs.shape[-1])


def _topk_bwd(k, res, cts):
    order, n = res
    d_vals, _ = cts  # indices carry no tangent
    onehot = jax.nn.one_hot(order, n, dtype=d_vals.dtype)  # [T,K,N]
    d_probs = jnp.einsum("tk,tkn->tn", d_vals, onehot)
    return (d_probs,)


topk_sorted.defvjp(_topk_fwd, _topk_bwd)


def router_topk(x, router_w, top_k):
    """OLMoE routing: softmax over expert logits, then top-k (no renorm).

    Returns (weights [T,K], indices [T,K] int32, probs [T,N]).
    """
    logits = x @ router_w  # [T, N]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, indices = topk_sorted(probs, top_k)
    return weights.astype(x.dtype), indices.astype(jnp.int32), probs


def expert_mlp(x, gate_w, up_w, down_w):
    """One expert: SwiGLU MLP. x [t,H], gate/up [H,I], down [I,H]."""
    return (silu(x @ gate_w) * (x @ up_w)) @ down_w


def naive_sparse_moe(x, weights, indices, gate_w, up_w, down_w,
                     n_start=0, n_end=None):
    """HF-style per-expert loop over the experts local to [n_start, n_end].

    x        [T, H]   tokens (already allgathered across EP in the EP case)
    weights  [T, K]   top-k routing weights
    indices  [T, K]   top-k expert ids (global ids)
    gate_w/up_w [NR, H, I], down_w [NR, I, H]  merged local expert weights
    Returns the *partial* output [T, H] contributed by local experts
    (paper Algorithm 1: rank r's contribution before the reduce-scatter).
    """
    nr = gate_w.shape[0]
    if n_end is None:
        n_end = n_start + nr - 1
    t, h = x.shape
    out = jnp.zeros((t, h), dtype=jnp.float32)
    for ln in range(nr):
        n = n_start + ln
        # mask[t] = routing weight of expert n for token t (0 if unrouted)
        sel = (indices == n)                      # [T, K]
        w_tok = jnp.sum(jnp.where(sel, weights, 0.0), axis=1)  # [T]
        y = expert_mlp(x, gate_w[ln], up_w[ln], down_w[ln])    # dense: all T
        out = out + w_tok[:, None] * y.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Numpy transcripts of Algorithm 1 stages 2-3 (exact, including layout)
# ---------------------------------------------------------------------------

def ref_token_counts(indices: np.ndarray, n_start: int, n_end: int, tbs: int):
    """Stage 2: per-(local-expert, thread) partial counts + expert counts.

    indices [T, K]; T must be divisible by tbs. Returns dict with
    partial_token_counts [NR*TH], partial_cum_token_counts [NR*TH+1],
    cum_token_counts [NR+1], expert_counts [T], cum_expert_counts [T+1].
    """
    t_tot, k = indices.shape
    assert t_tot % tbs == 0
    th = t_tot // tbs
    nr = n_end - n_start + 1
    partial = np.zeros(nr * th, dtype=np.int32)
    expert_counts = np.zeros(t_tot, dtype=np.int32)
    for tid in range(th):
        for i in range(tbs):
            t = tid * tbs + i
            for kk in range(k):
                n = int(indices[t, kk])
                if n_start <= n <= n_end:
                    ln = n - n_start
                    partial[ln * th + tid] += 1
                    expert_counts[t] += 1
    pcum = np.zeros(nr * th + 1, dtype=np.int32)
    pcum[1:] = np.cumsum(partial)
    cum_expert = np.zeros(t_tot + 1, dtype=np.int32)
    cum_expert[1:] = np.cumsum(expert_counts)
    cum_token = np.zeros(nr + 1, dtype=np.int32)
    for n in range(nr + 1):
        cum_token[n] = pcum[n * th]
    return dict(
        partial_token_counts=partial,
        partial_cum_token_counts=pcum,
        cum_token_counts=cum_token,
        expert_counts=expert_counts,
        cum_expert_counts=cum_expert,
    )


def ref_index_generation(indices: np.ndarray, n_start: int, n_end: int,
                         tbs: int):
    """Stage 3: input_indices / output_indices / selected_expert_indices.

    Follows Algorithm 1 lines 45-72 verbatim (same iteration order), so the
    produced layout matches the paper's Figure 5 example exactly.
    """
    counts = ref_token_counts(indices, n_start, n_end, tbs)
    pcum = counts["partial_cum_token_counts"]
    cum_expert = counts["cum_expert_counts"]
    t_tot, k = indices.shape
    th = t_tot // tbs
    nr = n_end - n_start + 1
    rt = int(counts["cum_token_counts"][-1])
    input_indices = np.zeros(rt, dtype=np.int32)
    output_indices = np.zeros(rt, dtype=np.int32)
    sel_k = np.zeros(rt, dtype=np.int32)
    counter = np.zeros((nr, th), dtype=np.int32)
    for tid in range(th):
        for i in range(tbs):
            t = tid * tbs + i
            o_ind = int(cum_expert[t])
            for kk in range(k):
                n = int(indices[t, kk])
                if n_start <= n <= n_end:
                    ln = n - n_start
                    base = int(pcum[ln * th + tid])
                    offset = int(counter[ln, tid])
                    i_ind = base + offset
                    input_indices[i_ind] = t
                    output_indices[o_ind] = i_ind
                    sel_k[o_ind] = kk
                    counter[ln, tid] += 1
                    o_ind += 1
    return dict(counts, input_indices=input_indices,
                output_indices=output_indices,
                selected_expert_indices=sel_k, rt=rt)


def ref_output_reduction(mlp_out_flat, weights, sel_k, output_indices,
                         cum_expert_counts):
    """Stage 5 forward oracle (Algorithm 1 lines 82-96), numpy."""
    t_tot, k = weights.shape
    h = mlp_out_flat.shape[1]
    out = np.zeros((t_tot, h), dtype=np.float64)
    for t in range(t_tot):
        base = int(cum_expert_counts[t])
        size = int(cum_expert_counts[t + 1]) - base
        for i in range(size):
            kk = int(sel_k[base + i])
            idx = int(output_indices[base + i])
            out[t] += float(weights[t, kk]) * mlp_out_flat[idx].astype(np.float64)
    return out.astype(np.float32)


def ref_output_reduction_bwd(output_grad, mlp_out_flat, weights, sel_k,
                             output_indices, cum_expert_counts, rt):
    """Stage 5 backward oracle (Algorithm 1 lines 98-113), numpy.

    Entries for token t occupy positions [cum_expert_counts[t],
    cum_expert_counts[t+1]) of the selected-expert arrays; the paper's
    per-rt loop visits exactly these (token, slot) pairs.
    """
    t_tot, k = weights.shape
    h = mlp_out_flat.shape[1]
    mlp_out_grad = np.zeros((rt, h), dtype=np.float64)
    weights_grad = np.zeros((t_tot, k), dtype=np.float64)
    for t in range(t_tot):
        base = int(cum_expert_counts[t])
        size = int(cum_expert_counts[t + 1]) - base
        for i in range(size):
            j = base + i
            kk = int(sel_k[j])
            idx = int(output_indices[j])
            mlp_out_grad[idx] = float(weights[t, kk]) * output_grad[t].astype(np.float64)
            weights_grad[t, kk] = np.dot(
                mlp_out_flat[idx].astype(np.float64),
                output_grad[t].astype(np.float64))
    return mlp_out_grad.astype(np.float32), weights_grad.astype(np.float32)
