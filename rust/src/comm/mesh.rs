//! N-D device mesh (DP × EP × PP) and its process groups.
//!
//! Mirrors the paper's placement: EP innermost (within a node, 12 tiles),
//! PP across nodes, DP across node groups. Rank numbering:
//! `rank = (dp * EP + ep) * PP + pp`.
//!
//! Groups exposed per rank:
//! - **dp group**  — ranks sharing (ep, pp): gradient sync + SO sharding
//! - **ep group**  — ranks sharing (dp, pp): Stage-1 token exchange
//! - **dpep group** — ranks sharing pp: EPSO's non-expert sharding domain
//! - **world**     — everything (barriers, health votes)
//!
//! [`Topology::node_size`] places rank r on node `r / node_size`
//! (Aurora hosts 12 tiles per node). Groups whose members span several
//! nodes are built hierarchical (see [`Group::new_on_nodes`]): their
//! sum/gather collectives run intra-node → leaders → intra-node, and
//! [`Mesh::traffic`] splits the byte counters into Xe-Link-priced
//! `intra_bytes` vs Slingshot-priced `inter_bytes`. `node_size: 1` is
//! the flat baseline — every group single-level, every byte inter-node.

use super::group::{CommStats, Group};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub dp: usize,
    pub ep: usize,
    pub pp: usize,
    /// ranks per node: rank r lives on node `r / node_size`. 1 = flat
    /// collectives (no node locality); validated against the world size
    /// by the `[topology]` plan check.
    pub node_size: usize,
}

impl Topology {
    /// Pure DP mesh, flat placement.
    pub fn dp_only(dp: usize) -> Topology {
        Topology::grid(dp, 1, 1)
    }

    /// A DP × EP × PP mesh with flat placement (`node_size: 1`) — the
    /// literal-free way to spell a topology; chain
    /// [`Topology::with_node_size`] for hierarchical collectives.
    pub fn grid(dp: usize, ep: usize, pp: usize) -> Topology {
        Topology { dp, ep, pp, node_size: 1 }
    }

    /// Same mesh, placed `node_size` ranks per node.
    pub fn with_node_size(self, node_size: usize) -> Topology {
        Topology { node_size, ..self }
    }

    pub fn world(&self) -> usize {
        self.dp * self.ep * self.pp
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MeshCoord {
    pub dp: usize,
    pub ep: usize,
    pub pp: usize,
}

pub struct Mesh {
    pub topo: Topology,
    /// indexed by ep * PP + pp
    dp_groups: Vec<Arc<Group>>,
    /// indexed by dp * PP + pp
    ep_groups: Vec<Arc<Group>>,
    /// indexed by pp
    dpep_groups: Vec<Arc<Group>>,
    world: Arc<Group>,
}

impl Mesh {
    pub fn new(topo: Topology) -> Arc<Mesh> {
        // stable labels per group: protocol-violation and stall reports
        // name the fabric they fired on (e.g. `dp[1]`, `world`); each
        // group is built knowing which node hosts each member, so
        // node-spanning groups get the three-phase hierarchy and
        // node-contained ones are accounted at Xe-Link pricing
        let ns = topo.node_size.max(1);
        let place = |label: &str, members: Vec<usize>| {
            let nodes: Vec<usize> = members.iter().map(|r| r / ns).collect();
            Group::new_on_nodes(members.len(), label, &nodes)
        };
        let dp_groups = (0..topo.ep * topo.pp)
            .map(|i| {
                let (ep, pp) = (i / topo.pp, i % topo.pp);
                let members =
                    (0..topo.dp).map(|dp| (dp * topo.ep + ep) * topo.pp + pp).collect();
                place(&format!("dp[{i}]"), members)
            })
            .collect();
        let ep_groups = (0..topo.dp * topo.pp)
            .map(|i| {
                let (dp, pp) = (i / topo.pp, i % topo.pp);
                let members =
                    (0..topo.ep).map(|ep| (dp * topo.ep + ep) * topo.pp + pp).collect();
                place(&format!("ep[{i}]"), members)
            })
            .collect();
        let dpep_groups = (0..topo.pp)
            .map(|pp| {
                let members =
                    (0..topo.dp * topo.ep).map(|de| de * topo.pp + pp).collect();
                place(&format!("dpep[{pp}]"), members)
            })
            .collect();
        let world = place("world", (0..topo.world()).collect());
        Arc::new(Mesh { topo, dp_groups, ep_groups, dpep_groups, world })
    }

    pub fn rank(&self, c: MeshCoord) -> usize {
        (c.dp * self.topo.ep + c.ep) * self.topo.pp + c.pp
    }

    pub fn coord(&self, rank: usize) -> MeshCoord {
        let pp = rank % self.topo.pp;
        let rest = rank / self.topo.pp;
        let ep = rest % self.topo.ep;
        let dp = rest / self.topo.ep;
        MeshCoord { dp, ep, pp }
    }

    /// (group, my index within it) for the data-parallel dimension.
    pub fn dp_group(&self, rank: usize) -> (&Arc<Group>, usize) {
        let c = self.coord(rank);
        (&self.dp_groups[c.ep * self.topo.pp + c.pp], c.dp)
    }

    /// (group, my index) for the expert-parallel dimension.
    pub fn ep_group(&self, rank: usize) -> (&Arc<Group>, usize) {
        let c = self.coord(rank);
        (&self.ep_groups[c.dp * self.topo.pp + c.pp], c.ep)
    }

    /// (group, my index) for the combined DP×EP domain (same pp stage).
    /// Index is `dp * EP + ep` — contiguous in dp-major order.
    pub fn dpep_group(&self, rank: usize) -> (&Arc<Group>, usize) {
        let c = self.coord(rank);
        (&self.dpep_groups[c.pp], c.dp * self.topo.ep + c.ep)
    }

    pub fn world_group(&self) -> &Arc<Group> {
        &self.world
    }

    /// Poison every group (used when a rank aborts so surviving ranks
    /// fail fast instead of hanging — paper §4 hard-failure semantics).
    /// [`Group::poison`] forwards into hierarchy subgroups, so members
    /// parked on an intra-node or leaders leg unblock too.
    pub fn poison_all(&self) {
        for g in self
            .dp_groups
            .iter()
            .chain(self.ep_groups.iter())
            .chain(self.dpep_groups.iter())
        {
            g.poison();
        }
        self.world.poison();
    }

    /// Aggregate traffic across every group of the mesh (dp, ep, dpep and
    /// world, including their hierarchy subgroups) — the bytes-moved
    /// number behind the perf gate's per-dtype column. Counters are at
    /// actual wire width (bf16 collectives move 2-byte words), split into
    /// node-local `intra_bytes` vs node-crossing `inter_bytes`.
    pub fn traffic(&self) -> CommStats {
        let mut total = CommStats::default();
        for g in self
            .dp_groups
            .iter()
            .chain(self.ep_groups.iter())
            .chain(self.dpep_groups.iter())
            .chain(std::iter::once(&self.world))
        {
            total.absorb(&g.stats());
        }
        total
    }

    /// Pipeline neighbours (same dp, ep): (prev, next) ranks if any.
    pub fn pp_neighbours(&self, rank: usize) -> (Option<usize>, Option<usize>) {
        let c = self.coord(rank);
        let prev = (c.pp > 0).then(|| self.rank(MeshCoord { pp: c.pp - 1, ..c }));
        let next =
            (c.pp + 1 < self.topo.pp).then(|| self.rank(MeshCoord { pp: c.pp + 1, ..c }));
        (prev, next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CollectiveOp, Reduce, ReduceDtype};

    #[test]
    fn rank_coord_roundtrip() {
        let m = Mesh::new(Topology::grid(3, 4, 2));
        for r in 0..24 {
            assert_eq!(m.rank(m.coord(r)), r);
        }
    }

    #[test]
    fn group_memberships_are_consistent() {
        let m = Mesh::new(Topology::grid(2, 2, 2));
        for r in 0..8 {
            let c = m.coord(r);
            let (dg, di) = m.dp_group(r);
            assert_eq!(dg.size(), 2);
            assert_eq!(di, c.dp);
            let (eg, ei) = m.ep_group(r);
            assert_eq!(eg.size(), 2);
            assert_eq!(ei, c.ep);
            let (xg, xi) = m.dpep_group(r);
            assert_eq!(xg.size(), 4);
            assert_eq!(xi, c.dp * 2 + c.ep);
        }
    }

    #[test]
    fn dp_groups_are_disjoint_by_ep_pp() {
        let m = Mesh::new(Topology::grid(2, 2, 1));
        let (g0, _) = m.dp_group(m.rank(MeshCoord { dp: 0, ep: 0, pp: 0 }));
        let (g1, _) = m.dp_group(m.rank(MeshCoord { dp: 0, ep: 1, pp: 0 }));
        assert!(!Arc::ptr_eq(g0, g1));
        let (g0b, _) = m.dp_group(m.rank(MeshCoord { dp: 1, ep: 0, pp: 0 }));
        assert!(Arc::ptr_eq(g0, g0b));
    }

    #[test]
    fn pp_neighbours_chain() {
        let m = Mesh::new(Topology::grid(1, 1, 4));
        assert_eq!(m.pp_neighbours(0), (None, Some(1)));
        assert_eq!(m.pp_neighbours(2), (Some(1), Some(3)));
        assert_eq!(m.pp_neighbours(3), (Some(2), None));
    }

    #[test]
    fn cross_thread_dp_allreduce_via_mesh() {
        let m = Mesh::new(Topology::grid(2, 2, 1));
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    let (g, i) = m.dp_group(r);
                    g.run(
                        i,
                        CollectiveOp::Allreduce {
                            data: vec![m.coord(r).dp as f32],
                            red: Reduce::Sum,
                            dt: ReduceDtype::F32,
                        },
                    )
                    .unwrap()
                    .values()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![1.0]); // 0 + 1
        }
    }

    #[test]
    fn node_size_places_groups_on_the_hierarchy() {
        // 8 ranks, 2 per node: the world and the contiguous dp groups
        // span nodes with cohabiting members → hierarchical
        let m = Mesh::new(Topology::grid(8, 1, 1).with_node_size(2));
        assert!(m.world_group().is_hierarchical());
        let (dg, _) = m.dp_group(0);
        assert!(dg.is_hierarchical());
        // flat placement: nothing hierarchical (bit-identical baseline)
        let m = Mesh::new(Topology::grid(8, 1, 1));
        assert!(!m.world_group().is_hierarchical());
        // whole mesh inside one node: flat again, but intra-priced
        let m = Mesh::new(Topology::grid(2, 2, 1).with_node_size(4));
        assert!(!m.world_group().is_hierarchical());
    }

    #[test]
    fn traffic_splits_by_node_locality() {
        // same collective on a flat mesh and a 2-ranks-per-node mesh:
        // hierarchical placement must strictly cut the inter-node bytes
        let run_world = |m: &Arc<Mesh>| {
            let handles: Vec<_> = (0..4)
                .map(|r| {
                    let m = Arc::clone(m);
                    std::thread::spawn(move || {
                        m.world_group()
                            .run(
                                r,
                                CollectiveOp::Allreduce {
                                    data: vec![1.0f32; 16],
                                    red: Reduce::Sum,
                                    dt: ReduceDtype::F32,
                                },
                            )
                            .unwrap()
                            .values()
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        };
        let flat = Mesh::new(Topology::grid(4, 1, 1));
        run_world(&flat);
        let hier = Mesh::new(Topology::grid(4, 1, 1).with_node_size(2));
        run_world(&hier);
        let ft = flat.traffic();
        let ht = hier.traffic();
        assert_eq!(ft.intra_bytes, 0);
        assert!(ft.inter_bytes > 0);
        assert!(ht.intra_bytes > 0);
        // 2 nodes of 2: the leaders exchange is half the flat world's
        assert!(
            ht.inter_bytes * 2 <= ft.inter_bytes,
            "hier {} vs flat {}",
            ht.inter_bytes,
            ft.inter_bytes
        );
    }
}
