//! Checkpointing (paper §4): sharded, topology-elastic checkpoints with
//! async zero-copy snapshots.
//!
//! The subsystem has three layers:
//!
//! * [`state`] — the `TrainState`/`StatePart` registry: every stateful
//!   component (parameter segments, per-segment AdamW moments,
//!   step/metrics scalars, PRNG streams) exports named, typed parts whose
//!   `F32` payloads are O(1) `Arc` captures annotated with *global*
//!   parameter runs.
//! * [`Checkpointer`] — each rank writes only the shards it owns per the
//!   plan's segment layout (the paper's DP-scattered writes), serialized
//!   on a background writer and committed via write-temp + fsync +
//!   manifest-rename two-phase commit into a keep-`k` ring of slots.
//! * [`reshard`] — resume is plan-agnostic: [`ResumeState`] re-slices the
//!   saved global runs through the resuming plan's layouts, so a dp2×ep2
//!   checkpoint resumes under dp4 (and vice versa). True state mismatches
//!   fail with stable `checkpoint resume failed [<check>]` strings.
//!
//! The legacy monolithic [`Checkpoint`] blob remains for *model-only*
//! persistent checkpoints (the paper's rewind-past-divergence files) and
//! for reading old files; writing an untagged checkpoint is no longer
//! possible — every save records a plan fingerprint.

mod checkpointer;
pub mod reshard;
pub mod state;

pub use checkpointer::{
    inspect, Checkpointer, CkptPolicy, CkptStats, SavedCheckpoint, SavedPart,
};
pub use reshard::ResumeState;
pub use state::{
    capture_rank_state, restore_optimizer, GlobalRun, LocalMap, PartPayload, StatePart,
    TrainState,
};

use crate::util::json::Json;
use crate::Result;
use anyhow::{anyhow, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// FNV-1a over the byte image — cheap corruption detection.
pub(crate) fn checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

pub(crate) fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode little-endian f32s. A byte length that is not a multiple of 4
/// is a **hard decode error** (a truncated or corrupt payload), never a
/// silent drop of the trailing bytes.
pub(crate) fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(anyhow!(
            "f32 payload length {} is not a multiple of 4 — truncated or corrupt",
            b.len()
        ));
    }
    Ok(b.chunks_exact(4)
        .map(|w| f32::from_le_bytes(w.try_into().unwrap()))
        .collect())
}

pub(crate) fn u16s_to_bytes(v: &[u16]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 2);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode little-endian bf16 storage words. An odd byte length is a
/// **hard decode error**, mirroring [`bytes_to_f32s`].
pub(crate) fn bytes_to_u16s(b: &[u8]) -> Result<Vec<u16>> {
    if b.len() % 2 != 0 {
        return Err(anyhow!(
            "bf16 payload length {} is not a multiple of 2 — truncated or corrupt",
            b.len()
        ));
    }
    Ok(b.chunks_exact(2)
        .map(|w| u16::from_le_bytes(w.try_into().unwrap()))
        .collect())
}

/// Legacy full or model-only checkpoint payload (one global blob). New
/// training-state checkpoints go through the sharded [`Checkpointer`];
/// this type remains for persistent model-only checkpoints and for
/// reading files written before the redesign.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: usize,
    pub params: Vec<f32>,
    /// optimizer moments (empty for model-only checkpoints; the paper
    /// restarts such checkpoints with fresh optimizer state)
    pub moments: Vec<f32>,
    /// serialized plan fingerprint (see
    /// [`crate::coordinator::JobSpec::fingerprint`]). Required on every
    /// write; `None` only for legacy files read back from disk.
    pub plan: Option<String>,
}

impl Checkpoint {
    /// Model-only checkpoint from an `Arc`-backed parameter tensor (e.g.
    /// [`crate::coordinator::TrainReport::final_params`]). The single copy
    /// here is the serialization boundary — nothing upstream cloned. The
    /// plan fingerprint is required: the old `.with_plan(..)` footgun
    /// (forgetting it produced untagged checkpoints) is gone.
    pub fn model_only(
        step: usize,
        params: &crate::runtime::Tensor,
        plan: &str,
    ) -> Result<Checkpoint> {
        Ok(Checkpoint {
            step,
            params: params.to_f32_vec()?,
            moments: Vec::new(),
            plan: Some(plan.to_string()),
        })
    }

    /// Resume-compatibility gate for the *legacy* blob format.
    ///
    /// * a different **model** is always an error (`[model]` — a
    ///   different parameter space cannot be resharded);
    /// * **model-only** checkpoints load under any topology (their params
    ///   are the global vector);
    /// * a full legacy blob under a different topology/sharding is still
    ///   rejected — its flat moment vector records no shard geometry, so
    ///   it cannot be resharded; the sharded [`Checkpointer`] path is the
    ///   topology-elastic one.
    ///
    /// Legacy untagged checkpoints (no recorded plan) pass.
    pub fn ensure_plan(&self, expected: &str) -> Result<()> {
        let Some(p) = &self.plan else { return Ok(()) };
        let model = |fp: &str| fp.split('/').next().unwrap_or("");
        if model(p) != model(expected) {
            return Err(crate::ft::checks::err(
                crate::ft::checks::RESUME,
                "model",
                format!(
                    "checkpoint was written for `{p}`, resuming `{expected}` — a \
                     different model cannot be resharded"
                ),
            ));
        }
        if self.is_model_only() {
            return Ok(());
        }
        // fingerprint shape: model/dpX-epY-ppZ/mode/schedule/mbN/comm
        let state_key = |fp: &str| fp.split('/').take(3).collect::<Vec<_>>().join("/");
        if state_key(p) != state_key(expected) {
            return Err(anyhow!(
                "checkpoint parallelism plan mismatch: saved under `{p}`, resuming \
                 with `{expected}` — legacy full-blob checkpoints do not reshard; \
                 use the sharded `ckpt::Checkpointer` (JobSpecBuilder::checkpoint_dir) \
                 for topology-elastic resume, or restart from a model-only checkpoint"
            ));
        }
        Ok(())
    }

    pub fn is_model_only(&self) -> bool {
        self.moments.is_empty()
    }

    /// Write the blob. Refuses untagged checkpoints: the plan fingerprint
    /// must be recorded (legacy untagged files can still be *read*).
    pub fn write(&self, dir: &Path) -> Result<()> {
        let plan = self.plan.as_deref().ok_or_else(|| {
            anyhow!(
                "refusing to write an untagged checkpoint: record the plan \
                 fingerprint (JobSpec::fingerprint) in `Checkpoint::plan`"
            )
        })?;
        std::fs::create_dir_all(dir)?;
        let pbytes = f32s_to_bytes(&self.params);
        let mbytes = f32s_to_bytes(&self.moments);
        std::fs::write(dir.join("params.bin"), &pbytes)?;
        std::fs::write(dir.join("moments.bin"), &mbytes)?;
        let mut meta = BTreeMap::new();
        meta.insert("step".to_string(), Json::Num(self.step as f64));
        meta.insert("params_len".to_string(), Json::Num(self.params.len() as f64));
        meta.insert("moments_len".to_string(), Json::Num(self.moments.len() as f64));
        meta.insert("plan".to_string(), Json::Str(plan.to_string()));
        meta.insert(
            "checksum".to_string(),
            Json::Str(format!("{:016x}", checksum(&pbytes) ^ checksum(&mbytes))),
        );
        // metadata written LAST: its presence + matching checksum marks a
        // complete checkpoint
        std::fs::write(dir.join("meta.json"), Json::Obj(meta).to_string())?;
        Ok(())
    }

    pub fn read(dir: &Path) -> Result<Checkpoint> {
        let meta_text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("no metadata in {dir:?}"))?;
        let meta = Json::parse(&meta_text).map_err(|e| anyhow!("{e}"))?;
        let pbytes = std::fs::read(dir.join("params.bin"))?;
        let mbytes = std::fs::read(dir.join("moments.bin"))?;
        let want = meta.req("checksum").as_str().unwrap_or("").to_string();
        let got = format!("{:016x}", checksum(&pbytes) ^ checksum(&mbytes));
        if want != got {
            return Err(anyhow!("checksum mismatch in {dir:?}"));
        }
        Ok(Checkpoint {
            step: meta.req("step").as_usize().unwrap(),
            params: bytes_to_f32s(&pbytes)
                .with_context(|| format!("decoding params in {dir:?}"))?,
            moments: bytes_to_f32s(&mbytes)
                .with_context(|| format!("decoding moments in {dir:?}"))?,
            plan: meta
                .get("plan")
                .and_then(|p| p.as_str())
                .map(|s| s.to_string()),
        })
    }
}

/// Dual checkpointing (paper §4) for the legacy blob format: two slots,
/// write to the *older* one, so a failure mid-write never destroys the
/// only valid checkpoint. The sharded [`Checkpointer`] generalizes this
/// to a keep-`k` ring with two-phase commits; `DualCheckpointer` remains
/// for the model-only blob path.
pub struct DualCheckpointer {
    root: PathBuf,
}

impl DualCheckpointer {
    pub fn new(root: &Path) -> DualCheckpointer {
        DualCheckpointer { root: root.to_path_buf() }
    }

    pub fn slot_dir(&self, slot: usize) -> PathBuf {
        self.root.join(format!("ckpt-{}", slot + 1))
    }

    fn slot_step(&self, slot: usize) -> Option<usize> {
        Checkpoint::read(&self.slot_dir(slot)).ok().map(|c| c.step)
    }

    /// Slot chosen for the next write: the invalid one, else the older.
    pub fn next_slot(&self) -> usize {
        match (self.slot_step(0), self.slot_step(1)) {
            (None, _) => 0,
            (_, None) => 1,
            (Some(a), Some(b)) => {
                if a <= b {
                    0
                } else {
                    1
                }
            }
        }
    }

    pub fn save(&self, ckpt: &Checkpoint) -> Result<PathBuf> {
        let dir = self.slot_dir(self.next_slot());
        // remove stale metadata first so a crash mid-write leaves the slot
        // *invalid* rather than stale-but-valid-looking
        let _ = std::fs::remove_file(dir.join("meta.json"));
        ckpt.write(&dir)?;
        Ok(dir)
    }

    /// Newest valid checkpoint, if any.
    pub fn load_latest(&self) -> Option<Checkpoint> {
        let a = Checkpoint::read(&self.slot_dir(0)).ok();
        let b = Checkpoint::read(&self.slot_dir(1)).ok();
        match (a, b) {
            (Some(x), Some(y)) => Some(if x.step >= y.step { x } else { y }),
            (Some(x), None) => Some(x),
            (None, Some(y)) => Some(y),
            (None, None) => None,
        }
    }
}

/// Persistent model-only checkpoints (paper §4): params only (4 bytes vs
/// 12 bytes/param here; the paper quotes 8× for BF16+AdamW), kept at every
/// interval forever so training can rewind past a divergence.
pub struct PersistentCheckpointer {
    root: PathBuf,
}

impl PersistentCheckpointer {
    pub fn new(root: &Path) -> PersistentCheckpointer {
        PersistentCheckpointer { root: root.to_path_buf() }
    }

    pub fn save(&self, step: usize, params: &[f32], plan: &str) -> Result<PathBuf> {
        let dir = self.root.join(format!("model-{step:08}"));
        Checkpoint {
            step,
            params: params.to_vec(),
            moments: Vec::new(),
            plan: Some(plan.to_string()),
        }
        .write(&dir)?;
        Ok(dir)
    }

    /// All persisted steps, sorted.
    pub fn steps(&self) -> Vec<usize> {
        let mut v: Vec<usize> = std::fs::read_dir(&self.root)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        e.file_name()
                            .to_str()
                            .and_then(|n| n.strip_prefix("model-").map(String::from))
                    })
                    .filter_map(|s| s.parse().ok())
                    .collect()
            })
            .unwrap_or_default();
        v.sort_unstable();
        v
    }

    /// Load the newest model-only checkpoint at or before `step` — the
    /// paper's "track back to a good training regime".
    pub fn load_at_or_before(&self, step: usize) -> Option<Checkpoint> {
        let s = *self.steps().iter().filter(|&&s| s <= step).next_back()?;
        Checkpoint::read(&self.root.join(format!("model-{s:08}"))).ok()
    }
}

/// DP-scattered model checkpointing (paper §4): model-parallel shard `m`
/// is written by DP index `d = m % DP`, spreading filesystem load. The
/// [`Checkpointer`] applies the same ownership idea at optimizer-shard
/// granularity; this helper remains as the paper's literal formulation.
pub fn dp_scattered_assignment(n_shards: usize, dp: usize) -> Vec<usize> {
    (0..n_shards).map(|m| m % dp).collect()
}

/// Write model-parallel shards with the scattered assignment; `my_dp` only
/// writes the shards it owns. Shard files carry their own checksums.
pub fn write_scattered_shards(
    root: &Path,
    my_dp: usize,
    dp: usize,
    shards: &[(usize, Vec<f32>)],
) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(root)?;
    let mut written = Vec::new();
    for (m, data) in shards {
        if m % dp != my_dp {
            continue;
        }
        let bytes = f32s_to_bytes(data);
        let path = root.join(format!("shard-{m:04}.bin"));
        std::fs::write(&path, &bytes)?;
        let meta = format!(
            "{{\"shard\":{m},\"writer_dp\":{my_dp},\"checksum\":\"{:016x}\"}}",
            checksum(&bytes)
        );
        std::fs::write(root.join(format!("shard-{m:04}.json")), meta)?;
        written.push(path);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Group, ReduceDtype};
    use crate::optim::sharded::{SegmentSpec, ShardedOptimizer};
    use crate::optim::AdamParams;
    use crate::runtime::Tensor;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("optimus-ck-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    const FP: &str = "mula-tiny/dp2-ep1-pp1/so/1f1b/mb2/allgather";

    fn ck(step: usize) -> Checkpoint {
        Checkpoint {
            step,
            params: (0..64).map(|i| i as f32 + step as f32).collect(),
            moments: vec![0.5; 128],
            plan: Some(FP.to_string()),
        }
    }

    #[test]
    fn plan_fingerprint_roundtrips_and_gates_resume() {
        let d = tmp("plan");
        let fp = "mula-tiny/dp1-ep2-pp2/epso/1f1b/mb2/allgather";
        let mut c = ck(5);
        c.plan = Some(fp.to_string());
        c.write(&d).unwrap();
        let c = Checkpoint::read(&d).unwrap();
        assert_eq!(c.plan.as_deref(), Some(fp));
        // matching plan resumes
        c.ensure_plan(fp).unwrap();
        // execution knobs that don't shape checkpoint state may change
        c.ensure_plan("mula-tiny/dp1-ep2-pp2/epso/gpipe/mb4/all2all")
            .unwrap();
        // a legacy full blob under a different topology is still rejected
        // (its flat moments cannot reshard) and points at the elastic path
        let e = c
            .ensure_plan("mula-tiny/dp2-ep1-pp1/so/1f1b/mb2/allgather")
            .unwrap_err()
            .to_string();
        assert!(e.contains("parallelism plan mismatch"), "{e}");
        assert!(e.contains("topology-elastic"), "{e}");
        // a different model is a stable [model] error
        let e = c
            .ensure_plan("mula-big/dp1-ep2-pp2/epso/1f1b/mb2/allgather")
            .unwrap_err()
            .to_string();
        assert!(e.contains("checkpoint resume failed [model]"), "{e}");
        // model-only checkpoints load under ANY topology of the model
        let mo = Checkpoint::model_only(5, &Tensor::f32(vec![1.0; 8], vec![8]), fp).unwrap();
        mo.ensure_plan("mula-tiny/dp8-ep1-pp1/so/1f1b/mb2/allgather")
            .unwrap();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn roundtrip_and_corruption_detection() {
        let d = tmp("rt");
        ck(7).write(&d).unwrap();
        assert_eq!(Checkpoint::read(&d).unwrap(), ck(7));
        let mut b = std::fs::read(d.join("params.bin")).unwrap();
        b[3] ^= 0xff;
        std::fs::write(d.join("params.bin"), b).unwrap();
        assert!(Checkpoint::read(&d).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn truncated_payload_is_a_hard_decode_error() {
        // satellite: chunks_exact silently dropped trailing bytes before
        let e = bytes_to_f32s(&[0u8; 6]).unwrap_err().to_string();
        assert!(e.contains("multiple of 4"), "{e}");
        assert_eq!(bytes_to_f32s(&[]).unwrap(), Vec::<f32>::new());
        // end-to-end: craft a file whose checksum matches its truncated
        // payload — the decode (not the checksum) must reject it
        let d = tmp("trunc");
        std::fs::create_dir_all(&d).unwrap();
        let pbytes = vec![1u8, 2, 3, 4, 5, 6];
        let mbytes: Vec<u8> = Vec::new();
        std::fs::write(d.join("params.bin"), &pbytes).unwrap();
        std::fs::write(d.join("moments.bin"), &mbytes).unwrap();
        let meta = format!(
            "{{\"checksum\":\"{:016x}\",\"step\":1}}",
            checksum(&pbytes) ^ checksum(&mbytes)
        );
        std::fs::write(d.join("meta.json"), meta).unwrap();
        let e = format!("{:#}", Checkpoint::read(&d).unwrap_err());
        assert!(e.contains("multiple of 4"), "{e}");
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn odd_length_bf16_payload_is_a_hard_decode_error() {
        // satellite: a bf16 shard with an odd byte count is truncated or
        // corrupt — never silently dropped to the nearest whole word
        let e = bytes_to_u16s(&[0u8; 3]).unwrap_err().to_string();
        assert!(e.contains("multiple of 2"), "{e}");
        assert!(e.contains("3"), "{e}");
        assert_eq!(bytes_to_u16s(&[]).unwrap(), Vec::<u16>::new());
        assert_eq!(bytes_to_u16s(&[0x80, 0x3f]).unwrap(), vec![0x3f80]);
    }

    /// bf16 parameter shards commit at half width, record their dtype in
    /// the manifest, decode exactly on resume, and gate a `--dtype f32`
    /// resume with the stable `[dtype]` string.
    #[test]
    fn bf16_checkpoint_half_width_roundtrip_and_dtype_gate() {
        let d = tmp("bf16");
        let n = 32usize;
        let vals: Vec<f32> = (0..n).map(|i| (i as f32 * 0.31).sin()).collect();
        let t = Tensor::from_f32(crate::runtime::Dtype::Bf16, vals, vec![n]);
        let mut st = TrainState::default();
        st.push_bf16(
            "params.s0",
            t.clone(),
            vec![GlobalRun { local_start: 0, global_start: 0, len: n }],
        );
        let ck = Checkpointer::new(
            &d,
            "mula-tiny/dp1-ep1-pp1/so/1f1b/mb2/allgather/bf16",
            1,
            &sync_policy(&d),
        )
        .unwrap();
        ck.submit(1, 0, st).unwrap();
        ck.drain().unwrap();
        // half-width payload: 2 bytes per parameter on disk, and the
        // stats feed the perf gate's per-dtype checkpoint-size column
        let shard = d.join("ckpt-00000001").join("r0.params.s0.bin");
        assert_eq!(std::fs::metadata(&shard).unwrap().len(), 2 * n as u64);
        assert_eq!(ck.stats().bytes_written, 2 * n as u64);
        let saved = SavedCheckpoint::load_latest(&d).unwrap();
        assert_eq!(saved.parts[0].dtype, "bf16");
        let rs = ResumeState::open(&saved).unwrap();
        assert_eq!(rs.param_dtype(), "bf16");
        rs.validate_dtype("bf16").unwrap();
        let e = rs.validate_dtype("f32").unwrap_err().to_string();
        assert!(e.contains("checkpoint resume failed [dtype]"), "{e}");
        // bf16 storage decodes exactly: the assembled global vector is
        // bit-identical to the tensor's own decoded view
        let got = rs.assemble_params(n).unwrap();
        for (g, v) in got.iter().zip(t.to_f32_vec().unwrap().iter()) {
            assert_eq!(g.to_bits(), v.to_bits());
        }
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn untagged_writes_are_refused_but_legacy_reads_pass() {
        let d = tmp("legacy");
        // the new save API cannot produce untagged checkpoints
        let mut c = ck(3);
        c.plan = None;
        let e = c.write(&d).unwrap_err().to_string();
        assert!(e.contains("untagged"), "{e}");
        // hand-write a legacy untagged file: reads still pass
        std::fs::create_dir_all(&d).unwrap();
        let pbytes = f32s_to_bytes(&c.params);
        let mbytes = f32s_to_bytes(&c.moments);
        std::fs::write(d.join("params.bin"), &pbytes).unwrap();
        std::fs::write(d.join("moments.bin"), &mbytes).unwrap();
        let meta = format!(
            "{{\"checksum\":\"{:016x}\",\"step\":3}}",
            checksum(&pbytes) ^ checksum(&mbytes)
        );
        std::fs::write(d.join("meta.json"), meta).unwrap();
        let r = Checkpoint::read(&d).unwrap();
        assert_eq!(r.plan, None);
        r.ensure_plan(FP).unwrap();
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn dual_alternates_and_survives_failed_write() {
        let d = tmp("dual");
        let dual = DualCheckpointer::new(&d);
        assert!(dual.load_latest().is_none());
        dual.save(&ck(1000)).unwrap();
        dual.save(&ck(2000)).unwrap();
        // next write goes to the *older* slot (holding step 1000)
        let slot = dual.next_slot();
        assert_eq!(dual.slot_step(slot), Some(1000));
        // simulate a crash mid-write at step 3000
        let dir = dual.slot_dir(slot);
        let _ = std::fs::remove_file(dir.join("meta.json"));
        std::fs::write(dir.join("params.bin"), b"garbage").unwrap();
        // the other slot (step 2000) must still load
        let latest = dual.load_latest().unwrap();
        assert_eq!(latest.step, 2000);
        // recovery resumes the alternation
        dual.save(&ck(3000)).unwrap();
        assert_eq!(dual.load_latest().unwrap().step, 3000);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn persistent_rewinds_past_divergence() {
        let d = tmp("persist");
        let p = PersistentCheckpointer::new(&d);
        for step in [1000, 2000, 3000] {
            p.save(step, &ck(step).params, FP).unwrap();
        }
        assert_eq!(p.steps(), vec![1000, 2000, 3000]);
        // diverged at 2500: rewind to 2000, fresh optimizer state
        let c = p.load_at_or_before(2500).unwrap();
        assert_eq!(c.step, 2000);
        assert!(c.is_model_only());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn scattered_assignment_spreads_writers() {
        // paper's example: 12-way model parallelism on 12 nodes
        let a = dp_scattered_assignment(12, 12);
        assert_eq!(a, (0..12).collect::<Vec<usize>>());
        let a = dp_scattered_assignment(8, 4);
        for d in 0..4 {
            assert_eq!(a.iter().filter(|&&x| x == d).count(), 2);
        }
    }

    #[test]
    fn scattered_writes_only_owned_shards() {
        let d = tmp("scat");
        let shards: Vec<(usize, Vec<f32>)> =
            (0..6).map(|m| (m, vec![m as f32; 8])).collect();
        for my in 0..3 {
            assert_eq!(write_scattered_shards(&d, my, 3, &shards).unwrap().len(), 2);
        }
        assert_eq!(std::fs::read_dir(&d).unwrap().count(), 12);
        std::fs::remove_dir_all(&d).unwrap();
    }

    // ----------------------------------------------------------------
    // The sharded Checkpointer + elastic reshard
    // ----------------------------------------------------------------

    fn sync_policy(dir: &Path) -> CkptPolicy {
        CkptPolicy {
            dir: Some(dir.to_path_buf()),
            every: 1,
            asynchronous: false,
            keep: 2,
        }
    }

    fn one_part_state(vals: Vec<f32>) -> TrainState {
        let n = vals.len();
        let mut st = TrainState::default();
        st.push_f32(
            "params.s0",
            Tensor::f32(vals, vec![n]),
            vec![GlobalRun { local_start: 0, global_start: 0, len: n }],
        );
        st
    }

    #[test]
    fn policy_gates() {
        let off = CkptPolicy::default();
        assert!(!off.enabled() && !off.due(10));
        let on = sync_policy(Path::new("/tmp/x"));
        assert!(on.due(3) && !on.due(0));
        assert!(on.invalid_reason().is_none());
        assert!(CkptPolicy { every: 0, ..on.clone() }
            .invalid_reason()
            .unwrap()
            .contains("interval"));
        assert!(CkptPolicy { keep: 1, ..on }
            .invalid_reason()
            .unwrap()
            .contains("keep"));
    }

    #[test]
    fn two_phase_commit_keep_k_and_inspect() {
        let d = tmp("tpc");
        let ck = Checkpointer::new(&d, FP, 1, &sync_policy(&d)).unwrap();
        for step in [1usize, 2, 3] {
            ck.submit(step, 0, one_part_state(vec![step as f32; 8])).unwrap();
        }
        ck.drain().unwrap();
        let st = ck.stats();
        assert_eq!(st.commits, 3);
        assert_eq!(st.last_commit_step, Some(3));
        // keep-2 ring: the oldest slot is pruned, newest two remain
        assert!(!d.join("ckpt-00000001").exists());
        assert!(d.join("ckpt-00000002").exists());
        let latest = SavedCheckpoint::load_latest(&d).unwrap();
        assert_eq!((latest.step, latest.world), (3, 1));
        assert_eq!(latest.plan, FP);
        let s = inspect(&d).unwrap();
        assert!(s.contains("ckpt-00000003") && s.contains("VALID"), "{s}");
        assert!(s.contains("r0.params.s0.bin"), "{s}");
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn partial_submission_never_commits() {
        let d = tmp("partial");
        let ck = Checkpointer::new(&d, FP, 2, &sync_policy(&d)).unwrap();
        // only rank 0 of 2 lands (rank 1 "died"): no commit, staging only
        ck.submit(5, 0, one_part_state(vec![1.0; 4])).unwrap();
        ck.drain().unwrap();
        assert_eq!(ck.stats().commits, 0);
        assert!(SavedCheckpoint::load_latest(&d).is_none());
        assert!(d.join(".tmp-00000005").exists());
        drop(ck);
        // the next attach cleans the stale staging dir
        let _ck2 = Checkpointer::new(&d, FP, 2, &sync_policy(&d)).unwrap();
        assert!(!d.join(".tmp-00000005").exists());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn async_writer_commits_after_drain() {
        let d = tmp("async");
        let pol = CkptPolicy { asynchronous: true, ..sync_policy(&d) };
        let ck = Checkpointer::new(&d, FP, 1, &pol).unwrap();
        ck.submit(4, 0, one_part_state((0..16).map(|i| i as f32).collect()))
            .unwrap();
        ck.drain().unwrap();
        assert_eq!(ck.stats().commits, 1);
        let saved = SavedCheckpoint::load_latest(&d).unwrap();
        assert_eq!(saved.step, 4);
        let rs = ResumeState::open(&saved).unwrap();
        let got = rs.assemble_params(16).unwrap();
        assert_eq!(got, (0..16).map(|i| i as f32).collect::<Vec<f32>>());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn missing_shard_file_is_a_manifest_violation() {
        let d = tmp("missing");
        let ck = Checkpointer::new(&d, FP, 1, &sync_policy(&d)).unwrap();
        ck.submit(1, 0, one_part_state(vec![1.0; 4])).unwrap();
        ck.drain().unwrap();
        // the manifest survives but a shard file vanishes (partial
        // restore of a backup, filesystem loss): open must fail with the
        // stable [manifest] string, not a bare io error
        std::fs::remove_file(d.join("ckpt-00000001").join("r0.params.s0.bin")).unwrap();
        let saved = SavedCheckpoint::load_latest(&d).unwrap();
        let e = ResumeState::open(&saved).unwrap_err().to_string();
        assert!(e.contains("checkpoint resume failed [manifest]"), "{e}");
        assert!(e.contains("r0.params.s0.bin"), "{e}");
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn corrupt_newest_slot_falls_back_to_older() {
        let d = tmp("fallback");
        let ck = Checkpointer::new(&d, FP, 1, &sync_policy(&d)).unwrap();
        ck.submit(1, 0, one_part_state(vec![1.0; 4])).unwrap();
        ck.submit(2, 0, one_part_state(vec![2.0; 4])).unwrap();
        ck.drain().unwrap();
        // damage the newest slot's shard payload (manifest stays valid)
        std::fs::write(d.join("ckpt-00000002").join("r0.params.s0.bin"), b"bad!").unwrap();
        let all = SavedCheckpoint::load_all(&d);
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].step, 2);
        let e = ResumeState::open(&all[0]).unwrap_err().to_string();
        assert!(e.contains("checkpoint resume failed [checksum]"), "{e}");
        // the resume walk falls back to the older, intact checkpoint
        let rs = ResumeState::open(&all[1]).unwrap();
        assert_eq!(rs.step(), 1);
        assert_eq!(rs.assemble_params(4).unwrap(), vec![1.0; 4]);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn save_api_requires_a_fingerprint() {
        let d = tmp("nofp");
        assert!(Checkpointer::new(&d, "", 1, &sync_policy(&d)).is_err());
        let _ = std::fs::remove_dir_all(&d);
    }

    /// The elastic core: shards saved under one (interleaved, EP-style)
    /// layout re-slice bitwise onto a different (contiguous, DP-style)
    /// layout, and every true-mismatch check fires its stable string.
    #[test]
    fn reshard_roundtrip_is_bitwise_across_topologies() {
        let d = tmp("reshard");
        let n = 40usize;
        let g_params: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let g_m: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
        // "dp2×ep2-like" save layout: two ranks with interleaved global runs
        let maps = [
            LocalMap::from_copies(&[(0, 0, 10), (20, 10, 10)]).unwrap(),
            LocalMap::from_copies(&[(10, 0, 10), (30, 10, 10)]).unwrap(),
        ];
        let ck = Checkpointer::new(&d, "toy/dp2-ep2-pp1/epso/1f1b/mb2/allgather", 2,
            &sync_policy(&d)).unwrap();
        for (r, map) in maps.iter().enumerate() {
            let runs = map.project(0, 20);
            let extract = |src: &[f32]| {
                let mut local = vec![0.0f32; 20];
                for run in &runs {
                    local[run.local_start..run.local_start + run.len]
                        .copy_from_slice(&src[run.global_start..run.global_start + run.len]);
                }
                local
            };
            let mut st = TrainState::default();
            st.push_f32("params.s0", Tensor::f32(extract(&g_params), vec![20]), runs.clone());
            st.push_f32("adam_m.s0", Tensor::f32(extract(&g_m), vec![20]), runs.clone());
            st.push_u64("adam_t.s0", 8);
            ck.submit(7, r, st).unwrap();
        }
        ck.drain().unwrap();
        let saved = SavedCheckpoint::load_latest(&d).unwrap();
        let rs = ResumeState::open(&saved).unwrap();
        rs.validate("toy", n).unwrap();
        assert_eq!(rs.step(), 7);
        assert_eq!(rs.scalars.get("r1.adam_t.s0"), Some(&8.0));
        // the bias-correction counter restores from the saved scalar
        assert_eq!(rs.adam_step(), Some(8));
        // reassembled global vector is bit-identical
        let ap = rs.assemble_params(n).unwrap();
        for (a, b) in ap.iter().zip(g_params.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // re-slice under a "dp4-like" layout: 4 contiguous quarters
        for r in 0..4 {
            let runs = [GlobalRun { local_start: 0, global_start: r * 10, len: 10 }];
            let got = rs.gather("adam_m", &runs, 10).unwrap();
            for (a, b) in got.iter().zip(g_m[r * 10..(r + 1) * 10].iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // stable [<check>] strings for true mismatches
        let e = rs.validate("other", n).unwrap_err().to_string();
        assert!(e.contains("checkpoint resume failed [model]"), "{e}");
        let e = rs.validate("toy", n + 1).unwrap_err().to_string();
        assert!(e.contains("checkpoint resume failed [param-count]"), "{e}");
        let e = rs
            .gather("adam_x", &[GlobalRun { local_start: 0, global_start: 0, len: 1 }], 1)
            .unwrap_err()
            .to_string();
        assert!(e.contains("checkpoint resume failed [coverage]"), "{e}");
        std::fs::remove_dir_all(&d).unwrap();
    }

    /// End-to-end capture → commit → reshard → restore on a *real*
    /// sharded optimizer: resumed training continues bit-identically.
    #[test]
    fn capture_restore_roundtrip_continues_bitwise() {
        let d = tmp("roundtrip");
        let n = 20usize;
        let map = LocalMap::from_copies(&[(0, 0, 12), (30, 12, 8)]).unwrap();
        let mk_opt = || {
            ShardedOptimizer::new(
                vec![
                    SegmentSpec {
                        local_offset: 0,
                        len: 12,
                        group: Group::new(1),
                        group_rank: 0,
                        norm_weight: 1.0,
                    },
                    SegmentSpec {
                        local_offset: 12,
                        len: 8,
                        group: Group::new(1),
                        group_rank: 0,
                        norm_weight: 1.0,
                    },
                ],
                Group::new(1),
                0,
                AdamParams::default(),
                ReduceDtype::F32,
                1.0,
            )
        };
        let grads = |step: usize| -> Vec<f32> {
            (0..n).map(|i| ((i + step * 3) as f32 * 0.21).sin()).collect()
        };
        let mut p1: Vec<f32> = (0..n).map(|i| 0.05 * i as f32 - 0.3).collect();
        let mut opt1 = mk_opt();
        for step in 0..3 {
            opt1.step(&mut p1, &grads(step), 1e-2, true);
        }
        // O(1) capture after step 2, committed through the Checkpointer
        let t = Tensor::f32(p1.clone(), vec![n]);
        let snap = capture_rank_state(&t, &map, &opt1).unwrap();
        let ck = Checkpointer::new(&d, "toy/dp1-ep1-pp1/so/1f1b/mb2/allgather", 1,
            &sync_policy(&d)).unwrap();
        ck.submit(2, 0, snap).unwrap();
        ck.drain().unwrap();
        // resume: fresh optimizer, params + moments re-sliced back
        let rs = ResumeState::open(&SavedCheckpoint::load_latest(&d).unwrap()).unwrap();
        let mut opt2 = mk_opt();
        let mut p2 = rs.gather("params", &map.project(0, n), n).unwrap();
        restore_optimizer(&mut opt2, &map, &rs, 3).unwrap();
        for (a, b) in p1.iter().zip(p2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "restored params differ");
        }
        // continued training is bit-identical to the uninterrupted run
        for step in 3..6 {
            opt1.step(&mut p1, &grads(step), 1e-2, true);
            opt2.step(&mut p2, &grads(step), 1e-2, true);
        }
        for (i, (a, b)) in p1.iter().zip(p2.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "param {i} diverged after resume");
        }
        std::fs::remove_dir_all(&d).unwrap();
    }
}
