//! Small substrates the offline environment forces us to own:
//! PRNG (no `rand`), JSON (no `serde`), CLI (no `clap`),
//! micro-benchmarks (no `criterion`) and property testing (no `proptest`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod proptest;

/// Round `x` up to a multiple of `m`.
pub fn round_up(x: usize, m: usize) -> usize {
    (x + m - 1) / m * m
}

/// Lock a mutex, recovering the data on poison. Outside the `comm/` and
/// `ckpt/` fabrics a poisoned lock means some peer thread panicked
/// mid-update of a read-mostly structure (counters, caches, node lists)
/// whose data is still coherent — propagating the poison panic from here
/// would mask the root cause the harness is trying to surface.
/// `optimus lint` forbids bare `.lock().unwrap()` outside comm/ckpt;
/// this is the sanctioned alternative.
pub fn lock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Budget for wall-clock *upper-bound* assertions in timing-sensitive
/// tests: multiplies `base_secs` by `OPTIMUS_TIME_MULT` when set, else by
/// a generous 4× on CI runners (the `CI` env var) and 1× locally — so the
/// suite stays deterministic on oversubscribed shared hardware without
/// loosening local signal.
pub fn time_budget_secs(base_secs: u64) -> std::time::Duration {
    let mult = std::env::var("OPTIMUS_TIME_MULT")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(if std::env::var_os("CI").is_some() { 4 } else { 1 });
    std::time::Duration::from_secs(base_secs * mult.max(1))
}

/// Split `n` items into `parts` contiguous ranges, padding semantics of
/// ZeRO-1: every shard has ceil(n/parts) logical slots; the last shards may
/// be short or empty. Returns (start, len) per part.
pub fn shard_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let per = (n + parts - 1) / parts;
    (0..parts)
        .map(|i| {
            let s = (i * per).min(n);
            let e = ((i + 1) * per).min(n);
            (s, e - s)
        })
        .collect()
}

/// f32 -> bf16 storage bits, round-to-nearest-even. bf16 is the high 16
/// bits of the f32 layout, so the conversion is a biased shift; NaNs are
/// quieted to a canonical payload so a signalling NaN can never round to
/// an infinity bit pattern.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // canonical quiet NaN, sign preserved
        return ((bits >> 16) as u16 & 0x8000) | 0x7fc1;
    }
    let rounding_bias = 0x7fff + ((bits >> 16) & 1);
    (bits.wrapping_add(rounding_bias) >> 16) as u16
}

/// bf16 storage bits -> f32 (exact: every bf16 value is representable).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Encode a whole f32 slice to bf16 storage.
pub fn f32s_to_bf16s(v: &[f32]) -> Vec<u16> {
    v.iter().map(|&x| f32_to_bf16(x)).collect()
}

/// Decode a whole bf16 slice to f32 (exact).
pub fn bf16s_to_f32s(v: &[u16]) -> Vec<f32> {
    v.iter().map(|&b| bf16_to_f32(b)).collect()
}

/// f32 -> bf16 -> f32 round trip (round-to-nearest-even), used for the
/// paper's bfloat16 gradient-reduction recipe (§2.1) and its ablation.
pub fn bf16_round(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_everything() {
        for n in [0usize, 1, 7, 64, 65, 1000] {
            for p in [1usize, 2, 3, 8] {
                let r = shard_ranges(n, p);
                assert_eq!(r.len(), p);
                let total: usize = r.iter().map(|x| x.1).sum();
                assert_eq!(total, n);
                let mut pos = 0;
                for (s, l) in &r {
                    if *l > 0 {
                        assert_eq!(*s, pos);
                    }
                    pos += l;
                }
            }
        }
    }

    #[test]
    fn bf16_round_is_idempotent_and_close() {
        for &v in &[0.0f32, 1.0, -1.5, 3.14159, 1e-8, 123456.78] {
            let r = bf16_round(v);
            assert_eq!(bf16_round(r), r);
            if v != 0.0 {
                assert!(((r - v) / v).abs() < 0.01, "{v} -> {r}");
            }
        }
    }

    #[test]
    fn bf16_decode_encode_is_identity_for_every_pattern() {
        // exhaustive over the whole 16-bit space: decoding is exact, so
        // re-encoding any non-NaN pattern must return it bit-for-bit
        // (this pins subnormals, ±0, ±inf and the full normal range)
        for b in 0..=u16::MAX {
            let v = bf16_to_f32(b);
            if v.is_nan() {
                // NaN payloads canonicalize to a sign-preserving qNaN
                let q = f32_to_bf16(v);
                assert_eq!(q & 0x7fff, 0x7fc1, "pattern {b:#06x}");
                assert_eq!(q & 0x8000, b & 0x8000, "pattern {b:#06x}");
            } else {
                assert_eq!(f32_to_bf16(v), b, "pattern {b:#06x}");
            }
        }
    }

    #[test]
    fn bf16_encode_rounds_to_nearest_even() {
        // 0x3f80 = 1.0, ulp at this scale = 2^-7; exact halfway points
        // must round to the even-mantissa neighbour on both sides
        assert_eq!(f32_to_bf16(1.00390625), 0x3f80); // tie down to even
        assert_eq!(f32_to_bf16(1.01171875), 0x3f82); // tie up to even
        // non-ties go to the nearest grid point
        assert_eq!(f32_to_bf16(1.0039), 0x3f80);
        assert_eq!(f32_to_bf16(1.0040), 0x3f81);
        // random sweep: relative error of one round is bounded by the
        // 8-bit significand (2^-8), with exact sign preservation
        crate::util::proptest::run_cases(30, |g| {
            for &v in g.vec_f32(256, -1e6, 1e6).iter() {
                let r = bf16_round(v);
                assert!(
                    (r - v).abs() <= v.abs() / 256.0 + f32::MIN_POSITIVE,
                    "{v} -> {r}"
                );
                assert_eq!(r.is_sign_negative(), v.is_sign_negative());
                assert_eq!(bf16_round(r), r, "rounding must be a fixpoint");
            }
        });
    }

    #[test]
    fn bf16_encode_handles_specials() {
        // ±inf map to the bf16 infinities and decode back
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7f80);
        assert_eq!(f32_to_bf16(f32::NEG_INFINITY), 0xff80);
        assert_eq!(bf16_to_f32(0x7f80), f32::INFINITY);
        // overflow saturates to infinity (f32::MAX is above bf16 max)
        assert_eq!(f32_to_bf16(f32::MAX), 0x7f80);
        assert_eq!(f32_to_bf16(-f32::MAX), 0xff80);
        // NaN stays NaN (quieted, sign kept) — never becomes a number
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(f32_to_bf16(-f32::NAN) & 0x8000, 0x8000);
        // f32 subnormals below the bf16 grid round to signed zero
        assert_eq!(f32_to_bf16(f32::from_bits(1)), 0x0000);
        assert_eq!(f32_to_bf16(-f32::from_bits(1)), 0x8000);
        // bf16 subnormals decode exactly (f32 covers their whole range)
        let tiny = bf16_to_f32(0x0001);
        assert!(tiny > 0.0 && tiny < f32::MIN_POSITIVE);
        assert_eq!(f32_to_bf16(tiny), 0x0001);
    }

    #[test]
    fn bf16_slice_codecs_roundtrip() {
        let vals = vec![0.0f32, -1.5, 3.25, 1e30, -2e-30];
        let enc = f32s_to_bf16s(&vals);
        assert_eq!(enc.len(), vals.len());
        let dec = bf16s_to_f32s(&enc);
        // every decoded value is the RNE rounding of its source
        for (v, d) in vals.iter().zip(dec.iter()) {
            assert_eq!(*d, bf16_round(*v));
        }
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }
}
