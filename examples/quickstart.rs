//! Quickstart: preprocess a synthetic corpus, train mula-tiny on 2
//! data-parallel ranks for 30 steps, report the loss curve and the
//! step-time breakdown.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec};
use optimus::data::{corpus, preprocess};

fn main() -> optimus::Result<()> {
    // 1. data pipeline: tokenize -> shuffle -> shard (paper §4)
    let data_dir = std::env::temp_dir().join("optimus-quickstart-data");
    if !data_dir.exists() {
        let files = corpus::data_files(42, 4, 24);
        let st = preprocess::preprocess(&files, 64, 7, &data_dir, 256)?;
        println!(
            "preprocessed: {} files, {} tokens, {} instances, {} shards",
            st.n_files, st.total_tokens, st.n_instances, st.n_shards
        );
    }

    // 2. train: DP=2, sharded AdamW, paper §2.1 recipe scaled down
    let manifest = Manifest::load(&optimus::artifacts_dir())?;
    let spec = JobSpec::new("mula-tiny")
        .data_dir(data_dir)
        .topology(2, 1, 1)
        .steps(30)
        .warmup_steps(4)
        .peak_lr(2e-3)
        .min_lr(2e-4)
        .build()?;
    let report = coordinator::train(&manifest, &spec)?;

    // 3. results
    println!("\nstep  loss    grad_norm");
    for ((s, l), (_, g)) in report.loss.points.iter().zip(report.grad_norm.points.iter()) {
        if s % 5 == 0 || *s == report.loss.points.len() - 1 {
            println!("{s:>4}  {l:.4}  {g:.3}");
        }
    }
    println!(
        "\nfirst loss {:.3} -> last {:.3} | {:.0} tokens/s | breakdown: \
         fwd+bwd {:.2}s opt {:.2}s comm {:.2}s data {:.2}s",
        report.loss.points[0].1,
        report.loss.last().unwrap(),
        report.tokens_per_sec(),
        report.breakdown.fwd_bwd_secs,
        report.breakdown.optimizer_secs,
        report.breakdown.comm_secs,
        report.breakdown.data_secs,
    );
    Ok(())
}
