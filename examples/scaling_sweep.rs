//! Figure 4 reproduction driver: (a) measured loss vs compute scale on
//! real tiny-scale runs (batch grows with DP), and (b) the Aurora
//! analytic model sweeping Mula-220B-A10B from 384 to 12288 tiles with
//! and without FUR.
//!
//! Run: `cargo run --release --example scaling_sweep`

use optimus::cluster::{scaling_efficiency, Aurora};
use optimus::config::models::MULA_220B;
use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec};
use optimus::data::{corpus, preprocess};
use optimus::util::bench::Report;

fn main() -> optimus::Result<()> {
    let data_dir = std::env::temp_dir().join("optimus-scaling-data");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 6, 48), 64, 7, &data_dir, 2048)?;
    }
    let manifest = Manifest::load(&optimus::artifacts_dir())?;

    // --- Fig 4a analog: loss vs compute scale (measured, mula-tiny) ---
    let mut fig4a = Report::new(
        "Fig 4a (measured analog): loss vs compute scale, mula-tiny",
        &["dp_ranks", "global_batch_tokens", "loss@20"],
    );
    for dp in [1usize, 2, 4] {
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data_dir.clone())
            .topology(dp, 1, 1)
            .steps(20)
            .warmup_steps(4)
            .peak_lr(2e-3)
            .build()?;
        let r = coordinator::train(&manifest, &spec)?;
        fig4a.row(&[
            dp.to_string(),
            r.tokens_per_step.to_string(),
            format!("{:.4}", r.loss.tail_mean(3)),
        ]);
    }
    fig4a.print();

    // --- Fig 4b: scaling efficiency from the Aurora model ---
    let hw = Aurora::default();
    let mut fig4b = Report::new(
        "Fig 4b (modeled): Mula-220B-A10B scaling efficiency vs 384 tiles",
        &["tiles", "nodes", "efficiency", "efficiency_FUR"],
    );
    for tiles in [384usize, 768, 1536, 3072, 6144, 12288] {
        let e = scaling_efficiency(&MULA_220B, &hw, 384, tiles, false);
        let ef = scaling_efficiency(&MULA_220B, &hw, 384, tiles, true);
        fig4b.row(&[
            tiles.to_string(),
            (tiles / 12).to_string(),
            format!("{:.3}", e),
            format!("{:.3}", ef),
        ]);
    }
    fig4b.print();
    println!("\npaper: ~0.97 at 768 tiles, ~0.90 plateau from 1536 to 12288;");
    println!("FUR tracks the regular runs (imbalance does not drive the drop).");
    Ok(())
}
