//! Per-rank background batch **prefetcher** — a bounded-queue producer
//! in the `ckpt-writer` / `CommRuntime` mold (one dedicated worker, FIFO
//! channel, accounting counters, poison-free shutdown on drop).
//!
//! A rank's batch-fetch sequence is fully deterministic: `(step, mb)`
//! for `mb` in `0..micro_batches`, step after step, at stream positions
//! the [`TokenCursor`] + [`BatchPlan`](super::BatchPlan) dictate. The
//! producer therefore runs *ahead* of the training thread, assembling
//! the next batches while the current step computes; the consumer's
//! queue pop is the only stall and is accounted as `data_wait_secs`
//! (additive), while the producer's assembly time is `data_prefetch_secs`
//! (hidden, concurrent — the Table-3-style "saved" data time).
//!
//! Correctness never depends on the prediction: a fetch that does not
//! match the predicted head key returns `None` and the caller falls back
//! to a synchronous read (and retires the producer). The stream is
//! read-only and position-addressed, so over-production is idempotent —
//! a killed rank simply drops the queue.

use super::dataset::BatchPlan;
use super::stream::{TokenCursor, TokenStream};
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::time::Instant;

struct Produced {
    step: usize,
    mb: usize,
    batch: Result<Vec<i32>>,
}

/// Handle owned by the rank thread. Dropping it closes the queue; the
/// producer exits on its next send.
pub struct Prefetcher {
    rx: Receiver<Produced>,
    data_rank: usize,
    /// next key the producer will deliver (`None` once the run's steps
    /// are exhausted)
    next: Option<(usize, usize)>,
    micro_batches: usize,
    steps: usize,
    busy_nanos: Arc<AtomicU64>,
}

impl Prefetcher {
    /// Spawn the producer (`data-prefetch-<data_rank>`), starting at key
    /// `start = (step, mb)` and running to the end of the step budget.
    /// The queue holds up to two steps' worth of batches, so a producer
    /// that outruns training backpressures instead of pinning memory.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        stream: Arc<TokenStream>,
        cursor: TokenCursor,
        batches: BatchPlan,
        data_rank: usize,
        rows: usize,
        seq: usize,
        steps: usize,
        start: (usize, usize),
    ) -> Prefetcher {
        let micro_batches = batches.micro_batches.max(1);
        let depth = 2 * micro_batches;
        let (tx, rx) = sync_channel::<Produced>(depth);
        let busy_nanos = Arc::new(AtomicU64::new(0));
        let busy = Arc::clone(&busy_nanos);
        std::thread::Builder::new()
            .name(format!("data-prefetch-{data_rank}"))
            .spawn(move || {
                let (mut step, mut mb) = start;
                while step < steps {
                    let t = Instant::now();
                    let pos = cursor.at_step(step) + batches.offset(data_rank, mb) as u64;
                    let batch = stream.batch_i32(pos, rows, seq);
                    busy.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let failed = batch.is_err();
                    if tx.send(Produced { step, mb, batch }).is_err() || failed {
                        // consumer gone (rank finished or died), or the
                        // stream refused the read (budget) — either way
                        // the error, if any, is already in flight
                        return;
                    }
                    mb += 1;
                    if mb == micro_batches {
                        mb = 0;
                        step += 1;
                    }
                }
            })
            .expect("spawn data-prefetch");
        Prefetcher {
            rx,
            data_rank,
            next: Some(start),
            micro_batches,
            steps,
            busy_nanos,
        }
    }

    /// Pop the batch for `(step, mb)`. Returns `None` when the request
    /// falls outside the predicted sequence (caller falls back to a
    /// synchronous read); `Some(Err(..))` surfaces a producer-side read
    /// failure. Time blocked in the pop accumulates into `wait_secs`.
    pub fn fetch(
        &mut self,
        step: usize,
        data_rank: usize,
        mb: usize,
        wait_secs: &mut f64,
    ) -> Option<Result<Vec<i32>>> {
        if data_rank != self.data_rank || self.next != Some((step, mb)) {
            return None;
        }
        let t = Instant::now();
        let got = self.rx.recv();
        *wait_secs += t.elapsed().as_secs_f64();
        match got {
            Ok(p) if (p.step, p.mb) == (step, mb) => {
                self.next = if mb + 1 < self.micro_batches {
                    Some((step, mb + 1))
                } else if step + 1 < self.steps {
                    Some((step + 1, 0))
                } else {
                    None
                };
                Some(p.batch)
            }
            // producer desync or death: let the caller re-read
            // synchronously (the stream will reproduce any real error)
            _ => None,
        }
    }

    /// Seconds the producer spent assembling batches (hidden behind
    /// training compute).
    pub fn busy_secs(&self) -> f64 {
        self.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corpus, preprocess, Dataset};

    fn fixture(tag: &str) -> (std::path::PathBuf, Arc<TokenStream>) {
        let dir = std::env::temp_dir()
            .join(format!("optimus-prefetch-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        preprocess::preprocess(&corpus::data_files(9, 3, 12), 32, 3, &dir, 64).unwrap();
        let ds = Arc::new(Dataset::open(&dir).unwrap());
        let st = Arc::new(TokenStream::new(ds, 17, 10_000));
        (dir, st)
    }

    #[test]
    fn produces_the_synchronous_sequence() {
        let (dir, st) = fixture("seq");
        let bp = BatchPlan { dp: 2, micro_batch: 2, micro_batches: 3 };
        let cur = TokenCursor::fresh(bp.instances_per_step() as u64);
        let mut pf = Prefetcher::spawn(Arc::clone(&st), cur, bp, 1, 2, 31, 4, (0, 0));
        let mut wait = 0.0;
        for step in 0..4 {
            for mb in 0..3 {
                let got = pf.fetch(step, 1, mb, &mut wait).unwrap().unwrap();
                let pos = cur.at_step(step) + bp.offset(1, mb) as u64;
                assert_eq!(got, st.batch_i32(pos, 2, 31).unwrap(), "step {step} mb {mb}");
            }
        }
        assert!(pf.busy_secs() > 0.0);
        assert!(wait >= 0.0);
        // the sequence is exhausted: further fetches miss
        assert!(pf.fetch(4, 1, 0, &mut wait).is_none());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn out_of_pattern_requests_miss() {
        let (dir, st) = fixture("miss");
        let bp = BatchPlan { dp: 1, micro_batch: 2, micro_batches: 2 };
        let cur = TokenCursor::fresh(bp.instances_per_step() as u64);
        let mut pf = Prefetcher::spawn(Arc::clone(&st), cur, bp, 0, 2, 31, 4, (0, 0));
        let mut wait = 0.0;
        // wrong mb, wrong data_rank, wrong step: all decline (the caller
        // falls back to the synchronous path)
        assert!(pf.fetch(0, 0, 1, &mut wait).is_none());
        assert!(pf.fetch(0, 3, 0, &mut wait).is_none());
        assert!(pf.fetch(2, 0, 0, &mut wait).is_none());
        // the predicted head is still intact afterwards
        assert!(pf.fetch(0, 0, 0, &mut wait).unwrap().is_ok());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn budget_errors_surface_through_the_queue() {
        let (dir, st) = fixture("budget");
        let budget = 4u64; // 2 steps of 2 instances
        let tiny = Arc::new(TokenStream::new(
            Arc::new(Dataset::open(&dir).unwrap()),
            17,
            budget,
        ));
        let _ = st;
        let bp = BatchPlan { dp: 1, micro_batch: 2, micro_batches: 1 };
        let cur = TokenCursor::fresh(2);
        // 3 steps demanded, only 2 in budget: the third batch is an error
        let mut pf = Prefetcher::spawn(tiny, cur, bp, 0, 2, 31, 3, (0, 0));
        let mut wait = 0.0;
        assert!(pf.fetch(0, 0, 0, &mut wait).unwrap().is_ok());
        assert!(pf.fetch(1, 0, 0, &mut wait).unwrap().is_ok());
        let e = pf.fetch(2, 0, 0, &mut wait).unwrap().unwrap_err().to_string();
        assert!(e.contains("data read past validated budget"), "{e}");
        std::fs::remove_dir_all(dir).unwrap();
    }
}
