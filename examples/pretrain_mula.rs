//! End-to-end pretraining driver — the repo's headline validation run.
//!
//! Trains a Mula MoE model (default: `mula-100m`, ~101 M total / ~35 M
//! active parameters — the same OLMoE architecture family as the paper's
//! Mula-7B-A1B) for a few hundred steps on the synthetic corpus with the
//! paper's §2.1 recipe (warmup + cosine, AdamW(0.9, 0.99), wd 0.1, clip
//! 1.0 after warmup, bf16 gradient reduction), logging the loss curve and
//! finishing with the synthetic benchmark suite.
//!
//! Run: `cargo run --release --example pretrain_mula -- [--model mula-100m]
//!      [--steps 300] [--dp 2] [--out runs/pretrain]`
//! Smaller/faster: `--model mula-mini --steps 200`.

use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec};
use optimus::data::{corpus, preprocess};
use optimus::eval;
use optimus::runtime::Engine;
use optimus::util::cli::Args;

fn main() -> optimus::Result<()> {
    let args = Args::from_env();
    let model = args.str_or("model", "mula-100m");
    let steps = args.usize_or("steps", 300);
    let dp = args.usize_or("dp", 2);
    let out = args.str_or("out", "runs/pretrain");

    let manifest = Manifest::load(&optimus::artifacts_dir())?;
    let mm = manifest.config(&model)?;
    println!(
        "pretraining {} — {:.1} M params ({:.1} M active), {} layers, {} experts top-{}",
        model,
        mm.param_count as f64 / 1e6,
        mm.param_count as f64 / 1e6, // refined below for MoE
        mm.hyper.n_layers,
        mm.hyper.n_experts,
        mm.hyper.top_k
    );

    // corpus sized for the run: steps * dp * batch instances
    let data_dir = std::env::temp_dir().join(format!("optimus-pretrain-{model}"));
    if !data_dir.exists() {
        let need = steps * dp * mm.hyper.batch + 64;
        let files = corpus::data_files(42, 8, need / 4 + 16);
        let st = preprocess::preprocess(
            &files, mm.hyper.seq + 1, 7, &data_dir, 4096)?;
        println!("corpus: {} tokens, {} instances", st.total_tokens, st.n_instances);
    }

    let spec = JobSpec::new(&model)
        .data_dir(data_dir)
        .topology(dp, 1, 1)
        .steps(steps)
        .warmup_steps((steps / 10).max(5))
        .peak_lr(4e-4 * 2.0) // tiny-scale analog of the paper's 4e-4
        .min_lr(4e-5)
        .engine_pool(dp.min(4))
        .build()?;

    let t0 = std::time::Instant::now();
    let report = coordinator::train(&manifest, &spec)?;
    let wall = t0.elapsed();

    println!("\nstep  loss");
    let n = report.loss.points.len();
    for (s, l) in &report.loss.points {
        if s % (steps / 20).max(1) == 0 || *s == n - 1 {
            println!("{s:>5}  {l:.4}");
        }
    }
    println!(
        "\n{} steps in {:.1}s — {:.0} tokens/s | mean step {:.3}s | \
         fwd+bwd {:.1}s opt {:.1}s comm {:.1}s data {:.1}s",
        n,
        wall.as_secs_f64(),
        report.tokens_per_sec(),
        report.mean_step_secs(),
        report.breakdown.fwd_bwd_secs,
        report.breakdown.optimizer_secs,
        report.breakdown.comm_secs,
        report.breakdown.data_secs,
    );

    // final benchmark suite (Table 2 machinery)
    let engine = Engine::new_pool(2)?;
    let scores = eval::run_suite(&engine, mm, &report.final_params, 32)?;
    println!("\nbenchmark suite:");
    for (task, score) in &scores {
        println!("  {task:<14} {score:6.1}");
    }
    println!("  {:<14} {:6.1}", "average", eval::average(&scores));

    // persist curves for EXPERIMENTS.md
    std::fs::create_dir_all(&out)?;
    std::fs::write(
        format!("{out}/{model}-loss.csv"),
        report.loss.to_csv(),
    )?;
    println!("\nloss curve -> {out}/{model}-loss.csv");
    Ok(())
}
