//! Async collective submission: a per-rank comm worker that executes
//! collectives off the rank thread so communication overlaps compute.
//!
//! [`CommRuntime`] owns one dedicated worker thread with a FIFO job
//! queue. The nonblocking collective variants on [`super::Group`]
//! (`allreduce_start` / `reduce_scatter_start` / `allgather_start`)
//! submit a closure and return a [`CommHandle`] future; `wait()` blocks
//! until the worker has finished that collective.
//!
//! FIFO submission is the correctness contract: rendezvous rounds on a
//! [`super::Group`] are strictly ordered, so every member must issue its
//! collectives on a group in the same program order — exactly what one
//! lane per rank preserves. Comm-on-comm serialization within a rank
//! mirrors a real NIC anyway; the win is communication running
//! concurrently with the rank thread's *compute* (the pipelined sharded
//! optimizer of DESIGN.md §6, paper §3.2).
//!
//! A collective that panics on the worker (e.g. a poisoned group after a
//! peer death) is captured and re-thrown from `wait()` on the submitting
//! rank thread, so failure semantics match the blocking path and the
//! harness's poison-guard still classifies the root cause.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

type Job = Box<dyn FnOnce() + Send>;

/// Future for one in-flight collective submitted to a [`CommRuntime`].
pub struct CommHandle<T = Vec<f32>> {
    rx: mpsc::Receiver<std::thread::Result<T>>,
}

impl<T> CommHandle<T> {
    /// Block until the collective completes. A panic on the worker
    /// (poisoned group) is re-thrown here, on the submitting thread.
    pub fn wait(self) -> T {
        match self.rx.recv() {
            Ok(Ok(v)) => v,
            Ok(Err(p)) => resume_unwind(p),
            Err(_) => panic!("comm runtime worker dropped an in-flight collective"),
        }
    }
}

/// A single-worker comm lane: FIFO execution plus busy-time accounting
/// (the overlap numerator behind
/// [`StepBreakdown::overlap_secs`](crate::metrics::StepBreakdown)).
/// Dropping the runtime shuts the worker down after the queue drains.
pub struct CommRuntime {
    tx: mpsc::Sender<Job>,
    busy_nanos: Arc<AtomicU64>,
    ops: Arc<AtomicU64>,
}

impl CommRuntime {
    /// Spawn the worker thread (named `comm-<label>`).
    pub fn new(label: &str) -> CommRuntime {
        let (tx, rx) = mpsc::channel::<Job>();
        let busy_nanos = Arc::new(AtomicU64::new(0));
        let ops = Arc::new(AtomicU64::new(0));
        let busy = Arc::clone(&busy_nanos);
        let done = Arc::clone(&ops);
        std::thread::Builder::new()
            .name(format!("comm-{label}"))
            .spawn(move || {
                // jobs never unwind (submit wraps them in catch_unwind),
                // so one poisoned collective doesn't kill the lane
                while let Ok(job) = rx.recv() {
                    let t = Instant::now();
                    job();
                    busy.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    done.fetch_add(1, Ordering::Relaxed);
                }
            })
            .expect("spawn comm worker");
        CommRuntime { tx, busy_nanos, ops }
    }

    /// Enqueue `f`. Jobs run FIFO on the worker; the handle resolves when
    /// `f` returns (or re-throws its panic at `wait`).
    pub fn submit<T, F>(&self, f: F) -> CommHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (rtx, rrx) = mpsc::channel();
        let job: Job = Box::new(move || {
            let r = catch_unwind(AssertUnwindSafe(f));
            let _ = rtx.send(r);
        });
        self.tx.send(job).expect("comm runtime worker gone");
        CommHandle { rx: rrx }
    }

    /// Total seconds the worker has spent inside collectives. The counter
    /// is bumped *after* a job's handle resolves, so a reading taken right
    /// after `wait()` may trail by one job — accounting only.
    pub fn busy_secs(&self) -> f64 {
        self.busy_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Number of jobs the worker has completed.
    pub fn completed_ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_resolves_in_fifo_order() {
        let rt = CommRuntime::new("test-fifo");
        let handles: Vec<CommHandle<usize>> =
            (0..16).map(|i| rt.submit(move || i * 2)).collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.wait(), i * 2);
        }
        assert_eq!(rt.completed_ops(), 16);
    }

    #[test]
    fn worker_survives_a_panicking_job() {
        let rt = CommRuntime::new("test-panic");
        let bad: CommHandle<()> = rt.submit(|| panic!("boom"));
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| bad.wait()));
        assert!(caught.is_err(), "wait must re-throw the job panic");
        // lane still alive afterwards
        let ok = rt.submit(|| 7usize);
        assert_eq!(ok.wait(), 7);
    }

    #[test]
    fn busy_time_accumulates() {
        let rt = CommRuntime::new("test-busy");
        rt.submit(|| std::thread::sleep(std::time::Duration::from_millis(5)))
            .wait();
        // flush: a second job guarantees the first's busy add landed
        rt.submit(|| ()).wait();
        assert!(rt.busy_secs() >= 0.004, "{}", rt.busy_secs());
    }
}
