//! Tiny CLI argument parser (`clap` is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and free
//! positional arguments; typed getters with defaults. Subcommands declare
//! their accepted flags and call [`Args::expect_flags`], which rejects
//! unknown flags with a "did you mean" suggestion instead of silently
//! falling back to defaults (a typo'd `--stpes 500` used to train 50
//! steps).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.into(), v.into());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.into(), v);
                } else {
                    out.flags.insert(rest.into(), "true".into());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    pub fn str_or(&self, k: &str, default: &str) -> String {
        self.get(k).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, k: &str, default: usize) -> usize {
        self.get(k)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{k} wants an integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, k: &str, default: f64) -> f64 {
        self.get(k)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{k} wants a number, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, k: &str, default: bool) -> bool {
        self.get(k)
            .map(|v| matches!(v, "true" | "1" | "yes" | "on"))
            .unwrap_or(default)
    }

    /// Reject any flag not in `allowed`. The error message names the
    /// offending flag, suggests the closest accepted one (edit distance
    /// ≤ 2 or a prefix match), and lists what the subcommand accepts.
    pub fn expect_flags(&self, allowed: &[&str]) -> std::result::Result<(), String> {
        for k in self.flags.keys() {
            if allowed.contains(&k.as_str()) {
                continue;
            }
            let mut msg = format!("unknown flag `--{k}`");
            if let Some(s) = closest(k, allowed) {
                msg.push_str(&format!(" — did you mean `--{s}`?"));
            }
            let list: Vec<String> = allowed.iter().map(|a| format!("--{a}")).collect();
            msg.push_str(&format!("\naccepted flags: {}", list.join(" ")));
            return Err(msg);
        }
        Ok(())
    }
}

/// Closest accepted flag by Levenshtein distance (≤ 2) or prefix match.
fn closest<'a>(typo: &str, allowed: &[&'a str]) -> Option<&'a str> {
    let mut best: Option<(&str, usize)> = None;
    for &a in allowed {
        if a.starts_with(typo) || typo.starts_with(a) {
            return Some(a);
        }
        let d = levenshtein(typo, a);
        if d <= 2 && best.map(|(_, bd)| d < bd).unwrap_or(true) {
            best = Some((a, d));
        }
    }
    best.map(|(a, _)| a)
}

fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn basics() {
        let a = parse("train --steps 100 --lr=0.1 --fur");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f64_or("lr", 0.0), 0.1);
        assert!(a.bool_or("fur", false));
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn bool_flag_followed_by_flag() {
        let a = parse("--a --b 3 tail");
        assert!(a.bool_or("a", false));
        assert_eq!(a.usize_or("b", 0), 3);
        assert_eq!(a.positional, vec!["tail"]);
    }

    #[test]
    fn flag_value_pairs() {
        let a = parse("--name mula-tiny --dp 4");
        assert_eq!(a.str_or("name", ""), "mula-tiny");
        assert_eq!(a.usize_or("dp", 1), 4);
    }

    #[test]
    fn unknown_flags_are_rejected_with_suggestion() {
        let allowed = &["steps", "warmup", "lr", "ep-comm"];
        let a = parse("train --stpes 500");
        let e = a.expect_flags(allowed).unwrap_err();
        assert!(e.contains("unknown flag `--stpes`"), "{e}");
        assert!(e.contains("did you mean `--steps`?"), "{e}");
        assert!(e.contains("accepted flags:"), "{e}");

        // prefix matches beat edit distance
        let a = parse("train --ep allgather");
        let e = a.expect_flags(allowed).unwrap_err();
        assert!(e.contains("did you mean `--ep-comm`?"), "{e}");

        // far-off typos get no suggestion but still fail
        let a = parse("train --zzzzzz 1");
        let e = a.expect_flags(allowed).unwrap_err();
        assert!(!e.contains("did you mean"), "{e}");

        // everything accepted passes
        let a = parse("train --steps 500 --lr 0.1");
        assert!(a.expect_flags(allowed).is_ok());
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("steps", "steps"), 0);
        assert_eq!(levenshtein("stpes", "steps"), 2);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
    }
}
