//! The validated parallelism plan: how a job maps onto the dp×ep×pp mesh.
//!
//! A [`ParallelismPlan`] is the single source of truth for placement —
//! mesh axes, per-stage layer ranges, expert placement per stage, the loss
//! domain and the optimizer segment layout — and the single place every
//! configuration invariant is checked. [`ParallelismPlan::validate`] runs
//! a table-driven list of checks (micro-batch bounds, artifact
//! availability per ep/pp degree, axis/world consistency, model
//! divisibility, data context vs sequence length, sharding-mode
//! feasibility) and fails with a stable `plan validation failed [<check>]`
//! error string *before* any engine executor or rank thread exists.
//! `crate::ft::classify` maps that prefix to a non-relaunchable
//! [`crate::ft::FailureKind::Config`] failure.
//!
//! [`ParallelismPlan::enumerate`] lists every dp×ep×pp factorization of a
//! world size — the sweep-tooling entry point (`optimus plans --world N`).

use super::ep::EpComm;
use super::ep_layout::EpLayout;
use super::pipeline::{Schedule, SEQ_SLOTS};
use crate::ckpt::CkptPolicy;
use crate::comm::Topology;
use crate::config::{ModelManifest, ParamSpec};
use crate::data::{BatchPlan, Dataset};
use crate::ft::checks;
use crate::optim::sharded::SegmentLayout;
use crate::optim::ShardingMode;
use crate::runtime::Dtype;
use crate::Result;
use std::ops::Range;

/// Which runnable engine drives the ranks for a given topology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// dp ≥ 1, ep = pp = 1: fused `train_step` artifact per rank
    Dp,
    /// ep > 1, pp = 1: per-layer Stage-1 exchange loop
    Ep,
    /// pp > 1, ep = 1: microbatch pipeline over stage artifacts
    Pp,
    /// pp > 1 and ep > 1: pipeline stages running the EP exchange loop
    /// over each stage's mesh slice
    PpEp,
}

/// Placement of one pipeline stage: which layers it owns, whether it holds
/// the embedding/head, how many experts each of its ranks keeps, and the
/// `[non-expert || expert]` segment layout its sharded optimizer uses.
#[derive(Clone, Debug)]
pub struct StagePlan {
    pub stage: usize,
    /// global decoder-layer range owned by this stage
    pub layers: Range<usize>,
    pub has_embed: bool,
    pub has_head: bool,
    /// experts held per rank within this stage's EP groups (N/EP)
    pub experts_per_rank: usize,
    /// rank-local optimizer segment layout for this stage
    pub seg: SegmentLayout,
}

/// Validated dp×ep×pp placement. Built by the
/// [`JobSpecBuilder`](super::JobSpecBuilder); the public fields allow
/// tests and sweep tooling to construct plans directly — such plans are
/// *unvalidated* until [`ParallelismPlan::validate`] passes.
#[derive(Clone, Debug)]
pub struct ParallelismPlan {
    pub topo: Topology,
    pub mode: ShardingMode,
    /// whether `mode` was an explicit user choice — EPSO at ep=1 is
    /// rejected only when explicitly requested (the implicit default
    /// degrades to SO, which is identical there)
    pub mode_explicit: bool,
    pub schedule: Schedule,
    pub micro_batches: usize,
    pub ep_comm: EpComm,
    /// expected world size (e.g. from a launcher); checked against
    /// `topo.world()` when set
    pub expected_world: Option<usize>,
    /// overlap the sharded optimizer's collectives with its compute (the
    /// pipelined step, paper §3.2) — a pure scheduling change, final
    /// parameters stay bit-identical to the serial path
    pub overlap: bool,
    /// pipeline chunk length in elements for the overlapped optimizer
    pub overlap_chunk: usize,
    /// checkpoint policy (interval, async on/off, keep-k). Like
    /// `overlap`, a pure execution knob: it never shapes the fingerprint,
    /// and a checkpoint written under one policy resumes under any other.
    pub ckpt: CkptPolicy,
    /// parameter/gradient-wire element dtype (paper §2.1 mixed
    /// precision): `F32` is the bit-identical baseline, `Bf16` runs bf16
    /// params + half-width collective/checkpoint payloads with f32
    /// master weights and moments inside the sharded optimizer. Shapes
    /// the fingerprint (a bf16 checkpoint is not an f32 checkpoint).
    pub dtype: Dtype,
    /// per-rank background batch prefetch (`--no-prefetch` disables).
    /// A pure execution knob: batches are identical either way; only the
    /// `data_wait_secs` / `data_prefetch_secs` accounting moves.
    pub prefetch: bool,
    /// maximum passes over the dataset the run may consume; `0` leaves
    /// the epoch budget unbounded (the `[data]` check is then skipped —
    /// the shuffle reshuffles every epoch regardless)
    pub data_epochs: usize,
    /// per-stage placement, filled by [`ParallelismPlan::materialized`]
    pub stages: Vec<StagePlan>,
}

/// Default optimizer-pipeline chunk length (elements). Small enough to
/// give the mula-tiny analogs several chunks per segment, large enough
/// that per-chunk submission overhead stays negligible at paper scale.
pub const DEFAULT_OVERLAP_CHUNK: usize = 16384;

type SpecCheck = fn(&ParallelismPlan) -> Option<String>;
type ModelCheck = fn(&ParallelismPlan, &ModelManifest) -> Option<String>;
type DataCheck = fn(&ParallelismPlan, &ModelManifest, &Dataset) -> Option<String>;

/// Checks that need only the plan itself (run by `JobSpecBuilder::build`).
const SPEC_CHECKS: &[(&str, SpecCheck)] = &[
    ("topology", |p| {
        if p.topo.dp == 0 || p.topo.ep == 0 || p.topo.pp == 0 {
            return Some(format!(
                "every mesh axis must be >= 1; got dp={} ep={} pp={}",
                p.topo.dp, p.topo.ep, p.topo.pp
            ));
        }
        if p.topo.node_size == 0 {
            return Some(
                "node_size must be >= 1 (1 selects the flat single-level \
                 collectives)"
                    .to_string(),
            );
        }
        if p.topo.world() % p.topo.node_size != 0 {
            return Some(format!(
                "node_size={} must divide the world size dp*ep*pp = {} so \
                 every node hosts a full tile complement",
                p.topo.node_size,
                p.topo.world()
            ));
        }
        None
    }),
    ("world-size", |p| match p.expected_world {
        Some(w) if p.topo.world() != w => Some(format!(
            "dp*ep*pp = {}*{}*{} = {} does not equal the requested world size {w}",
            p.topo.dp,
            p.topo.ep,
            p.topo.pp,
            p.topo.world()
        )),
        _ => None,
    }),
    ("micro-batches", |p| {
        (p.micro_batches == 0 || p.micro_batches > SEQ_SLOTS).then(|| {
            format!(
                "micro_batches must be in 1..={SEQ_SLOTS} (p2p sequence ids \
                 reserve {SEQ_SLOTS} slots per step); got {}",
                p.micro_batches
            )
        })
    }),
    ("sharding", |p| {
        (p.mode_explicit && p.mode == ShardingMode::Epso && p.topo.ep == 1).then(|| {
            "EPSO requires ep > 1 (its expert sharding domain is empty at \
             ep=1); use SO or raise the ep degree"
                .to_string()
        })
    }),
    ("schedule", |p| {
        (p.topo.pp > 1 && matches!(p.schedule, Schedule::Interleaved1F1B { .. })).then(|| {
            "interleaved-1f1b needs multi-chunk artifacts; the runnable \
             engines support gpipe and 1f1b"
                .to_string()
        })
    }),
    ("overlap", |p| {
        (p.overlap && p.overlap_chunk == 0).then(|| {
            "overlap requires a positive overlap_chunk (the optimizer \
             pipeline's chunk length in elements)"
                .to_string()
        })
    }),
    ("checkpoint", |p| p.ckpt.invalid_reason()),
    ("dtype", |p| {
        (p.dtype == Dtype::Bf16 && p.overlap).then(|| {
            "dtype=bf16 does not support the overlapped optimizer step yet \
             (the mixed-precision path is serial; drop --overlap or use \
             --dtype f32)"
                .to_string()
        })
    }),
];

/// Checks against the model manifest (layer/expert divisibility, artifact
/// availability per parallelism degree).
const MODEL_CHECKS: &[(&str, ModelCheck)] = &[
    ("layer-split", |p, mm| {
        (p.topo.pp > 1 && mm.hyper.n_layers % p.topo.pp != 0).then(|| {
            format!(
                "pp={} does not divide n_layers={} of {}",
                p.topo.pp, mm.hyper.n_layers, mm.name
            )
        })
    }),
    ("expert-split", |p, mm| {
        (p.topo.ep > 1 && (mm.hyper.n_experts == 0 || mm.hyper.n_experts % p.topo.ep != 0))
            .then(|| {
                format!(
                    "ep={} does not divide n_experts={} of {}",
                    p.topo.ep, mm.hyper.n_experts, mm.name
                )
            })
    }),
    ("pp-artifacts", |p, mm| {
        // the hybrid PP×EP engine runs on the per-layer EP artifacts, so
        // stage artifacts are only required for PP-without-EP
        (p.topo.pp > 1 && p.topo.ep == 1 && !mm.pp_degrees.contains(&p.topo.pp)).then(|| {
            format!(
                "no PP={} stage artifacts for {} (built: {:?})",
                p.topo.pp, mm.name, mm.pp_degrees
            )
        })
    }),
    ("ep-artifacts", |p, mm| {
        (p.topo.ep > 1 && !mm.ep_degrees.contains(&p.topo.ep)).then(|| {
            format!(
                "no EP={} artifacts for {} (built: {:?})",
                p.topo.ep, mm.name, mm.ep_degrees
            )
        })
    }),
];

/// Checks against the dataset. The `[data]` instance-budget check —
/// `consumed-so-far + remaining steps × instances_per_step ≤ dataset ×
/// data_epochs` — deliberately does NOT live in this table:
/// `steps × instances_per_step` under the *new* geometry both
/// over-counts (spuriously rejecting a valid elastic resume onto a
/// larger topology) and under-counts (missing what the checkpoint
/// already consumed). Only `harness::run` sees the real resume cursor,
/// so it enforces the budget there — still before any rank thread
/// spawns, with the same stable `plan validation failed [data]` string.
const DATA_CHECKS: &[(&str, DataCheck)] = &[("data-context", |_, mm, ds| {
    (ds.context < mm.hyper.seq + 1).then(|| {
        format!(
            "data context {} < model seq+1 = {}",
            ds.context,
            mm.hyper.seq + 1
        )
    })
})];

impl ParallelismPlan {
    /// Unvalidated plan with engine defaults. The usual constructor is
    /// [`JobSpecBuilder`](super::JobSpecBuilder); tests and sweep tooling
    /// may mutate the public fields directly and call `validate`.
    pub fn new(topo: Topology) -> ParallelismPlan {
        ParallelismPlan {
            topo,
            mode: if topo.ep > 1 { ShardingMode::Epso } else { ShardingMode::So },
            mode_explicit: false,
            schedule: Schedule::OneFOneB,
            micro_batches: 2,
            ep_comm: EpComm::Allgather,
            expected_world: None,
            overlap: false,
            overlap_chunk: DEFAULT_OVERLAP_CHUNK,
            ckpt: CkptPolicy::default(),
            dtype: Dtype::F32,
            prefetch: true,
            data_epochs: 0,
            stages: Vec::new(),
        }
    }

    /// The deterministic batch-consumption geometry this placement
    /// implies: how many contiguous stream instances one optimizer step
    /// consumes and how they split over (data rank, microbatch). One
    /// definition for every engine — the `[data]` budget check, the
    /// harness's token cursor and `optimus plans` all derive from it, so
    /// they can never drift from what the engines actually read.
    pub fn batch_plan(&self, mm: &ModelManifest) -> BatchPlan {
        let b = mm.hyper.batch;
        match self.kind() {
            EngineKind::Dp => {
                BatchPlan { dp: self.topo.dp, micro_batch: b, micro_batches: 1 }
            }
            // EP scales the global batch like DP (paper §1): every rank
            // is a data rank
            EngineKind::Ep => {
                BatchPlan { dp: self.topo.world(), micro_batch: b, micro_batches: 1 }
            }
            EngineKind::Pp => BatchPlan {
                dp: self.topo.dp,
                micro_batch: b,
                micro_batches: self.micro_batches,
            },
            // dp×ep pairs are the data ranks of the hybrid
            EngineKind::PpEp => BatchPlan {
                dp: self.topo.dp * self.topo.ep,
                micro_batch: b,
                micro_batches: self.micro_batches,
            },
        }
    }

    /// Which runnable engine this plan selects.
    pub fn kind(&self) -> EngineKind {
        match (self.topo.ep > 1, self.topo.pp > 1) {
            (false, false) => EngineKind::Dp,
            (true, false) => EngineKind::Ep,
            (false, true) => EngineKind::Pp,
            (true, true) => EngineKind::PpEp,
        }
    }

    /// The pipeline stage whose ranks see the loss (owns the LM head).
    pub fn loss_stage(&self) -> usize {
        self.topo.pp - 1
    }

    /// Plan-only subset of the validation table (no manifest/dataset
    /// needed) — what `JobSpecBuilder::build` runs.
    pub fn validate_spec(&self) -> Result<()> {
        for (name, check) in SPEC_CHECKS {
            if let Some(msg) = check(self) {
                return Err(checks::err(checks::PLAN, name, msg));
            }
        }
        Ok(())
    }

    /// Spec + model subset of the table (no dataset needed) — what sweep
    /// tooling uses to label placements runnable for a model.
    pub fn validate_model(&self, mm: &ModelManifest) -> Result<()> {
        self.validate_spec()?;
        for (name, check) in MODEL_CHECKS {
            if let Some(msg) = check(self, mm) {
                return Err(checks::err(checks::PLAN, name, msg));
            }
        }
        Ok(())
    }

    /// Serving-plan preflight (`optimus serve`). The decode engine reuses
    /// the training placement machinery — the ordinary spec+model tables
    /// run first — but supports only the ep-only / dp×ep slice of it and
    /// has no optimizer, so the training-only knobs must be quiescent.
    /// Violations fail with the stable `plan validation failed [serve]`
    /// string before any rank thread spawns.
    pub fn validate_serve(&self, mm: &ModelManifest) -> Result<()> {
        self.validate_model(mm)?;
        let fail = |msg: String| -> Result<()> {
            Err(checks::err(checks::PLAN, "serve", msg))
        };
        if self.topo.pp != 1 {
            return fail(format!(
                "serving runs ep-only or dp×ep placements; pp={} has no \
                 decode engine",
                self.topo.pp
            ));
        }
        if self.overlap {
            return fail(
                "serving has no optimizer step to overlap; drop --overlap".to_string(),
            );
        }
        if self.dtype != Dtype::F32 {
            return fail(
                "the decode engine computes in f32 (checkpoint dtype is \
                 checked separately at load); use an f32 serving plan"
                    .to_string(),
            );
        }
        Ok(())
    }

    /// Full preflight: every configuration invariant, checked in one
    /// table-driven pass with stable error strings, before any engine
    /// executor or rank thread exists. (The run-demand `[data]` budget
    /// check lives in `harness::run`, which alone sees the resume
    /// cursor — see the `DATA_CHECKS` note.)
    pub fn validate(&self, mm: &ModelManifest, ds: &Dataset) -> Result<()> {
        self.validate_model(mm)?;
        for (name, check) in DATA_CHECKS {
            if let Some(msg) = check(self, mm, ds) {
                return Err(checks::err(checks::PLAN, name, msg));
            }
        }
        Ok(())
    }

    /// Validate and fill the per-stage placement table.
    pub fn materialized(mut self, mm: &ModelManifest, ds: &Dataset) -> Result<ParallelismPlan> {
        self.validate(mm, ds)?;
        let h = &mm.hyper;
        let (ep, pp) = (self.topo.ep, self.topo.pp);
        let lps = h.n_layers / pp;
        let kind = self.kind();
        self.stages = (0..pp)
            .map(|s| {
                let layers = s * lps..(s + 1) * lps;
                let has_embed = s == 0;
                let has_head = s == pp - 1;
                let seg = match kind {
                    EngineKind::Dp => {
                        // the whole model is one "non-expert" segment
                        SegmentLayout { ne_len: mm.param_count, e_len: 0 }
                    }
                    EngineKind::Pp => SegmentLayout {
                        ne_len: stage_specs(mm, pp, s).iter().map(|p| p.numel).sum(),
                        e_len: 0,
                    },
                    EngineKind::Ep | EngineKind::PpEp => {
                        // lengths are ep_rank-independent; probe rank 0
                        let lay =
                            EpLayout::for_stage(mm, ep, 0, layers.clone(), has_embed, has_head);
                        SegmentLayout { ne_len: lay.ne_len, e_len: lay.e_len }
                    }
                };
                StagePlan {
                    stage: s,
                    layers,
                    has_embed,
                    has_head,
                    // ep >= 1 and divisibility already validated above
                    experts_per_rank: h.n_experts / ep,
                    seg,
                }
            })
            .collect();
        Ok(self)
    }

    /// Stable serialized form recorded in checkpoint metadata and compared
    /// on resume (see [`crate::ckpt::Checkpoint::ensure_plan`]).
    pub fn fingerprint(&self) -> String {
        let mode = match self.mode {
            ShardingMode::So => "so",
            ShardingMode::Epso => "epso",
        };
        let comm = match self.ep_comm {
            EpComm::Allgather => "allgather",
            EpComm::All2All => "all2all",
        };
        let mut fp = format!(
            "dp{}-ep{}-pp{}/{mode}/{}/mb{}/{comm}",
            self.topo.dp,
            self.topo.ep,
            self.topo.pp,
            self.schedule.name(),
            self.micro_batches
        );
        // execution knob, appended so serial fingerprints stay stable and
        // ckpt::ensure_plan's state key (first three segments) is unmoved
        if self.overlap {
            fp.push_str("/overlap");
        }
        // dtype suffix, appended last for the same state-key reason; f32
        // (the bit-identical default) stays suffix-free so every legacy
        // fingerprint is unchanged
        if self.dtype == Dtype::Bf16 {
            fp.push_str("/bf16");
        }
        // node placement shapes the hierarchical collective schedule but
        // not the state; appended last, and node_size=1 (the flat default)
        // stays suffix-free so every legacy fingerprint is unchanged
        if self.topo.node_size > 1 {
            fp.push_str(&format!("/nodes{}", self.topo.node_size));
        }
        fp
    }

    /// Every dp×ep×pp factorization of `world` (sweep tooling; filter by
    /// [`ParallelismPlan::validate`] against a manifest for runnability).
    pub fn enumerate(world: usize) -> Vec<Topology> {
        let mut out = Vec::new();
        for dp in 1..=world {
            if world % dp != 0 {
                continue;
            }
            let rest = world / dp;
            for ep in 1..=rest {
                if rest % ep == 0 {
                    out.push(Topology::grid(dp, ep, rest / ep));
                }
            }
        }
        out
    }
}

/// Stage-owned parameter specs for the PP stage artifacts (mirrors
/// python `model.stage_param_specs`: same filter, same order, local
/// offsets; the original global offset rides along in the name).
pub(crate) fn stage_specs(mm: &ModelManifest, pp: usize, stage: usize) -> Vec<ParamSpec> {
    let lps = mm.hyper.n_layers / pp;
    let lo = (stage * lps) as i64;
    let hi = ((stage + 1) * lps) as i64;
    let mut out = Vec::new();
    let mut off = 0usize;
    for p in &mm.params {
        let owned = (p.layer >= lo && p.layer < hi)
            || (stage == 0 && p.name == "embed")
            || (stage == pp - 1 && (p.name == "final_norm" || p.name == "head"));
        if owned {
            let mut q = p.clone();
            let goff = p.offset;
            q.offset = off;
            off += p.numel;
            out.push(ParamSpec { name: format!("{}@{goff}", q.name), ..q });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumerate_covers_all_factorizations() {
        let topos = ParallelismPlan::enumerate(12);
        // sum over dp|12 of d(12/dp) = 6+4+3+2+2+1
        assert_eq!(topos.len(), 18);
        for t in &topos {
            assert_eq!(t.world(), 12);
        }
        assert!(topos.contains(&Topology::grid(12, 1, 1)));
        assert!(topos.contains(&Topology::grid(1, 12, 1)));
        assert!(topos.contains(&Topology::grid(2, 3, 2)));
        // no duplicates
        for (i, a) in topos.iter().enumerate() {
            assert!(!topos[i + 1..].contains(a), "duplicate {a:?}");
        }
    }

    #[test]
    fn spec_checks_fire_with_stable_strings() {
        let mut p = ParallelismPlan::new(Topology::grid(2, 2, 2));
        p.micro_batches = 0;
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [micro-batches]"), "{e}");

        let mut p = ParallelismPlan::new(Topology::grid(2, 1, 1));
        p.expected_world = Some(8);
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [world-size]"), "{e}");

        let mut p = ParallelismPlan::new(Topology::dp_only(2));
        p.mode = ShardingMode::Epso;
        p.mode_explicit = true;
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [sharding]"), "{e}");
        // implicit default never trips the same check
        let mut p = ParallelismPlan::new(Topology::dp_only(2));
        p.mode_explicit = false;
        assert!(p.validate_spec().is_ok());
    }

    #[test]
    fn every_table_check_name_is_registered() {
        // the lint (`optimus lint`) cross-references emitted check strings
        // against ft::checks; the tables must never drift from the registry
        for (name, _) in SPEC_CHECKS {
            assert!(checks::is_registered(checks::PLAN, name), "unregistered [{name}]");
        }
        for (name, _) in MODEL_CHECKS {
            assert!(checks::is_registered(checks::PLAN, name), "unregistered [{name}]");
        }
        for (name, _) in DATA_CHECKS {
            assert!(checks::is_registered(checks::PLAN, name), "unregistered [{name}]");
        }
    }

    #[test]
    fn schedule_check_rejects_interleaved_on_runnable_engines() {
        let mut p = ParallelismPlan::new(Topology::grid(1, 1, 2));
        p.schedule = Schedule::Interleaved1F1B { chunks: 2 };
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [schedule]"), "{e}");
        // pp = 1 never consults the pipeline schedule
        let mut p = ParallelismPlan::new(Topology::dp_only(2));
        p.schedule = Schedule::Interleaved1F1B { chunks: 2 };
        assert!(p.validate_spec().is_ok());
    }

    #[test]
    fn kind_dispatch_matches_axes() {
        let k = |dp, ep, pp| ParallelismPlan::new(Topology::grid(dp, ep, pp)).kind();
        assert_eq!(k(4, 1, 1), EngineKind::Dp);
        assert_eq!(k(1, 2, 1), EngineKind::Ep);
        assert_eq!(k(1, 1, 2), EngineKind::Pp);
        assert_eq!(k(2, 2, 2), EngineKind::PpEp);
    }

    #[test]
    fn fingerprint_is_stable() {
        let p = ParallelismPlan::new(Topology::grid(1, 2, 2));
        assert_eq!(p.fingerprint(), "dp1-ep2-pp2/epso/1f1b/mb2/allgather");
        // overlap is an execution knob: appended, never reshaping the
        // state key a checkpoint resume compares
        let mut p = p;
        p.overlap = true;
        assert_eq!(p.fingerprint(), "dp1-ep2-pp2/epso/1f1b/mb2/allgather/overlap");
    }

    #[test]
    fn checkpoint_check_fires_with_stable_string() {
        let mut p = ParallelismPlan::new(Topology::dp_only(2));
        p.ckpt.dir = Some(std::path::PathBuf::from("/tmp/ck"));
        assert!(p.validate_spec().is_ok());
        p.ckpt.every = 0;
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [checkpoint]"), "{e}");
        p.ckpt.every = 5;
        p.ckpt.keep = 1;
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [checkpoint]"), "{e}");
        // a disabled policy never trips the check, whatever the knobs say
        p.ckpt.dir = None;
        p.ckpt.every = 0;
        assert!(p.validate_spec().is_ok());
    }

    #[test]
    fn dtype_check_rejects_bf16_with_overlap() {
        let mut p = ParallelismPlan::new(Topology::dp_only(2));
        p.dtype = Dtype::Bf16;
        assert!(p.validate_spec().is_ok(), "serial bf16 is valid");
        p.overlap = true;
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [dtype]"), "{e}");
        // f32 + overlap stays valid
        p.dtype = Dtype::F32;
        assert!(p.validate_spec().is_ok());
    }

    #[test]
    fn bf16_fingerprint_gets_a_suffix() {
        let mut p = ParallelismPlan::new(Topology::grid(1, 2, 2));
        p.dtype = Dtype::Bf16;
        assert_eq!(p.fingerprint(), "dp1-ep2-pp2/epso/1f1b/mb2/allgather/bf16");
        // the state key (first three segments) never moves
        assert!(p.fingerprint().starts_with("dp1-ep2-pp2/epso/1f1b"));
    }

    #[test]
    fn topology_check_validates_node_size() {
        // indivisible placement: 3 tiles per node cannot host world 4
        let p = ParallelismPlan::new(Topology::grid(4, 1, 1).with_node_size(3));
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [topology]"), "{e}");
        assert!(e.contains("node_size=3"), "{e}");
        // zero node size is rejected before the divisibility question
        let p = ParallelismPlan::new(Topology::grid(4, 1, 1).with_node_size(0));
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [topology]"), "{e}");
        // divisible placements (including the flat default) pass
        assert!(ParallelismPlan::new(Topology::grid(4, 1, 1).with_node_size(2))
            .validate_spec()
            .is_ok());
        assert!(ParallelismPlan::new(Topology::grid(4, 1, 1)).validate_spec().is_ok());
    }

    #[test]
    fn node_size_fingerprint_gets_a_suffix() {
        let p = ParallelismPlan::new(Topology::grid(2, 2, 1).with_node_size(2));
        assert_eq!(p.fingerprint(), "dp2-ep2-pp1/epso/1f1b/mb2/allgather/nodes2");
        // the state key (first three segments) never moves, and the flat
        // default stays suffix-free
        let p = ParallelismPlan::new(Topology::grid(2, 2, 1));
        assert_eq!(p.fingerprint(), "dp2-ep2-pp1/epso/1f1b/mb2/allgather");
    }

    #[test]
    fn overlap_check_fires_with_stable_string() {
        let mut p = ParallelismPlan::new(Topology::dp_only(2));
        p.overlap = true;
        p.overlap_chunk = 0;
        let e = p.validate_spec().unwrap_err().to_string();
        assert!(e.contains("plan validation failed [overlap]"), "{e}");
        p.overlap_chunk = 4096;
        assert!(p.validate_spec().is_ok());
        // overlap off never trips the check, whatever the chunk says
        p.overlap = false;
        p.overlap_chunk = 0;
        assert!(p.validate_spec().is_ok());
    }
}
