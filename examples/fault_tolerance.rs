//! Reliability features live (paper §4): a soft (NaN) failure at step 4
//! and a hard node failure at step 6 of the relaunched run, both
//! recovered automatically from buffer nodes + the sharded async
//! checkpoints. Auto-resume is built into the trainer: the JobSpec names
//! a checkpoint directory and every relaunched attempt continues from
//! the newest committed checkpoint (params *and* optimizer moments, so
//! the resumed trajectory is bit-identical to an uninterrupted run).
//!
//! Run: `cargo run --release --example fault_tolerance`

use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec, StepHook};
use optimus::data::{corpus, preprocess};
use optimus::ft::{HardKillHook, Launcher, NanInjectHook};
use std::sync::Arc;

struct Chain(Vec<Arc<dyn StepHook>>);
impl StepHook for Chain {
    fn on_step(&self, r: usize, s: usize, l: f32, p: &mut [f32]) -> optimus::Result<()> {
        self.0.iter().try_for_each(|h| h.on_step(r, s, l, p))
    }
}

fn main() -> optimus::Result<()> {
    let data_dir = std::env::temp_dir().join("optimus-ft-demo-data");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 3, 16), 64, 7, &data_dir, 256)?;
    }
    let ckroot = std::env::temp_dir().join("optimus-ft-demo-ckpt");
    let _ = std::fs::remove_dir_all(&ckroot);

    let manifest = Manifest::load(&optimus::artifacts_dir())?;
    let hard = Arc::new(HardKillHook::once(1, 6));
    let soft = Arc::new(NanInjectHook::once(0, 4));
    // 2 active "nodes" + 2 buffer nodes
    let launcher = Launcher::new(2, 2);

    let spec = JobSpec::new("mula-tiny")
        .data_dir(data_dir.clone())
        .topology(2, 1, 1)
        .steps(12)
        .warmup_steps(2)
        // sharded async checkpoints every 3 steps; relaunches auto-resume
        .checkpoint_dir(&ckroot)
        .ckpt_every(3)
        .hook(Arc::new(Chain(vec![hard.clone(), soft.clone()])))
        .build()?;

    let report = launcher.run(|attempt, nodes| {
        println!("\n=== attempt {attempt} on nodes {nodes:?} ===");
        if let Some(c) = optimus::ckpt::SavedCheckpoint::load_latest(&ckroot) {
            println!("auto-resuming from committed checkpoint at step {}", c.step);
        }
        coordinator::train(&manifest, &spec)
    })?;

    println!(
        "\nrecovered after {} relaunch(es); {} buffer nodes left; failed: {:?}",
        launcher.relaunches.load(std::sync::atomic::Ordering::Relaxed),
        launcher.pool.buffer_len(),
        launcher.pool.failed_nodes(),
    );
    println!("final loss: {:.4}", report.loss.last().unwrap());
    println!(
        "checkpoints committed in final attempt: {} (snapshot stall {:.4}s, \
         hidden write {:.4}s)",
        report.ckpt_commits,
        report.breakdown.snapshot_secs,
        report.breakdown.snapshot_write_secs
    );
    print!("{}", optimus::ckpt::inspect(&ckroot)?);
    Ok(())
}
