//! Reliability / fault tolerance (paper §4): buffer-node pool, hard and
//! soft node-failure handling, NaN detection, automatic relaunch.
//!
//! The launcher wraps a training attempt; on a **hard failure** (rank
//! aborts / "node" dies) or a **soft failure** (rank produces local NaNs)
//! it marks the node, swaps in a buffer node, and relaunches from the
//! latest valid checkpoint. Failure *injection* hooks drive the tests and
//! the fault_tolerance example.
//!
//! **Auto-resume** is built into the trainer: give the `JobSpec` a
//! checkpoint directory (`JobSpecBuilder::checkpoint_dir`) and every
//! relaunched attempt resumes from the newest committed sharded
//! checkpoint automatically — the launcher's attempt closure just calls
//! `coordinator::train` again. Resume failures that a relaunch cannot
//! fix (wrong model, corrupt shards — the stable
//! `checkpoint resume failed [<check>]` strings) classify as
//! [`FailureKind::Config`], so they surface instead of burning buffer
//! nodes.

pub mod checks;

use crate::ckpt::DualCheckpointer;
use crate::coordinator::StepHook;
use crate::util::lock;
use crate::Result;
use anyhow::anyhow;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Kinds of failure the launcher distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// training run exits immediately (ping failure, segfault, OS error)
    Hard,
    /// run continues but produces local NaNs on the failed node
    Soft,
    /// invalid job configuration (plan validation, unknown model) —
    /// deterministic, so relaunching on a buffer node cannot help
    Config,
}

/// Pool of nodes with spares ("launch the training run with some extra
/// buffer nodes and restart by replacing the failed node").
#[derive(Debug)]
pub struct NodePool {
    active: Mutex<Vec<usize>>,
    buffer: Mutex<Vec<usize>>,
    failed: Mutex<Vec<usize>>,
}

impl NodePool {
    pub fn new(active: usize, buffer: usize) -> NodePool {
        NodePool {
            active: Mutex::new((0..active).collect()),
            buffer: Mutex::new((active..active + buffer).collect()),
            failed: Mutex::new(Vec::new()),
        }
    }

    pub fn active_nodes(&self) -> Vec<usize> {
        lock(&self.active).clone()
    }

    pub fn buffer_len(&self) -> usize {
        lock(&self.buffer).len()
    }

    pub fn failed_nodes(&self) -> Vec<usize> {
        lock(&self.failed).clone()
    }

    /// Replace `node` with a buffer node; returns the replacement or an
    /// error when the pool is exhausted.
    pub fn replace(&self, node: usize) -> Result<usize> {
        let mut active = lock(&self.active);
        let pos = active
            .iter()
            .position(|&n| n == node)
            .ok_or_else(|| anyhow!("node {node} is not active"))?;
        let mut buffer = lock(&self.buffer);
        let replacement = buffer
            .pop()
            .ok_or_else(|| anyhow!("buffer-node pool exhausted"))?;
        active[pos] = replacement;
        lock(&self.failed).push(node);
        Ok(replacement)
    }
}

/// A detected failure: which rank, which kind, at which step.
#[derive(Clone, Debug)]
pub struct Failure {
    pub rank: usize,
    pub step: usize,
    pub kind: FailureKind,
}

/// Classify a trainer error string back into a failure. Trainers abort
/// ranks with recognizable messages; `coordinator::train`'s preflight
/// emits the stable `plan validation failed [<check>]` prefix.
pub fn classify(err: &anyhow::Error) -> FailureKind {
    let s = format!("{err:#}");
    if s.contains(checks::PROTOCOL) {
        // order/shape/dtype violations are deterministic program bugs —
        // a relaunch replays the same program order and fails again. A
        // [stall] is the one protocol failure whose dominant cause is a
        // dead or wedged peer, so it stays relaunchable.
        return if s.contains("[stall]") { FailureKind::Hard } else { FailureKind::Config };
    }
    if s.contains(checks::PLAN)
        || s.contains("parallelism plan mismatch")
        || s.contains(checks::RESUME)
        || s.contains(checks::SERVE)
        || s.contains(checks::LINT)
        || s.contains("unknown model config")
    {
        FailureKind::Config
    } else if s.contains("non-finite") || s.contains("NaN") {
        FailureKind::Soft
    } else {
        FailureKind::Hard
    }
}

/// Relaunch policy: run `attempt` until it succeeds or nodes run out.
/// Each failure consumes one buffer node ("restart the run by replacing
/// the failed node with one of the buffer nodes").
pub struct Launcher {
    pub pool: NodePool,
    pub max_relaunches: usize,
    pub relaunches: AtomicUsize,
}

impl Launcher {
    pub fn new(active: usize, buffer: usize) -> Launcher {
        Launcher {
            pool: NodePool::new(active, buffer),
            max_relaunches: buffer,
            relaunches: AtomicUsize::new(0),
        }
    }

    /// `attempt(relaunch_index, active_nodes)` runs one training attempt.
    /// Errors are classified; the offending node (hashed from the error
    /// rank if encoded, else node 0) is replaced and the attempt retried.
    pub fn run<T>(
        &self,
        mut attempt: impl FnMut(usize, &[usize]) -> Result<T>,
    ) -> Result<T> {
        loop {
            let nodes = self.pool.active_nodes();
            let n_try = self.relaunches.load(Ordering::Relaxed);
            match attempt(n_try, &nodes) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let kind = classify(&e);
                    // configuration errors are deterministic: replacing a
                    // node and relaunching reruns the same preflight —
                    // surface the error instead of burning buffer nodes
                    if kind == FailureKind::Config {
                        return Err(anyhow!("configuration error (not relaunchable): {e:#}"));
                    }
                    if n_try >= self.max_relaunches {
                        return Err(anyhow!(
                            "giving up after {n_try} relaunches: {e:#}"
                        ));
                    }
                    // failed node: encoded as "rank N" in trainer errors,
                    // mapped 1:1 onto nodes here
                    let failed = parse_rank(&e).unwrap_or(0).min(nodes.len() - 1);
                    let replacement = self.pool.replace(nodes[failed])?;
                    eprintln!(
                        "[launcher] {kind:?} failure on node {} -> replaced \
                         with buffer node {replacement}; relaunching",
                        nodes[failed]
                    );
                    self.relaunches.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

fn parse_rank(e: &anyhow::Error) -> Option<usize> {
    let s = format!("{e:#}");
    let i = s.find("rank ")?;
    s[i + 5..]
        .split(|c: char| !c.is_ascii_digit())
        .next()?
        .parse()
        .ok()
}

/// Scan for non-finite values (soft-failure detection on loss/grads/
/// params — paper: "we check local loss and gradients for NaN in each
/// rank").
pub fn has_nan(xs: &[f32]) -> bool {
    xs.iter().any(|v| !v.is_finite())
}

// ---------------------------------------------------------------------
// Failure-injection hooks (drive tests + the fault_tolerance example)
// ---------------------------------------------------------------------

/// Hard failure: the rank aborts at a given step (segfault analog).
pub struct HardKillHook {
    pub rank: usize,
    pub step: usize,
    pub armed: std::sync::atomic::AtomicBool,
}

impl HardKillHook {
    pub fn once(rank: usize, step: usize) -> HardKillHook {
        HardKillHook { rank, step, armed: std::sync::atomic::AtomicBool::new(true) }
    }
}

impl StepHook for HardKillHook {
    fn on_step(&self, rank: usize, step: usize, _loss: f32, _p: &mut [f32]) -> Result<()> {
        if rank == self.rank
            && step == self.step
            && self.armed.swap(false, Ordering::SeqCst)
        {
            return Err(anyhow!("rank {rank}: injected hard failure (os error)"));
        }
        Ok(())
    }
}

/// Soft failure: poisons the rank's parameters with NaNs; detection then
/// aborts the run before the NaNs contaminate a checkpoint.
pub struct NanInjectHook {
    pub rank: usize,
    pub step: usize,
    pub armed: std::sync::atomic::AtomicBool,
}

impl NanInjectHook {
    pub fn once(rank: usize, step: usize) -> NanInjectHook {
        NanInjectHook { rank, step, armed: std::sync::atomic::AtomicBool::new(true) }
    }
}

impl StepHook for NanInjectHook {
    fn on_step(&self, rank: usize, step: usize, loss: f32, params: &mut [f32]) -> Result<()> {
        if rank == self.rank
            && step == self.step
            && self.armed.swap(false, Ordering::SeqCst)
        {
            params[0] = f32::NAN; // the soft node corrupts local state
        }
        // detection path: every rank checks local values every step
        if has_nan(params) || !loss.is_finite() {
            return Err(anyhow!(
                "rank {rank}: NaN detected at step {step} (soft node failure)"
            ));
        }
        Ok(())
    }
}

/// Legacy model-only checkpoint-on-interval hook over the dual-slot blob
/// format. Superseded by the sharded [`crate::ckpt::Checkpointer`]
/// (enable with `JobSpecBuilder::checkpoint_dir`), which checkpoints
/// optimizer state too, writes asynchronously, and reshards on resume;
/// this hook remains for model-only rewind files. The plan fingerprint is
/// **required** — untagged checkpoints can no longer be written.
pub struct CkptHook {
    pub every: usize,
    pub dual: DualCheckpointer,
    /// plan fingerprint to record (see `JobSpec::fingerprint`)
    pub plan: String,
}

impl StepHook for CkptHook {
    fn on_step(&self, rank: usize, step: usize, _loss: f32, params: &mut [f32]) -> Result<()> {
        if rank == 0 && step > 0 && step % self.every == 0 {
            self.dual
                .save(&crate::ckpt::Checkpoint {
                    step,
                    params: params.to_vec(),
                    moments: Vec::new(),
                    plan: Some(self.plan.clone()),
                })
                .map(|_| ())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_replaces_until_exhausted() {
        let pool = NodePool::new(4, 2);
        let a0 = pool.active_nodes();
        assert_eq!(a0, vec![0, 1, 2, 3]);
        let r = pool.replace(2).unwrap();
        assert_eq!(r, 5);
        assert_eq!(pool.active_nodes(), vec![0, 1, 5, 3]);
        pool.replace(0).unwrap();
        assert_eq!(pool.buffer_len(), 0);
        assert!(pool.replace(1).is_err(), "pool exhausted");
        assert_eq!(pool.failed_nodes(), vec![2, 0]);
    }

    #[test]
    fn launcher_relaunches_on_hard_failure() {
        let l = Launcher::new(2, 2);
        let mut fails = 2;
        let out = l
            .run(|attempt, nodes| {
                assert_eq!(nodes.len(), 2);
                if fails > 0 {
                    fails -= 1;
                    Err(anyhow!("rank 1: injected hard failure"))
                } else {
                    Ok(attempt)
                }
            })
            .unwrap();
        assert_eq!(out, 2, "succeeded on third attempt");
        assert_eq!(l.pool.buffer_len(), 0);
    }

    #[test]
    fn launcher_gives_up_without_buffers() {
        let l = Launcher::new(2, 1);
        let r: Result<()> = l.run(|_, _| Err(anyhow!("rank 0: boom")));
        assert!(r.is_err());
    }

    #[test]
    fn classify_kinds() {
        assert_eq!(classify(&anyhow!("rank 3: NaN detected at step 5")), FailureKind::Soft);
        assert_eq!(classify(&anyhow!("rank 0: non-finite loss at step 2")), FailureKind::Soft);
        assert_eq!(classify(&anyhow!("rank 1: os error")), FailureKind::Hard);
        assert_eq!(
            classify(&anyhow!("plan validation failed [ep-artifacts]: no EP=3 artifacts")),
            FailureKind::Config
        );
        assert_eq!(
            classify(&anyhow!("unknown model config `mula-huge`")),
            FailureKind::Config
        );
        // a checkpoint resumed under the wrong topology is deterministic
        // too — relaunching on a buffer node cannot fix it
        assert_eq!(
            classify(&anyhow!(
                "checkpoint parallelism plan mismatch: saved under `a`, resuming with `b`"
            )),
            FailureKind::Config
        );
        // ... and so are the sharded-resume preflight failures
        assert_eq!(
            classify(&anyhow!(
                "checkpoint resume failed [model]: checkpoint was written for `x`"
            )),
            FailureKind::Config
        );
        // serve startup preflights are deterministic config errors too
        assert_eq!(
            classify(&anyhow!("serve startup failed [kv-oom]: pool too small")),
            FailureKind::Config
        );
        // lint findings are source defects: relaunching can't fix them
        assert_eq!(
            classify(&anyhow!(
                "{}",
                checks::msg(checks::LINT, "collective-divergence", "src/x.rs:4")
            )),
            FailureKind::Config
        );
        assert_eq!(parse_rank(&anyhow!("rank 7: x")), Some(7));
        // protocol violations: deterministic program bugs stay
        // non-relaunchable, a stall (likely dead peer) relaunches
        for name in ["order", "shape", "dtype"] {
            assert_eq!(
                classify(&anyhow!("{}", checks::msg(checks::PROTOCOL, name, "rank 1"))),
                FailureKind::Config,
                "[{name}]"
            );
        }
        assert_eq!(
            classify(&anyhow!("{}", checks::msg(checks::PROTOCOL, "stall", "rank 0 waiting"))),
            FailureKind::Hard
        );
    }

    #[test]
    fn launcher_does_not_burn_buffers_on_config_errors() {
        let l = Launcher::new(2, 2);
        let mut attempts = 0;
        let r: Result<()> = l.run(|_, _| {
            attempts += 1;
            Err(anyhow!("plan validation failed [micro-batches]: got 0"))
        });
        let e = r.unwrap_err().to_string();
        assert!(e.contains("not relaunchable"), "{e}");
        assert_eq!(attempts, 1, "config errors must not be retried");
        assert_eq!(l.pool.buffer_len(), 2, "no buffer node consumed");
    }

    #[test]
    fn nan_scan() {
        assert!(!has_nan(&[1.0, -2.0]));
        assert!(has_nan(&[1.0, f32::NAN]));
        assert!(has_nan(&[f32::INFINITY]));
    }
}
