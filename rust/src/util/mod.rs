//! Small substrates the offline environment forces us to own:
//! PRNG (no `rand`), JSON (no `serde`), CLI (no `clap`),
//! micro-benchmarks (no `criterion`) and property testing (no `proptest`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod proptest;

/// Round `x` up to a multiple of `m`.
pub fn round_up(x: usize, m: usize) -> usize {
    (x + m - 1) / m * m
}

/// Budget for wall-clock *upper-bound* assertions in timing-sensitive
/// tests: multiplies `base_secs` by `OPTIMUS_TIME_MULT` when set, else by
/// a generous 4× on CI runners (the `CI` env var) and 1× locally — so the
/// suite stays deterministic on oversubscribed shared hardware without
/// loosening local signal.
pub fn time_budget_secs(base_secs: u64) -> std::time::Duration {
    let mult = std::env::var("OPTIMUS_TIME_MULT")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(if std::env::var_os("CI").is_some() { 4 } else { 1 });
    std::time::Duration::from_secs(base_secs * mult.max(1))
}

/// Split `n` items into `parts` contiguous ranges, padding semantics of
/// ZeRO-1: every shard has ceil(n/parts) logical slots; the last shards may
/// be short or empty. Returns (start, len) per part.
pub fn shard_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let per = (n + parts - 1) / parts;
    (0..parts)
        .map(|i| {
            let s = (i * per).min(n);
            let e = ((i + 1) * per).min(n);
            (s, e - s)
        })
        .collect()
}

/// f32 -> bf16 -> f32 round trip (round-to-nearest-even), used for the
/// paper's bfloat16 gradient-reduction recipe (§2.1) and its ablation.
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let rounding_bias = 0x7fff + ((bits >> 16) & 1);
    f32::from_bits(((bits + rounding_bias) & 0xffff_0000) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_everything() {
        for n in [0usize, 1, 7, 64, 65, 1000] {
            for p in [1usize, 2, 3, 8] {
                let r = shard_ranges(n, p);
                assert_eq!(r.len(), p);
                let total: usize = r.iter().map(|x| x.1).sum();
                assert_eq!(total, n);
                let mut pos = 0;
                for (s, l) in &r {
                    if *l > 0 {
                        assert_eq!(*s, pos);
                    }
                    pos += l;
                }
            }
        }
    }

    #[test]
    fn bf16_round_is_idempotent_and_close() {
        for &v in &[0.0f32, 1.0, -1.5, 3.14159, 1e-8, 123456.78] {
            let r = bf16_round(v);
            assert_eq!(bf16_round(r), r);
            if v != 0.0 {
                assert!(((r - v) / v).abs() < 0.01, "{v} -> {r}");
            }
        }
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }
}
