//! Host tensors: the plain-data currency between rank threads and the
//! PJRT executor threads (xla::Literal is !Send, so it never leaves the
//! executor).

use crate::Result;
use anyhow::anyhow;

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32 { data, shape }
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::I32 { data, shape }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::F32 { data: vec![0.0; n], shape }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { data: vec![v], shape: vec![] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// First element as f32 (scalar outputs like losses).
    pub fn scalar(&self) -> Result<f32> {
        match self {
            Tensor::F32 { data, .. } => data
                .first()
                .copied()
                .ok_or_else(|| anyhow!("empty tensor")),
            Tensor::I32 { data, .. } => data
                .first()
                .map(|v| *v as f32)
                .ok_or_else(|| anyhow!("empty tensor")),
        }
    }
}

pub(super) fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64>;
    let lit = match t {
        Tensor::F32 { data, shape } => {
            dims = shape.iter().map(|d| *d as i64).collect();
            xla::Literal::vec1(data)
        }
        Tensor::I32 { data, shape } => {
            dims = shape.iter().map(|d| *d as i64).collect();
            xla::Literal::vec1(data)
        }
    };
    lit.reshape(&dims).map_err(|e| anyhow!("reshape literal: {e}"))
}

pub(super) fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(Tensor::F32 {
            data: lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            shape: dims,
        }),
        xla::ElementType::S32 => Ok(Tensor::I32 {
            data: lit.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?,
            shape: dims,
        }),
        // predicates / other ints: fetch via conversion
        other => {
            let conv = lit
                .convert(xla::PrimitiveType::F32)
                .map_err(|e| anyhow!("convert {other:?}: {e}"))?;
            Ok(Tensor::F32 {
                data: conv.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
                shape: dims,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_and_accessors() {
        let t = Tensor::f32(vec![1.0, 2.0], vec![2]);
        assert_eq!(t.scalar().unwrap(), 1.0);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(t.as_i32().is_err());
        let i = Tensor::i32(vec![3], vec![1]);
        assert_eq!(i.scalar().unwrap(), 3.0);
    }
}
