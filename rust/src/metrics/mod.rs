//! Step timing breakdown + loss logging.
//!
//! A training step decomposes into the paper's three components —
//! forward, backward (fused here as fwd+bwd artifacts), and optimizer —
//! plus communication and data time. Table 3's speedups are ratios of
//! these component times.

use std::time::Instant;

/// Per-step wall-clock decomposition. Every field carries a `class:` tag
/// (checked by `optimus lint`) stating its accounting role:
///
/// * `class: additive` — real blocking time on the training thread;
///   summed by [`StepBreakdown::total`], which must track wall-clock.
/// * `class: concurrent` — time hidden on a background thread while the
///   training thread computes; informational, never summed.
/// * `class: contained` — time physically spent *inside* another additive
///   field; never summed (it would double-count).
#[derive(Clone, Debug, Default)]
pub struct StepBreakdown {
    /// fused forward+backward artifact execution. class: additive
    pub fwd_bwd_secs: f64,
    /// the optimizer's own compute (update math, exposed). class: additive
    pub optimizer_secs: f64,
    /// *exposed* communication: time a rank thread actually blocked in a
    /// collective / p2p transfer (with `--overlap`, comm hidden behind
    /// compute moves to `overlap_secs` instead). class: additive
    pub comm_secs: f64,
    /// synchronous batch assembly on the training thread (prefetch off,
    /// or a fetch outside the prefetcher's predicted sequence).
    /// class: additive
    pub data_secs: f64,
    /// time the training thread blocked popping the prefetch queue — the
    /// *exposed* remainder of data time once the background producer hides
    /// the assembly. Real step wall-clock. class: additive
    pub data_wait_secs: f64,
    /// batch assembly hidden on the per-rank `data-prefetch-*` producer
    /// thread. Runs while the training thread computes (like
    /// `overlap_secs`) — informational, never part of the wall-clock sum.
    /// class: concurrent
    pub data_prefetch_secs: f64,
    /// PJRT executor queue wait: time submitted artifacts sat waiting for
    /// a free executor, folded in by the harness at finish from
    /// [`crate::runtime::EngineStats`]. The pool counters are shared by
    /// every rank of the run, so this is the run delta averaged over
    /// ranks — an *estimate* of this rank's queue share (exact only for
    /// balanced topologies; a skewed pipeline can make it exceed this
    /// rank's own waits). Queue time is physically spent inside the
    /// engines' end-to-end `exec` timing (`fwd_bwd_secs`), so
    /// [`StepBreakdown::total`] never adds it again — totals keep
    /// matching wall-clock step time; this field is the pool-sizing
    /// signal, not an additive component. class: contained
    pub queue_secs: f64,
    /// communication hidden behind compute by the async overlap pipeline
    /// (comm-lane busy time minus exposed waits). It runs *concurrently*
    /// with `optimizer_secs`, so it is informational — Table-3-style
    /// component ratios use it as the "saved" comm — and is never part of
    /// the wall-clock sum. class: concurrent
    pub overlap_secs: f64,
    /// time the training thread was blocked taking checkpoint snapshots:
    /// the O(1) `Arc` capture + submit (async mode) or the full inline
    /// write (sync mode). Real step wall-clock. class: additive
    pub snapshot_secs: f64,
    /// checkpoint serialization hidden on the Checkpointer's background
    /// writer. Runs while the training thread computes (like
    /// `overlap_secs`), recorded as this rank's share (run total / world)
    /// — informational, never part of the wall-clock sum.
    /// class: concurrent
    pub snapshot_write_secs: f64,
}

impl StepBreakdown {
    /// Wall-clock-additive components only: `queue_secs` is spent inside
    /// `fwd_bwd_secs` and `overlap_secs`/`data_prefetch_secs`/
    /// `snapshot_write_secs` are concurrent-by-design, so none of those
    /// are added — the sum tracks real step time. `snapshot_secs` (the
    /// capture stall) and `data_wait_secs` (the prefetch-pop stall) are
    /// real blocking time and are added.
    pub fn total(&self) -> f64 {
        self.fwd_bwd_secs
            + self.optimizer_secs
            + self.comm_secs
            + self.data_secs
            + self.data_wait_secs
            + self.snapshot_secs
    }

    /// Fraction of total communication (exposed + hidden) that the
    /// overlap pipeline hid behind compute; 0 when nothing was hidden.
    pub fn overlap_ratio(&self) -> f64 {
        let comm = self.comm_secs + self.overlap_secs;
        if comm <= 0.0 {
            return 0.0;
        }
        self.overlap_secs / comm
    }

    pub fn add(&mut self, other: &StepBreakdown) {
        self.fwd_bwd_secs += other.fwd_bwd_secs;
        self.optimizer_secs += other.optimizer_secs;
        self.comm_secs += other.comm_secs;
        self.data_secs += other.data_secs;
        self.data_wait_secs += other.data_wait_secs;
        self.data_prefetch_secs += other.data_prefetch_secs;
        self.queue_secs += other.queue_secs;
        self.overlap_secs += other.overlap_secs;
        self.snapshot_secs += other.snapshot_secs;
        self.snapshot_write_secs += other.snapshot_write_secs;
    }
}

/// Log-bucketed latency/duration histogram, mergeable across ranks.
///
/// Buckets are powers of two over seconds: bucket `i` holds samples in
/// `[2^(i-32), 2^(i-31))`, so the 64 buckets span ~2.3e-10 s … ~4.3e9 s —
/// every latency this codebase can observe. State is nothing but counts
/// and a sum, so a cross-rank merge is pure addition (the serving engine
/// and the harness ship the bucket counts through one `Allreduce` /
/// `Reduce::Sum` and every rank ends up with the identical global
/// distribution).
///
/// Quantiles are read off the bucket boundaries (upper edge of the bucket
/// containing the q-th sample): at most one power of two of relative
/// error, which is what a p50/p99 report needs — not what a calibration
/// oracle needs.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// per-bucket sample counts; index = log2(seconds) + 32, clamped
    counts: [u64; 64],
    /// total samples (== counts.iter().sum(), kept for O(1) reads)
    count: u64,
    /// exact sum of recorded values — `mean()` does not pay the bucket
    /// quantization
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { counts: [0; 64], count: 0, sum: 0.0 }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    fn bucket(secs: f64) -> usize {
        if !(secs > 0.0) {
            return 0; // zero, negative and NaN all land in the floor bucket
        }
        let i = secs.log2().floor() as i64 + 32;
        i.clamp(0, 63) as usize
    }

    /// Record one sample (seconds, or any nonnegative quantity).
    pub fn record(&mut self, secs: f64) {
        self.counts[Self::bucket(secs)] += 1;
        self.count += 1;
        self.sum += secs.max(0.0);
    }

    /// Fold another histogram in — the cross-rank merge.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum / self.count as f64
    }

    /// Upper edge of the bucket holding the `q`-quantile sample
    /// (`0.0 < q <= 1.0`); 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 2f64.powi(i as i32 - 31);
            }
        }
        2f64.powi(32) // unreachable: counts sum to count
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Bucket counts as f32 — the wire format for a `Reduce::Sum` merge
    /// (collectives carry f32 payloads). Counts stay exact through f32 up
    /// to 2^24 samples per bucket — orders of magnitude past any run here.
    pub fn counts_f32_wire(&self) -> Vec<f32> {
        self.counts.iter().map(|&c| c as f32).collect()
    }

    /// Rebuild from a summed wire (inverse of [`Histogram::counts_f32_wire`]
    /// after the allreduce) plus the summed scalar `sum`.
    pub fn from_wire(wire: &[f32], sum: f64) -> Histogram {
        let mut h = Histogram::default();
        for (i, &c) in wire.iter().take(64).enumerate() {
            let c = c.max(0.0).round() as u64;
            h.counts[i] = c;
            h.count += c;
        }
        h.sum = sum;
        h
    }
}

/// Scoped timer: `let _t = Scoped::new(&mut acc);`
pub struct Scoped<'a> {
    start: Instant,
    sink: &'a mut f64,
}

impl<'a> Scoped<'a> {
    pub fn new(sink: &'a mut f64) -> Scoped<'a> {
        Scoped { start: Instant::now(), sink }
    }
}

impl<'a> Drop for Scoped<'a> {
    fn drop(&mut self) {
        *self.sink += self.start.elapsed().as_secs_f64();
    }
}

/// Loss / metric curve: (step, value) pairs with CSV export.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub name: String,
    pub points: Vec<(usize, f64)>,
}

impl Curve {
    pub fn new(name: &str) -> Curve {
        Curve { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, step: usize, v: f64) {
        self.points.push((step, v));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    /// Mean of the final `n` points (smoothed terminal loss).
    pub fn tail_mean(&self, n: usize) -> f64 {
        let k = self.points.len().saturating_sub(n);
        let tail = &self.points[k..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|p| p.1).sum::<f64>() / tail.len() as f64
    }

    pub fn to_csv(&self) -> String {
        let mut s = format!("step,{}\n", self.name);
        for (st, v) in &self.points {
            s.push_str(&format!("{st},{v}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_accumulates() {
        let mut acc = 0.0;
        {
            let _t = Scoped::new(&mut acc);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(acc >= 0.004);
    }

    #[test]
    fn breakdown_totals_exclude_concurrent_components() {
        let mut b = StepBreakdown {
            fwd_bwd_secs: 2.0,
            optimizer_secs: 1.0,
            comm_secs: 0.5,
            data_secs: 0.125,
            data_wait_secs: 0.125,     // prefetch-pop stall — additive
            data_prefetch_secs: 0.75,  // hidden on the producer thread
            queue_secs: 0.75,          // inside fwd_bwd
            overlap_secs: 0.5,         // concurrent with optimizer
            snapshot_secs: 0.25,       // blocking capture stall — additive
            snapshot_write_secs: 1.25, // hidden on the ckpt writer
        };
        assert_eq!(b.total(), 4.0);
        assert_eq!(b.overlap_ratio(), 0.5);
        let other = b.clone();
        b.add(&other);
        assert_eq!(b.queue_secs, 1.5);
        assert_eq!(b.overlap_secs, 1.0);
        assert_eq!(b.data_wait_secs, 0.25);
        assert_eq!(b.data_prefetch_secs, 1.5);
        assert_eq!(b.snapshot_secs, 0.5);
        assert_eq!(b.snapshot_write_secs, 2.5);
        assert_eq!(b.total(), 8.0);
    }

    #[test]
    fn histogram_quantiles_bound_samples() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3); // 1ms .. 1s
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.5005).abs() < 1e-9);
        // bucket-edge quantiles over-estimate by at most one power of two
        let p50 = h.p50();
        assert!((0.5..=1.0).contains(&p50), "{p50}");
        let p99 = h.p99();
        assert!((0.99..=2.0).contains(&p99), "{p99}");
        assert!(h.quantile(1.0) >= 1.0);
        // zero / negative / NaN samples land in the floor bucket, not a panic
        let mut z = Histogram::new();
        z.record(0.0);
        z.record(-1.0);
        z.record(f64::NAN);
        assert_eq!(z.count(), 3);
        assert_eq!(z.sum(), 0.0);
        assert!(z.p99() > 0.0); // floor bucket's upper edge
        // empty histogram reads as all-zero
        let e = Histogram::new();
        assert_eq!((e.count(), e.mean(), e.p50(), e.p99()), (0, 0.0, 0.0, 0.0));
    }

    #[test]
    fn histogram_merge_matches_union_and_wire_roundtrips() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut u = Histogram::new();
        for i in 0..200 {
            let v = 1e-4 * (1.07f64).powi(i % 97);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            u.record(v);
        }
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.count(), u.count());
        assert!((m.sum() - u.sum()).abs() < 1e-12);
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(m.quantile(q), u.quantile(q), "q={q}");
        }
        // the allreduce wire: counts out, summed counts back in
        let wire = m.counts_f32_wire();
        assert_eq!(wire.len(), 64);
        let r = Histogram::from_wire(&wire, m.sum());
        assert_eq!(r.count(), m.count());
        assert_eq!(r.p50(), m.p50());
        assert_eq!(r.p99(), m.p99());
    }

    #[test]
    fn curve_tail_mean() {
        let mut c = Curve::new("loss");
        for i in 0..10 {
            c.push(i, i as f64);
        }
        assert_eq!(c.tail_mean(2), 8.5);
        assert_eq!(c.last(), Some(9.0));
        assert!(c.to_csv().contains("9,9"));
    }
}
