//! The offline data pipeline end to end (paper §4): tokenize -> shuffle
//! -> shard, then mmap loading with contiguous per-rank reads.
//!
//! Run: `cargo run --release --example data_pipeline`

use optimus::data::{corpus, preprocess, BatchPlan, Dataset, Tokenizer};

fn main() -> optimus::Result<()> {
    let dir = std::env::temp_dir().join("optimus-datapipe-demo");
    let _ = std::fs::remove_dir_all(&dir);

    // "a typical hugging face dataset consists of data files"
    let files = corpus::data_files(7, 8, 32);
    let tok = Tokenizer::new();
    println!("sample doc: {:?}...", &files[0][0][..60.min(files[0][0].len())]);
    println!("vocab size: {}", tok.vocab_size());

    let t0 = std::time::Instant::now();
    let st = preprocess::preprocess(&files, 128, 99, &dir, 512)?;
    println!(
        "preprocess: {} files -> {} tokens -> {} instances -> {} shards in {:?}",
        st.n_files, st.total_tokens, st.n_instances, st.n_shards, t0.elapsed()
    );

    // mmap'd lazy loading
    let ds = Dataset::open(&dir)?;
    println!("dataset: {} instances of context {}", ds.len(), ds.context);

    // deterministic contiguous batch plan across DP ranks
    let plan = BatchPlan { dp: 4, micro_batch: 8, micro_batches: 2 };
    let t1 = std::time::Instant::now();
    let mut tokens_read = 0usize;
    for step in 0..50 {
        for rank in 0..4 {
            for micro in 0..2 {
                let b = ds.batch_i32(plan.start(step, rank, micro), 8, 127);
                tokens_read += b.len();
            }
        }
    }
    let dt = t1.elapsed();
    println!(
        "read {} tokens in {:?} ({:.1} M tokens/s) — contiguous mmap reads",
        tokens_read,
        dt,
        tokens_read as f64 / dt.as_secs_f64() / 1e6
    );
    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
