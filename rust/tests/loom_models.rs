//! Loom model checks for the comm fabric's two protocol state machines:
//! the [`Group`] rendezvous (arrive → combine → depart → reset, plus
//! poison-on-peer-death) and the [`CommRuntime`] lane lifecycle
//! (submit → execute → wait, plus abort-orphaning and drop-drain).
//!
//! These only compile (and run) under `RUSTFLAGS="--cfg loom"`, which
//! swaps every primitive in `comm::lsync` for loom's model-checked
//! versions: each `loom::model` body is executed under *every* relevant
//! thread interleaving, so a lost wakeup, double reset, leaked in-flight
//! job or missed poison check fails deterministically here instead of
//! hanging CI once a month. Bound the search with
//! `LOOM_MAX_PREEMPTIONS=3` (the CI setting) for tractable runtimes.
//!
//! Keep the models small: loom supports at most 4 threads (including the
//! model's main thread) and the state space is exponential in the number
//! of synchronization operations.
#![cfg(loom)]

use loom::thread;
use optimus::comm::{CollectiveOp, CommFault, CommRuntime, Group, Reduce, ReduceDtype};
use std::sync::Arc;

// ---- Group rendezvous ------------------------------------------------

/// Two members, two back-to-back rounds: exercises the full
/// arrive/combine/depart/reset cycle *including* the drain-wait (an
/// early finisher re-entering for round r+1 while round r still holds
/// its result must park until the reset). A lost wakeup or a premature
/// reset deadlocks or mis-sums some interleaving.
#[test]
fn allreduce_two_ranks_two_rounds() {
    loom::model(|| {
        let g = Group::new_labeled(2, "loom-ar");
        let hs: Vec<_> = (0..2usize)
            .map(|r| {
                let g = Arc::clone(&g);
                thread::spawn(move || {
                    for round in 0..2u32 {
                        let v = g
                            .run(
                                r,
                                CollectiveOp::Allreduce {
                                    data: vec![r as f32 + round as f32],
                                    red: Reduce::Sum,
                                    dt: ReduceDtype::F32,
                                },
                            )
                            .unwrap()
                            .values();
                        // sum over ranks of (r + round) = 1 + 2*round
                        assert_eq!(v, vec![1.0 + 2.0 * round as f32]);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    });
}

/// Three members, one round: the last-arrival-combines and
/// last-departure-resets transitions with a bigger membership (first
/// and last arrival are different ranks in different interleavings).
#[test]
fn allreduce_three_ranks_single_round() {
    loom::model(|| {
        let g = Group::new_labeled(3, "loom-ar3");
        let hs: Vec<_> = (0..3usize)
            .map(|r| {
                let g = Arc::clone(&g);
                thread::spawn(move || {
                    g.run(
                        r,
                        CollectiveOp::Allreduce {
                            data: vec![1.0],
                            red: Reduce::Sum,
                            dt: ReduceDtype::F32,
                        },
                    )
                    .unwrap()
                    .values()
                })
            })
            .collect();
        for h in hs {
            assert_eq!(h.join().unwrap(), vec![3.0]);
        }
    });
}

/// Peer death: one member deposits and waits, the "dead" peer poisons
/// the group instead of arriving. Whatever the interleaving — poison
/// before the survivor enters, between its deposit and its wait, or
/// while it is parked on the condvar — the survivor must come back with
/// `Poisoned`, never deadlock. (This model is what caught the missing
/// pre-wait poison check in `Group::wait_step`.)
#[test]
fn peer_death_poisons_the_waiting_member() {
    loom::model(|| {
        let g = Group::new_labeled(2, "loom-poison");
        let survivor = {
            let g = Arc::clone(&g);
            thread::spawn(move || {
                g.run(
                    0,
                    CollectiveOp::Allreduce {
                        data: vec![1.0],
                        red: Reduce::Sum,
                        dt: ReduceDtype::F32,
                    },
                )
            })
        };
        let dead = thread::spawn(move || g.poison());
        dead.join().unwrap();
        let r = survivor.join().unwrap();
        assert!(matches!(r, Err(CommFault::Poisoned)), "{r:?}");
    });
}

/// Program-order divergence: the two members issue *different*
/// collectives into the same round. Exactly one of them pins the round;
/// the other must fail with the `[order]` violation, and the violation
/// must poison the group so the pinner unblocks with `Poisoned` —
/// in every arrival order.
#[test]
fn order_violation_fails_both_members_without_hanging() {
    loom::model(|| {
        let g = Group::new_labeled(2, "loom-order");
        let a = {
            let g = Arc::clone(&g);
            thread::spawn(move || {
                g.run(
                    0,
                    CollectiveOp::Allreduce {
                        data: vec![1.0],
                        red: Reduce::Sum,
                        dt: ReduceDtype::F32,
                    },
                )
            })
        };
        let b = thread::spawn(move || {
            g.run(1, CollectiveOp::Allgather { data: vec![2.0], dt: ReduceDtype::F32 })
        });
        let ra = a.join().unwrap();
        let rb = b.join().unwrap();
        let faults = [ra.unwrap_err(), rb.unwrap_err()];
        let violations = faults
            .iter()
            .filter(|f| matches!(f, CommFault::Violated { check: "order", .. }))
            .count();
        let poisons = faults
            .iter()
            .filter(|f| matches!(f, CommFault::Poisoned))
            .count();
        // the second arrival violates; the first either was still waiting
        // (Poisoned) or had not yet deposited when the poison landed
        assert_eq!(violations + poisons, 2, "{faults:?}");
        assert!(violations >= 1, "someone must see the order violation: {faults:?}");
    });
}

// ---- CommRuntime lane ------------------------------------------------

/// Submit → execute → wait on a live lane, then drop it: two FIFO jobs
/// must both resolve with their own results (no lost wakeup between the
/// worker's `Done` notify and the waiter), and `Drop` must join the
/// worker cleanly (loom fails leaked threads).
#[test]
fn lane_submit_wait_drop_lifecycle() {
    loom::model(|| {
        let rt = CommRuntime::new("loom-lane");
        let h1 = rt.submit(|| 1usize);
        let h2 = rt.submit(|| 2usize);
        assert_eq!(h1.wait(), 1);
        assert_eq!(h2.wait(), 2);
        drop(rt);
    });
}

/// Dropping a lane with a job still queued: `Drop` closes the queue and
/// the worker drains what was already submitted before exiting — the
/// handle must resolve to the job's value, never to a lost job.
#[test]
fn dropping_the_lane_never_loses_a_queued_job() {
    loom::model(|| {
        let rt = CommRuntime::new("loom-drop");
        let h = rt.submit(|| 9usize);
        drop(rt);
        assert_eq!(h.wait(), 9);
    });
}

/// Abort racing the worker: the submitted job either ran (worker popped
/// it first) or was orphaned with its lane label and op counter (abort
/// drained it first). Both are legal; silently hanging or losing the
/// slot is not.
#[test]
fn abort_orphans_or_completes_but_never_hangs() {
    loom::model(|| {
        let rt = CommRuntime::new("loom-abort");
        let h = rt.submit(|| 5usize);
        rt.abort();
        match h.try_wait() {
            Ok(v) => assert_eq!(v, 5),
            Err(d) => {
                assert_eq!(d.op, 1);
                assert!(d.lane.contains("loom-abort"), "{}", d.lane);
            }
        }
        drop(rt);
    });
}
