//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The `xla` crate's types hold `Rc`s and raw pointers (`!Send`), so all
//! XLA objects live on dedicated **executor threads**; rank threads talk
//! to them through channels with plain `Tensor` values (safe, no
//! `unsafe impl Send`). An [`Engine`] is a clonable handle over a pool of
//! executors — each executor owns its own `PjRtClient` and executable
//! cache, so executions proceed in parallel across the pool.
//!
//! Interchange format: HLO *text* (see DESIGN.md / aot.py) loaded with
//! `HloModuleProto::from_text_file`, compiled once per (executor,
//! artifact) and cached.

mod tensor;

pub use tensor::{Dtype, Tensor};

use crate::Result;
use anyhow::anyhow;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

struct Request {
    key: String,
    path: PathBuf,
    inputs: Vec<Tensor>,
    reply: mpsc::Sender<Result<Vec<Tensor>>>,
    /// when the caller enqueued the request — the executor splits
    /// queue-wait from execution time at pickup
    queued: std::time::Instant,
}

/// Pool counters, split so queue pressure and artifact cost are separately
/// visible: `queue_secs` is time requests sat waiting for a free executor
/// (the pool-sizing signal), `exec_secs` is time actually spent compiling
/// and running artifacts.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EngineStats {
    pub calls: u64,
    pub queue_secs: f64,
    pub exec_secs: f64,
}

/// Handle to the executor pool. Cheap to clone; `exec` blocks until the
/// artifact has run and returns host tensors.
#[derive(Clone)]
pub struct Engine {
    tx: mpsc::Sender<Request>,
    // stats
    calls: Arc<AtomicU64>,
    queue_nanos: Arc<AtomicU64>,
    exec_nanos: Arc<AtomicU64>,
}

impl Engine {
    /// Pool with `n` executor threads (each with its own PJRT CPU client).
    pub fn new_pool(n: usize) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let queue_nanos = Arc::new(AtomicU64::new(0));
        let exec_nanos = Arc::new(AtomicU64::new(0));
        for i in 0..n.max(1) {
            let rx = Arc::clone(&rx);
            let q = Arc::clone(&queue_nanos);
            let e = Arc::clone(&exec_nanos);
            std::thread::Builder::new()
                .name(format!("pjrt-exec-{i}"))
                .spawn(move || executor_loop(rx, q, e))
                .expect("spawn executor");
        }
        Ok(Engine {
            tx,
            calls: Arc::new(AtomicU64::new(0)),
            queue_nanos,
            exec_nanos,
        })
    }

    pub fn new() -> Result<Engine> {
        Self::new_pool(1)
    }

    /// Execute artifact at `path` (cache key `key`) on the pool.
    pub fn exec(&self, key: &str, path: PathBuf, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request {
                key: key.to_string(),
                path,
                inputs,
                reply: rtx,
                queued: std::time::Instant::now(),
            })
            .map_err(|_| anyhow!("executor pool is gone"))?;
        let out = rrx.recv().map_err(|_| anyhow!("executor dropped reply"))?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Pool counters with the queue-wait / execution split.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            calls: self.calls.load(Ordering::Relaxed),
            queue_secs: self.queue_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            exec_secs: self.exec_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }
}

fn executor_loop(
    rx: Arc<Mutex<mpsc::Receiver<Request>>>,
    queue_nanos: Arc<AtomicU64>,
    exec_nanos: Arc<AtomicU64>,
) {
    // One PJRT client + executable cache per executor thread; all xla
    // objects stay on this thread.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("fatal: cannot create PJRT CPU client: {e}");
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    loop {
        let req = {
            let guard = crate::util::lock(&rx);
            match guard.recv() {
                Ok(r) => r,
                Err(_) => return, // engine dropped
            }
        };
        queue_nanos.fetch_add(req.queued.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let t_exec = std::time::Instant::now();
        let reply = req.reply.clone();
        let result = run_one(&client, &mut cache, req);
        exec_nanos.fetch_add(t_exec.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let _ = reply.send(result);
    }
}

fn run_one(
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    req: Request,
) -> Result<Vec<Tensor>> {
    if !cache.contains_key(&req.key) {
        let proto = xla::HloModuleProto::from_text_file(&req.path)
            .map_err(|e| anyhow!("loading {:?}: {e}", req.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", req.key))?;
        cache.insert(req.key.clone(), exe);
    }
    let exe = cache.get(&req.key).unwrap();
    let lits: Vec<xla::Literal> = req
        .inputs
        .iter()
        .map(tensor::to_literal)
        .collect::<Result<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&lits)
        .map_err(|e| anyhow!("executing {}: {e}", req.key))?;
    // single replica, single partition; aot lowers with return_tuple=True
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching result of {}: {e}", req.key))?;
    let parts = lit
        .to_tuple()
        .map_err(|e| anyhow!("detupling result of {}: {e}", req.key))?;
    parts.iter().map(tensor::from_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_art(name: &str) -> PathBuf {
        crate::artifacts_dir().join("mula-tiny").join(format!("{name}.hlo.txt"))
    }

    #[test]
    fn engine_runs_eval_step() {
        let Some(m) = crate::manifest_or_skip("runtime::engine_runs_eval_step") else {
            return;
        };
        let cfg = m.config("mula-tiny").unwrap();
        let eng = Engine::new().unwrap();
        let p = Tensor::zeros_f32(vec![cfg.param_count]);
        let toks = Tensor::i32(
            vec![1; cfg.hyper.batch * (cfg.hyper.seq + 1)],
            vec![cfg.hyper.batch, cfg.hyper.seq + 1],
        );
        let out = eng
            .exec("eval", tiny_art("eval_step"), vec![p, toks])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].shape(), &[cfg.hyper.batch, cfg.hyper.seq]);
        // zero params -> uniform logits -> nll == ln(V)
        let nll = out[0].as_f32().unwrap();
        let want = (cfg.hyper.vocab_size as f32).ln();
        for v in nll {
            assert!((v - want).abs() < 1e-3, "{v} vs {want}");
        }
    }

    #[test]
    fn parallel_execs_from_many_threads() {
        let Some(m) = crate::manifest_or_skip("runtime::parallel_execs_from_many_threads")
        else {
            return;
        };
        let cfg = m.config("mula-tiny").unwrap();
        let eng = Engine::new_pool(2).unwrap();
        let pc = cfg.param_count;
        let (b, s) = (cfg.hyper.batch, cfg.hyper.seq);
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let eng = eng.clone();
                let path = tiny_art("eval_step");
                std::thread::spawn(move || {
                    let p = Tensor::zeros_f32(vec![pc]);
                    let toks =
                        Tensor::i32(vec![(i % 7) as i32; b * (s + 1)], vec![b, s + 1]);
                    eng.exec("eval", path, vec![p, toks]).unwrap()
                })
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out.len(), 2);
        }
        let st = eng.stats();
        assert_eq!(st.calls, 4);
        // executor time is real work; the queue split never counts it
        assert!(st.exec_secs > 0.0, "{st:?}");
        assert!(st.queue_secs >= 0.0, "{st:?}");
    }
}
