//! `optimus lint` — the repo's own invariant lint over the crate sources.
//!
//! Generic tooling can't know this codebase's contracts; this pass can.
//! It walks `src/**.rs` and `tests/*.rs` with a small Rust-shaped line
//! scanner (comment-, string- and raw-string-aware — no parser, no new
//! dependencies) and enforces four rules the rest of the crate relies on:
//!
//! 1. **check-strings** — every stable failure tag of the shape
//!    `"<domain> [<name>]"` (domains end in `failed`/`violated`, see
//!    [`crate::ft::checks`]) must name a registered check. A typo'd tag
//!    would silently escape [`crate::ft::classify`] and every runbook
//!    grep.
//! 2. **check-coverage** — the reverse direction: every registered check
//!    must be asserted, as its full stable literal, by at least one test
//!    (a `#[cfg(test)]` region or an integration test file). A check
//!    nobody tests is a check that silently rots.
//! 3. **named-spawn** — no bare `thread::spawn` outside tests: threads
//!    must come from `std::thread::Builder` with a name (so stall dumps
//!    and panics identify the thread) or `comm::lsync::spawn_named`.
//! 4. **lock-discipline** — no `.lock().unwrap()` outside `comm/` and
//!    `ckpt/` (whose rendezvous/writer protocols poison deliberately and
//!    re-panic by design): shared-state readers elsewhere must use the
//!    poison-tolerant [`crate::util::lock`] so one dead rank thread
//!    doesn't cascade into every thread that later peeks at a counter.
//! 5. **metrics-class** — every `f64` field of
//!    [`crate::metrics::StepBreakdown`] must carry a
//!    `class: additive|concurrent|contained` doc tag so `total()` can
//!    never silently double-count a concurrent component.
//!
//! The scanner is line-based on a sanitized view of each file: comments
//! are stripped everywhere (so `[<check>]` placeholders in docs don't
//! trip rule 1), and for structural rules (2, 3 and the `#[cfg(test)]`
//! region tracker) string contents are dropped too (so braces inside
//! format strings don't corrupt region tracking, and rule text quoting a
//! forbidden pattern doesn't flag itself).

use crate::ft::checks;
use crate::Result;
use anyhow::anyhow;
use std::collections::BTreeSet;
use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding, formatted `file:line: [rule] message`.
#[derive(Clone, Debug)]
pub struct Violation {
    /// crate-relative path, e.g. `src/comm/group.rs`
    pub file: String,
    /// 1-based; 0 when the finding is not anchored to a line
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line == 0 {
            write!(f, "{}: [{}] {}", self.file, self.rule, self.msg)
        } else {
            write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
        }
    }
}

/// A source file handed to [`scan`]: crate-relative path + full text.
pub struct SrcFile {
    pub rel: String,
    pub text: String,
}

impl SrcFile {
    /// Integration tests and benches are all-test: exempt from the
    /// structural rules, still scanned (and counted) by rules 1–2.
    fn is_test_file(&self) -> bool {
        self.rel.starts_with("tests/") || self.rel.starts_with("benches/")
    }
}

/// The crate directory this binary was built from — the default lint
/// root, so `optimus lint` works from any CWD inside the checkout.
pub fn default_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Collect `src/**.rs` and `tests/**.rs` under `root`, sorted for
/// deterministic output.
pub fn collect(root: &Path) -> Result<Vec<SrcFile>> {
    let mut out = Vec::new();
    walk(&root.join("src"), "src", &mut out)?;
    walk(&root.join("tests"), "tests", &mut out)?;
    if out.is_empty() {
        return Err(anyhow!(
            "no .rs sources under {root:?} — pass --root <crate dir>"
        ));
    }
    out.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(out)
}

fn walk(dir: &Path, rel: &str, out: &mut Vec<SrcFile>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        let name = e.file_name().to_string_lossy().into_owned();
        if p.is_dir() {
            walk(&p, &format!("{rel}/{name}"), out)?;
        } else if name.ends_with(".rs") {
            out.push(SrcFile {
                rel: format!("{rel}/{name}"),
                text: std::fs::read_to_string(&p)?,
            });
        }
    }
    Ok(())
}

/// Lint the crate at `root`; empty result means clean.
pub fn run(root: &Path) -> Result<Vec<Violation>> {
    Ok(scan(&collect(root)?))
}

/// Pure core: lint an in-memory file set (what the self-tests seed).
pub fn scan(files: &[SrcFile]) -> Vec<Violation> {
    let mut domains: Vec<&'static str> = checks::CHECKS.iter().map(|c| c.domain).collect();
    domains.dedup();

    let mut v = Vec::new();
    let mut asserted: BTreeSet<(&'static str, &'static str)> = BTreeSet::new();
    for f in files {
        let with_strings = sanitize(&f.text, true);
        let code_only = sanitize(&f.text, false);
        let mask = test_mask(&code_only, f.is_test_file());
        check_strings(f, &with_strings, &mask, &domains, &mut v, &mut asserted);
        if !f.is_test_file() {
            spawn_rule(f, &code_only, &mask, &mut v);
            lock_rule(f, &code_only, &mask, &mut v);
        }
        if f.rel.ends_with("metrics/mod.rs") {
            metrics_rule(f, &mut v);
        }
    }
    for c in checks::CHECKS {
        if !asserted.contains(&(c.domain, c.name)) {
            v.push(Violation {
                file: "src/ft/checks.rs".into(),
                line: 0,
                rule: "check-coverage",
                msg: format!(
                    "registered check `{} [{}]` is asserted by no test — add a test \
                     containing its full stable string",
                    c.domain, c.name
                ),
            });
        }
    }
    v
}

/// Rule 1 + the assertion census for rule 2. Runs on comment-stripped
/// text *with* string contents kept (the tags live in string literals),
/// over every line — a typo'd tag in a test assertion is as wrong as one
/// in an error site.
fn check_strings(
    f: &SrcFile,
    text: &str,
    mask: &[bool],
    domains: &[&'static str],
    v: &mut Vec<Violation>,
    asserted: &mut BTreeSet<(&'static str, &'static str)>,
) {
    for (ix, line) in text.lines().enumerate() {
        for (bpos, _) in line.match_indices('[') {
            let rest = &line[bpos + 1..];
            let Some(end) = rest.find(']') else { continue };
            let name = &rest[..end];
            let tag_shaped = !name.is_empty()
                && name
                    .chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-');
            if !tag_shaped {
                continue;
            }
            let before = &line[..bpos];
            if !(before.ends_with("failed ") || before.ends_with("violated ")) {
                continue;
            }
            let head = &before[..before.len() - 1];
            match domains.iter().find(|d| head.ends_with(**d)) {
                Some(d) => match checks::CHECKS
                    .iter()
                    .find(|c| c.domain == **d && c.name == name)
                {
                    Some(c) => {
                        if mask.get(ix) == Some(&true) {
                            asserted.insert((c.domain, c.name));
                        }
                    }
                    None => v.push(Violation {
                        file: f.rel.clone(),
                        line: ix + 1,
                        rule: "check-strings",
                        msg: format!(
                            "`{d} [{name}]` is not registered in ft::checks::CHECKS"
                        ),
                    }),
                },
                None => v.push(Violation {
                    file: f.rel.clone(),
                    line: ix + 1,
                    rule: "check-strings",
                    msg: format!(
                        "check-shaped tag `[{name}]` follows an unknown failure domain \
                         (`...{}`) — route it through ft::checks",
                        &head[head.len().saturating_sub(30)..]
                    ),
                }),
            }
        }
    }
}

/// Rule 3: bare `thread::spawn` outside tests. The loom shim is the one
/// place allowed to call it (loom's spawn has no named builder).
fn spawn_rule(f: &SrcFile, code: &str, mask: &[bool], v: &mut Vec<Violation>) {
    if f.rel == "src/comm/lsync.rs" {
        return;
    }
    for (ix, line) in code.lines().enumerate() {
        if mask.get(ix) == Some(&true) {
            continue;
        }
        if line.contains("thread::spawn") {
            v.push(Violation {
                file: f.rel.clone(),
                line: ix + 1,
                rule: "named-spawn",
                msg: "bare thread::spawn — use std::thread::Builder::new().name(..) \
                      (joinable, shows up in stall dumps) or comm::lsync::spawn_named"
                    .into(),
            });
        }
    }
}

/// Rule 4: `.lock().unwrap()` outside `comm/` and `ckpt/`.
fn lock_rule(f: &SrcFile, code: &str, mask: &[bool], v: &mut Vec<Violation>) {
    if f.rel.starts_with("src/comm/") || f.rel.starts_with("src/ckpt/") {
        return;
    }
    for (ix, line) in code.lines().enumerate() {
        if mask.get(ix) == Some(&true) {
            continue;
        }
        if line.contains(".lock().unwrap()") {
            v.push(Violation {
                file: f.rel.clone(),
                line: ix + 1,
                rule: "lock-discipline",
                msg: "`.lock().unwrap()` outside comm/ and ckpt/ — use the \
                      poison-tolerant crate::util::lock so one panicked thread \
                      doesn't cascade"
                    .into(),
            });
        }
    }
}

/// Rule 5: every `StepBreakdown` `f64` field documents its accounting
/// class, so `total()` can be audited against the tags.
fn metrics_rule(f: &SrcFile, v: &mut Vec<Violation>) {
    let lines: Vec<&str> = f.text.lines().collect();
    let Some(start) = lines.iter().position(|l| l.contains("pub struct StepBreakdown")) else {
        v.push(Violation {
            file: f.rel.clone(),
            line: 0,
            rule: "metrics-class",
            msg: "pub struct StepBreakdown not found — if it moved, update \
                  analysis::metrics_rule"
                .into(),
        });
        return;
    };
    for ix in start + 1..lines.len() {
        let t = lines[ix].trim();
        if t == "}" {
            break;
        }
        if !(t.starts_with("pub ") && t.contains(": f64")) {
            continue;
        }
        let mut classified = false;
        let mut j = ix;
        while j > start + 1 {
            j -= 1;
            let d = lines[j].trim();
            if !d.starts_with("///") {
                break;
            }
            if d.contains("class: additive")
                || d.contains("class: concurrent")
                || d.contains("class: contained")
            {
                classified = true;
            }
        }
        if !classified {
            v.push(Violation {
                file: f.rel.clone(),
                line: ix + 1,
                rule: "metrics-class",
                msg: format!(
                    "StepBreakdown field `{}` lacks a `class: \
                     additive|concurrent|contained` doc tag",
                    t.trim_end_matches(',')
                ),
            });
        }
    }
}

/// Sanitize Rust source for line scanning: strip `//` and (nesting)
/// `/* */` comments; handle `"…"`, `r"…"`/`r#"…"#` and char literals.
/// With `keep_strings` the string *contents* survive (rule 1 reads
/// them); without, only the bare quotes survive (structural rules).
/// Newlines are preserved everywhere, so line numbers map 1:1.
fn sanitize(text: &str, keep_strings: bool) -> String {
    let cs: Vec<char> = text.chars().collect();
    let mut out = String::with_capacity(cs.len());
    let mut i = 0;
    while i < cs.len() {
        let c = cs[i];
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            while i < cs.len() && cs[i] != '\n' {
                i += 1;
            }
            continue; // the newline itself is emitted by the fall-through
        }
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            i += 2;
            while i < cs.len() && depth > 0 {
                if cs[i] == '/' && cs.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && cs.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        out.push('\n');
                    }
                    i += 1;
                }
            }
            continue;
        }
        if c == 'r' && !prev_is_ident(&cs, i) {
            // raw string r"…" / r#"…"# (any hash count)
            let mut j = i + 1;
            let mut hashes = 0usize;
            while cs.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if cs.get(j) == Some(&'"') {
                j += 1;
                let content = j;
                while j < cs.len() {
                    if cs[j] == '"'
                        && (0..hashes).all(|k| cs.get(j + 1 + k) == Some(&'#'))
                    {
                        break;
                    }
                    j += 1;
                }
                out.push('"');
                for &ch in &cs[content..j.min(cs.len())] {
                    if keep_strings || ch == '\n' {
                        out.push(ch);
                    }
                }
                out.push('"');
                i = (j + 1 + hashes).min(cs.len());
                continue;
            }
        }
        if c == '"' {
            out.push('"');
            i += 1;
            while i < cs.len() && cs[i] != '"' {
                if cs[i] == '\\' {
                    if keep_strings {
                        out.push(cs[i]);
                        if let Some(&n) = cs.get(i + 1) {
                            out.push(n);
                        }
                    } else if cs.get(i + 1) == Some(&'\n') {
                        out.push('\n');
                    }
                    i += 2;
                    continue;
                }
                if keep_strings || cs[i] == '\n' {
                    out.push(cs[i]);
                }
                i += 1;
            }
            out.push('"');
            i += 1;
            continue;
        }
        if c == '\'' {
            if cs.get(i + 1) == Some(&'\\') {
                // escaped char literal: '\n', '\'', '\u{1F600}'
                let mut j = i + 2;
                if cs.get(j) == Some(&'u') {
                    while j < cs.len() && cs[j] != '\'' {
                        j += 1;
                    }
                } else {
                    j += 1;
                }
                out.push('\'');
                i = (j + 1).min(cs.len());
                continue;
            }
            if cs.get(i + 2) == Some(&'\'') {
                // plain char literal — may hold '{' or '"'
                out.push('\'');
                i += 3;
                continue;
            }
            // lifetime
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

fn prev_is_ident(cs: &[char], i: usize) -> bool {
    i > 0 && (cs[i - 1].is_alphanumeric() || cs[i - 1] == '_' || cs[i - 1] == '"')
}

/// Per-line `is this line test code?` mask. `#[cfg(test)]` arms the
/// tracker; the braces of the next item (on string-stripped text, so
/// format-string braces can't skew the depth) delimit the region.
fn test_mask(code: &str, whole_file_is_test: bool) -> Vec<bool> {
    let lines: Vec<&str> = code.lines().collect();
    if whole_file_is_test {
        return vec![true; lines.len()];
    }
    let mut mask = vec![false; lines.len()];
    let mut pending = false;
    let mut in_test = false;
    let mut depth: i64 = 0;
    for (ix, line) in lines.iter().enumerate() {
        let opens = line.matches('{').count() as i64;
        let closes = line.matches('}').count() as i64;
        if in_test {
            mask[ix] = true;
            depth += opens - closes;
            if depth <= 0 {
                in_test = false;
            }
            continue;
        }
        if pending {
            mask[ix] = true;
            if opens > 0 {
                pending = false;
                depth = opens - closes;
                if depth > 0 {
                    in_test = true;
                }
            } else if line.trim_end().ends_with(';') {
                pending = false; // braceless item, e.g. a gated `use`
            }
            continue;
        }
        if line.contains("#[cfg(test)]") {
            mask[ix] = true;
            if opens > 0 {
                depth = opens - closes;
                if depth > 0 {
                    in_test = true;
                }
            } else {
                pending = true;
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(rel: &str, text: &str) -> SrcFile {
        SrcFile { rel: rel.into(), text: text.into() }
    }

    fn rules(v: &[Violation], rule: &str) -> usize {
        v.iter().filter(|x| x.rule == rule).count()
    }

    #[test]
    fn sanitizer_strips_comments_and_strings() {
        let t = "let a = 1; // x.lock().unwrap()\n/* {{{ */ let s = \"{ } [x]\";\n";
        let code = sanitize(t, false);
        assert!(!code.contains("lock"), "{code}");
        assert!(!code.contains('['), "{code}");
        assert_eq!(code.lines().count(), t.lines().count());
        let kept = sanitize(t, true);
        assert!(kept.contains("[x]"), "{kept}");
        assert!(!kept.contains("unwrap"), "{kept}");
    }

    #[test]
    fn sanitizer_handles_raw_strings_and_char_literals() {
        let t = "let j = r#\"{\"a\": {\"b\": 1}}\"#;\nlet c = '{';\nlet s = \"one \\\n two\";\nfn f<'a>(x: &'a str) {}\n";
        let code = sanitize(t, false);
        // every brace inside the raw string / char literal is gone
        assert_eq!(code.matches('{').count(), 1, "{code}");
        assert_eq!(code.matches('}').count(), 1, "{code}");
        assert_eq!(code.lines().count(), t.lines().count());
        assert!(code.contains("<'a>"), "{code}");
    }

    #[test]
    fn test_regions_are_tracked_by_braces() {
        let t = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() { let s = \"}\"; }\n}\nfn c() {}\n";
        let mask = test_mask(&sanitize(t, false), false);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn unregistered_check_string_is_flagged() {
        // assemble the tag at runtime so linting *this* file stays clean
        let text = format!(
            "fn f() -> anyhow::Error {{\n    anyhow::anyhow!(\"plan validation {} [no-such-check]: boom\")\n}}\n",
            "failed"
        );
        let v = scan(&[src("src/foo.rs", &text)]);
        assert_eq!(rules(&v, "check-strings"), 1, "{v:?}");
        assert!(v.iter().any(|x| x.msg.contains("no-such-check")), "{v:?}");

        let text = format!("const T: &str = \"quota exceeded {} [retry]\";\n", "failed");
        let v = scan(&[src("src/foo.rs", &text)]);
        assert_eq!(rules(&v, "check-strings"), 1, "unknown domain must flag: {v:?}");

        // comments and doc placeholders never trip the rule
        let text = format!("// plan validation {} [nope]\n/// `{} [<check>]`\n", "failed", "violated");
        let v = scan(&[src("src/foo.rs", &text)]);
        assert_eq!(rules(&v, "check-strings"), 0, "{v:?}");
    }

    #[test]
    fn every_registered_check_needs_a_test_assertion() {
        // a file set with no test literals at all: every check uncovered
        let v = scan(&[src("src/foo.rs", "fn a() {}\n")]);
        assert_eq!(rules(&v, "check-coverage"), checks::CHECKS.len());

        // a test file asserting every registered tag: zero uncovered
        let mut t = String::from("fn all() {\n");
        for c in checks::CHECKS {
            t.push_str(&format!(
                "    assert!(e.contains(\"{} [{}]\"));\n",
                c.domain, c.name
            ));
        }
        t.push_str("}\n");
        let v = scan(&[src("tests/cover.rs", &t)]);
        assert_eq!(rules(&v, "check-coverage"), 0, "{v:?}");
        // ...and the same literals inside a src #[cfg(test)] region count too
        let t2 = format!("#[cfg(test)]\nmod tests {{\n{}}}\n", &t);
        let v = scan(&[src("src/foo.rs", &t2)]);
        assert_eq!(rules(&v, "check-coverage"), 0, "{v:?}");
    }

    #[test]
    fn spawn_and_lock_rules_respect_regions_and_exemptions() {
        let bad = "fn f() {\n    std::thread::spawn(|| {});\n    let g = m.lock().unwrap();\n}\n";
        let v = scan(&[src("src/foo.rs", bad)]);
        assert_eq!(rules(&v, "named-spawn"), 1, "{v:?}");
        assert_eq!(rules(&v, "lock-discipline"), 1, "{v:?}");

        // the same text is fine in a test region, a test file, or comm/
        let in_test = format!("#[cfg(test)]\nmod tests {{\n{bad}}}\n");
        let v = scan(&[src("src/foo.rs", &in_test)]);
        assert_eq!(rules(&v, "named-spawn") + rules(&v, "lock-discipline"), 0, "{v:?}");
        let v = scan(&[src("tests/foo.rs", bad)]);
        assert_eq!(rules(&v, "named-spawn") + rules(&v, "lock-discipline"), 0, "{v:?}");
        let v = scan(&[src("src/comm/foo.rs", bad), src("src/ckpt/bar.rs", bad)]);
        assert_eq!(rules(&v, "lock-discipline"), 0, "{v:?}");
        assert_eq!(rules(&v, "named-spawn"), 2, "comm is not spawn-exempt: {v:?}");
        let v = scan(&[src("src/comm/lsync.rs", bad)]);
        assert_eq!(rules(&v, "named-spawn"), 0, "{v:?}");
    }

    #[test]
    fn unclassified_breakdown_field_is_flagged() {
        let m = "pub struct StepBreakdown {\n    /// class: additive\n    pub a_secs: f64,\n    /// no tag here\n    pub b_secs: f64,\n}\n";
        let v = scan(&[src("src/metrics/mod.rs", m)]);
        assert_eq!(rules(&v, "metrics-class"), 1, "{v:?}");
        assert!(v.iter().any(|x| x.msg.contains("b_secs")), "{v:?}");
    }

    #[test]
    fn the_repo_lints_clean() {
        // the acceptance gate: `optimus lint` over this very checkout
        let v = run(&default_root()).unwrap();
        let report: Vec<String> = v.iter().map(|x| x.to_string()).collect();
        assert!(v.is_empty(), "repo lint violations:\n{}", report.join("\n"));
    }
}
