//! Data-parallel engine: the fused `train_step` artifact on every rank.
//!
//! Per step and rank: contiguous data slice → fused fwd+bwd (HLO) →
//! sharded optimizer (reduce-scatter grads / AdamW shard / allgather
//! params). Everything else — spawning, broadcast, NaN guard, loss
//! averaging, report assembly — lives in the shared
//! [`harness`](super::harness); the optimizer segment layout comes from
//! the [`ParallelismPlan`](super::ParallelismPlan).
//!
//! The parameter vector is an `Arc`-backed [`Tensor`]: re-submitting it to
//! the engine each step is a refcount bump, and the optimizer mutates it
//! in place via copy-on-write once the engine has dropped its handle.

use super::clip_now;
use super::harness::{
    CkptView, LossDomain, RankCtx, RankFinish, RankTrainer, ReportParts, StepOutcome,
};
use super::plan::ParallelismPlan;
use crate::ckpt::LocalMap;
use crate::config::ModelManifest;
use crate::metrics::{Scoped, StepBreakdown};
use crate::optim::sharded::{plan_segments, ShardedOptimizer};
use crate::runtime::Tensor;
use crate::Result;
use std::path::PathBuf;
use std::sync::Arc;

pub(super) struct DpTrainer {
    params: Tensor,
    /// local == global for DP (the identity checkpoint map)
    map: LocalMap,
    opt: ShardedOptimizer,
    art: PathBuf,
    key: String,
    loss_dom: LossDomain,
}

impl RankTrainer for DpTrainer {
    const LABEL: &'static str = "dp";
    type Shared = ();

    fn shared(_mm: &ModelManifest, _plan: &ParallelismPlan) -> Result<Arc<()>> {
        Ok(Arc::new(()))
    }

    fn setup(ctx: &RankCtx, _shared: &Arc<()>, global_params: Vec<f32>) -> Result<DpTrainer> {
        let rank = ctx.rank;
        let (dp_group, dp_rank) = ctx.mesh.dp_group(rank);
        let (xg, xr) = ctx.mesh.dpep_group(rank);
        let segs = plan_segments(
            ctx.plan.mode,
            ctx.plan.stages[0].seg,
            dp_group,
            dp_rank,
            xg,
            xr,
            1,
        );
        let opt = ctx.sharded_optimizer(segs, &format!("dp{rank}"));
        Ok(DpTrainer {
            // plan dtype decides the resident precision: bf16 params
            // round once here (RNE) and stay bf16 for the whole run —
            // the optimizer's f32 masters carry full-width state
            params: Tensor::from_f32(ctx.plan.dtype, global_params, vec![ctx.mm.param_count]),
            map: LocalMap::identity(ctx.mm.param_count),
            opt,
            art: ctx.mm.artifact_path("train_step")?,
            key: format!("{}:train_step", ctx.mm.name),
            loss_dom: LossDomain {
                group: Arc::clone(ctx.mesh.world_group()),
                group_rank: rank,
                record: rank == 0,
            },
        })
    }

    fn step(
        &mut self,
        ctx: &RankCtx,
        step: usize,
        breakdown: &mut StepBreakdown,
    ) -> Result<StepOutcome> {
        let tokens = ctx.fetch_tokens(step, ctx.rank, 0, breakdown)?;
        let outs = {
            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
            // zero-copy: params is Arc-backed, clone() bumps a refcount
            ctx.engine
                .exec(&self.key, self.art.clone(), vec![self.params.clone(), tokens])?
        };
        // curve uses the LM cross-entropy (outs[1]); outs[0] is the
        // training objective (lm + aux) used for gradients only.
        let loss = outs[1].scalar()?;
        if !loss.is_finite() {
            return Err(ctx.non_finite(step));
        }
        let grads = outs[3].as_f32()?;
        let lr = ctx.spec.run.lr_at(step) as f32;
        let gn = self
            .opt
            .step_tensor(&mut self.params, grads, lr, clip_now(&ctx.spec.run, step))?;
        Ok(StepOutcome { loss, grad_norm: gn })
    }

    fn params_mut(&mut self) -> Result<&mut [f32]> {
        Ok(self.params.as_f32_mut()?.as_mut_slice())
    }

    fn ckpt_view(&mut self) -> CkptView<'_> {
        CkptView { params: &self.params, map: &self.map, opt: &mut self.opt }
    }

    fn loss_domain(&self) -> Option<&LossDomain> {
        Some(&self.loss_dom)
    }

    fn finish(self, ctx: &RankCtx) -> Result<RankFinish> {
        if ctx.rank != 0 {
            return Ok(RankFinish::None);
        }
        Ok(RankFinish::Report(Box::new(ReportParts {
            final_params: self.params,
            opt_state_bytes: self.opt.state_bytes(),
            optimizer_update_secs: self.opt.update_secs,
            optimizer_comm_secs: self.opt.comm_secs,
            optimizer_overlap_secs: self.opt.overlap_secs,
            optimizer_lane_ops: self.opt.lane_ops(),
        })))
    }
}
