//! Integration: paper §4 reliability features against the real trainer —
//! hard/soft node-failure handling with buffer nodes, auto-resume from
//! the sharded async checkpoints, NaN containment.

use optimus::ckpt::{Checkpoint, ResumeState, SavedCheckpoint};
use optimus::coordinator::{self, JobSpec, JobSpecBuilder, StepHook};
use optimus::data::{corpus, preprocess};
use optimus::ft::{HardKillHook, Launcher, NanInjectHook};
use std::path::PathBuf;
use std::sync::Arc;

fn data_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("optimus-rel-data-{}", std::process::id()));
    if !dir.exists() {
        let files = corpus::data_files(42, 3, 16);
        preprocess::preprocess(&files, 64, 7, &dir, 256).unwrap();
    }
    dir
}

fn spec(steps: usize) -> JobSpecBuilder {
    JobSpec::new("mula-tiny")
        .data_dir(data_dir())
        .topology(2, 1, 1)
        .steps(steps)
        .warmup_steps(2)
        .engine_pool(2)
}

#[test]
fn hard_failure_relaunches_and_auto_resumes_from_sharded_checkpoint() {
    let Some(m) =
        optimus::manifest_or_skip("reliability::hard_failure_relaunches_from_checkpoint")
    else {
        return;
    };
    let ckroot =
        std::env::temp_dir().join(format!("optimus-rel-ck-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckroot);
    let kill = Arc::new(HardKillHook::once(1, 6));
    let launcher = Launcher::new(2, 2);

    let report = launcher
        .run(|attempt, nodes| {
            assert_eq!(nodes.len(), 2, "active set stays at world size");
            let s = spec(10)
                .world_size(nodes.len())
                .hook(kill.clone())
                .checkpoint_dir(&ckroot)
                .ckpt_every(3)
                .build()?;
            // auto-resume is inside train(): nothing to wire up here
            if attempt > 0 {
                let c = SavedCheckpoint::load_latest(&ckroot)
                    .expect("a committed checkpoint from before the crash");
                assert!(c.step >= 3);
            }
            coordinator::train(&m, &s)
        })
        .unwrap();
    assert_eq!(launcher.relaunches.load(std::sync::atomic::Ordering::Relaxed), 1);
    assert_eq!(launcher.pool.buffer_len(), 1, "one buffer node consumed");
    // the relaunched attempt resumed at step 4 (checkpoint at 3) and ran
    // to 9 — its curve holds exactly the resumed steps
    assert_eq!(report.loss.points.first().unwrap().0, 4);
    assert_eq!(report.loss.points.last().unwrap().0, 9);
    assert!(report.ckpt_commits >= 1, "resumed run kept checkpointing");
    // checkpoints written and valid; the newest committed is step 9
    let latest = SavedCheckpoint::load_latest(&ckroot).unwrap();
    assert_eq!(latest.step, 9);
    let _ = std::fs::remove_dir_all(&ckroot);
}

#[test]
fn soft_failure_is_detected_before_contaminating_checkpoints() {
    let Some(m) = optimus::manifest_or_skip("reliability::soft_failure_is_detected") else {
        return;
    };
    let ckroot =
        std::env::temp_dir().join(format!("optimus-rel-soft-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckroot);
    let s = spec(10)
        .hook(Arc::new(NanInjectHook::once(0, 4)))
        .checkpoint_dir(&ckroot)
        .ckpt_every(3)
        .build()
        .unwrap();
    let err = coordinator::train(&m, &s).unwrap_err();
    let kind = optimus::ft::classify(&err);
    assert_eq!(kind, optimus::ft::FailureKind::Soft, "{err:#}");
    // every committed checkpoint predates the NaN and is NaN-free
    let saved = SavedCheckpoint::load_latest(&ckroot).expect("step-3 checkpoint committed");
    assert!(saved.step < 4);
    let rs = ResumeState::open(&saved).unwrap();
    let param_count = m.config("mula-tiny").unwrap().param_count;
    let params = rs.assemble_params(param_count).unwrap();
    assert!(!optimus::ft::has_nan(&params), "checkpoint contaminated");
    let _ = std::fs::remove_dir_all(&ckroot);
}

#[test]
fn training_resumes_from_model_only_checkpoint() {
    // persistent model-only checkpoints restart with fresh optimizer
    // state; training continues sanely afterwards (paper: "does not alter
    // the training in any significant manner")
    let Some(m) = optimus::manifest_or_skip("reliability::resumes_from_model_only_ckpt")
    else {
        return;
    };
    let s1 = spec(8).peak_lr(2e-3).build().unwrap();
    let r1 = coordinator::train(&m, &s1).unwrap();

    struct LoadHook(Vec<f32>);
    impl StepHook for LoadHook {
        fn on_step(&self, _r: usize, s: usize, _l: f32, p: &mut [f32]) -> optimus::Result<()> {
            if s == 0 {
                p.copy_from_slice(&self.0);
            }
            Ok(())
        }
    }
    // the save API requires the plan fingerprint — no untagged files
    let ck = Checkpoint::model_only(8, &r1.final_params, &s1.fingerprint()).unwrap();
    assert!(ck.is_model_only());
    assert!(ck.plan.is_some());
    let s2 = spec(8)
        .peak_lr(2e-3)
        .hook(Arc::new(LoadHook(ck.params.clone())))
        .build()
        .unwrap();
    let r2 = coordinator::train(&m, &s2).unwrap();
    assert!(
        r2.loss.tail_mean(2) < r1.loss.tail_mean(2) + 0.3,
        "resume regressed: {:?} vs {:?}",
        r2.loss.tail_mean(2),
        r1.loss.tail_mean(2)
    );
}
