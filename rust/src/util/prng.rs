//! Deterministic PRNG: SplitMix64 core with normal/uniform helpers.
//!
//! Used for parameter init, data shuffling and the property-test harness.
//! Quality is plenty for simulation; determinism across runs (seeded) is
//! the property the trainer and tests rely on.

#[derive(Clone, Debug)]
pub struct Prng {
    state: u64,
    /// cached second Box-Muller sample
    spare: Option<f64>,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare: None }
    }

    /// Derive an independent stream (e.g. per rank, per layer).
    pub fn fork(&self, stream: u64) -> Self {
        let mut p = Prng::new(self.state ^ stream.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        p.next_u64();
        p
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            if u > 1e-12 {
                let r = (-2.0 * u.ln()).sqrt();
                let t = 2.0 * std::f64::consts::PI * v;
                self.spare = Some(r * t.sin());
                return r * t.cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u64> {
        let mut v: Vec<u64> = (0..n as u64).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut p = Prng::new(7);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| p.next_f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(9);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut p = Prng::new(3);
        let mut perm = p.permutation(1000);
        perm.sort_unstable();
        assert_eq!(perm, (0..1000).collect::<Vec<u64>>());
    }

    #[test]
    fn forked_streams_differ() {
        let p = Prng::new(5);
        let mut a = p.fork(1);
        let mut b = p.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
