//! Data-parallel training with the fused `train_step` artifact.
//!
//! Per step and rank: contiguous data slice → fused fwd+bwd (HLO) →
//! sharded optimizer (reduce-scatter grads / AdamW shard / allgather
//! params). Model broadcasting (paper §4): rank 0 initializes, everyone
//! else receives via the world group broadcast.

use super::{clip_now, init_global_params, TrainOptions, TrainReport};
use crate::comm::Mesh;
use crate::config::ModelManifest;
use crate::data::{BatchPlan, Dataset};
use crate::metrics::{Curve, Scoped, StepBreakdown};
use crate::optim::sharded::{build_segments, ShardedOptimizer};
use crate::runtime::{Engine, Tensor};
use crate::Result;
use anyhow::anyhow;
use std::sync::Arc;

pub fn run(
    mm: &ModelManifest,
    ds: Arc<Dataset>,
    engine: Engine,
    mesh: Arc<Mesh>,
    opts: &TrainOptions,
) -> Result<TrainReport> {
    let dp = opts.topo.dp;
    let plan = BatchPlan { dp, micro_batch: mm.hyper.batch, micro_batches: 1 };
    let art = mm.artifact_path("train_step")?;

    let handles: Vec<_> = (0..dp)
        .map(|rank| {
            let mm = mm.clone();
            let ds = Arc::clone(&ds);
            let engine = engine.clone();
            let mesh = Arc::clone(&mesh);
            let opts = opts.clone();
            let art = art.clone();
            std::thread::Builder::new()
                .name(format!("dp-rank-{rank}"))
                .spawn(move || {
                    let m2 = Arc::clone(&mesh);
                    let r = rank_main(rank, &mm, ds, engine, mesh, &opts, art, plan);
                    if r.is_err() {
                        // dead node: unblock peers (paper §4 hard failure)
                        m2.poison_all();
                    }
                    r
                })
                .expect("spawn rank")
        })
        .collect();

    let mut report = None;
    let mut first_err: Option<anyhow::Error> = None;
    let mut panic_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(Some(r))) => report = Some(r),
            Ok(Ok(None)) => {}
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            // panics are usually peers aborted by group poisoning —
            // prefer the root-cause error returned by the failed rank
            Err(_) => panic_err = panic_err.or(Some(anyhow!("rank thread panicked"))),
        }
    }
    if let Some(e) = first_err.or(panic_err) {
        return Err(e);
    }
    report.ok_or_else(|| anyhow!("rank 0 produced no report"))
}

#[allow(clippy::too_many_arguments)]
fn rank_main(
    rank: usize,
    mm: &ModelManifest,
    ds: Arc<Dataset>,
    engine: Engine,
    mesh: Arc<Mesh>,
    opts: &TrainOptions,
    art: std::path::PathBuf,
    plan: BatchPlan,
) -> Result<Option<TrainReport>> {
    let world = mesh.world_group();
    // --- model broadcasting (paper §4): only rank 0 materializes init ---
    let mut params = if rank == 0 {
        let p = init_global_params(mm, opts.run.seed);
        world.broadcast(rank, 0, p.clone());
        p
    } else {
        world.broadcast(rank, 0, Vec::new())
    };

    let (dp_group, dp_rank) = mesh.dp_group(rank);
    let (xg, xr) = mesh.dpep_group(rank);
    let segs = build_segments(
        opts.mode,
        mm.param_count, // whole model is "non-expert" wrt EP=1
        0,
        dp_group,
        dp_rank,
        xg,
        xr,
        1,
    );
    let mut opt = ShardedOptimizer::new(
        segs,
        Arc::clone(xg),
        xr,
        opts.adam(),
        opts.reduce_dtype(),
        opts.run.grad_clip,
    );

    let (b, s) = (mm.hyper.batch, mm.hyper.seq);
    let mut loss_curve = Curve::new("loss");
    let mut gn_curve = Curve::new("grad_norm");
    let mut breakdown = StepBreakdown::default();
    let mut step_secs = Vec::with_capacity(opts.run.steps);

    for step in 0..opts.run.steps {
        let t_step = std::time::Instant::now();
        let tokens = {
            let _t = Scoped::new(&mut breakdown.data_secs);
            ds.batch_i32(plan.start(step, rank, 0), b, s)
        };
        let outs = {
            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
            engine.exec(
                &format!("{}:train_step", mm.name),
                art.clone(),
                vec![
                    Tensor::f32(params.clone(), vec![mm.param_count]),
                    Tensor::i32(tokens, vec![b, s + 1]),
                ],
            )?
        };
        // curve uses the LM cross-entropy (outs[1]); outs[0] is the
        // training objective (lm + aux) used for gradients only.
        let loss = outs[1].scalar()?;
        let grads = outs[3].as_f32()?;
        // soft-failure guard (paper §4): NaN loss/grads abort the rank
        if !loss.is_finite() {
            return Err(anyhow!("rank {rank}: non-finite loss at step {step}"));
        }
        let lr = opts.run.lr_at(step) as f32;
        let gn = {
            let _t = Scoped::new(&mut breakdown.optimizer_secs);
            opt.step(&mut params, grads, lr, clip_now(&opts.run, step))
        };
        opts.hook.on_step(rank, step, loss, &mut params)?;

        if rank == 0 {
            // loss is rank-local; average across DP for the curve
            let mean =
                world.allreduce_mean(rank, vec![loss], crate::comm::ReduceDtype::F32)[0];
            loss_curve.push(step, mean as f64);
            gn_curve.push(step, gn);
        } else {
            world.allreduce_mean(rank, vec![loss], crate::comm::ReduceDtype::F32);
        }
        step_secs.push(t_step.elapsed().as_secs_f64());
    }

    if rank != 0 {
        return Ok(None);
    }
    breakdown.comm_secs = opt.comm_secs;
    breakdown.optimizer_secs = opt.update_secs;
    Ok(Some(TrainReport {
        loss: loss_curve,
        grad_norm: gn_curve,
        breakdown,
        step_secs,
        tokens_per_step: plan.instances_per_step() * s,
        final_params: params,
        opt_state_bytes: opt.state_bytes(),
        optimizer_update_secs: opt.update_secs,
        optimizer_comm_secs: opt.comm_secs,
    }))
}
