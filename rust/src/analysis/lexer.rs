//! Comment/string-aware tokenizer for the invariant lint.
//!
//! `optimus lint` used to scan sanitized *lines*; the flow-aware passes
//! (collective-divergence, collective-order, lock-order, poison-path)
//! need real structure: which tokens sit inside which braces, which
//! condition guards which call. This module produces that view with no
//! dependencies: a token stream (idents, punctuation, string contents,
//! literals — comments and whitespace removed but line-attributed), a
//! side-channel of comments (doc tags and `// lint:` annotations live
//! there), and a brace tree ([`Block`]) the passes recurse over.
//!
//! The lexer is Rust-shaped, not a Rust parser: it understands `//` and
//! nesting `/* */` comments, `"…"` strings with escapes, `r#"…"#` raw
//! strings (any hash count, `b`/`br` prefixes), char literals vs
//! lifetimes, and numbers — exactly enough that braces, brackets and
//! identifiers in the token stream are the real program structure.

/// Token kinds. `text` holds the identifier, the string *content*
/// (escapes kept verbatim), or the single punctuation character.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// identifier or keyword
    Ident,
    /// one punctuation character (multi-char operators arrive as runs)
    Punct,
    /// string literal — `text` is the content between the quotes
    Str,
    /// char literal (content irrelevant to every pass)
    Char,
    /// numeric literal
    Num,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Kind::Ident && self.text == s
    }
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Kind::Punct && self.text.len() == 1 && self.text.as_bytes()[0] == c as u8
    }
}

/// One `//` comment: 1-based line + the text after the slashes
/// (doc-comment text therefore starts with `/` or `!`).
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// A parsed `// lint: <rule> <reason>` suppression annotation. The
/// reason is mandatory — an annotation with an empty reason suppresses
/// nothing, so the underlying finding still fires.
#[derive(Clone, Debug)]
pub struct Annotation {
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

/// Lexer output: the token stream plus the comment side-channel.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
    pub annos: Vec<Annotation>,
}

/// Tokenize `text`. Never fails: unterminated constructs run to EOF.
pub fn lex(text: &str) -> Lexed {
    let cs: Vec<char> = text.chars().collect();
    let mut out = Lexed::default();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (incl. /// and //! docs): capture to the side
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut j = start;
            while j < cs.len() && cs[j] != '\n' {
                j += 1;
            }
            let body: String = cs[start..j].iter().collect();
            // a doc comment's text begins with '/' or '!', so quoting the
            // annotation grammar in docs can never register as one
            let t = body.trim();
            if let Some(rest) = t.strip_prefix("lint:") {
                let rest = rest.trim_start();
                let (rule, reason) = match rest.find(char::is_whitespace) {
                    Some(sp) => (&rest[..sp], rest[sp..].trim()),
                    None => (rest, ""),
                };
                if !rule.is_empty() {
                    out.annos.push(Annotation {
                        line,
                        rule: rule.to_string(),
                        reason: reason.to_string(),
                    });
                }
            }
            out.comments.push(Comment { line, text: body });
            i = j;
            continue;
        }
        // nesting block comment
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            i += 2;
            while i < cs.len() && depth > 0 {
                if cs[i] == '/' && cs.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && cs.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte-raw string: r"…", r#"…"#, br#"…"# …
        if (c == 'r' || c == 'b') && !prev_is_ident(&cs, i) {
            let mut j = i;
            if c == 'b' && cs.get(j + 1) == Some(&'r') {
                j += 1;
            }
            if cs[j] == 'r' || j > i {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while cs.get(k) == Some(&'#') {
                    hashes += 1;
                    k += 1;
                }
                if cs.get(k) == Some(&'"') {
                    k += 1;
                    let content = k;
                    while k < cs.len() {
                        if cs[k] == '"' && (0..hashes).all(|h| cs.get(k + 1 + h) == Some(&'#')) {
                            break;
                        }
                        k += 1;
                    }
                    let body: String = cs[content..k.min(cs.len())].iter().collect();
                    out.toks.push(Tok { kind: Kind::Str, text: body.clone(), line });
                    line += body.matches('\n').count();
                    i = (k + 1 + hashes).min(cs.len());
                    continue;
                }
            }
        }
        // plain (or byte) string with escapes; content kept verbatim
        if c == '"' || (c == 'b' && cs.get(i + 1) == Some(&'"') && !prev_is_ident(&cs, i)) {
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            let start_line = line;
            let mut body = String::new();
            while j < cs.len() && cs[j] != '"' {
                if cs[j] == '\\' {
                    body.push(cs[j]);
                    if let Some(&n) = cs.get(j + 1) {
                        body.push(n);
                        if n == '\n' {
                            line += 1;
                        }
                    }
                    j += 2;
                    continue;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                body.push(cs[j]);
                j += 1;
            }
            out.toks.push(Tok { kind: Kind::Str, text: body, line: start_line });
            i = j + 1;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if cs.get(i + 1) == Some(&'\\') {
                // escaped char: '\n', '\'', '\u{1F600}'
                let mut j = i + 2;
                if cs.get(j) == Some(&'u') {
                    while j < cs.len() && cs[j] != '\'' {
                        j += 1;
                    }
                } else {
                    j += 1;
                }
                out.toks.push(Tok { kind: Kind::Char, text: String::new(), line });
                i = (j + 1).min(cs.len());
                continue;
            }
            if cs.get(i + 2) == Some(&'\'') {
                // plain char — may hold '{' or '"'
                out.toks.push(Tok { kind: Kind::Char, text: String::new(), line });
                i += 3;
                continue;
            }
            // lifetime: consume the tick + ident so 'a never opens a char
            let mut j = i + 1;
            while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            out.toks.push(Tok { kind: Kind::Punct, text: "'".into(), line });
            i = j.max(i + 1);
            continue;
        }
        // identifier / keyword
        if c.is_alphabetic() || c == '_' {
            let mut j = i + 1;
            while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            out.toks.push(Tok { kind: Kind::Ident, text: cs[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        // number (floats: a '.' only binds when a digit follows, so
        // `1..n` stays two range dots)
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < cs.len() {
                let d = cs[j];
                if d.is_alphanumeric() || d == '_' {
                    j += 1;
                } else if d == '.' && cs.get(j + 1).is_some_and(char::is_ascii_digit) {
                    j += 2;
                } else {
                    break;
                }
            }
            out.toks.push(Tok { kind: Kind::Num, text: String::new(), line });
            i = j;
            continue;
        }
        out.toks.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

fn prev_is_ident(cs: &[char], i: usize) -> bool {
    i > 0 && (cs[i - 1].is_alphanumeric() || cs[i - 1] == '_' || cs[i - 1] == '"')
}

// ---------------------------------------------------------------------
// brace tree
// ---------------------------------------------------------------------

/// A node of the brace tree: either a token (by index into the lexed
/// stream) or a nested `{ … }` block.
#[derive(Debug)]
pub enum Node {
    Tok(usize),
    Block(Block),
}

/// One `{ … }` span. The synthetic root block covers the whole file.
#[derive(Debug)]
pub struct Block {
    /// line of the opening brace (the file's first line for the root)
    pub open_line: usize,
    /// line of the closing brace (the file's last line for the root)
    pub close_line: usize,
    pub nodes: Vec<Node>,
}

/// Build the brace tree over a token stream. Tolerant of imbalance:
/// a stray `}` is dropped, an unclosed `{` closes at EOF.
pub fn tree(toks: &[Tok]) -> Block {
    let mut stack: Vec<Block> = vec![Block {
        open_line: 1,
        close_line: toks.last().map_or(1, |t| t.line),
        nodes: Vec::new(),
    }];
    for (i, t) in toks.iter().enumerate() {
        if t.is_punct('{') {
            stack.push(Block { open_line: t.line, close_line: t.line, nodes: Vec::new() });
        } else if t.is_punct('}') {
            if stack.len() > 1 {
                let mut b = stack.pop().expect("brace stack");
                b.close_line = t.line;
                stack.last_mut().expect("root block").nodes.push(Node::Block(b));
            }
        } else {
            stack.last_mut().expect("block stack").nodes.push(Node::Tok(i));
        }
    }
    while stack.len() > 1 {
        let mut b = stack.pop().expect("brace stack");
        b.close_line = toks.last().map_or(b.open_line, |t| t.line);
        stack.last_mut().expect("root block").nodes.push(Node::Block(b));
    }
    stack.pop().expect("root block")
}

/// Per-token `is this test code?` marks. A whole-file flag covers
/// `tests/` and `benches/`; otherwise every `#[cfg(test)]`-attributed
/// item (its braces found by counting on the token stream, so braces in
/// strings can't skew the depth) is marked, plus the attribute itself.
pub fn test_marks(toks: &[Tok], whole_file_is_test: bool) -> Vec<bool> {
    let mut marks = vec![whole_file_is_test; toks.len()];
    if whole_file_is_test {
        return marks;
    }
    let mut i = 0usize;
    while i < toks.len() {
        if is_cfg_test_attr(toks, i) {
            // mark from the attribute through the item's brace span (or
            // to the `;` of a braceless gated item, e.g. a `use`)
            let mut j = i;
            let mut depth = 0usize;
            while j < toks.len() {
                marks[j] = true;
                if toks[j].is_punct('{') {
                    depth += 1;
                } else if toks[j].is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if depth == 0 && toks[j].is_punct(';') {
                    break;
                }
                j += 1;
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    marks
}

/// `# [ cfg ( … test … ) ]` starting at token `i`?
fn is_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    if !(toks[i].is_punct('#')
        && toks.get(i + 1).is_some_and(|t| t.is_punct('['))
        && toks.get(i + 2).is_some_and(|t| t.is_ident("cfg"))
        && toks.get(i + 3).is_some_and(|t| t.is_punct('(')))
    {
        return false;
    }
    let mut depth = 1usize;
    let mut j = i + 4;
    while j < toks.len() && depth > 0 {
        if toks[j].is_punct('(') {
            depth += 1;
        } else if toks[j].is_punct(')') {
            depth -= 1;
        } else if toks[j].is_ident("test") {
            return true;
        }
        j += 1;
    }
    false
}

/// Index of the `)` matching the `(` at `open` (which must be a `(`),
/// or `toks.len()` when unterminated.
pub fn match_paren(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_strings_and_chars_never_reach_the_token_stream() {
        let lx = lex("let a = 1; // x.lock().unwrap()\n/* {{{ */ let s = \"{ } [x]\";\nlet c = '{';\n");
        assert!(!lx.toks.iter().any(|t| t.is_ident("unwrap")));
        // the brace inside the string/char is content, not structure
        assert!(!lx.toks.iter().any(|t| t.is_punct('{')));
        let s = lx.toks.iter().find(|t| t.kind == Kind::Str).expect("string token");
        assert_eq!(s.text, "{ } [x]");
        assert_eq!(s.line, 2);
        assert_eq!(lx.comments.len(), 1);
    }

    #[test]
    fn raw_strings_and_lifetimes_lex_cleanly() {
        let lx = lex("let j = r#\"{\"a\": 1}\"#;\nfn f<'a>(x: &'a str) {}\nlet b = br\"[y]\";\n");
        let raws: Vec<&Tok> = lx.toks.iter().filter(|t| t.kind == Kind::Str).collect();
        assert_eq!(raws.len(), 2);
        assert_eq!(raws[0].text, "{\"a\": 1}");
        assert_eq!(raws[1].text, "[y]");
        // exactly the fn body's braces survive as structure
        assert_eq!(lx.toks.iter().filter(|t| t.is_punct('{')).count(), 1);
        assert!(lx.toks.iter().any(|t| t.is_ident("str")));
    }

    #[test]
    fn multiline_strings_keep_line_attribution() {
        let lx = lex("let s = \"one\ntwo\";\nlet t = 3;\n");
        let t3 = lx.toks.iter().find(|t| t.kind == Kind::Num).expect("number");
        assert_eq!(t3.line, 3);
    }

    #[test]
    fn annotations_parse_rule_and_reason() {
        let lx = lex(
            "// lint: rank-uniform every leader reaches this leg\n\
             // lint: poison-path\n\
             /// `// lint: rank-uniform <why>` (doc quote, not an annotation)\n",
        );
        assert_eq!(lx.annos.len(), 2);
        assert_eq!(lx.annos[0].rule, "rank-uniform");
        assert_eq!(lx.annos[0].reason, "every leader reaches this leg");
        assert_eq!(lx.annos[1].rule, "poison-path");
        assert_eq!(lx.annos[1].reason, "", "reason-less annotation carries no reason");
    }

    #[test]
    fn tree_nests_blocks_and_keeps_token_order() {
        let lx = lex("fn a() { if x { y(); } z(); }\n");
        let root = tree(&lx.toks);
        // root: fn a ( ) <block>
        let Node::Block(body) = root.nodes.last().expect("fn body") else {
            panic!("expected fn body block")
        };
        let inner_blocks =
            body.nodes.iter().filter(|n| matches!(n, Node::Block(_))).count();
        assert_eq!(inner_blocks, 1, "one nested if-arm block");
    }

    #[test]
    fn cfg_test_regions_mark_their_braces() {
        let lx = lex(
            "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() { let s = \"}\"; }\n}\nfn c() {}\n",
        );
        let marks = test_marks(&lx.toks, false);
        let b_ix = lx.toks.iter().position(|t| t.is_ident("b")).expect("fn b");
        let c_ix = lx.toks.iter().position(|t| t.is_ident("c")).expect("fn c");
        let a_ix = lx.toks.iter().position(|t| t.is_ident("a")).expect("fn a");
        assert!(marks[b_ix]);
        assert!(!marks[c_ix]);
        assert!(!marks[a_ix]);
    }
}
