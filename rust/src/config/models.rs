//! Paper Table 1 model configurations, kept in Rust for the analytic
//! cluster model (no artifacts are lowered for these). Mirrors
//! `python/compile/configs.py::PAPER`.

/// FLOP/byte-level description of a Mula model for the cluster model.
#[derive(Clone, Copy, Debug)]
pub struct MulaSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub hidden: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub intermediate: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub vocab_size: usize,
    pub context: usize,
}

pub const MULA_1B: MulaSpec = MulaSpec {
    name: "mula-1b", n_layers: 16, hidden: 2048, n_heads: 16, head_dim: 128,
    intermediate: 8192, n_experts: 0, top_k: 0, vocab_size: 50304, context: 2048,
};
pub const MULA_7B: MulaSpec = MulaSpec {
    name: "mula-7b-a1b", n_layers: 16, hidden: 2048, n_heads: 16, head_dim: 128,
    intermediate: 1024, n_experts: 64, top_k: 8, vocab_size: 50304, context: 2048,
};
pub const MULA_20B: MulaSpec = MulaSpec {
    name: "mula-20b-a2b", n_layers: 32, hidden: 2048, n_heads: 16, head_dim: 128,
    intermediate: 1024, n_experts: 96, top_k: 8, vocab_size: 50304, context: 2048,
};
pub const MULA_100B: MulaSpec = MulaSpec {
    name: "mula-100b-a7b", n_layers: 48, hidden: 3072, n_heads: 24, head_dim: 128,
    intermediate: 1536, n_experts: 144, top_k: 8, vocab_size: 50304, context: 2048,
};
pub const MULA_220B: MulaSpec = MulaSpec {
    name: "mula-220b-a10b", n_layers: 64, hidden: 3072, n_heads: 24, head_dim: 128,
    intermediate: 1536, n_experts: 240, top_k: 8, vocab_size: 50304, context: 2048,
};

pub const PAPER_MODELS: [MulaSpec; 5] =
    [MULA_1B, MULA_7B, MULA_20B, MULA_100B, MULA_220B];

impl MulaSpec {
    pub fn by_name(name: &str) -> Option<&'static MulaSpec> {
        PAPER_MODELS.iter().find(|m| m.name == name)
    }

    pub fn is_moe(&self) -> bool {
        self.n_experts > 0
    }

    /// Total parameters (same layout as python configs.param_count).
    pub fn param_count(&self) -> usize {
        let h = self.hidden;
        let v = self.vocab_size;
        let emb = v * h;
        let attn = 4 * h * h;
        let norms = 2 * h;
        let mlp = if self.is_moe() {
            self.n_experts * 3 * h * self.intermediate + self.n_experts * h
        } else {
            3 * h * self.intermediate
        };
        emb + self.n_layers * (attn + norms + mlp) + h + v * h
    }

    /// Parameters touched per token.
    pub fn active_param_count(&self) -> usize {
        if !self.is_moe() {
            return self.param_count();
        }
        let inactive =
            (self.n_experts - self.top_k) * 3 * self.hidden * self.intermediate;
        self.param_count() - self.n_layers * inactive
    }

    /// Training FLOPs per token (fwd+bwd ≈ 6 × active params, plus
    /// attention quadratic term).
    pub fn train_flops_per_token(&self) -> f64 {
        let act = self.active_param_count() as f64;
        let attn_quad = (self.n_layers * self.context * self.hidden * 2) as f64;
        6.0 * act + 3.0 * 2.0 * attn_quad
    }

    /// Expert parameter fraction — drives EPSO's speedup (paper §3.2).
    pub fn expert_param_fraction(&self) -> f64 {
        if !self.is_moe() {
            return 0.0;
        }
        let e = self.n_layers * self.n_experts * 3 * self.hidden * self.intermediate;
        e as f64 / self.param_count() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_counts() {
        // Table 1 total / active parameters (within ~12%/15% — embedding
        // conventions differ slightly from the paper's exact tokenizer)
        let cases: [(&MulaSpec, f64, f64); 5] = [
            (&MULA_1B, 1.3e9, 1.3e9),
            (&MULA_7B, 6.9e9, 1.3e9),
            (&MULA_20B, 20e9, 2.4e9),
            (&MULA_100B, 100e9, 7.6e9),
            (&MULA_220B, 220e9, 10e9),
        ];
        for (m, tot, act) in cases {
            let t = m.param_count() as f64;
            let a = m.active_param_count() as f64;
            assert!((t - tot).abs() / tot < 0.12, "{}: total {t:.3e}", m.name);
            assert!((a - act).abs() / act < 0.15, "{}: active {a:.3e}", m.name);
        }
    }

    #[test]
    fn expert_fraction_grows_with_model() {
        assert!(MULA_220B.expert_param_fraction() > MULA_7B.expert_param_fraction() * 0.9);
        assert!(MULA_7B.expert_param_fraction() > 0.8);
        assert_eq!(MULA_1B.expert_param_fraction(), 0.0);
    }
}
