//! The Optimus trainer: multi-rank DP / EP / PP / PP×EP training
//! orchestration.
//!
//! One OS thread per rank; real HLO execution per rank through the PJRT
//! [`crate::runtime::Engine`]; real collectives through [`crate::comm`].
//! The public entry point is a [`JobSpec`] (builder-constructed) whose
//! [`ParallelismPlan`] is the single validated source of placement truth;
//! [`train`] materializes the plan — one table-driven preflight, before
//! any rank thread spawns — and dispatches on [`plan::EngineKind`].
//! All topologies run on the shared rank-execution [`harness`], which owns
//! spawning, failure poisoning, model broadcasting, the per-step driver
//! loop and report assembly; a parallelism engine is one
//! [`harness::RankTrainer`] impl holding only its distinct logic.
//! Four runnable engines (matching the paper's experiments, §2):
//!
//! * **DP (fused)** — every rank runs the fused `train_step` artifact;
//!   gradient sync + sharded AdamW via [`crate::optim::ShardedOptimizer`].
//! * **EP** — per-layer execution with Stage-1 token exchange in Rust
//!   (allgather or all2all), FastSparseMoE expert artifacts per rank, and
//!   SO/EPSO sharding (§3.2).
//! * **PP** — GPipe / 1F1B microbatch schedules over stage artifacts with
//!   activations over point-to-point channels; backward recomputes from
//!   stashed stage inputs (selective activation checkpointing, §1).
//! * **PP×EP** — pipeline stages running the EP exchange loop over each
//!   stage's dp×ep mesh slice on the per-layer EP artifacts; the
//!   composition the paper's 12,288-tile runs rely on.

pub mod ep;
pub mod harness;
pub mod pipeline;
pub mod plan;

mod ep_layout;
mod jobspec;
mod train_dp;
mod train_ep;
mod train_pp;
mod train_pp_ep;

pub use ep_layout::EpLayout;
// the serving engine's expert-parallel decoder reuses the trainer's
// artifact table and per-step parameter slicing verbatim
pub(crate) use train_ep::{Arts as EpArts, ParamSlices as EpParamSlices};
#[allow(deprecated)]
pub use jobspec::TrainOptions;
pub use jobspec::{DataTrace, JobSpec, JobSpecBuilder};
pub use plan::{DEFAULT_OVERLAP_CHUNK, EngineKind, ParallelismPlan, StagePlan};

use crate::comm::Mesh;
use crate::config::{Manifest, ModelManifest, RunConfig};
use crate::data::Dataset;
use crate::metrics::{Curve, Histogram, StepBreakdown};
use crate::runtime::{Engine, Tensor};
use crate::util::prng::Prng;
use crate::Result;
use std::sync::Arc;

/// Per-step callback for checkpointing / fault injection / NaN handling.
/// Returning `Err` aborts the rank (simulating a failure the launcher
/// must handle).
pub trait StepHook: Send + Sync {
    fn on_step(
        &self,
        rank: usize,
        step: usize,
        loss: f32,
        params: &mut [f32],
    ) -> Result<()> {
        let _ = (rank, step, loss, params);
        Ok(())
    }
}

/// No-op hook.
pub struct NoHook;
impl StepHook for NoHook {}

/// Result of a training run (aggregated over ranks).
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub loss: Curve,
    pub grad_norm: Curve,
    pub breakdown: StepBreakdown,
    /// per-pop distribution of the prefetch-queue stall whose *sum* is
    /// `breakdown.data_wait_secs`: merged across every rank (one Sum
    /// allreduce of the bucket counts), so p99 tail stalls are visible
    /// even when the additive total looks healthy. Empty when prefetch
    /// is off.
    pub data_wait_hist: Histogram,
    pub step_secs: Vec<f64>,
    pub tokens_per_step: usize,
    /// total instances consumed through the end of the step budget,
    /// including consumption before a resume (the token cursor's final
    /// position)
    pub instances_consumed: u64,
    /// `instances_consumed` in dataset passes (the epoch count the
    /// shuffle reshuffles on)
    pub epochs_consumed: f64,
    /// final full parameter vector (rank 0's view) for eval/checkpoints —
    /// `Arc`-backed, so passing it on to [`crate::eval::run_suite`] or a
    /// checkpoint writer involves no copy
    pub final_params: Tensor,
    /// optimizer state bytes per rank (Figure 6 quantity)
    pub opt_state_bytes: usize,
    pub optimizer_update_secs: f64,
    /// exposed optimizer comm (rank thread blocked in collectives)
    pub optimizer_comm_secs: f64,
    /// optimizer comm hidden behind compute by the `--overlap` pipeline
    /// (0.0 on serial runs) — the Table-3 "saved communication" quantity
    pub optimizer_overlap_secs: f64,
    /// collectives completed on the optimizer's comm lane (0 when serial)
    pub optimizer_lane_ops: u64,
    /// checkpoints committed by this run's [`crate::ckpt::Checkpointer`]
    /// (0 when the policy is off) — the falsifiable signal that async
    /// snapshots actually landed, used by the kill-and-resume tests
    pub ckpt_commits: u64,
    /// bytes deposited into collectives across the whole mesh at actual
    /// wire width (bf16 payloads count 2 B/elem) — the perf gate's
    /// bytes-moved column
    pub comm_bytes_in: u64,
    /// bytes picked up from collective results across the whole mesh,
    /// also at wire width
    pub comm_bytes_out: u64,
    /// in+out bytes that stayed inside a node — moved on groups whose
    /// members share one node under [`crate::comm::Topology::node_size`]
    /// (the Xe-Link legs of the hierarchy); 0 on flat meshes
    pub comm_intra_bytes: u64,
    /// in+out bytes that crossed nodes — flat groups spanning nodes and
    /// the hierarchy's leaders legs; the quantity `--node-size` shrinks
    pub comm_inter_bytes: u64,
    /// shard-payload bytes written by the checkpointer (manifests
    /// excluded); halves per param shard under `--dtype bf16`
    pub ckpt_bytes: u64,
}

impl TrainReport {
    pub fn tokens_per_sec(&self) -> f64 {
        let total: f64 = self.step_secs.iter().sum();
        if total == 0.0 {
            return 0.0;
        }
        (self.tokens_per_step * self.step_secs.len()) as f64 / total
    }

    pub fn mean_step_secs(&self) -> f64 {
        if self.step_secs.is_empty() {
            return 0.0;
        }
        // skip the first (compile) step
        let s: Vec<f64> = self.step_secs.iter().skip(1).copied().collect();
        if s.is_empty() {
            return self.step_secs[0];
        }
        s.iter().sum::<f64>() / s.len() as f64
    }
}

/// Deterministic parameter init (distribution-parity with python's
/// `model.init_params`): N(0, 0.02) everywhere, 1.0 for norm gains.
pub fn init_global_params(mm: &ModelManifest, seed: u64) -> Vec<f32> {
    let mut flat = vec![0.0f32; mm.param_count];
    let mut rng = Prng::new(seed).fork(17);
    for spec in &mm.params {
        let seg = &mut flat[spec.offset..spec.offset + spec.numel];
        if spec.name.contains("norm") {
            seg.fill(1.0);
        } else {
            for v in seg.iter_mut() {
                *v = rng.normal_f32() * 0.02;
            }
        }
    }
    flat
}

/// Entry point: materialize the [`ParallelismPlan`] — the single
/// table-driven preflight; every invalid configuration fails here, before
/// any engine executor or rank thread exists — then dispatch on
/// [`EngineKind`]. Every topology runs through the shared [`harness`];
/// the dispatch only picks which [`harness::RankTrainer`] impl drives the
/// ranks.
pub fn train(manifest: &Manifest, spec: &JobSpec) -> Result<TrainReport> {
    let mm = manifest.config(&spec.model)?;
    let ds = Arc::new(Dataset::open(&spec.data_dir)?);
    let plan = Arc::new(spec.plan.clone().materialized(mm, &ds)?);
    let engine = Engine::new_pool(spec.engine_pool)?;
    let mesh = Mesh::new(plan.topo);
    match plan.kind() {
        EngineKind::Dp => harness::run::<train_dp::DpTrainer>(mm, ds, engine, mesh, spec, &plan),
        EngineKind::Ep => harness::run::<train_ep::EpTrainer>(mm, ds, engine, mesh, spec, &plan),
        EngineKind::Pp => harness::run::<train_pp::PpTrainer>(mm, ds, engine, mesh, spec, &plan),
        EngineKind::PpEp => {
            harness::run::<train_pp_ep::PpEpTrainer>(mm, ds, engine, mesh, spec, &plan)
        }
    }
}

/// Deprecated entry point for the old flat options bag.
#[deprecated(since = "0.2.0", note = "build a `JobSpec` and call `train`")]
#[allow(deprecated)]
pub fn train_with_options(manifest: &Manifest, opts: &TrainOptions) -> Result<TrainReport> {
    train(manifest, &JobSpec::from(opts.clone()))
}

/// Should this step clip (paper: clipping only after warmup)?
pub(crate) fn clip_now(run: &RunConfig, step: usize) -> bool {
    !run.clip_after_warmup_only || step >= run.warmup_steps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic_and_scaled() {
        let Some(m) = crate::manifest_or_skip("coordinator::init_is_deterministic_and_scaled")
        else {
            return;
        };
        let mm = m.config("mula-tiny").unwrap();
        let a = init_global_params(mm, 5);
        let b = init_global_params(mm, 5);
        assert_eq!(a, b);
        let c = init_global_params(mm, 6);
        assert_ne!(a, c);
        // norms are ones
        let norm_spec = mm.params.iter().find(|p| p.name.contains("norm1")).unwrap();
        assert!(a[norm_spec.offset..norm_spec.offset + norm_spec.numel]
            .iter()
            .all(|&v| v == 1.0));
        // weights roughly N(0, 0.02)
        let emb = &a[0..mm.params[0].numel];
        let mean: f32 = emb.iter().sum::<f32>() / emb.len() as f32;
        let var: f32 =
            emb.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / emb.len() as f32;
        assert!(mean.abs() < 2e-3, "{mean}");
        assert!((var.sqrt() - 0.02).abs() < 2e-3, "{}", var.sqrt());
    }
}
