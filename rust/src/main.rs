//! `optimus` — CLI for the Optimus-RS training stack.
//!
//! Subcommands:
//!   models                      list model configs (paper Table 1 + analogs)
//!   preprocess --out DIR        run tokenize->shuffle->shard on the corpus
//!   train --model M [--dp N --ep N --pp N --steps N --mode so|epso --fur]
//!   eval --model M              run the synthetic benchmark suite
//!   scaling [--fur]             Aurora-model Fig 4b sweep

use optimus::cluster::{scaling_efficiency, Aurora};
use optimus::comm::Topology;
use optimus::config::models::{MulaSpec, MULA_220B, PAPER_MODELS};
use optimus::config::Manifest;
use optimus::coordinator::{self, TrainOptions};
use optimus::data::{corpus, preprocess};
use optimus::eval;
use optimus::optim::ShardingMode;
use optimus::runtime::Engine;
use optimus::util::cli::Args;

fn main() -> optimus::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("models") => models(),
        Some("preprocess") => do_preprocess(&args),
        Some("train") => do_train(&args),
        Some("eval") => do_eval(&args),
        Some("scaling") => do_scaling(&args),
        _ => {
            eprintln!(
                "usage: optimus <models|preprocess|train|eval|scaling> [flags]\n\
                 see rust/src/main.rs header for flags"
            );
            Ok(())
        }
    }
}

fn models() -> optimus::Result<()> {
    println!("paper configs (Table 1, projection-only):");
    for m in PAPER_MODELS {
        println!(
            "  {:<16} layers {:<3} hidden {:<5} experts {:<4} top-{} — {:.1}B total / {:.1}B active",
            m.name, m.n_layers, m.hidden, m.n_experts, m.top_k,
            m.param_count() as f64 / 1e9,
            m.active_param_count() as f64 / 1e9
        );
    }
    let man = Manifest::load(&optimus::artifacts_dir())?;
    println!("\nrunnable analogs (artifacts built):");
    for (name, mm) in &man.configs {
        println!(
            "  {:<16} {:>8.2}M params, {} artifacts, pp={:?} ep={:?}",
            name,
            mm.param_count as f64 / 1e6,
            mm.artifacts.len(),
            mm.pp_degrees,
            mm.ep_degrees
        );
    }
    Ok(())
}

fn default_data(args: &Args, context: usize) -> optimus::Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        args.str_or("data", &format!("{}/optimus-cli-data-{context}",
            std::env::temp_dir().display())));
    if !dir.exists() {
        let st = preprocess::preprocess(
            &corpus::data_files(42, 8, 64), context, 7, &dir, 2048)?;
        println!("preprocessed {} instances into {} shards", st.n_instances, st.n_shards);
    }
    Ok(dir)
}

fn do_preprocess(args: &Args) -> optimus::Result<()> {
    let out = std::path::PathBuf::from(args.str_or("out", "data/shards"));
    let files = corpus::data_files(
        args.usize_or("seed", 42) as u64,
        args.usize_or("files", 8),
        args.usize_or("docs", 64),
    );
    let st = preprocess::preprocess(
        &files,
        args.usize_or("context", 192),
        args.usize_or("shuffle-seed", 7) as u64,
        &out,
        args.usize_or("per-shard", 2048),
    )?;
    println!("{st:?}");
    Ok(())
}

fn do_train(args: &Args) -> optimus::Result<()> {
    let model = args.str_or("model", "mula-tiny");
    let man = Manifest::load(&optimus::artifacts_dir())?;
    let mm = man.config(&model)?;
    let data = default_data(args, mm.hyper.seq + 1)?;
    let topo = Topology {
        dp: args.usize_or("dp", 2),
        ep: args.usize_or("ep", 1),
        pp: args.usize_or("pp", 1),
    };
    let mut o = TrainOptions::new(&model, topo, data);
    o.run.steps = args.usize_or("steps", 50);
    o.run.warmup_steps = args.usize_or("warmup", o.run.steps / 10);
    o.run.peak_lr = args.f64_or("lr", 2e-3);
    o.run.min_lr = o.run.peak_lr / 10.0;
    o.mode = if args.str_or("mode", "epso") == "so" {
        ShardingMode::So
    } else {
        ShardingMode::Epso
    };
    o.fur = args.bool_or("fur", false);
    o.micro_batches = args.usize_or("micro", 2);
    o.engine_pool = args.usize_or("pool", 2);
    let r = coordinator::train(&man, &o)?;
    for (s, l) in &r.loss.points {
        if s % args.usize_or("log-every", 5) == 0 {
            println!("step {s:>5}  loss {l:.4}");
        }
    }
    println!(
        "done: {:.0} tok/s, optimizer state {}B/rank, final loss {:.4}",
        r.tokens_per_sec(),
        r.opt_state_bytes,
        r.loss.last().unwrap_or(f64::NAN)
    );
    Ok(())
}

fn do_eval(args: &Args) -> optimus::Result<()> {
    let model = args.str_or("model", "mula-tiny");
    let man = Manifest::load(&optimus::artifacts_dir())?;
    let mm = man.config(&model)?;
    let engine = Engine::new_pool(2)?;
    let params = optimus::runtime::Tensor::f32(
        coordinator::init_global_params(mm, args.usize_or("seed", 0) as u64),
        vec![mm.param_count],
    );
    let scores = eval::run_suite(&engine, mm, &params, args.usize_or("cases", 16))?;
    for (t, s) in &scores {
        println!("{t:<14} {s:6.1}");
    }
    println!("{:<14} {:6.1}", "average", eval::average(&scores));
    Ok(())
}

fn do_scaling(args: &Args) -> optimus::Result<()> {
    let hw = Aurora::default();
    let fur = args.bool_or("fur", false);
    let model = args.str_or("model", "mula-220b-a10b");
    let spec: &MulaSpec = MulaSpec::by_name(&model).unwrap_or(&MULA_220B);
    println!("tiles  nodes  efficiency (fur={fur})");
    for tiles in [384usize, 768, 1536, 3072, 6144, 12288] {
        println!(
            "{tiles:>6} {:>6} {:>8.3}",
            tiles / 12,
            scaling_efficiency(spec, &hw, 384, tiles, fur)
        );
    }
    Ok(())
}
