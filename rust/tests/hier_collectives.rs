//! Integration: hierarchical topology-aware collectives (`--node-size`).
//!
//! The contract under test: routing a collective through the three-phase
//! hierarchy (intra-node reduce → inter-node exchange over the leaders →
//! intra-node broadcast) is a *transport* change, never a numeric one.
//! With integer-valued inputs — exact in bf16 and order-independent under
//! summation — every world × node-size × wire-dtype cell must produce
//! bit-identical results to the flat single-level path. Plus the failure
//! semantics: a dead peer inside one node's subgroup must fail the whole
//! family via `[stall]`/`Poisoned` in bounded wall-clock, not hang the
//! other node's members forever.

use optimus::comm::{CollectiveOp, CollectiveOut, Mesh, Parts, Reduce, ReduceDtype, Topology};
use std::sync::Arc;

/// Run one collective per rank over the world group of a `world`-rank
/// dp-only mesh with the given node size; returns each rank's output.
fn run_ranks(world: usize, node_size: usize, ops: Vec<CollectiveOp>) -> Vec<CollectiveOut> {
    assert_eq!(ops.len(), world);
    let mesh = Mesh::new(Topology::dp_only(world).with_node_size(node_size));
    let handles: Vec<_> = ops
        .into_iter()
        .enumerate()
        .map(|(r, op)| {
            let mesh = Arc::clone(&mesh);
            std::thread::spawn(move || mesh.world_group().run(r, op).unwrap())
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Integer-valued per-rank input: exact under bf16 rounding (|v| < 256)
/// and order-independent under f32 summation, so flat and hierarchical
/// reduction orders cannot diverge even in the last bit.
fn rank_data(rank: usize, len: usize) -> Vec<f32> {
    (0..len).map(|i| ((rank * 7 + i) % 23) as f32).collect()
}

fn values(outs: Vec<CollectiveOut>) -> Vec<Vec<f32>> {
    outs.into_iter().map(CollectiveOut::values).collect()
}

#[test]
fn hierarchical_matches_flat_bitwise_across_the_matrix() {
    // worlds {2,4,8} × node sizes {1,2,4} (where the node size divides
    // the world) × wire dtypes {f32,bf16} × four reduce/gather shapes
    for world in [2usize, 4, 8] {
        for ns in [1usize, 2, 4] {
            if ns > world || world % ns != 0 {
                continue;
            }
            for dt in [ReduceDtype::F32, ReduceDtype::Bf16] {
                let tag = format!("world={world} ns={ns} dt={dt:?}");
                // len 18 exercises ragged shards at world 4 and 8; the
                // even reduce-scatter gets 16 (divisible by every world)
                let ops_of = |mk: &dyn Fn(&[f32]) -> CollectiveOp, len: usize| {
                    (0..world).map(|r| mk(&rank_data(r, len))).collect::<Vec<_>>()
                };
                let shapes: Vec<(&str, Box<dyn Fn(&[f32]) -> CollectiveOp>, usize)> = vec![
                    (
                        "allreduce-sum",
                        Box::new(move |d: &[f32]| CollectiveOp::Allreduce {
                            data: d.to_vec(),
                            red: Reduce::Sum,
                            dt,
                        }),
                        18,
                    ),
                    (
                        "allreduce-mean",
                        Box::new(move |d: &[f32]| CollectiveOp::Allreduce {
                            data: d.to_vec(),
                            red: Reduce::Mean,
                            dt,
                        }),
                        18,
                    ),
                    (
                        "reduce-scatter-mean-ragged",
                        Box::new(move |d: &[f32]| CollectiveOp::ReduceScatter {
                            data: d.to_vec(),
                            red: Reduce::Mean,
                            dt,
                            parts: Parts::Ragged,
                        }),
                        18,
                    ),
                    (
                        "reduce-scatter-sum-even",
                        Box::new(move |d: &[f32]| CollectiveOp::ReduceScatter {
                            data: d.to_vec(),
                            red: Reduce::Sum,
                            dt,
                            parts: Parts::Even,
                        }),
                        16,
                    ),
                    (
                        "allgather",
                        Box::new(move |d: &[f32]| CollectiveOp::Allgather {
                            data: d.to_vec(),
                            dt,
                        }),
                        18,
                    ),
                ];
                for (name, mk, len) in &shapes {
                    let flat = values(run_ranks(world, 1, ops_of(mk.as_ref(), *len)));
                    let hier = values(run_ranks(world, ns, ops_of(mk.as_ref(), *len)));
                    for (r, (f, h)) in flat.iter().zip(hier.iter()).enumerate() {
                        assert_eq!(f.len(), h.len(), "{tag} {name} rank {r}");
                        for (i, (a, b)) in f.iter().zip(h.iter()).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "{tag} {name} rank {r} elem {i}: flat {a} vs hier {b}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn hierarchical_bit_allgather_matches_flat() {
    // the raw-bits (bf16 payload) gather: concat order must equal member
    // order through the intra → leaders → broadcast relay
    for world in [4usize, 8] {
        for ns in [2usize, 4] {
            if ns > world || world % ns != 0 {
                continue;
            }
            let mk_ops = || {
                (0..world)
                    .map(|r| CollectiveOp::AllgatherBits {
                        data: (0..5u16).map(|i| (r * 100) as u16 + i).collect(),
                    })
                    .collect::<Vec<_>>()
            };
            let flat: Vec<Vec<u16>> =
                run_ranks(world, 1, mk_ops()).into_iter().map(CollectiveOut::bits).collect();
            let hier: Vec<Vec<u16>> =
                run_ranks(world, ns, mk_ops()).into_iter().map(CollectiveOut::bits).collect();
            assert_eq!(flat, hier, "world={world} ns={ns}");
        }
    }
}

#[test]
fn hierarchy_moves_traffic_off_the_inter_node_fabric() {
    // same collective, flat vs node_size=2: the hierarchical mesh must
    // report intra-node bytes (the Xe-Link legs) and strictly fewer
    // inter-node bytes than the flat world-wide rendezvous
    let run_with = |ns: usize| {
        let mesh = Mesh::new(Topology::dp_only(4).with_node_size(ns));
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let mesh = Arc::clone(&mesh);
                std::thread::spawn(move || {
                    mesh.world_group()
                        .run(
                            r,
                            CollectiveOp::Allreduce {
                                data: rank_data(r, 64),
                                red: Reduce::Sum,
                                dt: ReduceDtype::F32,
                            },
                        )
                        .unwrap()
                        .values()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        mesh.traffic()
    };
    let flat = run_with(1);
    let hier = run_with(2);
    assert_eq!(flat.intra_bytes, 0, "flat mesh has no node-local groups");
    assert!(flat.inter_bytes > 0);
    assert!(hier.intra_bytes > 0, "hierarchy must use the intra-node legs");
    assert!(
        hier.inter_bytes < flat.inter_bytes,
        "hier {} vs flat {} inter-node bytes",
        hier.inter_bytes,
        flat.inter_bytes
    );
}

#[test]
fn dead_peer_in_a_node_subgroup_fails_the_family_in_bounded_time() {
    // rank 1 (node 0, slot 1) dies before depositing: its intra subgroup
    // stalls, the fault must poison the parent and the *other* node's
    // subgroup, and every surviving member must come back — with the
    // stable `[stall]` violation or the collateral `Poisoned` — instead
    // of riding its own watchdog or hanging forever
    let mesh = Mesh::new(Topology::dp_only(4).with_node_size(2));
    let g = Arc::clone(mesh.world_group());
    g.set_stall_timeout(std::time::Duration::from_millis(150));
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = [0usize, 2, 3]
        .into_iter()
        .map(|r| {
            let g = Arc::clone(&g);
            std::thread::spawn(move || {
                g.run(
                    r,
                    CollectiveOp::Allreduce {
                        data: vec![1.0],
                        red: Reduce::Sum,
                        dt: ReduceDtype::F32,
                    },
                )
                .unwrap_err()
            })
        })
        .collect();
    let msgs: Vec<String> =
        handles.into_iter().map(|h| h.join().unwrap().to_string()).collect();
    assert!(
        t0.elapsed() < optimus::util::time_budget_secs(60),
        "survivors took {:?} to unblock",
        t0.elapsed()
    );
    assert!(
        msgs.iter().any(|m| m.contains("collective protocol violated [stall]")),
        "{msgs:?}"
    );
    for m in &msgs {
        assert!(
            m.contains("[stall]") || m.contains("comm group poisoned"),
            "unexpected fault: {m}"
        );
    }
}
