//! Sharded optimizers: SO (ZeRO-1 style) and the paper's **EPSO** (§3.2).
//!
//! The local parameter vector of a rank is split into *segments*, each
//! synchronized and sharded over a process group:
//!
//! * **SO** (baseline): every segment shards over the **DP group** only.
//!   With EP, non-expert optimizer states are therefore replicated EP
//!   times (the inefficiency Figure 6 shows).
//! * **EPSO**: expert segments shard over **DP** (their replication
//!   domain), non-expert segments shard over **DP×EP** — optimizer states
//!   are never replicated, shards shrink, the optimizer step gets faster
//!   (Table 3, 1.07-1.36×).
//!
//! Step = reduce-scatter(grads) → global-norm clip → AdamW on owned shard
//! → allgather(params), per segment. Gradient reduction optionally rounds
//! through bf16 (paper §2.1 recipe).

use super::adamw::{clip_scale, sumsq, AdamParams, AdamState};
use crate::comm::{Group, ReduceDtype};
use crate::util::shard_ranges;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardingMode {
    /// standard sharded optimizer: shard over DP only
    So,
    /// EP-aware: non-expert over DP×EP, expert over DP
    Epso,
}

/// One contiguous segment of the rank-local parameter vector.
pub struct SegmentSpec {
    /// offset in the local parameter vector
    pub local_offset: usize,
    pub len: usize,
    /// group that replicates this segment (gradient sync + shard domain)
    pub group: Arc<Group>,
    pub group_rank: usize,
    /// multiplicity correction for the global grad-norm: 1/(number of
    /// times this segment's shards are counted across the world)
    pub norm_weight: f64,
}

struct Segment {
    spec: SegmentSpec,
    /// owned shard range within the segment
    shard: (usize, usize),
    state: AdamState,
    /// staging for the post-reduce shard gradient
    shard_grad: Vec<f32>,
}

/// Per-rank sharded optimizer instance.
pub struct ShardedOptimizer {
    segments: Vec<Segment>,
    /// group spanning every contributor to the global grad norm (the
    /// full DP×EP domain of the pp stage, independent of sharding mode)
    norm_group: Arc<Group>,
    norm_rank: usize,
    pub hp: AdamParams,
    pub reduce_dtype: ReduceDtype,
    pub max_grad_norm: f64,
    /// time spent in the local AdamW update (the component EPSO speeds up)
    pub update_secs: f64,
    /// time spent in collectives
    pub comm_secs: f64,
}

impl ShardedOptimizer {
    pub fn new(
        specs: Vec<SegmentSpec>,
        norm_group: Arc<Group>,
        norm_rank: usize,
        hp: AdamParams,
        reduce_dtype: ReduceDtype,
        max_grad_norm: f64,
    ) -> ShardedOptimizer {
        let segments = specs
            .into_iter()
            .map(|spec| {
                let shard = shard_ranges(spec.len, spec.group.size())[spec.group_rank];
                Segment {
                    shard,
                    state: AdamState::new(shard.1),
                    shard_grad: vec![0.0; shard.1],
                    spec,
                }
            })
            .collect();
        ShardedOptimizer {
            segments,
            norm_group,
            norm_rank,
            hp,
            reduce_dtype,
            max_grad_norm,
            update_secs: 0.0,
            comm_secs: 0.0,
        }
    }

    /// Optimizer-state bytes held by this rank — the quantity EPSO shrinks
    /// (paper Figure 6).
    pub fn state_bytes(&self) -> usize {
        self.segments.iter().map(|s| s.state.bytes()).sum()
    }

    /// Owned shard sizes (diagnostics / tests).
    pub fn shard_lens(&self) -> Vec<usize> {
        self.segments.iter().map(|s| s.shard.1).collect()
    }

    /// One optimizer step. `params`/`grads` are the rank-local vectors;
    /// `clip` enables global-norm clipping (paper: only after warmup).
    /// Returns the global gradient norm (pre-clip).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32, clip: bool) -> f64 {
        // Phase 1: reduce-scatter each segment's grads over its group.
        let t0 = std::time::Instant::now();
        for seg in self.segments.iter_mut() {
            let g = grads[seg.spec.local_offset..seg.spec.local_offset + seg.spec.len].to_vec();
            let reduced =
                seg.spec.group.reduce_scatter_mean(seg.spec.group_rank, g, self.reduce_dtype);
            debug_assert_eq!(reduced.len(), seg.shard.1);
            seg.shard_grad.copy_from_slice(&reduced);
        }
        // Phase 2: global grad norm (sum of owned-shard sumsq, weighted by
        // multiplicity, allreduced over the widest group).
        let mut local_sumsq = 0.0f64;
        for seg in &self.segments {
            local_sumsq += sumsq(&seg.shard_grad) * seg.spec.norm_weight;
        }
        let total = self.norm_group.allreduce(
            self.norm_rank,
            vec![local_sumsq as f32],
            ReduceDtype::F32,
        )[0] as f64;
        self.comm_secs += t0.elapsed().as_secs_f64();

        let scale = if clip { clip_scale(total, self.max_grad_norm) } else { 1.0 };

        // Phase 3: AdamW on owned shards (the timed "optimizer component"
        // of Table 3).
        let t1 = std::time::Instant::now();
        for seg in self.segments.iter_mut() {
            let (s, l) = seg.shard;
            let base = seg.spec.local_offset + s;
            let grads_shard = seg.shard_grad.clone();
            seg.state.update(self.hp, lr, scale, &mut params[base..base + l], &grads_shard);
        }
        self.update_secs += t1.elapsed().as_secs_f64();

        // Phase 4: allgather updated shards back to full segments.
        let t2 = std::time::Instant::now();
        for seg in self.segments.iter_mut() {
            let (s, l) = seg.shard;
            let base = seg.spec.local_offset + s;
            let mine = params[base..base + l].to_vec();
            let full = seg
                .spec
                .group
                .allgather_shards(seg.spec.group_rank, mine, seg.spec.len);
            params[seg.spec.local_offset..seg.spec.local_offset + seg.spec.len]
                .copy_from_slice(&full);
        }
        self.comm_secs += t2.elapsed().as_secs_f64();
        total.sqrt()
    }
}

/// Rank-local `[non-expert(ne_len) || expert(e_len)]` segment lengths.
/// Computed per pipeline stage by
/// [`crate::coordinator::ParallelismPlan::materialized`] and handed to
/// [`plan_segments`] — the plan, not the trainer, owns the layout.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SegmentLayout {
    pub ne_len: usize,
    pub e_len: usize,
}

/// Plan-driven [`SegmentSpec`] construction for a rank whose local params
/// are `[non_expert(ne_len) || expert(e_len)]` — the stage's segment
/// layout plus the stage-local process groups fully determine the
/// sharding.
///
/// * `dp_group`   — ranks replicating the expert block (same ep coord)
/// * `dpep_group` — all ranks of the pp stage (replicate the NE block)
/// * `ep` — EP degree (for SO's norm multiplicity of the NE block)
#[allow(clippy::too_many_arguments)]
pub fn plan_segments(
    mode: ShardingMode,
    layout: SegmentLayout,
    dp_group: &Arc<Group>,
    dp_rank: usize,
    dpep_group: &Arc<Group>,
    dpep_rank: usize,
    ep: usize,
) -> Vec<SegmentSpec> {
    let SegmentLayout { ne_len, e_len } = layout;
    let mut v = Vec::new();
    match mode {
        ShardingMode::So => {
            // everything shards over DP; NE shards exist once per ep rank
            // -> their sumsq is counted ep times in the world sum
            if ne_len > 0 {
                v.push(SegmentSpec {
                    local_offset: 0,
                    len: ne_len,
                    group: Arc::clone(dp_group),
                    group_rank: dp_rank,
                    norm_weight: 1.0 / ep as f64,
                });
            }
            if e_len > 0 {
                v.push(SegmentSpec {
                    local_offset: ne_len,
                    len: e_len,
                    group: Arc::clone(dp_group),
                    group_rank: dp_rank,
                    norm_weight: 1.0,
                });
            }
        }
        ShardingMode::Epso => {
            if ne_len > 0 {
                v.push(SegmentSpec {
                    local_offset: 0,
                    len: ne_len,
                    group: Arc::clone(dpep_group),
                    group_rank: dpep_rank,
                    norm_weight: 1.0,
                });
            }
            if e_len > 0 {
                v.push(SegmentSpec {
                    local_offset: ne_len,
                    len: e_len,
                    group: Arc::clone(dp_group),
                    group_rank: dp_rank,
                    norm_weight: 1.0,
                });
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Mesh, Topology};

    /// Run `steps` of a toy problem on a DP×EP mesh in both modes and
    /// check that parameter trajectories are identical (EPSO changes
    /// *where* states live, never the math) while EPSO's NE shard is
    /// EP× smaller.
    fn run_mode(mode: ShardingMode, steps: usize) -> (Vec<Vec<f32>>, Vec<usize>, usize) {
        let topo = Topology { dp: 2, ep: 2, pp: 1 };
        let mesh = Mesh::new(topo);
        let ne_len = 13; // odd: exercises ragged shards
        let e_len = 8;
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let mesh = Arc::clone(&mesh);
                std::thread::spawn(move || {
                    let c = mesh.coord(r);
                    let (dpg, dpr) = mesh.dp_group(r);
                    let (xg, xr) = mesh.dpep_group(r);
                    let segs = plan_segments(
                        mode, SegmentLayout { ne_len, e_len }, dpg, dpr, xg, xr, 2,
                    );
                    let mut opt = ShardedOptimizer::new(
                        segs,
                        Arc::clone(xg),
                        xr,
                        AdamParams { weight_decay: 0.0, ..Default::default() },
                        ReduceDtype::F32,
                        1.0,
                    );
                    // NE params replicated everywhere; expert params differ
                    // by ep coord (two expert groups)
                    let mut params: Vec<f32> = (0..ne_len + e_len)
                        .map(|i| {
                            if i < ne_len {
                                0.5 + i as f32 * 0.01
                            } else {
                                (c.ep as f32 + 1.0) * (1.0 + i as f32 * 0.01)
                            }
                        })
                        .collect();
                    for step in 0..steps {
                        // deterministic grads: NE grads equal across the
                        // dpep group after averaging; expert grads differ
                        // per dp but match across dp after mean.
                        let grads: Vec<f32> = (0..ne_len + e_len)
                            .map(|i| {
                                let base = (i as f32 * 0.1 + step as f32 * 0.01).sin();
                                base + c.dp as f32 * 0.001
                            })
                            .collect();
                        opt.step(&mut params, &grads, 1e-2, true);
                    }
                    (params, opt.shard_lens(), opt.state_bytes())
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let params: Vec<Vec<f32>> = results.iter().map(|r| r.0.clone()).collect();
        let lens = results[0].1.clone();
        let bytes = results[0].2;
        (params, lens, bytes)
    }

    #[test]
    fn so_and_epso_agree_numerically() {
        let (p_so, lens_so, bytes_so) = run_mode(ShardingMode::So, 6);
        let (p_epso, lens_epso, bytes_epso) = run_mode(ShardingMode::Epso, 6);
        for (a, b) in p_so.iter().zip(p_epso.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 2e-5, "{x} vs {y}");
            }
        }
        // EPSO NE shard is EP(=2)x smaller: SO NE shard ceil(13/2)=7,
        // EPSO ceil(13/4)=4
        assert_eq!(lens_so[0], 7);
        assert_eq!(lens_epso[0], 4);
        assert!(bytes_epso < bytes_so, "{bytes_epso} vs {bytes_so}");
    }

    #[test]
    fn replicas_stay_in_sync() {
        let (p, _, _) = run_mode(ShardingMode::Epso, 4);
        // ranks 0,1 share ep=0? rank layout: rank = (dp*EP + ep)*PP
        // rank0=(0,0) rank1=(0,1) rank2=(1,0) rank3=(1,1)
        // NE block identical on all; expert block identical across dp
        for r in 1..4 {
            assert_eq!(p[0][..13], p[r][..13], "NE desynced on rank {r}");
        }
        assert_eq!(p[0][13..], p[2][13..], "experts desynced across dp");
        assert_eq!(p[1][13..], p[3][13..]);
        assert_ne!(p[0][13..21], p[1][13..21], "distinct expert groups should differ");
    }

    #[test]
    fn clipping_bounds_update() {
        let g = crate::comm::Group::new(1);
        let segs = vec![SegmentSpec {
            local_offset: 0,
            len: 4,
            group: g,
            group_rank: 0,
            norm_weight: 1.0,
        }];
        let mut opt = ShardedOptimizer::new(
            segs,
            crate::comm::Group::new(1),
            0,
            AdamParams { weight_decay: 0.0, ..Default::default() },
            ReduceDtype::F32,
            1.0,
        );
        let mut p = vec![0.0f32; 4];
        let huge = vec![1e6f32; 4];
        let norm = opt.step(&mut p, &huge, 1e-3, true);
        assert!(norm > 1e6);
        // post-clip effective grads have norm 1 -> bounded first step
        for v in &p {
            assert!(v.abs() < 2e-3, "{v}");
        }
    }
}
