//! Integration: paper §4 reliability features against the real trainer —
//! hard/soft node-failure handling with buffer nodes, relaunch from dual
//! checkpoints, NaN containment.

use optimus::ckpt::{Checkpoint, DualCheckpointer};
use optimus::coordinator::{self, JobSpec, JobSpecBuilder, StepHook};
use optimus::data::{corpus, preprocess};
use optimus::ft::{CkptHook, HardKillHook, Launcher, NanInjectHook};
use std::path::PathBuf;
use std::sync::Arc;

fn data_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("optimus-rel-data-{}", std::process::id()));
    if !dir.exists() {
        let files = corpus::data_files(42, 3, 16);
        preprocess::preprocess(&files, 64, 7, &dir, 256).unwrap();
    }
    dir
}

fn spec(steps: usize) -> JobSpecBuilder {
    JobSpec::new("mula-tiny")
        .data_dir(data_dir())
        .topology(2, 1, 1)
        .steps(steps)
        .warmup_steps(2)
        .engine_pool(2)
}

/// Composite hook: injection + checkpointing together.
struct Chain(Vec<Arc<dyn StepHook>>);
impl StepHook for Chain {
    fn on_step(&self, r: usize, s: usize, l: f32, p: &mut [f32]) -> optimus::Result<()> {
        for h in &self.0 {
            h.on_step(r, s, l, p)?;
        }
        Ok(())
    }
}

#[test]
fn hard_failure_relaunches_from_checkpoint_and_finishes() {
    let Some(m) =
        optimus::manifest_or_skip("reliability::hard_failure_relaunches_from_checkpoint")
    else {
        return;
    };
    let ckroot =
        std::env::temp_dir().join(format!("optimus-rel-ck-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckroot);
    let kill = Arc::new(HardKillHook::once(1, 6));
    let launcher = Launcher::new(2, 2);

    let report = launcher
        .run(|attempt, nodes| {
            assert_eq!(nodes.len(), 2, "active set stays at world size");
            let base = spec(10).world_size(nodes.len()).build()?;
            let s = spec(10)
                .world_size(nodes.len())
                .hook(Arc::new(Chain(vec![
                    kill.clone(),
                    Arc::new(CkptHook {
                        every: 3,
                        dual: DualCheckpointer::new(&ckroot),
                        plan: Some(base.fingerprint()),
                    }),
                ])))
                .build()?;
            // resume from the latest valid checkpoint if any
            if let Some(c) = DualCheckpointer::new(&ckroot).load_latest() {
                assert!(attempt > 0);
                assert!(c.step >= 3, "checkpoint from before the crash");
                // recorded plan must match the resuming spec
                c.ensure_plan(&s.fingerprint())?;
            }
            coordinator::train(&m, &s)
        })
        .unwrap();
    assert_eq!(launcher.relaunches.load(std::sync::atomic::Ordering::Relaxed), 1);
    assert_eq!(launcher.pool.buffer_len(), 1, "one buffer node consumed");
    assert_eq!(report.loss.points.len(), 10);
    // checkpoints written and valid
    let latest = DualCheckpointer::new(&ckroot).load_latest().unwrap();
    assert!(latest.step >= 6);
    let _ = std::fs::remove_dir_all(&ckroot);
}

#[test]
fn soft_failure_is_detected_before_contaminating_checkpoints() {
    let Some(m) = optimus::manifest_or_skip("reliability::soft_failure_is_detected") else {
        return;
    };
    let ckroot =
        std::env::temp_dir().join(format!("optimus-rel-soft-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckroot);
    let s = spec(10)
        .hook(Arc::new(Chain(vec![
            Arc::new(NanInjectHook::once(0, 4)),
            Arc::new(CkptHook { every: 3, dual: DualCheckpointer::new(&ckroot), plan: None }),
        ])))
        .build()
        .unwrap();
    let err = coordinator::train(&m, &s).unwrap_err();
    let kind = optimus::ft::classify(&err);
    assert_eq!(kind, optimus::ft::FailureKind::Soft, "{err:#}");
    // every surviving checkpoint must be NaN-free
    let dual = DualCheckpointer::new(&ckroot);
    if let Some(c) = dual.load_latest() {
        assert!(!optimus::ft::has_nan(&c.params), "checkpoint contaminated");
        assert!(c.step < 4);
    }
    let _ = std::fs::remove_dir_all(&ckroot);
}

#[test]
fn training_resumes_from_model_only_checkpoint() {
    // persistent model-only checkpoints restart with fresh optimizer
    // state; training continues sanely afterwards (paper: "does not alter
    // the training in any significant manner")
    let Some(m) = optimus::manifest_or_skip("reliability::resumes_from_model_only_ckpt")
    else {
        return;
    };
    let s1 = spec(8).peak_lr(2e-3).build().unwrap();
    let r1 = coordinator::train(&m, &s1).unwrap();

    struct LoadHook(Vec<f32>);
    impl StepHook for LoadHook {
        fn on_step(&self, _r: usize, s: usize, _l: f32, p: &mut [f32]) -> optimus::Result<()> {
            if s == 0 {
                p.copy_from_slice(&self.0);
            }
            Ok(())
        }
    }
    let ck = Checkpoint::model_only(8, &r1.final_params).unwrap();
    assert!(ck.is_model_only());
    let s2 = spec(8)
        .peak_lr(2e-3)
        .hook(Arc::new(LoadHook(ck.params.clone())))
        .build()
        .unwrap();
    let r2 = coordinator::train(&m, &s2).unwrap();
    assert!(
        r2.loss.tail_mean(2) < r1.loss.tail_mean(2) + 0.3,
        "resume regressed: {:?} vs {:?}",
        r2.loss.tail_mean(2),
        r1.loss.tail_mean(2)
    );
}
