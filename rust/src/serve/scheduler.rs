//! Continuous-batching scheduler: one lane per serving rank.
//!
//! A lane owns the rank's `batch` decode slots, its [`KvPool`], and the
//! bounded arrival queue the traffic source feeds. The lane loop runs the
//! admission/eviction state machine at every decode step:
//!
//! 1. **drain** — pull arrivals off the queue without blocking (blocking
//!    here would stall EP lockstep siblings);
//! 2. **admit** — continuous mode seats queued requests into free slots
//!    whenever the KV pool can reserve their *entire* window (prompt +
//!    max generation) up front; static mode (the comparison baseline)
//!    only refills at a batch boundary, once every slot is empty. A
//!    failed reservation leaves the request queued — head-of-line, so
//!    admission order stays deterministic — and the bounded queue
//!    propagates the backpressure to the generator;
//! 3. **decode** — one fixed-shape step over every active slot, idle
//!    slots riding along as EOS padding;
//! 4. **evict** — rows that hit their generation budget emit a
//!    [`Completion`], release their pages, and free the slot for the
//!    next iteration's admission.
//!
//! EP lockstep: ranks of one EP group share every collective inside
//! [`Decoder::step`], so they must agree — at every loop iteration — on
//! whether a step happens. A 2-float `Max` allreduce of (any-active,
//! any-alive) flags decides: the group decodes while any member has work
//! and exits only when every member is drained, with idle members padding
//! until then. `dp` lanes never synchronize with each other.

use super::engine::Decoder;
use super::kv_cache::{KvPool, PageTable};
use super::traffic::Request;
use crate::comm::{CollectiveOp, Group, Reduce, ReduceDtype};
use crate::ft::checks;
use crate::metrics::Histogram;
use crate::runtime::Engine;
use crate::Result;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Admission policy for a serving run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchMode {
    /// admit + evict at every decode step (the serving engine proper)
    Continuous,
    /// refill only when the whole batch has drained (the baseline the
    /// perf gate compares against)
    Static,
}

/// One finished request. The token vector is a pure function of
/// (checkpoint, prompt) — greedy decode is batch-independent — so the
/// set of completions is identical across schedules and reruns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    /// generated tokens only (prompt excluded)
    pub tokens: Vec<i32>,
}

/// Per-lane results, merged into the [`super::ServeReport`] after join.
#[derive(Default)]
pub(crate) struct LaneReport {
    pub completions: Vec<Completion>,
    pub ttft: Histogram,
    pub per_token: Histogram,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub pages_leaked: usize,
    pub pages_peak: usize,
}

/// An admitted request occupying a decode slot.
struct Active {
    req: Request,
    table: PageTable,
    generated: usize,
}

pub(crate) fn run_lane(
    engine: &Engine,
    decoder: &Decoder,
    mut pool: KvPool,
    rx: Receiver<Request>,
    mode: BatchMode,
    slots: usize,
    lockstep: Option<(Arc<Group>, usize)>,
) -> Result<LaneReport> {
    let mut out = LaneReport::default();
    let mut seats: Vec<Option<Active>> = (0..slots).map(|_| None).collect();
    let mut pending: VecDeque<Request> = VecDeque::new();
    let mut rx_open = true;
    loop {
        // 1. drain arrivals (non-blocking)
        while rx_open {
            match rx.try_recv() {
                Ok(r) => pending.push_back(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => rx_open = false,
            }
        }
        // 2. admit
        let active_now = seats.iter().filter(|s| s.is_some()).count();
        let admit_now = match mode {
            BatchMode::Continuous => true,
            // a static batch launches full whenever arrivals can still
            // fill it; the tail batch launches short
            BatchMode::Static => active_now == 0 && (pending.len() >= slots || !rx_open),
        };
        if admit_now {
            for seat in seats.iter_mut() {
                if seat.is_some() {
                    continue;
                }
                let Some(front) = pending.front() else { break };
                let window = front.prompt.len() + front.max_new;
                if pool.pages_for(window) > pool.total_pages() {
                    // can never fit even an empty pool: waiting would
                    // head-of-line-block forever. The startup sizing
                    // check prevents this for generated traffic, so
                    // reaching it means a mis-sized hand-built request.
                    return Err(checks::err(
                        checks::SERVE,
                        "kv-oom",
                        format!(
                            "request {} needs {} kv pages for its {window}-token \
                             window but the lane pool only holds {}",
                            front.id,
                            pool.pages_for(window),
                            pool.total_pages()
                        ),
                    ));
                }
                let mut table = PageTable::new();
                if !table.reserve(&mut pool, window) {
                    // backpressure: pages return when a neighbor finishes
                    break;
                }
                let req = pending.pop_front().expect("front() just matched");
                let seeded = table.extend(&mut pool, &req.prompt);
                debug_assert!(seeded, "the full window was just reserved");
                *seat = Some(Active { req, table, generated: 0 });
            }
        }
        // 3. lockstep agreement on whether this iteration decodes
        let local_active = seats.iter().any(|s| s.is_some());
        let local_alive = local_active || !pending.is_empty() || rx_open;
        let (any_active, any_alive) = match &lockstep {
            Some((group, ep_rank)) => {
                let flags = group
                    .run(
                        *ep_rank,
                        CollectiveOp::Allreduce {
                            data: vec![local_active as u8 as f32, local_alive as u8 as f32],
                            red: Reduce::Max,
                            dt: ReduceDtype::F32,
                        },
                    )
                    .unwrap_or_else(|f| panic!("{f}"))
                    .values();
                (flags[0] > 0.0, flags[1] > 0.0)
            }
            None => (local_active, local_alive),
        };
        if !any_alive {
            break;
        }
        if !any_active {
            // someone still expects arrivals but nobody holds work yet;
            // idle together and re-vote (the vote keeps the EP group's
            // collective sequence uniform)
            std::thread::sleep(Duration::from_micros(200));
            continue;
        }
        // 4. decode one token for every active slot; idle slots (and
        // fully idle lockstep lanes) pad with empty rows
        let rows: Vec<Vec<i32>> =
            seats.iter().map(|s| s.as_ref().map_or_else(Vec::new, |a| a.table.tokens(&pool))).collect();
        let t0 = Instant::now();
        let next = decoder.step(engine, &rows)?;
        let dt = t0.elapsed().as_secs_f64();
        out.decode_steps += 1;
        // 5. record + evict finished rows
        for (i, seat) in seats.iter_mut().enumerate() {
            let finished = match seat.as_mut() {
                None => false,
                Some(a) => {
                    let appended = a.table.append(&mut pool, next[i]);
                    debug_assert!(appended, "admission reserved the full window");
                    a.generated += 1;
                    out.tokens_generated += 1;
                    out.per_token.record(dt);
                    if a.generated == 1 {
                        out.ttft.record(a.req.arrival.elapsed().as_secs_f64());
                    }
                    a.generated == a.req.max_new
                }
            };
            if finished {
                let mut a = seat.take().expect("matched Some above");
                let window = a.table.tokens(&pool);
                a.table.release(&mut pool);
                out.completions.push(Completion {
                    id: a.req.id,
                    prompt_len: a.req.prompt.len(),
                    tokens: window[a.req.prompt.len()..].to_vec(),
                });
            }
        }
    }
    out.pages_leaked = pool.pages_in_use();
    out.pages_peak = pool.peak_pages_used();
    Ok(out)
}
