"""AOT compilation: lower every artifact to HLO *text* + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: the image's
xla_extension 0.5.1 rejects jax>=0.5's 64-bit-instruction-id protos, while
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowered with ``return_tuple=True``; the Rust
side unwraps the tuple.

Python runs ONCE (`make artifacts`); the Rust binary is self-contained
afterwards. The manifest records, per config: the flat parameter layout
(offset/shape/is_expert/layer — everything SO/EPSO sharding and PP/EP
segmenting need) and, per artifact: the HLO file plus input/output shapes.
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    text = comp.as_hlo_text()
    # Version-skew shim: jax 0.8's HLO printer emits `topk(..., k=K,
    # largest=true)`; the xla_extension 0.5.1 text parser predates the
    # `largest` attribute (its TopK is always largest-first, which is what
    # router top-k needs). Strip it.
    assert "largest=false" not in text, "descending topk unsupported by shim"
    return text.replace(", largest=true", "")


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_plan(cfg: configs.ModelConfig):
    """(name, fn, example_args) for every artifact of one config."""
    p_total = model.param_count(cfg)
    b, s = cfg.batch, cfg.seq
    h = cfg.hidden
    toks = _spec((b, s + 1), jnp.int32)
    flat = _spec((p_total,))
    plan = [
        ("train_step", model.make_train_step(cfg, "fsmoe" if cfg.is_moe else "naive"),
         (flat, toks)),
        ("eval_step", model.make_eval_step(cfg, "fsmoe" if cfg.is_moe else "naive"),
         (flat, toks)),
    ]
    if cfg.is_moe:
        t = b * s
        x = _spec((t, h))
        fs_step, blk_n = model.make_moe_block_step(cfg, "fsmoe")
        nv_step, _ = model.make_moe_block_step(cfg, "naive")
        plan += [
            ("moe_block_fsmoe", fs_step, (_spec((blk_n,)), x, x)),
            ("moe_block_naive", nv_step, (_spec((blk_n,)), x, x)),
        ]
    return plan


def pp_artifact_plan(cfg, pp):
    """Pipeline-stage artifacts (SAC-native fwdbwd; DESIGN.md §6)."""
    b, s, h = cfg.batch, cfg.seq, cfg.hidden
    toks = _spec((b, s + 1), jnp.int32)
    act = _spec((b, s, h))
    plan = []
    for st in range(pp):
        specs = model.stage_param_specs(cfg, pp, st)
        pn = specs[-1]["offset"] + specs[-1]["numel"]
        pf = _spec((pn,))
        if st == 0:
            plan.append((f"pp{pp}_stage{st}_fwd",
                         model.make_stage_fwd(cfg, pp, st), (pf, toks)))
            plan.append((f"pp{pp}_stage{st}_fwdbwd",
                         model.make_stage_fwdbwd(cfg, pp, st), (pf, toks, act)))
        elif st == pp - 1:
            plan.append((f"pp{pp}_stage{st}_fwdbwd",
                         model.make_stage_fwdbwd(cfg, pp, st), (pf, act, toks)))
        else:
            plan.append((f"pp{pp}_stage{st}_fwd",
                         model.make_stage_fwd(cfg, pp, st), (pf, act)))
            plan.append((f"pp{pp}_stage{st}_fwdbwd",
                         model.make_stage_fwdbwd(cfg, pp, st), (pf, act, act)))
    return plan


def ep_artifact_plan(cfg, ep):
    """Per-layer EP artifacts (Algorithm 1 split at Stage 1)."""
    b, s, h, k = cfg.batch, cfg.seq, cfg.hidden, cfg.top_k
    v = cfg.vocab_size
    t_local = b * s
    t_all = ep * t_local
    toks = _spec((b, s + 1), jnp.int32)
    act = _spec((b, s, h))
    x_all = _spec((t_all, h))
    w_all = _spec((t_all, k))
    i_all = _spec((t_all, k), jnp.int32)
    ne = model.layer_nonexpert_specs(cfg)
    pn_layer = ne[-1]["offset"] + ne[-1]["numel"]
    pe_n = model.layer_expert_numel(cfg, ep)
    x2d_local = _spec((t_local, h))
    w_local = _spec((t_local, k))
    return [
        (f"ep{ep}_embed_fwd", model.make_ep_embed_fwd(cfg),
         (_spec((v * h,)), toks)),
        (f"ep{ep}_embed_bwd", model.make_ep_embed_bwd(cfg),
         (_spec((v * h,)), toks, act)),
        (f"ep{ep}_layer_pre_fwd", model.make_ep_layer_pre_fwd(cfg),
         (_spec((pn_layer,)), act)),
        (f"ep{ep}_layer_pre_bwd", model.make_ep_layer_pre_bwd(cfg),
         (_spec((pn_layer,)), act, act, x2d_local, w_local)),
        (f"ep{ep}_expert_fwd", model.make_ep_expert_fwd(cfg, ep),
         (_spec((pe_n,)), x_all, w_all, i_all)),
        (f"ep{ep}_expert_bwd", model.make_ep_expert_bwd(cfg, ep),
         (_spec((pe_n,)), x_all, w_all, i_all, x_all)),
        (f"ep{ep}_head_fwdbwd", model.make_ep_head_fwdbwd(cfg),
         (_spec((h + h * v,)), act, toks)),
        # serve-only forward head: argmax predictions for the EP decoder
        (f"ep{ep}_head_fwd", model.make_ep_head_fwd(cfg),
         (_spec((h + h * v,)), act)),
    ]


# Which extra decompositions get lowered, per config (tiny = tests,
# mini = runnable demos/examples; bigger configs use the fused path).
PP_FOR = {"mula-tiny": [2], "mula-mini": [2]}
EP_FOR = {"mula-tiny": [2], "mula-mini": [2]}
DEFAULT_CONFIGS = [c.name for c in configs.RUNNABLE]


def lower_all(out_dir, names):
    manifest = {"configs": {}}
    for name in names:
        cfg = configs.get(name)
        cdir = os.path.join(out_dir, cfg.name)
        os.makedirs(cdir, exist_ok=True)
        plan = list(artifact_plan(cfg))
        for pp in PP_FOR.get(cfg.name, []):
            plan += pp_artifact_plan(cfg, pp)
        for ep in EP_FOR.get(cfg.name, []):
            if cfg.is_moe:
                plan += ep_artifact_plan(cfg, ep)
        arts = {}
        for art_name, fn, args in plan:
            t0 = time.time()
            lowered = jax.jit(fn, keep_unused=True).lower(*args)
            text = to_hlo_text(lowered)
            rel = os.path.join(cfg.name, art_name + ".hlo.txt")
            with open(os.path.join(out_dir, rel), "w") as f:
                f.write(text)
            out_info = jax.eval_shape(fn, *args)
            outs = [dict(shape=list(o.shape), dtype=str(o.dtype))
                    for o in jax.tree.leaves(out_info)]
            arts[art_name] = dict(
                file=rel,
                inputs=[dict(shape=list(a.shape), dtype=str(a.dtype))
                        for a in args],
                outputs=outs,
            )
            print(f"  [{cfg.name}] {art_name}: {len(text)/1e6:.2f} MB "
                  f"({time.time()-t0:.1f}s)", flush=True)
        specs = [dict(name=s["name"], shape=list(s["shape"]),
                      offset=s["offset"], numel=s["numel"],
                      is_expert=s["is_expert"], layer=s["layer"])
                 for s in model.param_specs(cfg)]
        manifest["configs"][cfg.name] = dict(
            params=specs,
            param_count=model.param_count(cfg),
            hyper=dict(
                n_layers=cfg.n_layers, hidden=cfg.hidden,
                n_heads=cfg.n_heads, head_dim=cfg.head_dim,
                intermediate=cfg.intermediate, n_experts=cfg.n_experts,
                top_k=cfg.top_k, vocab_size=cfg.vocab_size,
                context=cfg.context, batch=cfg.batch, seq=cfg.seq,
                aux_coef=cfg.aux_coef, tbs=cfg.tbs, tile=cfg.tile,
            ),
            pp=PP_FOR.get(cfg.name, []),
            ep=EP_FOR.get(cfg.name, []) if cfg.is_moe else [],
            artifacts=arts,
        )
    # paper configs: hyper only (cluster model projections)
    manifest["paper_configs"] = {
        c.name: dict(n_layers=c.n_layers, hidden=c.hidden, n_heads=c.n_heads,
                     head_dim=c.head_dim, intermediate=c.intermediate,
                     n_experts=c.n_experts, top_k=c.top_k,
                     vocab_size=c.vocab_size, context=c.context,
                     param_count=c.param_count(),
                     active_param_count=c.active_param_count())
        for c in configs.PAPER}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['configs'])} configs -> {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default=",".join(DEFAULT_CONFIGS))
    args = ap.parse_args()
    lower_all(args.out, [c for c in args.configs.split(",") if c])


if __name__ == "__main__":
    main()
