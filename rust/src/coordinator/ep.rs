//! Expert-parallel helpers: Stage-1 token exchange policies and FUR.
//!
//! The paper's Stage 1 finding: allgathering all tokens beats all2all on
//! OneCCL despite higher volume, because the communication pattern is
//! regular. Both policies are implemented; `ep_comm` selects one and the
//! ablation bench compares them.

use crate::comm::{CollectiveOp, Group, ReduceDtype};
use crate::util::bf16_round;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EpComm {
    /// paper's choice: allgather everything (regular, uniform)
    Allgather,
    /// send each token only to ranks owning a chosen expert (irregular)
    All2All,
}

impl EpComm {
    /// Parse a CLI policy name.
    pub fn parse(s: &str) -> Option<EpComm> {
        match s {
            "allgather" => Some(EpComm::Allgather),
            "all2all" => Some(EpComm::All2All),
            _ => None,
        }
    }
}

/// Forced Uniform Routing (paper §2.3): replace routed expert ids with a
/// fixed round-robin pattern so every expert receives the same number of
/// tokens in the same pattern — used to decouple scaling measurements from
/// expert-selection imbalance.
pub fn fur_indices(t: usize, k: usize, n_experts: usize) -> Vec<i32> {
    let mut idx = Vec::with_capacity(t * k);
    for tok in 0..t {
        for slot in 0..k {
            idx.push(((tok * k + slot) % n_experts) as i32);
        }
    }
    idx
}

/// Stage-1 exchange via allgather: gathers tokens, routing weights and
/// indices across the EP group. Returns (x_all, w_all, idx_all).
/// `wire` selects the activation payload width: `Bf16` ships token
/// activations and routing weights as genuine 2-byte frames (the mixed
/// precision plan's activation wire); indices always travel as i32.
pub fn exchange_allgather(
    group: &Arc<Group>,
    ep_rank: usize,
    x_local: Vec<f32>,
    w_local: Vec<f32>,
    idx_local: &[i32],
    wire: ReduceDtype,
) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
    let ag = |data: Vec<f32>| {
        group
            .run(ep_rank, CollectiveOp::Allgather { data, dt: wire })
            .unwrap_or_else(|f| panic!("{f}"))
            .values()
    };
    let x_all = ag(x_local);
    let w_all = ag(w_local);
    let idx_all = group.allgather_i32(ep_rank, idx_local);
    (x_all, w_all, idx_all)
}

/// Stage-1 exchange via all2all: each token row is sent only to ranks that
/// own one of its chosen experts. Returns the same dense (x_all, w_all,
/// idx_all) views as the allgather path, with rows this rank does not need
/// zero-filled and their indices set to -1 (ignored by the kernels).
///
/// The *communication volume* is what differs (tracked by the group's
/// byte counters); the kernels' numeric result is identical because
/// non-local rows never contribute.
///
/// `wire = Bf16` rounds activation/weight values through bf16 before the
/// frames are built, so both exchange policies see the same numbers
/// under a mixed-precision plan. The all2all frames themselves stay
/// f32-width on the wire: each row interleaves a slot header and raw
/// i32 index bits with the payload, and halving only the value lanes of
/// an irregular frame is not worth the complexity when the paper's
/// production policy is allgather (which does ship 2-byte frames).
#[allow(clippy::too_many_arguments)]
pub fn exchange_all2all(
    group: &Arc<Group>,
    ep_rank: usize,
    ep: usize,
    n_local: usize, // experts per rank (NR)
    hidden: usize,
    mut x_local: Vec<f32>,
    mut w_local: Vec<f32>,
    idx_local: &[i32],
    wire: ReduceDtype,
) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
    if wire == ReduceDtype::Bf16 {
        for v in x_local.iter_mut().chain(w_local.iter_mut()) {
            *v = bf16_round(*v);
        }
    }
    if hidden == 0 || x_local.is_empty() {
        // empty micro-batch slice: `t_local` would be 0 and `k =
        // idx_local.len() / t_local` divides by zero. The rank still
        // must rendezvous (peers may carry tokens and every group
        // member issues the same collective sequence), so send empty
        // frames, then return empty dense views.
        let _ = group
            .run(ep_rank, CollectiveOp::All2All { parts: vec![Vec::new(); ep] })
            .unwrap_or_else(|f| panic!("{f}"));
        return (Vec::new(), Vec::new(), Vec::new());
    }
    let t_local = x_local.len() / hidden;
    let k = idx_local.len() / t_local;
    // build per-destination frames: [t_global_slot, x.., w.., idx..] per row
    let row_len = 1 + hidden + k + k;
    let mut frames: Vec<Vec<f32>> = vec![Vec::new(); ep];
    for t in 0..t_local {
        let mut dests = [false; 64];
        for s in 0..k {
            let e = idx_local[t * k + s];
            if e >= 0 {
                let d = (e as usize) / n_local;
                if d < ep {
                    dests[d] = true;
                }
            }
        }
        for (d, frame) in frames.iter_mut().enumerate() {
            if dests[d] {
                frame.push(t as f32);
                frame.extend_from_slice(&x_local[t * hidden..(t + 1) * hidden]);
                frame.extend_from_slice(&w_local[t * k..(t + 1) * k]);
                frame.extend(
                    idx_local[t * k..(t + 1) * k]
                        .iter()
                        .map(|v| f32::from_bits(*v as u32)),
                );
            }
        }
    }
    let received = group
        .run(ep_rank, CollectiveOp::All2All { parts: frames })
        .unwrap_or_else(|f| panic!("{f}"))
        .buckets();
    // reassemble dense views over the global token space
    let t_all = t_local * ep;
    let mut x_all = vec![0.0f32; t_all * hidden];
    let mut w_all = vec![0.0f32; t_all * k];
    let mut idx_all = vec![-1i32; t_all * k];
    for (src, frame) in received.iter().enumerate() {
        let rows = frame.len() / row_len;
        for r in 0..rows {
            let base = r * row_len;
            let t_global = src * t_local + frame[base] as usize;
            x_all[t_global * hidden..(t_global + 1) * hidden]
                .copy_from_slice(&frame[base + 1..base + 1 + hidden]);
            w_all[t_global * k..(t_global + 1) * k]
                .copy_from_slice(&frame[base + 1 + hidden..base + 1 + hidden + k]);
            for s in 0..k {
                idx_all[t_global * k + s] =
                    frame[base + 1 + hidden + k + s].to_bits() as i32;
            }
        }
    }
    (x_all, w_all, idx_all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_cases;

    #[test]
    fn fur_is_uniform() {
        let n = 8;
        let idx = fur_indices(32, 2, n);
        let mut counts = vec![0usize; n];
        for v in &idx {
            counts[*v as usize] += 1;
        }
        for c in &counts {
            assert_eq!(*c, 32 * 2 / n);
        }
    }

    #[test]
    fn all2all_empty_microbatch_returns_empty_frames() {
        // single rank, empty slice: must not divide by zero
        let g1 = crate::comm::Group::new(1);
        let (x, w, i) =
            exchange_all2all(&g1, 0, 1, 2, 4, Vec::new(), Vec::new(), &[], ReduceDtype::F32);
        assert!(x.is_empty() && w.is_empty() && i.is_empty());

        // every rank of a group empty: all still rendezvous and return
        let ep = 2;
        let group = crate::comm::Group::new(ep);
        let handles: Vec<_> = (0..ep)
            .map(|r| {
                let group = std::sync::Arc::clone(&group);
                std::thread::spawn(move || {
                    exchange_all2all(
                        &group,
                        r,
                        ep,
                        2,
                        4,
                        Vec::new(),
                        Vec::new(),
                        &[],
                        ReduceDtype::F32,
                    )
                })
            })
            .collect();
        for h in handles {
            let (x, w, i) = h.join().unwrap();
            assert!(x.is_empty() && w.is_empty() && i.is_empty());
        }
    }

    #[test]
    fn all2all_matches_allgather_for_local_rows() {
        run_cases(20, |g| {
            let ep = *g.choose(&[2usize, 4]);
            let n_local = *g.choose(&[2usize, 4]);
            let n = ep * n_local;
            let h = 4;
            let t_local = *g.choose(&[4usize, 8]);
            let k = 2;
            let group = crate::comm::Group::new(ep);
            // per-rank inputs
            let mut xs = Vec::new();
            let mut ws = Vec::new();
            let mut ids = Vec::new();
            for r in 0..ep {
                xs.push(g.vec_f32(t_local * h, -1.0, 1.0));
                ws.push(g.vec_f32(t_local * k, 0.0, 1.0));
                let mut idx = Vec::new();
                for t in 0..t_local {
                    let a = g.below(n);
                    let mut b = g.below(n);
                    if b == a {
                        b = (b + 1) % n;
                    }
                    idx.extend([a as i32, b as i32]);
                    let _ = t;
                }
                ids.push(idx);
                let _ = r;
            }
            let mut handles = Vec::new();
            for r in 0..ep {
                let group = std::sync::Arc::clone(&group);
                let (x, w, id) = (xs[r].clone(), ws[r].clone(), ids[r].clone());
                handles.push(std::thread::spawn(move || {
                    let a2a = exchange_all2all(
                        &group, r, ep, n_local, h, x, w, &id, ReduceDtype::F32,
                    );
                    a2a
                }));
            }
            let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            // manual "allgather" reference
            let x_ref: Vec<f32> = xs.concat();
            let w_ref: Vec<f32> = ws.concat();
            let i_ref: Vec<i32> = ids.concat();
            let t_all = ep * t_local;
            for (r, (xa, wa, ia)) in outs.iter().enumerate() {
                let lo = (r * n_local) as i32;
                let hi = lo + n_local as i32 - 1;
                for t in 0..t_all {
                    let local_row =
                        (0..k).any(|s| (lo..=hi).contains(&i_ref[t * k + s]));
                    if local_row {
                        assert_eq!(
                            &xa[t * h..(t + 1) * h],
                            &x_ref[t * h..(t + 1) * h],
                            "rank {r} token {t} x mismatch"
                        );
                        for s in 0..k {
                            // weights for rows we need must match;
                            // indices match exactly
                            assert_eq!(ia[t * k + s], i_ref[t * k + s]);
                            assert_eq!(wa[t * k + s], w_ref[t * k + s]);
                        }
                    }
                }
            }
        });
    }
}
