//! Step timing breakdown + loss logging.
//!
//! A training step decomposes into the paper's three components —
//! forward, backward (fused here as fwd+bwd artifacts), and optimizer —
//! plus communication and data time. Table 3's speedups are ratios of
//! these component times.

use std::time::Instant;

/// Per-step wall-clock decomposition. Every field carries a `class:` tag
/// (checked by `optimus lint`) stating its accounting role:
///
/// * `class: additive` — real blocking time on the training thread;
///   summed by [`StepBreakdown::total`], which must track wall-clock.
/// * `class: concurrent` — time hidden on a background thread while the
///   training thread computes; informational, never summed.
/// * `class: contained` — time physically spent *inside* another additive
///   field; never summed (it would double-count).
#[derive(Clone, Debug, Default)]
pub struct StepBreakdown {
    /// fused forward+backward artifact execution. class: additive
    pub fwd_bwd_secs: f64,
    /// the optimizer's own compute (update math, exposed). class: additive
    pub optimizer_secs: f64,
    /// *exposed* communication: time a rank thread actually blocked in a
    /// collective / p2p transfer (with `--overlap`, comm hidden behind
    /// compute moves to `overlap_secs` instead). class: additive
    pub comm_secs: f64,
    /// synchronous batch assembly on the training thread (prefetch off,
    /// or a fetch outside the prefetcher's predicted sequence).
    /// class: additive
    pub data_secs: f64,
    /// time the training thread blocked popping the prefetch queue — the
    /// *exposed* remainder of data time once the background producer hides
    /// the assembly. Real step wall-clock. class: additive
    pub data_wait_secs: f64,
    /// batch assembly hidden on the per-rank `data-prefetch-*` producer
    /// thread. Runs while the training thread computes (like
    /// `overlap_secs`) — informational, never part of the wall-clock sum.
    /// class: concurrent
    pub data_prefetch_secs: f64,
    /// PJRT executor queue wait: time submitted artifacts sat waiting for
    /// a free executor, folded in by the harness at finish from
    /// [`crate::runtime::EngineStats`]. The pool counters are shared by
    /// every rank of the run, so this is the run delta averaged over
    /// ranks — an *estimate* of this rank's queue share (exact only for
    /// balanced topologies; a skewed pipeline can make it exceed this
    /// rank's own waits). Queue time is physically spent inside the
    /// engines' end-to-end `exec` timing (`fwd_bwd_secs`), so
    /// [`StepBreakdown::total`] never adds it again — totals keep
    /// matching wall-clock step time; this field is the pool-sizing
    /// signal, not an additive component. class: contained
    pub queue_secs: f64,
    /// communication hidden behind compute by the async overlap pipeline
    /// (comm-lane busy time minus exposed waits). It runs *concurrently*
    /// with `optimizer_secs`, so it is informational — Table-3-style
    /// component ratios use it as the "saved" comm — and is never part of
    /// the wall-clock sum. class: concurrent
    pub overlap_secs: f64,
    /// time the training thread was blocked taking checkpoint snapshots:
    /// the O(1) `Arc` capture + submit (async mode) or the full inline
    /// write (sync mode). Real step wall-clock. class: additive
    pub snapshot_secs: f64,
    /// checkpoint serialization hidden on the Checkpointer's background
    /// writer. Runs while the training thread computes (like
    /// `overlap_secs`), recorded as this rank's share (run total / world)
    /// — informational, never part of the wall-clock sum.
    /// class: concurrent
    pub snapshot_write_secs: f64,
}

impl StepBreakdown {
    /// Wall-clock-additive components only: `queue_secs` is spent inside
    /// `fwd_bwd_secs` and `overlap_secs`/`data_prefetch_secs`/
    /// `snapshot_write_secs` are concurrent-by-design, so none of those
    /// are added — the sum tracks real step time. `snapshot_secs` (the
    /// capture stall) and `data_wait_secs` (the prefetch-pop stall) are
    /// real blocking time and are added.
    pub fn total(&self) -> f64 {
        self.fwd_bwd_secs
            + self.optimizer_secs
            + self.comm_secs
            + self.data_secs
            + self.data_wait_secs
            + self.snapshot_secs
    }

    /// Fraction of total communication (exposed + hidden) that the
    /// overlap pipeline hid behind compute; 0 when nothing was hidden.
    pub fn overlap_ratio(&self) -> f64 {
        let comm = self.comm_secs + self.overlap_secs;
        if comm <= 0.0 {
            return 0.0;
        }
        self.overlap_secs / comm
    }

    pub fn add(&mut self, other: &StepBreakdown) {
        self.fwd_bwd_secs += other.fwd_bwd_secs;
        self.optimizer_secs += other.optimizer_secs;
        self.comm_secs += other.comm_secs;
        self.data_secs += other.data_secs;
        self.data_wait_secs += other.data_wait_secs;
        self.data_prefetch_secs += other.data_prefetch_secs;
        self.queue_secs += other.queue_secs;
        self.overlap_secs += other.overlap_secs;
        self.snapshot_secs += other.snapshot_secs;
        self.snapshot_write_secs += other.snapshot_write_secs;
    }
}

/// Scoped timer: `let _t = Scoped::new(&mut acc);`
pub struct Scoped<'a> {
    start: Instant,
    sink: &'a mut f64,
}

impl<'a> Scoped<'a> {
    pub fn new(sink: &'a mut f64) -> Scoped<'a> {
        Scoped { start: Instant::now(), sink }
    }
}

impl<'a> Drop for Scoped<'a> {
    fn drop(&mut self) {
        *self.sink += self.start.elapsed().as_secs_f64();
    }
}

/// Loss / metric curve: (step, value) pairs with CSV export.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub name: String,
    pub points: Vec<(usize, f64)>,
}

impl Curve {
    pub fn new(name: &str) -> Curve {
        Curve { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, step: usize, v: f64) {
        self.points.push((step, v));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    /// Mean of the final `n` points (smoothed terminal loss).
    pub fn tail_mean(&self, n: usize) -> f64 {
        let k = self.points.len().saturating_sub(n);
        let tail = &self.points[k..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|p| p.1).sum::<f64>() / tail.len() as f64
    }

    pub fn to_csv(&self) -> String {
        let mut s = format!("step,{}\n", self.name);
        for (st, v) in &self.points {
            s.push_str(&format!("{st},{v}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_accumulates() {
        let mut acc = 0.0;
        {
            let _t = Scoped::new(&mut acc);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(acc >= 0.004);
    }

    #[test]
    fn breakdown_totals_exclude_concurrent_components() {
        let mut b = StepBreakdown {
            fwd_bwd_secs: 2.0,
            optimizer_secs: 1.0,
            comm_secs: 0.5,
            data_secs: 0.125,
            data_wait_secs: 0.125,     // prefetch-pop stall — additive
            data_prefetch_secs: 0.75,  // hidden on the producer thread
            queue_secs: 0.75,          // inside fwd_bwd
            overlap_secs: 0.5,         // concurrent with optimizer
            snapshot_secs: 0.25,       // blocking capture stall — additive
            snapshot_write_secs: 1.25, // hidden on the ckpt writer
        };
        assert_eq!(b.total(), 4.0);
        assert_eq!(b.overlap_ratio(), 0.5);
        let other = b.clone();
        b.add(&other);
        assert_eq!(b.queue_secs, 1.5);
        assert_eq!(b.overlap_secs, 1.0);
        assert_eq!(b.data_wait_secs, 0.25);
        assert_eq!(b.data_prefetch_secs, 1.5);
        assert_eq!(b.snapshot_secs, 0.5);
        assert_eq!(b.snapshot_write_secs, 2.5);
        assert_eq!(b.total(), 8.0);
    }

    #[test]
    fn curve_tail_mean() {
        let mut c = Curve::new("loss");
        for i in 0..10 {
            c.push(i, i as f64);
        }
        assert_eq!(c.tail_mean(2), 8.5);
        assert_eq!(c.last(), Some(9.0));
        assert!(c.to_csv().contains("9,9"));
    }
}
