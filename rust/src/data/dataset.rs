//! Mmap shard reader + deterministic global batch plan.
//!
//! Shards are mapped read-only with `libc::mmap` (lazy, zero-copy) — the
//! paper's "loaded in mmap mode in a lazy manner". The batch plan gives
//! every (step, dp_rank, row) a unique instance id so all ranks consume
//! disjoint, contiguous slices of the shuffled instance stream.

use super::preprocess::{MAGIC, VERSION};
use crate::Result;
use anyhow::{anyhow, Context};
use std::path::{Path, PathBuf};

struct Mmap {
    ptr: *mut libc::c_void,
    len: usize,
}

// SAFETY: the mapping is read-only (PROT_READ, MAP_PRIVATE) for its whole
// lifetime; concurrent reads from multiple rank threads are safe.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    fn open(path: &Path) -> Result<Mmap> {
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            return Err(anyhow!("empty shard {path:?}"));
        }
        use std::os::unix::io::AsRawFd;
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ,
                libc::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            return Err(anyhow!("mmap failed for {path:?}"));
        }
        Ok(Mmap { ptr, len })
    }

    fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        unsafe {
            libc::munmap(self.ptr, self.len);
        }
    }
}

struct Shard {
    map: Mmap,
    n_instances: usize,
    context: usize,
}

impl Shard {
    fn open(path: &Path) -> Result<Shard> {
        let map = Mmap::open(path)?;
        let b = map.bytes();
        if b.len() < 20 || &b[0..4] != MAGIC {
            return Err(anyhow!("bad shard magic in {path:?}"));
        }
        let version = u32::from_le_bytes(b[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(anyhow!("unsupported shard version {version}"));
        }
        let context = u32::from_le_bytes(b[8..12].try_into().unwrap()) as usize;
        let n = u64::from_le_bytes(b[12..20].try_into().unwrap()) as usize;
        let want = 20 + n * context * 4;
        if b.len() < want {
            return Err(anyhow!("truncated shard {path:?}: {} < {want}", b.len()));
        }
        Ok(Shard { map, n_instances: n, context })
    }

    fn instance(&self, i: usize) -> Vec<u32> {
        let c = self.context;
        let start = 20 + i * c * 4;
        let b = &self.map.bytes()[start..start + c * 4];
        b.chunks_exact(4)
            .map(|w| u32::from_le_bytes(w.try_into().unwrap()))
            .collect()
    }
}

/// A directory of `.oshard` files seen as one flat instance array.
pub struct Dataset {
    shards: Vec<Shard>,
    /// prefix sums of shard instance counts
    offsets: Vec<usize>,
    pub context: usize,
}

impl Dataset {
    pub fn open(dir: &Path) -> Result<Dataset> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("reading shard dir {dir:?}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().map(|e| e == "oshard").unwrap_or(false))
            .collect();
        paths.sort();
        if paths.is_empty() {
            return Err(anyhow!("no .oshard files in {dir:?}"));
        }
        let shards: Vec<Shard> =
            paths.iter().map(|p| Shard::open(p)).collect::<Result<_>>()?;
        let context = shards[0].context;
        let mut offsets = vec![0usize];
        for s in &shards {
            if s.context != context {
                return Err(anyhow!("mixed context sizes across shards"));
            }
            offsets.push(offsets.last().unwrap() + s.n_instances);
        }
        Ok(Dataset { shards, offsets, context })
    }

    pub fn len(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Instance `i` as tokens (length = context). A raw index outside
    /// the dataset is a **hard error**: epoch wrapping is the token
    /// cursor's job ([`super::TokenStream`] maps a stream position
    /// through the epoch-aware shuffle), and an escaped raw index here
    /// means a caller bypassed the validated budget — the silent
    /// `i % len` wrap this replaces turned that bug into quiet data
    /// repetition.
    pub fn instance(&self, i: usize) -> Result<Vec<u32>> {
        if i >= self.len() {
            return Err(anyhow!(
                "data read past validated budget: raw instance {i} is outside the \
                 dataset's {} instances (epoch wrapping goes through the token cursor)",
                self.len()
            ));
        }
        // binary search the shard
        let s = match self.offsets.binary_search(&i) {
            Ok(k) => k,
            Err(k) => k - 1,
        };
        Ok(self.shards[s].instance(i - self.offsets[s]))
    }

    /// Batch of `rows` consecutive *raw* instances starting at `start`,
    /// each extended to `seq+1` tokens (input + shifted target). Tokens
    /// past the instance's `context` continue into the **next instance
    /// slot** — the `seq+1`th token is the next slot's first token — and
    /// EOS-padding happens only at the true stream end (the last
    /// instance of the dataset). Shuffled, budget-checked reads go
    /// through [`super::TokenStream::batch_i32`] instead.
    pub fn batch_i32(&self, start: usize, rows: usize, seq: usize) -> Result<Vec<i32>> {
        let c = self.context;
        let mut out = Vec::with_capacity(rows * (seq + 1));
        for r in 0..rows {
            let mut ext = self.instance(start + r)?;
            while ext.len() < seq + 1 {
                let next = start + r + ext.len() / c;
                if next >= self.len() {
                    break; // true stream end: EOS-pad below
                }
                let more = self.instance(next)?;
                ext.extend(more);
            }
            for j in 0..=seq {
                out.push(*ext.get(j).unwrap_or(&super::tokenizer::EOS) as i32);
            }
        }
        Ok(out)
    }
}

/// Deterministic *geometry* of a step's data consumption: how the
/// `instances_per_step()` stream positions a step consumes split across
/// (data rank, microbatch, row). All ranks at a step consume one
/// contiguous block of the shuffled stream — the paper's contiguous-read
/// property; the block's *position* comes from the
/// [`TokenCursor`](super::TokenCursor), never from `step ×
/// instances_per_step` (which silently re-read or skipped data when an
/// elastic resume changed the geometry).
#[derive(Clone, Copy, Debug)]
pub struct BatchPlan {
    pub dp: usize,
    pub micro_batch: usize,
    pub micro_batches: usize,
}

impl BatchPlan {
    pub fn instances_per_step(&self) -> usize {
        self.dp * self.micro_batch * self.micro_batches
    }

    /// Offset of (data rank, micro step) within a step's contiguous
    /// stream block. The absolute position is
    /// `cursor.at_step(step) + offset(..)`.
    pub fn offset(&self, dp_rank: usize, micro: usize) -> usize {
        dp_rank * self.micro_batch * self.micro_batches + micro * self.micro_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{corpus, preprocess};

    fn build(tag: &str, context: usize) -> (std::path::PathBuf, Dataset) {
        let dir = std::env::temp_dir()
            .join(format!("optimus-ds-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let files = corpus::data_files(5, 3, 8);
        preprocess::preprocess(&files, context, 11, &dir, 64).unwrap();
        let ds = Dataset::open(&dir).unwrap();
        (dir, ds)
    }

    #[test]
    fn instances_read_across_shards() {
        let (dir, ds) = build("multi", 32);
        assert!(ds.len() > 64, "need multiple shards");
        for i in [0, 1, 63, 64, ds.len() - 1] {
            let inst = ds.instance(i).unwrap();
            assert_eq!(inst.len(), 32);
            assert!(inst.iter().all(|&t| t < 300));
        }
        // a raw index past the dataset is a hard error, never a wrap
        let e = ds.instance(ds.len()).unwrap_err().to_string();
        assert!(e.contains("data read past validated budget"), "{e}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn batch_shapes_and_determinism() {
        let (dir, ds) = build("batch", 32);
        let b1 = ds.batch_i32(5, 4, 31).unwrap();
        let b2 = ds.batch_i32(5, 4, 31).unwrap();
        assert_eq!(b1, b2);
        assert_eq!(b1.len(), 4 * 32);

        // seq == context: the seq+1th token of each row is the FIRST
        // token of the next instance slot, not EOS
        let b = ds.batch_i32(5, 4, 32).unwrap();
        assert_eq!(b.len(), 4 * 33);
        for r in 0..4 {
            let next_first = ds.instance(5 + r + 1).unwrap()[0];
            assert_eq!(b[r * 33 + 32], next_first as i32, "row {r}");
        }
        // EOS appears only at the true stream end (last instance)
        let e = ds.batch_i32(ds.len() - 1, 1, 32).unwrap();
        assert_eq!(e[32], super::super::tokenizer::EOS as i32);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn plan_assigns_disjoint_contiguous_blocks() {
        use crate::data::TokenCursor;
        let p = BatchPlan { dp: 4, micro_batch: 2, micro_batches: 3 };
        let cur = TokenCursor::fresh(p.instances_per_step() as u64);
        let mut seen = std::collections::HashSet::new();
        for step in 0..3 {
            for rank in 0..4 {
                for m in 0..3 {
                    let s = cur.at_step(step) + p.offset(rank, m) as u64;
                    for r in 0..2 {
                        assert!(seen.insert(s + r), "instance reused");
                    }
                }
            }
        }
        assert_eq!(seen.len(), 3 * p.instances_per_step());
        // contiguity: the full set is an interval
        let max = *seen.iter().max().unwrap();
        assert_eq!(max as usize + 1, seen.len());
    }
}
