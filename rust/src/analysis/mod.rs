//! `optimus lint` — the repo's own invariant lint over the crate sources.
//!
//! Generic tooling can't know this codebase's contracts; this pass can.
//! It walks `src/**.rs` and `tests/*.rs` with a dependency-free,
//! Rust-shaped **token + block-structure analyzer** ([`lexer`]): a
//! comment/string/raw-string-aware token stream, a brace tree, and a
//! per-token `#[cfg(test)]` mark. Nine passes ([`passes`], see
//! [`RULES`]) run over that view:
//!
//! * **check-strings** — every stable failure tag of the shape
//!   `"<domain> [<name>]"` (domains end in `failed`/`violated`, see
//!   [`crate::ft::checks`]) must name a registered check. A typo'd tag
//!   would silently escape [`crate::ft::classify`] and every runbook
//!   grep.
//! * **check-coverage** — the reverse direction: every registered check
//!   must be asserted, as its full stable literal, by at least one test
//!   (a `#[cfg(test)]` region or an integration test file). A check
//!   nobody tests is a check that silently rots.
//! * **named-spawn** — no bare `thread::spawn` outside tests, and every
//!   `std::thread::Builder` chain that reaches `.spawn(..)` must have
//!   called `.name(..)` (so stall dumps and panics identify the
//!   thread); `comm::lsync::spawn_named` is the loom-aware wrapper.
//! * **lock-discipline** — no `.lock().unwrap()` outside `comm/` and
//!   `ckpt/` (whose rendezvous/writer protocols poison deliberately and
//!   re-panic by design): shared-state readers elsewhere must use the
//!   poison-tolerant [`crate::util::lock`].
//! * **metrics-class** — every `f64` field of
//!   [`crate::metrics::StepBreakdown`] must carry a
//!   `class: additive|concurrent|contained` doc tag so `total()` can
//!   never silently double-count a concurrent component.
//! * **collective-divergence** — a collective call site reachable only
//!   under a rank-dependent condition deadlocks the rest of the group;
//!   flagged unless annotated `// lint: rank-uniform <why>`.
//! * **collective-order** — sibling arms of a rank-dependent branch
//!   must issue identical collective-kind sequences.
//! * **lock-order** — no lock pair acquired in both orders anywhere
//!   across `comm/`, `ckpt/`, `serve/` (the AB/BA deadlock shape).
//! * **poison-path** — `unwrap`/`expect`/`panic!` inside rank/lane
//!   worker closures must route through the poison protocol.
//!
//! Output: human `file:line: [rule] message` lines, [`to_json`] for
//! machines, and [`to_sarif`] (SARIF 2.1.0) for GitHub code scanning.
//! DESIGN.md §12 documents the pass catalog and the annotation grammar.

pub mod lexer;
mod passes;

pub use passes::RULES;

use crate::ft::checks;
use crate::Result;
use anyhow::anyhow;
use std::collections::BTreeSet;
use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding, formatted `file:line: [rule] message`.
#[derive(Clone, Debug)]
pub struct Violation {
    /// crate-relative path, e.g. `src/comm/group.rs`
    pub file: String,
    /// 1-based; 0 when the finding is not anchored to a line
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line == 0 {
            write!(f, "{}: [{}] {}", self.file, self.rule, self.msg)
        } else {
            write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
        }
    }
}

/// A source file handed to [`scan`]: crate-relative path + full text.
pub struct SrcFile {
    pub rel: String,
    pub text: String,
}

impl SrcFile {
    /// Integration tests and benches are all-test: exempt from the
    /// structural rules, still scanned (and counted) by the check-string
    /// rules.
    fn is_test_file(&self) -> bool {
        self.rel.starts_with("tests/") || self.rel.starts_with("benches/")
    }
}

/// One file, fully analyzed: the token stream, the brace tree and the
/// per-token test mark every pass shares.
pub(crate) struct FileView<'a> {
    pub f: &'a SrcFile,
    pub lx: lexer::Lexed,
    pub root: lexer::Block,
    pub test: Vec<bool>,
}

impl<'a> FileView<'a> {
    fn new(f: &'a SrcFile) -> FileView<'a> {
        let lx = lexer::lex(&f.text);
        let root = lexer::tree(&lx.toks);
        let test = lexer::test_marks(&lx.toks, f.is_test_file());
        FileView { f, lx, root, test }
    }
}

/// The crate directory this binary was built from — the default lint
/// root, so `optimus lint` works from any CWD inside the checkout.
pub fn default_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Collect `src/**.rs` and `tests/**.rs` under `root`, sorted for
/// deterministic output.
pub fn collect(root: &Path) -> Result<Vec<SrcFile>> {
    let mut out = Vec::new();
    walk(&root.join("src"), "src", &mut out)?;
    walk(&root.join("tests"), "tests", &mut out)?;
    if out.is_empty() {
        return Err(anyhow!(
            "no .rs sources under {root:?} — pass --root <crate dir>"
        ));
    }
    out.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(out)
}

fn walk(dir: &Path, rel: &str, out: &mut Vec<SrcFile>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        let name = e.file_name().to_string_lossy().into_owned();
        if p.is_dir() {
            walk(&p, &format!("{rel}/{name}"), out)?;
        } else if name.ends_with(".rs") {
            out.push(SrcFile {
                rel: format!("{rel}/{name}"),
                text: std::fs::read_to_string(&p)?,
            });
        }
    }
    Ok(())
}

/// Lint the crate at `root`; empty result means clean.
pub fn run(root: &Path) -> Result<Vec<Violation>> {
    Ok(scan(&collect(root)?))
}

/// Pure core: lint an in-memory file set (what the self-tests seed).
/// Runs every pass, then sorts findings by `(file, line, rule)`.
pub fn scan(files: &[SrcFile]) -> Vec<Violation> {
    let views: Vec<FileView<'_>> = files.iter().map(FileView::new).collect();
    let mut domains: Vec<&'static str> = checks::CHECKS.iter().map(|c| c.domain).collect();
    domains.sort_unstable();
    domains.dedup();

    let mut v = Vec::new();
    let mut asserted: BTreeSet<(&'static str, &'static str)> = BTreeSet::new();
    let mut pairs = passes::PairTable::new();
    for view in &views {
        passes::check_strings(view, &domains, &mut v, &mut asserted);
        passes::named_spawn(view, &mut v);
        passes::lock_discipline(view, &mut v);
        passes::collective_flow(view, &mut v);
        passes::poison_path(view, &mut v);
        if view.f.rel.starts_with("src/comm/")
            || view.f.rel.starts_with("src/ckpt/")
            || view.f.rel.starts_with("src/serve/")
        {
            passes::lock_order_collect(view, &mut pairs);
        }
    }
    // metrics-class runs wherever the struct lives; if it vanished from
    // the canonical file entirely, that file reports the not-found guard
    let has_bd = |w: &&FileView<'_>| {
        w.lx.toks
            .windows(2)
            .any(|p| p[0].is_ident("struct") && p[1].is_ident("StepBreakdown"))
    };
    match views.iter().find(has_bd) {
        Some(w) => passes::metrics_class(w, &mut v),
        None => {
            if let Some(w) = views.iter().find(|w| w.f.rel == "src/metrics/mod.rs") {
                passes::metrics_class(w, &mut v);
            }
        }
    }
    passes::check_coverage(&views, &asserted, &mut v);
    passes::lock_order_finalize(&pairs, &mut v);

    v.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.msg).cmp(&(&b.file, b.line, b.rule, &b.msg))
    });
    v.dedup_by(|a, b| a.file == b.file && a.line == b.line && a.rule == b.rule && a.msg == b.msg);
    v
}

/// Minimal JSON string escape for the emitters below.
fn esc(s: &str) -> String {
    let mut o = String::with_capacity(s.len() + 8);
    for c in s.chars() {
        match c {
            '"' => o.push_str("\\\""),
            '\\' => o.push_str("\\\\"),
            '\n' => o.push_str("\\n"),
            '\t' => o.push_str("\\t"),
            '\r' => o.push_str("\\r"),
            c if (c as u32) < 0x20 => o.push_str(&format!("\\u{:04x}", c as u32)),
            c => o.push(c),
        }
    }
    o
}

/// Machine-readable findings: `{"violations":[{file,line,rule,msg}]}`.
pub fn to_json(v: &[Violation]) -> String {
    let mut out = String::from("{\"violations\":[");
    for (i, x) in v.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"msg\":\"{}\"}}",
            esc(&x.file),
            x.line,
            x.rule,
            esc(&x.msg)
        ));
    }
    out.push_str("]}\n");
    out
}

/// SARIF 2.1.0 for GitHub code scanning. `uri_prefix` rebases the
/// crate-relative paths onto the repository root (CI passes `"rust/"`).
pub fn to_sarif(v: &[Violation], uri_prefix: &str) -> String {
    let rules: Vec<String> = RULES
        .iter()
        .map(|r| format!("{{\"id\":\"{r}\",\"name\":\"{r}\"}}"))
        .collect();
    let results: Vec<String> = v
        .iter()
        .map(|x| {
            format!(
                "{{\"ruleId\":\"{}\",\"level\":\"error\",\
                 \"message\":{{\"text\":\"{}\"}},\
                 \"locations\":[{{\"physicalLocation\":{{\
                 \"artifactLocation\":{{\"uri\":\"{}{}\"}},\
                 \"region\":{{\"startLine\":{}}}}}}}]}}",
                x.rule,
                esc(&x.msg),
                esc(uri_prefix),
                esc(&x.file),
                x.line.max(1)
            )
        })
        .collect();
    format!(
        "{{\"$schema\":\"https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json\",\
         \"version\":\"2.1.0\",\
         \"runs\":[{{\"tool\":{{\"driver\":{{\"name\":\"optimus-lint\",\
         \"informationUri\":\"DESIGN.md\",\
         \"rules\":[{}]}}}},\
         \"results\":[{}]}}]}}\n",
        rules.join(","),
        results.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(rel: &str, text: &str) -> SrcFile {
        SrcFile { rel: rel.into(), text: text.into() }
    }

    fn rules(v: &[Violation], rule: &str) -> usize {
        v.iter().filter(|x| x.rule == rule).count()
    }

    #[test]
    fn unregistered_check_string_is_flagged() {
        // assemble the tag at runtime so linting *this* file stays clean
        let text = format!(
            "fn f() -> anyhow::Error {{\n    anyhow::anyhow!(\"plan validation {} [no-such-check]: boom\")\n}}\n",
            "failed"
        );
        let v = scan(&[src("src/foo.rs", &text)]);
        assert_eq!(rules(&v, "check-strings"), 1, "{v:?}");
        assert!(v.iter().any(|x| x.msg.contains("no-such-check")), "{v:?}");

        let text = format!("const T: &str = \"quota exceeded {} [retry]\";\n", "failed");
        let v = scan(&[src("src/foo.rs", &text)]);
        assert_eq!(rules(&v, "check-strings"), 1, "unknown domain must flag: {v:?}");

        // comments and doc placeholders never trip the rule
        let text = format!("// plan validation {} [nope]\n/// `{} [<check>]`\n", "failed", "violated");
        let v = scan(&[src("src/foo.rs", &text)]);
        assert_eq!(rules(&v, "check-strings"), 0, "{v:?}");
    }

    #[test]
    fn every_registered_check_needs_a_test_assertion() {
        // a file set with no test literals at all: every check uncovered
        let v = scan(&[src("src/foo.rs", "fn a() {}\n")]);
        assert_eq!(rules(&v, "check-coverage"), checks::CHECKS.len());

        // a test file asserting every registered tag: zero uncovered
        let mut t = String::from("fn all() {\n");
        for c in checks::CHECKS {
            t.push_str(&format!(
                "    assert!(e.contains(\"{} [{}]\"));\n",
                c.domain, c.name
            ));
        }
        t.push_str("}\n");
        let v = scan(&[src("tests/cover.rs", &t)]);
        assert_eq!(rules(&v, "check-coverage"), 0, "{v:?}");
        // ...and the same literals inside a src #[cfg(test)] region count too
        let t2 = format!("#[cfg(test)]\nmod tests {{\n{}}}\n", &t);
        let v = scan(&[src("src/foo.rs", &t2)]);
        assert_eq!(rules(&v, "check-coverage"), 0, "{v:?}");
    }

    #[test]
    fn spawn_and_lock_rules_respect_regions_and_exemptions() {
        let bad = "fn f() {\n    std::thread::spawn(|| {});\n    let g = m.lock().unwrap();\n}\n";
        let v = scan(&[src("src/foo.rs", bad)]);
        assert_eq!(rules(&v, "named-spawn"), 1, "{v:?}");
        assert_eq!(rules(&v, "lock-discipline"), 1, "{v:?}");

        // the same text is fine in a test region, a test file, or comm/
        let in_test = format!("#[cfg(test)]\nmod tests {{\n{bad}}}\n");
        let v = scan(&[src("src/foo.rs", &in_test)]);
        assert_eq!(rules(&v, "named-spawn") + rules(&v, "lock-discipline"), 0, "{v:?}");
        let v = scan(&[src("tests/foo.rs", bad)]);
        assert_eq!(rules(&v, "named-spawn") + rules(&v, "lock-discipline"), 0, "{v:?}");
        let v = scan(&[src("src/comm/foo.rs", bad), src("src/ckpt/bar.rs", bad)]);
        assert_eq!(rules(&v, "lock-discipline"), 0, "{v:?}");
        assert_eq!(rules(&v, "named-spawn"), 2, "comm is not spawn-exempt: {v:?}");
        let v = scan(&[src("src/comm/lsync.rs", bad)]);
        assert_eq!(rules(&v, "named-spawn"), 0, "{v:?}");
    }

    #[test]
    fn builder_chain_must_name_before_spawn() {
        // the tightened contract: using Builder is not enough
        let t = "fn f() {\n    std::thread::Builder::new().spawn(|| {}).unwrap();\n}\n";
        let v = scan(&[src("src/foo.rs", t)]);
        assert_eq!(rules(&v, "named-spawn"), 1, "{v:?}");
        assert!(v.iter().any(|x| x.msg.contains("without .name")), "{v:?}");

        let t = "fn f() {\n    std::thread::Builder::new().name(\"w\".into()).spawn(|| {}).unwrap();\n}\n";
        let v = scan(&[src("src/foo.rs", t)]);
        assert_eq!(rules(&v, "named-spawn"), 0, "{v:?}");
    }

    #[test]
    fn unclassified_breakdown_field_is_flagged() {
        let m = "pub struct StepBreakdown {\n    /// class: additive\n    pub a_secs: f64,\n    /// no tag here\n    pub b_secs: f64,\n}\n";
        let v = scan(&[src("src/metrics/mod.rs", m)]);
        assert_eq!(rules(&v, "metrics-class"), 1, "{v:?}");
        assert!(v.iter().any(|x| x.msg.contains("b_secs")), "{v:?}");
    }

    fn divergent_fixture() -> &'static str {
        "use crate::comm::{CollectiveOp, Group};
pub fn f(g: &Group, rank: usize, data: Vec<f32>) {
    if rank == 0 {
        g.run(rank, CollectiveOp::Broadcast { root: 0, data }).unwrap();
    }
}
"
    }

    #[test]
    fn divergent_collective_is_flagged() {
        let v = scan(&[src("src/comm/fx1.rs", divergent_fixture())]);
        assert_eq!(rules(&v, "collective-divergence"), 1, "{v:?}");
        let f = v.iter().find(|x| x.rule == "collective-divergence").unwrap();
        assert!(
            f.to_string().starts_with("src/comm/fx1.rs:4: [collective-divergence]"),
            "{f}"
        );
        assert!(f.msg.contains("Broadcast"), "{f}");
    }

    #[test]
    fn rank_uniform_annotation_suppresses_divergence() {
        let t = "use crate::comm::{CollectiveOp, Group};
pub fn f(g: &Group, rank: usize, data: Vec<f32>) {
    if rank == 0 {
        // lint: rank-uniform every peer posts the matching recv in the same round
        g.run(rank, CollectiveOp::Broadcast { root: 0, data }).unwrap();
    }
}
";
        let v = scan(&[src("src/comm/fx1.rs", t)]);
        assert_eq!(rules(&v, "collective-divergence"), 0, "{v:?}");

        // an annotation without a reason suppresses nothing
        let t = "use crate::comm::{CollectiveOp, Group};
pub fn f(g: &Group, rank: usize, data: Vec<f32>) {
    if rank == 0 {
        // lint: rank-uniform
        g.run(rank, CollectiveOp::Broadcast { root: 0, data }).unwrap();
    }
}
";
        let v = scan(&[src("src/comm/fx1.rs", t)]);
        assert_eq!(rules(&v, "collective-divergence"), 1, "reason is mandatory: {v:?}");
    }

    #[test]
    fn sibling_arms_must_issue_identical_order() {
        let t = "use crate::comm::{CollectiveOp, Group};
pub fn f(g: &Group, is_leader: bool, r: usize, d: Vec<f32>) {
    if is_leader {
        g.run(r, CollectiveOp::Allreduce { data: d.clone(), red, dt }).unwrap();
        g.run(r, CollectiveOp::Allgather { data: d.clone(), dt }).unwrap();
    } else {
        g.run(r, CollectiveOp::Allgather { data: d.clone(), dt }).unwrap();
        g.run(r, CollectiveOp::Allreduce { data: d, red, dt }).unwrap();
    }
}
";
        let v = scan(&[src("src/comm/fx2.rs", t)]);
        assert_eq!(rules(&v, "collective-order"), 1, "{v:?}");
        let f = v.iter().find(|x| x.rule == "collective-order").unwrap();
        assert!(f.to_string().starts_with("src/comm/fx2.rs:3: [collective-order]"), "{f}");

        // identical sequences across both arms: clean
        let t = "use crate::comm::{CollectiveOp, Group};
pub fn f(g: &Group, is_leader: bool, r: usize, d: Vec<f32>) {
    if is_leader {
        g.run(r, CollectiveOp::Allreduce { data: d.clone(), red, dt }).unwrap();
    } else {
        g.run(r, CollectiveOp::Allreduce { data: d, red, dt }).unwrap();
    }
}
";
        let v = scan(&[src("src/comm/fx2.rs", t)]);
        assert_eq!(rules(&v, "collective-order") + rules(&v, "collective-divergence"), 0, "{v:?}");
    }

    #[test]
    fn inverted_lock_pair_is_flagged() {
        let t = "pub fn a(s: &S) {
    let g1 = s.alpha.lock().unwrap();
    let g2 = s.beta.lock().unwrap();
    drop(g2);
    drop(g1);
}
pub fn b(s: &S) {
    let h1 = s.beta.lock().unwrap();
    let h2 = s.alpha.lock().unwrap();
    drop(h2);
    drop(h1);
}
";
        let v = scan(&[src("src/comm/fx3.rs", t)]);
        assert_eq!(rules(&v, "lock-order"), 1, "{v:?}");
        let f = v.iter().find(|x| x.rule == "lock-order").unwrap();
        assert!(f.to_string().starts_with("src/comm/fx3.rs:9: [lock-order]"), "{f}");
        assert!(f.msg.contains("alpha") && f.msg.contains("beta"), "{f}");

        // same order in both functions: no inversion
        let t = "pub fn a(s: &S) {
    let g1 = s.alpha.lock().unwrap();
    let g2 = s.beta.lock().unwrap();
    drop(g2);
    drop(g1);
}
pub fn b(s: &S) {
    let h1 = s.alpha.lock().unwrap();
    let h2 = s.beta.lock().unwrap();
    drop(h2);
    drop(h1);
}
";
        let v = scan(&[src("src/comm/fx3.rs", t)]);
        assert_eq!(rules(&v, "lock-order"), 0, "{v:?}");
    }

    #[test]
    fn bare_unwrap_in_lane_worker_is_flagged() {
        let t = "pub fn f(n: usize) {
    let h = std::thread::Builder::new()
        .name(format!(\"lane-{n}\"))
        .spawn(move || {
            step().unwrap();
        })
        .expect(\"spawn lane\");
    h.join().ok();
}
";
        let v = scan(&[src("src/serve/fx4.rs", t)]);
        assert_eq!(rules(&v, "poison-path"), 1, "{v:?}");
        let f = v.iter().find(|x| x.rule == "poison-path").unwrap();
        assert!(f.to_string().starts_with("src/serve/fx4.rs:5: [poison-path]"), "{f}");

        // routing through the poison protocol makes the same shape clean
        let t = "pub fn f(n: usize, g: Arc<Group>) {
    let h = std::thread::Builder::new()
        .name(format!(\"lane-{n}\"))
        .spawn(move || {
            let _guard = PoisonGuard::new(&g);
            step().unwrap();
        })
        .expect(\"spawn lane\");
    h.join().ok();
}
";
        let v = scan(&[src("src/serve/fx4.rs", t)]);
        assert_eq!(rules(&v, "poison-path"), 0, "{v:?}");

        // a thread whose name is not rank/lane-scoped is out of scope
        let t = "pub fn f() {
    std::thread::Builder::new()
        .name(\"background-io\".into())
        .spawn(|| { step().unwrap(); })
        .expect(\"spawn io\");
}
";
        let v = scan(&[src("src/serve/fx4.rs", t)]);
        assert_eq!(rules(&v, "poison-path"), 0, "{v:?}");
    }

    #[test]
    fn lint_rules_are_registered_checks() {
        // the stable LINT tags, verbatim: this doubles as the coverage
        // assertion for the lint's own registry entries
        let tags = [
            "lint invariant violated [check-strings]",
            "lint invariant violated [check-coverage]",
            "lint invariant violated [named-spawn]",
            "lint invariant violated [lock-discipline]",
            "lint invariant violated [metrics-class]",
            "lint invariant violated [collective-divergence]",
            "lint invariant violated [collective-order]",
            "lint invariant violated [lock-order]",
            "lint invariant violated [poison-path]",
        ];
        assert_eq!(RULES.len(), tags.len());
        for (rule, tag) in RULES.iter().zip(tags) {
            assert!(checks::is_registered(checks::LINT, rule), "{rule}");
            assert_eq!(checks::tag(checks::LINT, rule), tag);
        }
    }

    #[test]
    fn json_and_sarif_round_trip() {
        let v = scan(&[src("src/comm/fx1.rs", divergent_fixture())]);
        let div: Vec<&Violation> =
            v.iter().filter(|x| x.rule == "collective-divergence").collect();
        assert_eq!(div.len(), 1);

        let j = crate::util::json::Json::parse(&to_json(&v)).expect("to_json parses");
        let arr = j.req("violations").as_arr().unwrap();
        assert_eq!(arr.len(), v.len());
        let jd = arr
            .iter()
            .find(|x| x.req("rule").as_str() == Some("collective-divergence"))
            .unwrap();
        assert_eq!(jd.req("file").as_str(), Some("src/comm/fx1.rs"));
        assert_eq!(jd.req("line").as_usize(), Some(4));
        assert_eq!(jd.req("msg").as_str(), Some(div[0].msg.as_str()));

        let s = crate::util::json::Json::parse(&to_sarif(&v, "rust/")).expect("sarif parses");
        assert_eq!(s.req("version").as_str(), Some("2.1.0"));
        let run = &s.req("runs").as_arr().unwrap()[0];
        assert_eq!(
            run.req("tool").req("driver").req("name").as_str(),
            Some("optimus-lint")
        );
        let results = run.req("results").as_arr().unwrap();
        assert_eq!(results.len(), v.len());
        let rd = results
            .iter()
            .find(|x| x.req("ruleId").as_str() == Some("collective-divergence"))
            .unwrap();
        assert_eq!(rd.req("message").req("text").as_str(), Some(div[0].msg.as_str()));
        let loc = &rd.req("locations").as_arr().unwrap()[0];
        let phys = loc.req("physicalLocation");
        assert_eq!(
            phys.req("artifactLocation").req("uri").as_str(),
            Some("rust/src/comm/fx1.rs")
        );
        assert_eq!(phys.req("region").req("startLine").as_usize(), Some(4));
    }

    #[test]
    fn rank_uniform_annotation_budget() {
        // acceptance: the real repo carries at most 10 rank-uniform
        // annotations, every one with a reason
        let files = collect(&default_root()).unwrap();
        let mut n = 0usize;
        for f in &files {
            if f.is_test_file() {
                continue;
            }
            for a in &lexer::lex(&f.text).annos {
                if a.rule == "rank-uniform" {
                    n += 1;
                    assert!(
                        !a.reason.is_empty(),
                        "{}:{}: rank-uniform annotation without a reason",
                        f.rel,
                        a.line
                    );
                }
            }
        }
        assert!(
            (1..=10).contains(&n),
            "expected 1..=10 rank-uniform annotations, found {n}"
        );
    }

    #[test]
    fn the_repo_lints_clean() {
        // the acceptance gate: `optimus lint` over this very checkout
        let v = run(&default_root()).unwrap();
        let report: Vec<String> = v.iter().map(|x| x.to_string()).collect();
        assert!(v.is_empty(), "repo lint violations:\n{}", report.join("\n"));
    }
}
