//! Host tensors: the plain-data currency between rank threads and the
//! PJRT executor threads (xla::Literal is !Send, so it never leaves the
//! executor).
//!
//! Storage is `Arc`-backed (copy-on-write): cloning a `Tensor` — e.g. to
//! re-submit the same parameter vector to [`crate::runtime::Engine::exec`]
//! every step — bumps a refcount instead of copying megabytes of floats.
//! Mutation goes through [`Tensor::as_f32_mut`], which uses
//! `Arc::make_mut`: in-place when this handle is the sole owner (the
//! steady state — the engine drops its clones before `exec` returns),
//! a deep copy only when another live handle still shares the buffer.
//! See DESIGN.md §3 for the full ownership rules.

use crate::util::{bf16s_to_f32s, f32s_to_bf16s};
use crate::Result;
use anyhow::anyhow;
use std::sync::Arc;

/// Parameter/activation element type of a training plan. `I32` tensors
/// (token ids, routing indices) exist regardless and are not a plan knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Dtype {
    #[default]
    F32,
    Bf16,
}

impl Dtype {
    /// Wire/storage width in bytes per element.
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 => 2,
        }
    }

    /// Flag spelling (`--dtype {f32,bf16}`), also the fingerprint suffix.
    pub fn as_str(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
        }
    }

    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "bf16" => Ok(Dtype::Bf16),
            other => Err(anyhow!("unknown dtype `{other}` — expected `f32` or `bf16`")),
        }
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { data: Arc<Vec<f32>>, shape: Vec<usize> },
    /// bf16 storage: the high 16 bits of the f32 layout, round-to-nearest
    /// even on encode. Same Arc-backed COW discipline as `F32`.
    Bf16 { data: Arc<Vec<u16>>, shape: Vec<usize> },
    I32 { data: Arc<Vec<i32>>, shape: Vec<usize> },
}

impl Default for Tensor {
    /// Empty f32 tensor (placeholder for `TrainReport::default()` et al).
    fn default() -> Tensor {
        Tensor::F32 { data: Arc::new(Vec::new()), shape: vec![0] }
    }
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32 { data: Arc::new(data), shape }
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::I32 { data: Arc::new(data), shape }
    }

    /// F32 tensor over an existing shared buffer — an `Arc` bump, never a
    /// copy (the zero-copy snapshot payloads of [`crate::ckpt`]).
    pub fn f32_shared(data: Arc<Vec<f32>>) -> Tensor {
        let n = data.len();
        Tensor::F32 { data, shape: vec![n] }
    }

    /// bf16 tensor from pre-encoded storage bits.
    pub fn bf16(data: Vec<u16>, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::Bf16 { data: Arc::new(data), shape }
    }

    /// Encode f32 values into a tensor of the requested dtype
    /// (round-to-nearest-even for `Bf16`, identity for `F32`).
    pub fn from_f32(dtype: Dtype, data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        match dtype {
            Dtype::F32 => Tensor::f32(data, shape),
            Dtype::Bf16 => Tensor::bf16(f32s_to_bf16s(&data), shape),
        }
    }

    /// Element dtype of the value payload (`I32` index tensors report
    /// `F32` — index data is never a plan dtype).
    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::Bf16 { .. } => Dtype::Bf16,
            _ => Dtype::F32,
        }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::F32 { data: Arc::new(vec![0.0; n]), shape }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { data: Arc::new(vec![v]), shape: vec![] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. }
            | Tensor::Bf16 { shape, .. }
            | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::Bf16 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when `self` and `other` share the same underlying buffer —
    /// i.e. no data was copied between them (zero-copy witness).
    pub fn ptr_eq(&self, other: &Tensor) -> bool {
        match (self, other) {
            (Tensor::F32 { data: a, .. }, Tensor::F32 { data: b, .. }) => Arc::ptr_eq(a, b),
            (Tensor::Bf16 { data: a, .. }, Tensor::Bf16 { data: b, .. }) => Arc::ptr_eq(a, b),
            (Tensor::I32 { data: a, .. }, Tensor::I32 { data: b, .. }) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// Address of the first element — stable across `Arc` clones, changes
    /// only when copy-on-write actually copies.
    pub fn data_ptr(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.as_ptr() as usize,
            Tensor::Bf16 { data, .. } => data.as_ptr() as usize,
            Tensor::I32 { data, .. } => data.as_ptr() as usize,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data.as_slice()),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Copy-on-write mutable access: in-place when uniquely owned, deep
    /// copy when clones of this tensor are still alive elsewhere.
    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(Arc::make_mut(data)),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Raw bf16 storage bits.
    pub fn as_bf16(&self) -> Result<&[u16]> {
        match self {
            Tensor::Bf16 { data, .. } => Ok(data.as_slice()),
            _ => Err(anyhow!("tensor is not bf16")),
        }
    }

    /// Copy-on-write mutable access to bf16 storage (same COW discipline
    /// as [`Tensor::as_f32_mut`]).
    pub fn as_bf16_mut(&mut self) -> Result<&mut Vec<u16>> {
        match self {
            Tensor::Bf16 { data, .. } => Ok(Arc::make_mut(data)),
            _ => Err(anyhow!("tensor is not bf16")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data.as_slice()),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Copy-on-write mutable access to i32 storage (same COW discipline
    /// as [`Tensor::as_f32_mut`]) — what the serving KV pages use to
    /// append tokens in place while holding free-listed `Arc` blocks.
    pub fn as_i32_mut(&mut self) -> Result<&mut Vec<i32>> {
        match self {
            Tensor::I32 { data, .. } => Ok(Arc::make_mut(data)),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Take the values as f32: by move when a uniquely owned f32 buffer,
    /// by copy otherwise; bf16 storage decodes (exact).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => {
                Ok(Arc::try_unwrap(data).unwrap_or_else(|a| a.as_ref().clone()))
            }
            Tensor::Bf16 { data, .. } => Ok(bf16s_to_f32s(&data)),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Owned f32 copy of the values (serialization boundaries like
    /// [`crate::ckpt::Checkpoint`]; bf16 decodes exactly).
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        match self {
            Tensor::Bf16 { data, .. } => Ok(bf16s_to_f32s(data)),
            _ => Ok(self.as_f32()?.to_vec()),
        }
    }

    /// First element as f32 (scalar outputs like losses).
    pub fn scalar(&self) -> Result<f32> {
        match self {
            Tensor::F32 { data, .. } => data
                .first()
                .copied()
                .ok_or_else(|| anyhow!("empty tensor")),
            Tensor::Bf16 { data, .. } => data
                .first()
                .map(|b| crate::util::bf16_to_f32(*b))
                .ok_or_else(|| anyhow!("empty tensor")),
            Tensor::I32 { data, .. } => data
                .first()
                .map(|v| *v as f32)
                .ok_or_else(|| anyhow!("empty tensor")),
        }
    }
}

pub(super) fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64>;
    let lit = match t {
        Tensor::F32 { data, shape } => {
            dims = shape.iter().map(|d| *d as i64).collect();
            xla::Literal::vec1(data.as_slice())
        }
        // the HLO artifacts are lowered in f32; bf16 host tensors decode
        // (exactly) at the executor boundary
        Tensor::Bf16 { data, shape } => {
            dims = shape.iter().map(|d| *d as i64).collect();
            xla::Literal::vec1(bf16s_to_f32s(data).as_slice())
        }
        Tensor::I32 { data, shape } => {
            dims = shape.iter().map(|d| *d as i64).collect();
            xla::Literal::vec1(data.as_slice())
        }
    };
    lit.reshape(&dims).map_err(|e| anyhow!("reshape literal: {e}"))
}

pub(super) fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(Tensor::F32 {
            data: Arc::new(lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?),
            shape: dims,
        }),
        xla::ElementType::S32 => Ok(Tensor::I32 {
            data: Arc::new(lit.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?),
            shape: dims,
        }),
        // predicates / other ints: fetch via conversion
        other => {
            let conv = lit
                .convert(xla::PrimitiveType::F32)
                .map_err(|e| anyhow!("convert {other:?}: {e}"))?;
            Ok(Tensor::F32 {
                data: Arc::new(conv.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?),
                shape: dims,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_and_accessors() {
        let t = Tensor::f32(vec![1.0, 2.0], vec![2]);
        assert_eq!(t.scalar().unwrap(), 1.0);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(t.as_i32().is_err());
        let i = Tensor::i32(vec![3], vec![1]);
        assert_eq!(i.scalar().unwrap(), 3.0);
    }

    #[test]
    fn clone_shares_storage() {
        let t = Tensor::f32(vec![0.5; 1024], vec![1024]);
        let c = t.clone();
        assert!(t.ptr_eq(&c), "clone must be an Arc bump, not a copy");
        assert_eq!(t.data_ptr(), c.data_ptr());
        let i = Tensor::i32(vec![1, 2], vec![2]);
        assert!(!t.ptr_eq(&i));
    }

    #[test]
    fn cow_mutation_in_place_when_unique() {
        let mut t = Tensor::f32(vec![1.0; 64], vec![64]);
        let before = t.data_ptr();
        t.as_f32_mut().unwrap()[0] = 9.0;
        assert_eq!(t.data_ptr(), before, "sole owner must mutate in place");
    }

    #[test]
    fn cow_mutation_copies_when_shared() {
        let mut t = Tensor::f32(vec![1.0; 64], vec![64]);
        let snapshot = t.clone();
        t.as_f32_mut().unwrap()[0] = 9.0;
        assert!(!t.ptr_eq(&snapshot), "shared buffer must copy on write");
        assert_eq!(snapshot.as_f32().unwrap()[0], 1.0, "snapshot unchanged");
        assert_eq!(t.as_f32().unwrap()[0], 9.0);
    }

    #[test]
    fn into_f32_moves_when_unique() {
        let t = Tensor::f32(vec![3.0; 8], vec![8]);
        let ptr = t.data_ptr();
        let v = t.into_f32().unwrap();
        assert_eq!(v.as_ptr() as usize, ptr, "unique owner must move, not copy");
    }

    #[test]
    fn bf16_tensor_encodes_decodes_and_cows() {
        let t = Tensor::from_f32(Dtype::Bf16, vec![1.0, -2.5, 0.0, 3.14159], vec![4]);
        assert_eq!(t.dtype(), Dtype::Bf16);
        assert_eq!(t.len(), 4);
        let back = t.to_f32_vec().unwrap();
        // exactly representable values round-trip bitwise
        assert_eq!(back[0], 1.0);
        assert_eq!(back[1], -2.5);
        assert_eq!(back[2], 0.0);
        assert!((back[3] - 3.14159).abs() / 3.14159 < 0.01);
        assert_eq!(t.scalar().unwrap(), 1.0);
        // clone is an Arc bump; COW copies only when shared
        let c = t.clone();
        assert!(t.ptr_eq(&c));
        let mut m = t.clone();
        m.as_bf16_mut().unwrap()[0] = crate::util::f32_to_bf16(9.0);
        assert!(!m.ptr_eq(&t), "shared bf16 buffer must copy on write");
        assert_eq!(m.scalar().unwrap(), 9.0);
        assert_eq!(t.scalar().unwrap(), 1.0);
        // wrong-dtype access is a hard error
        assert!(t.as_f32().is_err());
        assert!(Tensor::f32(vec![1.0], vec![1]).as_bf16().is_err());
    }

    #[test]
    fn from_f32_identity_for_f32_dtype() {
        let t = Tensor::from_f32(Dtype::F32, vec![0.1, 0.2], vec![2]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.as_f32().unwrap(), &[0.1, 0.2]);
        assert_eq!(Dtype::F32.bytes(), 4);
        assert_eq!(Dtype::Bf16.bytes(), 2);
        assert_eq!(Dtype::parse("bf16").unwrap(), Dtype::Bf16);
        assert!(Dtype::parse("fp8").is_err());
    }
}
