//! Seeded randomized property-test runner (`proptest` is unavailable
//! offline). No shrinking — failures report the seed so a case can be
//! replayed deterministically:
//!
//! ```ignore
//! run_cases(200, |g| {
//!     let n = g.range(1, 64);
//!     let xs = g.vec_f32(n, -1.0, 1.0);
//!     prop_assert(xs.len() == n, g, "len mismatch");
//! });
//! ```

use super::prng::Prng;

pub struct Gen {
    pub rng: Prng,
    pub seed: u64,
}

impl Gen {
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn below(&mut self, n: usize) -> usize {
        self.rng.below(n)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| self.range(lo, hi)).collect()
    }
}

/// Run `cases` property cases with deterministic per-case seeds. Panics
/// (with the seed) on the first failing case.
pub fn run_cases<F: FnMut(&mut Gen)>(cases: usize, mut f: F) {
    let base = std::env::var("OPTIMUS_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Prng::new(seed), seed };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut g)
        }));
        if let Err(e) = r {
            eprintln!(
                "property failed at case {i} (replay with OPTIMUS_PROPTEST_SEED={base} case seed {seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        run_cases(50, |g| {
            let n = g.range(1, 10);
            assert!((1..10).contains(&n));
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic]
    fn reports_failures() {
        run_cases(10, |g| {
            assert!(g.range(0, 100) < 50, "eventually fails");
        });
    }
}
