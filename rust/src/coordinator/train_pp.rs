//! Pipeline-parallel engine: microbatch schedules over stage artifacts.
//!
//! Stage ranks execute the schedule's op list; activations/cotangents move
//! over point-to-point channels. The backward artifacts recompute their
//! stage forward from the stashed stage *input* (tokens for stage 0,
//! received activations otherwise) — i.e. selective activation
//! checkpointing is the engine's native execution mode (paper §1, used
//! for Mula-100B/220B).
//!
//! Gradients accumulate over microbatches and are averaged before the
//! sharded optimizer step (per-stage DP group); the gradient-norm domain
//! is the *world* group, so clipping sees the true global norm exactly as
//! the DP engine does. Stage ownership comes from the
//! [`ParallelismPlan`](super::ParallelismPlan)'s `stage_specs`;
//! scaffolding lives in the shared [`harness`](super::harness). The stage
//! parameter vector is an `Arc`-backed [`Tensor`], so handing it to every
//! microbatch execution is a refcount bump instead of the seed's per-op
//! full-stage copy.

use super::clip_now;
use super::harness::{
    AuxParams, CkptView, LossDomain, RankCtx, RankFinish, RankTrainer, ReportParts, StepOutcome,
};
use super::pipeline::{seq_id, PipeOp};
use super::plan::{stage_specs, ParallelismPlan};
use super::TrainReport;
use crate::ckpt::LocalMap;
use crate::comm::P2p;
use crate::config::{ModelManifest, ParamSpec};
use crate::metrics::{Scoped, StepBreakdown};
use crate::optim::sharded::{plan_segments, ShardedOptimizer};
use crate::runtime::{Dtype, Tensor};
use crate::util::bf16_round;
use crate::Result;
use std::sync::Arc;

fn stage_len(specs: &[ParamSpec]) -> usize {
    specs.iter().map(|s| s.numel).sum()
}

/// Global offset a stage spec was cut from (rides in the name as `@goff`).
fn spec_goff(s: &ParamSpec) -> usize {
    s.name
        .rsplit('@')
        .next()
        .unwrap()
        .parse()
        .expect("stage spec global offset")
}

fn extract_stage(global: &[f32], specs: &[ParamSpec]) -> Vec<f32> {
    let mut out = Vec::with_capacity(stage_len(specs));
    for s in specs {
        let goff = spec_goff(s);
        out.extend_from_slice(&global[goff..goff + s.numel]);
    }
    out
}

fn scatter_stage(local: &[f32], specs: &[ParamSpec], global: &mut [f32]) {
    let mut off = 0usize;
    for s in specs {
        let goff = spec_goff(s);
        global[goff..goff + s.numel].copy_from_slice(&local[off..off + s.numel]);
        off += s.numel;
    }
}

/// The stage's checkpoint map: one local→global run per stage spec.
fn stage_map(specs: &[ParamSpec]) -> Result<LocalMap> {
    let mut copies = Vec::with_capacity(specs.len());
    let mut off = 0usize;
    for s in specs {
        copies.push((spec_goff(s), off, s.numel));
        off += s.numel;
    }
    LocalMap::from_copies(&copies)
}

pub(super) struct PpTrainer {
    params: Tensor,
    /// stage-local→global checkpoint map (one run per stage spec)
    map: LocalMap,
    specs: Vec<ParamSpec>,
    my_len: usize,
    opt: ShardedOptimizer,
    p2p: Arc<P2p>,
    stage: usize,
    last: bool,
    dp_coord: usize,
    prev: Option<usize>,
    next: Option<usize>,
    ops: Vec<PipeOp>,
    art_fwd: Option<std::path::PathBuf>,
    art_fwdbwd: std::path::PathBuf,
    key_prefix: String,
    loss_dom: Option<LossDomain>,
}

impl RankTrainer for PpTrainer {
    const LABEL: &'static str = "pp";
    type Shared = P2p;

    fn shared(_mm: &ModelManifest, plan: &ParallelismPlan) -> Result<Arc<P2p>> {
        // tag 0 = fwd activations, 1 = cotangents
        Ok(P2p::new(plan.topo.world(), 2))
    }

    fn poison_shared(shared: &P2p) {
        shared.poison();
    }

    fn setup(ctx: &RankCtx, shared: &Arc<P2p>, global_params: Vec<f32>) -> Result<PpTrainer> {
        let rank = ctx.rank;
        let mm = &ctx.mm;
        let pp = ctx.plan.topo.pp;
        let c = ctx.mesh.coord(rank);
        let stage = c.pp;
        let last = stage == pp - 1;
        let specs = stage_specs(mm, pp, stage);
        let my_len = stage_len(&specs);
        let (dp_group, dp_rank) = ctx.mesh.dp_group(rank);
        let (dpep_group, dpep_rank) = ctx.mesh.dpep_group(rank);
        let (prev, next) = ctx.mesh.pp_neighbours(rank);

        let params = extract_stage(&global_params, &specs);
        drop(global_params);

        let sp = &ctx.plan.stages[stage];
        debug_assert_eq!(sp.seg.ne_len, my_len);
        let segs = plan_segments(
            ctx.plan.mode,
            sp.seg,
            dp_group,
            dp_rank,
            dpep_group,
            dpep_rank,
            1,
        );
        let opt = ctx.sharded_optimizer(segs, &format!("pp{rank}"));

        let art_fwd = if last {
            None
        } else {
            Some(mm.artifact_path(&format!("pp{pp}_stage{stage}_fwd"))?)
        };
        let art_fwdbwd = mm.artifact_path(&format!("pp{pp}_stage{stage}_fwdbwd"))?;

        Ok(PpTrainer {
            // resident precision follows the plan dtype (one RNE round
            // here for bf16; the optimizer's f32 masters carry state)
            params: Tensor::from_f32(ctx.plan.dtype, params, vec![my_len]),
            map: stage_map(&specs)?,
            specs,
            my_len,
            opt,
            p2p: Arc::clone(shared),
            stage,
            last,
            dp_coord: c.dp,
            prev,
            next,
            ops: ctx.plan.schedule.ops(stage, pp, ctx.plan.micro_batches),
            art_fwd,
            art_fwdbwd,
            key_prefix: format!("{}:pp{pp}s{stage}", mm.name),
            loss_dom: last.then(|| LossDomain {
                group: Arc::clone(dp_group),
                group_rank: dp_rank,
                record: c.dp == 0,
            }),
        })
    }

    fn step(
        &mut self,
        ctx: &RankCtx,
        step: usize,
        breakdown: &mut StepBreakdown,
    ) -> Result<StepOutcome> {
        let rank = ctx.rank;
        let h = &ctx.mm.hyper;
        let (b, s) = (h.batch, h.seq);
        let micro = ctx.plan.micro_batches;
        let p2p = &self.p2p;
        let exec = |key: &str, path: &std::path::Path, inputs: Vec<Tensor>| {
            ctx.engine.exec(
                &format!("{}:{key}", self.key_prefix),
                path.to_path_buf(),
                inputs,
            )
        };

        // in bf16 mode activation/cotangent payloads value-round through
        // bf16 before every p2p hop (the channels move owned Vec<f32>
        // frames, so the rounding models the paper's bf16 stage wires;
        // Group collectives are where genuine 2-byte frames travel)
        let round = |mut v: Vec<f32>| {
            if ctx.plan.dtype == Dtype::Bf16 {
                for x in v.iter_mut() {
                    *x = bf16_round(*x);
                }
            }
            v
        };

        let mut grads = vec![0.0f32; self.my_len];
        let mut step_loss = 0.0f32;
        // stashed stage inputs per microbatch (SAC)
        let mut stash: Vec<Option<Tensor>> = vec![None; micro];

        for op in &self.ops {
            match *op {
                PipeOp::Fwd { mb, .. } => {
                    // only the token-consuming stages fetch: stage 0
                    // (inputs) and the last stage (targets); middle
                    // stages work purely on received activations
                    if self.stage == 0 {
                        let tokens_t = ctx.fetch_tokens(step, self.dp_coord, mb, breakdown)?;
                        let outs = {
                            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                            exec("fwd", self.art_fwd.as_ref().unwrap(), vec![
                                self.params.clone(),
                                tokens_t.clone(),
                            ])?
                        };
                        let hout = outs[0].as_f32()?.to_vec();
                        stash[mb] = Some(tokens_t);
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.send(rank, self.next.unwrap(), 0, seq_id(step, mb), round(hout));
                    } else if self.last {
                        // targets first (prefetched), then recv + fused
                        // fwdbwd + send cotangent immediately
                        let tokens_t = ctx.fetch_tokens(step, self.dp_coord, mb, breakdown)?;
                        let hin = {
                            let _t = Scoped::new(&mut breakdown.comm_secs);
                            p2p.recv(self.prev.unwrap(), rank, 0, seq_id(step, mb))
                        };
                        let outs = {
                            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                            exec("fwdbwd", &self.art_fwdbwd, vec![
                                self.params.clone(),
                                Tensor::f32(hin, vec![b, s, h.hidden]),
                                tokens_t,
                            ])?
                        };
                        let loss = outs[0].scalar()?;
                        if !loss.is_finite() {
                            return Err(ctx.non_finite(step));
                        }
                        step_loss += loss;
                        let dx = outs[2].as_f32()?.to_vec();
                        for (g, d) in grads.iter_mut().zip(outs[3].as_f32()?) {
                            *g += d;
                        }
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.send(rank, self.prev.unwrap(), 1, seq_id(step, mb), round(dx));
                    } else {
                        let hin = {
                            let _t = Scoped::new(&mut breakdown.comm_secs);
                            p2p.recv(self.prev.unwrap(), rank, 0, seq_id(step, mb))
                        };
                        let hin_t = Tensor::f32(hin, vec![b, s, h.hidden]);
                        let outs = {
                            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                            exec("fwd", self.art_fwd.as_ref().unwrap(), vec![
                                self.params.clone(),
                                hin_t.clone(),
                            ])?
                        };
                        stash[mb] = Some(hin_t);
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.send(
                            rank,
                            self.next.unwrap(),
                            0,
                            seq_id(step, mb),
                            round(outs[0].as_f32()?.to_vec()),
                        );
                    }
                }
                PipeOp::Bwd { mb, .. } => {
                    if self.last {
                        continue; // fused into Fwd above
                    }
                    let d_out = {
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.recv(self.next.unwrap(), rank, 1, seq_id(step, mb))
                    };
                    let d_out_t = Tensor::f32(d_out, vec![b, s, h.hidden]);
                    let input = stash[mb].take().expect("bwd before fwd");
                    let outs = {
                        let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                        exec("fwdbwd", &self.art_fwdbwd, vec![
                            self.params.clone(),
                            input,
                            d_out_t,
                        ])?
                    };
                    if self.stage == 0 {
                        for (g, d) in grads.iter_mut().zip(outs[0].as_f32()?) {
                            *g += d;
                        }
                    } else {
                        let dx = outs[0].as_f32()?.to_vec();
                        for (g, d) in grads.iter_mut().zip(outs[1].as_f32()?) {
                            *g += d;
                        }
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        p2p.send(rank, self.prev.unwrap(), 1, seq_id(step, mb), round(dx));
                    }
                }
            }
        }

        // average gradient over microbatches
        let inv = 1.0 / micro as f32;
        for g in grads.iter_mut() {
            *g *= inv;
        }
        let lr = ctx.spec.run.lr_at(step) as f32;
        let gn = self
            .opt
            .step_tensor(&mut self.params, &grads, lr, clip_now(&ctx.spec.run, step))?;
        Ok(StepOutcome { loss: step_loss / micro as f32, grad_norm: gn })
    }

    fn params_mut(&mut self) -> Result<&mut [f32]> {
        Ok(self.params.as_f32_mut()?.as_mut_slice())
    }

    fn ckpt_view(&mut self) -> CkptView<'_> {
        CkptView { params: &self.params, map: &self.map, opt: &mut self.opt }
    }

    fn loss_domain(&self) -> Option<&LossDomain> {
        self.loss_dom.as_ref()
    }

    fn finish(self, ctx: &RankCtx) -> Result<RankFinish> {
        if self.dp_coord != 0 {
            return Ok(RankFinish::None);
        }
        if self.last {
            // seed the global vector with this stage's segment; the other
            // stages' Aux payloads are scattered in by merge_aux
            let mut final_params = vec![0.0f32; ctx.mm.param_count];
            scatter_stage(&self.params.to_f32_vec()?, &self.specs, &mut final_params);
            return Ok(RankFinish::Report(Box::new(ReportParts {
                final_params: Tensor::f32(final_params, vec![ctx.mm.param_count]),
                opt_state_bytes: self.opt.state_bytes(),
                optimizer_update_secs: self.opt.update_secs,
                optimizer_comm_secs: self.opt.comm_secs,
                optimizer_overlap_secs: self.opt.overlap_secs,
                optimizer_lane_ops: self.opt.lane_ops(),
            })));
        }
        Ok(RankFinish::Aux(AuxParams { tag: self.stage, params: self.params.into_f32()? }))
    }

    fn merge_aux(
        mm: &ModelManifest,
        plan: &ParallelismPlan,
        report: &mut TrainReport,
        aux: Vec<AuxParams>,
    ) -> Result<()> {
        // assemble the full parameter vector from every stage's segment
        let global = report.final_params.as_f32_mut()?;
        for a in aux {
            let specs = stage_specs(mm, plan.topo.pp, a.tag);
            scatter_stage(&a.params, &specs, global);
        }
        Ok(())
    }
}
