//! The data pipeline end to end (paper §4 + DESIGN.md §7): tokenize ->
//! shuffle -> shard offline, then the deterministic streaming read path
//! — epoch-aware blockwise shuffle, budget-enforced token stream, and
//! the background prefetcher — over mmap'd contiguous per-rank reads.
//!
//! Run: `cargo run --release --example data_pipeline`

use optimus::data::{
    corpus, preprocess, BatchPlan, Dataset, Prefetcher, TokenCursor, TokenStream, Tokenizer,
};
use std::sync::Arc;

fn main() -> optimus::Result<()> {
    let dir = std::env::temp_dir().join("optimus-datapipe-demo");
    let _ = std::fs::remove_dir_all(&dir);

    // "a typical hugging face dataset consists of data files"
    let files = corpus::data_files(7, 8, 32);
    let tok = Tokenizer::new();
    println!("sample doc: {:?}...", &files[0][0][..60.min(files[0][0].len())]);
    println!("vocab size: {}", tok.vocab_size());

    let t0 = std::time::Instant::now();
    let st = preprocess::preprocess(&files, 128, 99, &dir, 512)?;
    println!(
        "preprocess: {} files -> {} tokens -> {} instances -> {} shards in {:?}",
        st.n_files, st.total_tokens, st.n_instances, st.n_shards, t0.elapsed()
    );

    // mmap'd lazy loading
    let ds = Arc::new(Dataset::open(&dir)?);
    println!("dataset: {} instances of context {}", ds.len(), ds.context);

    // the shuffled, budget-enforced token stream: (data_seed, dataset) →
    // one deterministic instance order, reshuffled blockwise each epoch
    let plan = BatchPlan { dp: 4, micro_batch: 8, micro_batches: 2 };
    let steps = 50usize;
    let cursor = TokenCursor::fresh(plan.instances_per_step() as u64);
    let budget = steps as u64 * cursor.per_step;
    let stream = Arc::new(TokenStream::new(Arc::clone(&ds), 42, budget));
    println!(
        "stream: budget {budget} instances = {:.2} epochs (reshuffled per epoch)",
        budget as f64 / stream.epoch_len() as f64
    );

    // synchronous reads, all ranks
    let t1 = std::time::Instant::now();
    let mut tokens_read = 0usize;
    for step in 0..steps {
        for rank in 0..plan.dp {
            for micro in 0..plan.micro_batches {
                let pos = cursor.at_step(step) + plan.offset(rank, micro) as u64;
                let b = stream.batch_i32(pos, plan.micro_batch, 127)?;
                tokens_read += b.len();
            }
        }
    }
    let dt = t1.elapsed();
    println!(
        "sync: read {} tokens in {:?} ({:.1} M tokens/s) — contiguous within shuffle blocks",
        tokens_read,
        dt,
        tokens_read as f64 / dt.as_secs_f64() / 1e6
    );

    // the same reads through one rank's background prefetcher: the pop
    // is the only stall, assembly hides on the producer thread
    let mut pf = Prefetcher::spawn(
        Arc::clone(&stream), cursor, plan, 0, plan.micro_batch, 127, steps, (0, 0),
    );
    let mut wait = 0.0;
    let t2 = std::time::Instant::now();
    let mut prefetched = 0usize;
    for step in 0..steps {
        for micro in 0..plan.micro_batches {
            prefetched += pf.fetch(step, 0, micro, &mut wait).unwrap()?.len();
        }
    }
    println!(
        "prefetch (rank 0): {} tokens in {:?}, pop stall {:.4}s, hidden assembly {:.4}s",
        prefetched,
        t2.elapsed(),
        wait,
        pf.busy_secs()
    );

    // the budget is a hard wall — no silent epoch wrap
    let err = stream.batch_i32(budget, 1, 127).unwrap_err();
    println!("past-budget read correctly refused: {err}");

    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
