"""L2 model tests: shapes, gradient parity across MoE impls, stage/EP
decomposition equivalence against the fused forward."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, model
from compile.kernels import fast_moe


TINY = configs.MULA_TINY
TINY_DENSE = configs.MULA_TINY_DENSE


def batch_tokens(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size,
                        size=(cfg.batch, cfg.seq + 1)).astype(np.int32)


def test_param_count_matches_config():
    for cfg in [TINY, TINY_DENSE, configs.MULA_MINI, configs.MULA_100M]:
        assert model.param_count(cfg) == cfg.param_count(), cfg.name


def test_paper_table1_param_counts():
    """Table 1: our layout reproduces the paper's total/active counts."""
    expect_total = {"mula-1b": 1.3e9, "mula-7b-a1b": 6.9e9,
                    "mula-20b-a2b": 20e9, "mula-100b-a7b": 100e9,
                    "mula-220b-a10b": 220e9}
    expect_active = {"mula-1b": 1.3e9, "mula-7b-a1b": 1.3e9,
                     "mula-20b-a2b": 2.4e9, "mula-100b-a7b": 7.6e9,
                     "mula-220b-a10b": 10e9}
    for cfg in configs.PAPER:
        tot, act = cfg.param_count(), cfg.active_param_count()
        assert abs(tot - expect_total[cfg.name]) / expect_total[cfg.name] < 0.12, \
            (cfg.name, tot)
        assert abs(act - expect_active[cfg.name]) / expect_active[cfg.name] < 0.15, \
            (cfg.name, act)


@pytest.mark.parametrize("cfg", [TINY, TINY_DENSE], ids=lambda c: c.name)
def test_forward_shapes_and_finiteness(cfg):
    flat = jnp.asarray(model.init_params(cfg, 1))
    toks = jnp.asarray(batch_tokens(cfg))
    lm, aux, logits = model.forward(cfg, flat, toks)
    assert logits.shape == (cfg.batch, cfg.seq, cfg.vocab_size)
    assert np.isfinite(float(lm)) and float(lm) > 0
    # random init ≈ uniform predictions: loss ≈ ln(V)
    assert abs(float(lm) - np.log(cfg.vocab_size)) < 0.5
    if cfg.is_moe:
        assert np.isfinite(float(aux))


def test_fsmoe_and_naive_paths_agree():
    """Fused fwd+bwd through the Pallas FSMOE path equals the HF-style
    naive path — the two sides of Table 3 compute the same function."""
    cfg = TINY
    flat = jnp.asarray(model.init_params(cfg, 2))
    toks = jnp.asarray(batch_tokens(cfg, 3))
    f_fast = model.make_train_step(cfg, "fsmoe")
    f_naive = model.make_train_step(cfg, "naive")
    tf, lmf, auxf, gf = f_fast(flat, toks)
    tn, lmn, auxn, gn = f_naive(flat, toks)
    np.testing.assert_allclose(float(tf), float(tn), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gn),
                               rtol=3e-4, atol=3e-6)


def test_train_step_decreases_loss():
    """A few SGD steps on a repeated batch must reduce the loss (sanity of
    the full fwd+bwd artifact)."""
    cfg = TINY
    flat = jnp.asarray(model.init_params(cfg, 4))
    toks = jnp.asarray(batch_tokens(cfg, 5))
    step = jax.jit(model.make_train_step(cfg, "fsmoe"))
    losses = []
    for _ in range(5):
        total, lm, aux, g = step(flat, toks)
        losses.append(float(total))
        flat = flat - 0.5 * g
    assert losses[-1] < losses[0] - 0.1, losses


def test_eval_step_shapes():
    cfg = TINY
    flat = jnp.asarray(model.init_params(cfg, 6))
    toks = jnp.asarray(batch_tokens(cfg, 7))
    nll, preds = model.make_eval_step(cfg)(flat, toks)
    assert nll.shape == (cfg.batch, cfg.seq)
    assert preds.shape == (cfg.batch, cfg.seq)
    assert preds.dtype == jnp.int32


@pytest.mark.parametrize("pp", [2])
def test_pipeline_stages_compose_to_fused(pp):
    """stage_fwd chain == fused forward loss; stage_fwdbwd chain == fused
    grads (the PP engine's correctness contract)."""
    cfg = TINY
    flat = jnp.asarray(model.init_params(cfg, 8))
    toks = jnp.asarray(batch_tokens(cfg, 9))

    # split flat params into per-stage segments
    segs = []
    for st in range(pp):
        specs = model.stage_param_specs(cfg, pp, st)
        seg = jnp.concatenate([
            jax.lax.dynamic_slice(flat, (s0["offset"],), (s0["numel"],))
            for s0 in _orig_specs(cfg, pp, st)])
        segs.append(seg)

    # forward chain
    h, aux0 = model.make_stage_fwd(cfg, pp, 0)(segs[0], toks)
    loss, aux1 = model.make_stage_fwd(cfg, pp, 1)(segs[1], h, toks)
    lm_f, aux_f, _ = model.forward(cfg, flat, toks)
    np.testing.assert_allclose(float(loss), float(lm_f), rtol=1e-5)
    np.testing.assert_allclose(float(aux0 + aux1), float(aux_f), rtol=1e-4)

    # backward chain vs fused grads
    _, _, gflat = _fused_loss_grads(cfg, flat, toks)
    loss_b, aux_b, dx, dp1 = model.make_stage_fwdbwd(cfg, pp, 1)(segs[1], h, toks)
    (dp0,) = model.make_stage_fwdbwd(cfg, pp, 0)(segs[0], toks, dx)
    got = _scatter_stage_grads(cfg, pp, [dp0, dp1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(gflat),
                               rtol=5e-4, atol=5e-6)


def _orig_specs(cfg, pp, stage):
    layers = set(model.stage_layers(cfg, pp, stage))
    return [s for s in model.param_specs(cfg)
            if (s["layer"] in layers
                or (stage == 0 and s["name"] == "embed")
                or (stage == pp - 1 and s["name"] in ("final_norm", "head")))]


def _scatter_stage_grads(cfg, pp, dps):
    out = np.zeros(model.param_count(cfg), np.float32)
    for st, dp in enumerate(dps):
        local = model.stage_param_specs(cfg, pp, st)
        orig = _orig_specs(cfg, pp, st)
        dp = np.asarray(dp)
        for lo, o in zip(local, orig):
            out[o["offset"]:o["offset"] + o["numel"]] = \
                dp[lo["offset"]:lo["offset"] + lo["numel"]]
    return out


def _fused_loss_grads(cfg, flat, toks):
    def loss_fn(f):
        lm, aux, _ = model.forward(cfg, f, toks)
        return lm + cfg.aux_coef * aux
    l, g = jax.value_and_grad(loss_fn)(flat)
    return l, None, g


def test_ep_decomposition_matches_fused_forward():
    """EP split (pre-layer artifact + expert artifact per rank + manual
    allgather/reduce in numpy) reproduces the fused forward — the contract
    the Rust EP engine relies on. Single 'DP' sample, EP=2."""
    cfg = TINY
    ep = 2
    nr = cfg.n_experts // ep
    flat = jnp.asarray(model.init_params(cfg, 10))
    toks_all = batch_tokens(cfg, 11)

    # fused reference on the full batch
    lm_ref, aux_ref, _ = model.forward(cfg, jnp.asarray(flat),
                                       jnp.asarray(toks_all))

    # EP=2: each rank holds the same non-expert params, experts split.
    # Ranks process disjoint halves of the batch (EP scales batch like DP).
    b_half = cfg.batch // ep
    toks_r = [toks_all[r * b_half:(r + 1) * b_half] for r in range(ep)]
    p = {s["name"]: np.asarray(flat[s["offset"]:s["offset"] + s["numel"]])
         for s in model.param_specs(cfg)}

    emb_fwd = model.make_ep_embed_fwd(cfg)
    pre_fwd = model.make_ep_layer_pre_fwd(cfg)
    exp_fwd = model.make_ep_expert_fwd(cfg, ep, tile=4)
    head = model.make_ep_head_fwdbwd(cfg)

    ne_specs = model.layer_nonexpert_specs(cfg)
    h_r = [emb_fwd(jnp.asarray(p["embed"]), jnp.asarray(toks_r[r]))
           for r in range(ep)]
    for l in range(cfg.n_layers):
        pl_flat = jnp.concatenate([
            jnp.asarray(p[s["name"].replace("layer0", f"layer{l}")]).ravel()
            for s in ne_specs])
        pre = [pre_fwd(pl_flat, h_r[r]) for r in range(ep)]
        # Stage 1: allgather tokens + routing across EP group
        x_all = jnp.concatenate([pr[1] for pr in pre])          # [T,H]
        w_all = jnp.concatenate([pr[2] for pr in pre])
        idx_all = jnp.concatenate([pr[3] for pr in pre])
        partials = []
        for r in range(ep):
            pe = jnp.concatenate([
                jnp.asarray(p[f"layer{l}.gate"].reshape(cfg.n_experts, -1)[r * nr:(r + 1) * nr]).ravel(),
                jnp.asarray(p[f"layer{l}.up"].reshape(cfg.n_experts, -1)[r * nr:(r + 1) * nr]).ravel(),
                jnp.asarray(p[f"layer{l}.down"].reshape(cfg.n_experts, -1)[r * nr:(r + 1) * nr]).ravel(),
            ])
            partials.append(exp_fwd(pe, x_all, w_all, idx_all - r * nr))
        # Stage 5 tail: reduce(-scatter) partial outputs, then residual
        moe_all = sum(partials)                                  # [T,H]
        t_half = b_half * cfg.seq
        for r in range(ep):
            a = pre[r][0]
            mo = moe_all[r * t_half:(r + 1) * t_half].reshape(a.shape)
            h_r[r] = a + mo
    hn = jnp.concatenate(h_r)
    ph = jnp.concatenate([jnp.asarray(p["final_norm"]).ravel(),
                          jnp.asarray(p["head"]).ravel()])
    loss, _, _ = head(ph, hn, jnp.asarray(toks_all))
    np.testing.assert_allclose(float(loss), float(lm_ref), rtol=2e-5)
