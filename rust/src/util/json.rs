//! Minimal JSON parser (recursive descent) — `serde` is unavailable in the
//! offline crate set, and the only JSON we consume is our own
//! `artifacts/manifest.json`, so a few hundred lines suffice.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (used by checkpoint metadata files).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    s.push_str(&format!("{}", *n as i64));
                } else {
                    s.push_str(&format!("{n}"));
                }
            }
            Json::Str(v) => {
                s.push('"');
                for c in v.chars() {
                    match c {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\t' => s.push_str("\\t"),
                        '\r' => s.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            s.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(a) => {
                s.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    v.write(s);
                }
                s.push(']');
            }
            Json::Obj(m) => {
                s.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    Json::Str(k.clone()).write(s);
                    s.push(':');
                    v.write(s);
                }
                s.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let s = &self.b[self.i..];
                    let ch = std::str::from_utf8(&s[..s.len().min(4)])
                        .ok()
                        .and_then(|t| t.chars().next())
                        .ok_or_else(|| self.err("bad utf8"))?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\n\"y\""}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(j.req("a").as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(j.req("b").req("c").as_bool(), Some(true));
        assert_eq!(j.req("s").as_str(), Some("x\n\"y\""));
        // serialize then reparse
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
