//! Serving-engine acceptance gates: deterministic completions (same seed
//! → same completion set, continuous and static alike), KV-page
//! leak-freedom, cross-topology checkpoint loading (a dp2×ep2 EPSO
//! checkpoint re-sliced onto ep2 and ep1 serving placements reassembles
//! bit-identically), and the stable startup/rejection strings
//! (`serve startup failed [plan]`/`[kv-oom]`/`[ckpt]`,
//! `checkpoint resume failed [dtype]`).

use optimus::comm::Topology;
use optimus::coordinator::{self, EpLayout, JobSpec, JobSpecBuilder};
use optimus::data::{corpus, preprocess};
use optimus::optim::ShardingMode;
use optimus::runtime::Dtype;
use optimus::serve::{self, BatchMode, ServeConfig, TrafficConfig};
use std::path::PathBuf;
use std::sync::OnceLock;

fn data_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("optimus-sv-data-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let files = corpus::data_files(42, 4, 24);
        preprocess::preprocess(&files, 64, 7, &dir, 256).unwrap();
        dir
    })
    .clone()
}

fn ckroot(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("optimus-sv-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn base(topo: Topology, steps: usize) -> JobSpecBuilder {
    let mut b = JobSpec::new("mula-tiny")
        .data_dir(data_dir())
        .topo(topo)
        .steps(steps)
        .warmup_steps(2)
        .peak_lr(2e-3)
        .min_lr(2e-4)
        .engine_pool(2)
        .bf16_grad_reduce(false);
    if topo.ep > 1 {
        b = b.sharding(ShardingMode::Epso);
    }
    b
}

/// Small bounded workload that always fits the 32-token artifact window.
fn small_traffic(seed: u64, requests: usize) -> TrafficConfig {
    TrafficConfig {
        seed,
        requests,
        rate_rps: 0.0,
        prompt_len: (4, 8),
        gen_len: (4, 10),
        queue_depth: 4,
    }
}

/// The three serve startup preflights fire with their stable strings
/// *before* any checkpoint is read or thread spawns — so none of these
/// need a trained checkpoint, and all classify as non-relaunchable
/// config errors.
#[test]
fn startup_preflights_fire_with_stable_strings() {
    let Some(m) = optimus::manifest_or_skip("serve::startup_preflights") else {
        return;
    };
    let missing = std::env::temp_dir().join(format!("optimus-sv-none-{}", std::process::id()));

    // [plan]: worst-case prompt+gen window exceeds the fixed artifact seq
    let mut cfg = ServeConfig::new("mula-tiny", &missing);
    cfg.traffic.prompt_len = (20, 20);
    cfg.traffic.gen_len = (20, 20);
    let e = serve::serve(&m, &cfg).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("serve startup failed [plan]"), "{msg}");
    assert_eq!(optimus::ft::classify(&e), optimus::ft::FailureKind::Config, "{msg}");

    // [kv-oom]: a pool too small to ever host one worst-case request
    let mut cfg = ServeConfig::new("mula-tiny", &missing);
    cfg.traffic = small_traffic(0, 4); // worst case 8 + 10 = 18 tokens
    cfg.kv_pages = 2;
    cfg.kv_page_size = 8; // 18 tokens need 3 pages > 2
    let e = serve::serve(&m, &cfg).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("serve startup failed [kv-oom]"), "{msg}");
    assert_eq!(optimus::ft::classify(&e), optimus::ft::FailureKind::Config, "{msg}");

    // [ckpt]: a valid config but nothing to load under the directory
    let mut cfg = ServeConfig::new("mula-tiny", &missing);
    cfg.traffic = small_traffic(0, 4);
    let e = serve::serve(&m, &cfg).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("serve startup failed [ckpt]"), "{msg}");
    assert_eq!(optimus::ft::classify(&e), optimus::ft::FailureKind::Config, "{msg}");
}

/// Greedy decode over a fixed checkpoint is a pure function of the
/// request content: rerunning the same seed reproduces the completion
/// set exactly, and static batching produces the *same* completions as
/// continuous batching (it only schedules them differently). Every lane
/// returns all of its KV pages.
#[test]
fn completions_are_deterministic_and_pages_leak_free() {
    let Some(m) = optimus::manifest_or_skip("serve::determinism_and_leaks") else {
        return;
    };
    let ck = ckroot("det");
    coordinator::train(
        &m,
        &base(Topology::dp_only(1), 4)
            .checkpoint_dir(&ck)
            .ckpt_every(3)
            .build()
            .unwrap(),
    )
    .unwrap();

    let run = |mode: BatchMode| {
        let mut cfg = ServeConfig::new("mula-tiny", &ck);
        cfg.mode = mode;
        cfg.traffic = small_traffic(7, 12);
        serve::serve(&m, &cfg).unwrap()
    };
    let a = run(BatchMode::Continuous);
    let b = run(BatchMode::Continuous);
    let c = run(BatchMode::Static);
    for (tag, r) in [("cont-a", &a), ("cont-b", &b), ("static", &c)] {
        assert_eq!(r.completions.len(), r.submitted, "{tag}: bounded run incomplete");
        assert_eq!(r.kv_pages_leaked, 0, "{tag}: leaked KV pages");
        assert!(r.kv_pages_peak > 0 && r.kv_pages_peak <= r.kv_pages_total, "{tag}");
        assert!(r.tokens_generated > 0 && r.decode_steps > 0, "{tag}");
        assert_eq!(r.resumed_step, 3, "{tag}: served the step-3 checkpoint");
        for comp in &r.completions {
            assert!(!comp.tokens.is_empty(), "{tag}: empty completion {}", comp.id);
        }
    }
    assert_eq!(a.completions, b.completions, "same seed must reproduce completions");
    assert_eq!(
        a.completions, c.completions,
        "batching mode must not change what gets generated"
    );
    // latency percentiles are populated and ordered
    assert!(a.ttft.count() == a.submitted as u64);
    assert!(a.ttft.p50() <= a.ttft.p99());
    assert!(a.per_token.count() == a.tokens_generated);
    let _ = std::fs::remove_dir_all(&ck);
}

/// The cross-topology gate: train dp2×ep2 under EPSO, checkpoint, then
/// serve-load onto ep2 and ep1 placements. The reassembled full
/// parameter vector is bit-identical to the uninterrupted reference
/// state, the EP re-slice round-trips bit-exactly, and both serving
/// topologies drain the same bounded workload leak-free.
#[test]
fn dp2ep2_checkpoint_serves_on_ep2_and_ep1() {
    let Some(m) = optimus::manifest_or_skip("serve::cross_topology_load") else {
        return;
    };
    let mm = m.config("mula-tiny").unwrap();
    // reference: uninterrupted 6-step run (no checkpointing)
    let reference = coordinator::train(
        &m,
        &base(Topology::grid(2, 2, 1), 6).build().unwrap(),
    )
    .unwrap();
    // producer: 7-step run committing sharded EPSO checkpoints at 3 and 6
    let ck = ckroot("xtopo");
    let produced = coordinator::train(
        &m,
        &base(Topology::grid(2, 2, 1), 7)
            .checkpoint_dir(&ck)
            .ckpt_every(3)
            .build()
            .unwrap(),
    )
    .unwrap();
    assert!(produced.ckpt_commits >= 2, "commits at steps 3 and 6");

    // the serve loader reassembles the sharded checkpoint to the exact
    // bits the reference run holds after the same number of steps
    let (params, step) = serve::load_params(mm, &ck).unwrap();
    assert_eq!(step, 6);
    let reference_params = reference.final_params.as_f32().unwrap();
    assert_eq!(params.len(), reference_params.len());
    for (i, (p, q)) in params.iter().zip(reference_params.iter()).enumerate() {
        assert_eq!(
            p.to_bits(),
            q.to_bits(),
            "param {i} diverged between checkpoint reassembly and reference: {p} vs {q}"
        );
    }

    // ep2 re-slice round-trip: extracting both ranks' serving shards and
    // scattering them back reconstructs the full vector bit-exactly
    let mut rebuilt = vec![0.0f32; params.len()];
    for ep_rank in 0..2 {
        let layout = EpLayout::new(mm, 2, ep_rank);
        let local = layout.extract(&params);
        assert_eq!(local.len(), layout.local_len());
        layout.scatter(&local, &mut rebuilt);
    }
    for (i, (p, q)) in params.iter().zip(rebuilt.iter()).enumerate() {
        assert_eq!(p.to_bits(), q.to_bits(), "param {i} lost in ep2 re-slice round-trip");
    }

    // the same checkpoint serves on both placements; each topology is
    // internally deterministic and leak-free (token streams are not
    // compared across topologies — fp reduction order differs)
    for (tag, topo) in [("ep2", Topology::grid(1, 2, 1)), ("ep1", Topology::dp_only(1))] {
        let run = || {
            let mut cfg = ServeConfig::new("mula-tiny", &ck);
            cfg.topo = topo;
            cfg.traffic = small_traffic(3, 8);
            serve::serve(&m, &cfg).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.completions.len(), 8, "{tag}: bounded run incomplete");
        assert_eq!(a.kv_pages_leaked, 0, "{tag}: leaked KV pages");
        assert_eq!(a.resumed_step, 6, "{tag}");
        assert_eq!(a.completions, b.completions, "{tag}: nondeterministic completions");
    }
    let _ = std::fs::remove_dir_all(&ck);
}

/// A bf16 training checkpoint offered to the f32 decode engine is
/// refused with the same stable `[dtype]` string the trainer's resume
/// preflight uses — no silent up-conversion.
#[test]
fn serve_rejects_a_bf16_checkpoint() {
    let Some(m) = optimus::manifest_or_skip("serve::bf16_rejection") else {
        return;
    };
    let ck = ckroot("bf16");
    coordinator::train(
        &m,
        &base(Topology::dp_only(1), 4)
            .dtype(Dtype::Bf16)
            .checkpoint_dir(&ck)
            .ckpt_every(3)
            .build()
            .unwrap(),
    )
    .unwrap();
    let mut cfg = ServeConfig::new("mula-tiny", &ck);
    cfg.traffic = small_traffic(0, 4);
    let e = serve::serve(&m, &cfg).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("checkpoint resume failed [dtype]"), "{msg}");
    assert_eq!(optimus::ft::classify(&e), optimus::ft::FailureKind::Config, "{msg}");
    let _ = std::fs::remove_dir_all(&ck);
}
