//! Step timing breakdown + loss logging.
//!
//! A training step decomposes into the paper's three components —
//! forward, backward (fused here as fwd+bwd artifacts), and optimizer —
//! plus communication and data time. Table 3's speedups are ratios of
//! these component times.

use std::time::Instant;

#[derive(Clone, Debug, Default)]
pub struct StepBreakdown {
    pub fwd_bwd_secs: f64,
    pub optimizer_secs: f64,
    pub comm_secs: f64,
    pub data_secs: f64,
}

impl StepBreakdown {
    pub fn total(&self) -> f64 {
        self.fwd_bwd_secs + self.optimizer_secs + self.comm_secs + self.data_secs
    }

    pub fn add(&mut self, other: &StepBreakdown) {
        self.fwd_bwd_secs += other.fwd_bwd_secs;
        self.optimizer_secs += other.optimizer_secs;
        self.comm_secs += other.comm_secs;
        self.data_secs += other.data_secs;
    }
}

/// Scoped timer: `let _t = Scoped::new(&mut acc);`
pub struct Scoped<'a> {
    start: Instant,
    sink: &'a mut f64,
}

impl<'a> Scoped<'a> {
    pub fn new(sink: &'a mut f64) -> Scoped<'a> {
        Scoped { start: Instant::now(), sink }
    }
}

impl<'a> Drop for Scoped<'a> {
    fn drop(&mut self) {
        *self.sink += self.start.elapsed().as_secs_f64();
    }
}

/// Loss / metric curve: (step, value) pairs with CSV export.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub name: String,
    pub points: Vec<(usize, f64)>,
}

impl Curve {
    pub fn new(name: &str) -> Curve {
        Curve { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, step: usize, v: f64) {
        self.points.push((step, v));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.1)
    }

    /// Mean of the final `n` points (smoothed terminal loss).
    pub fn tail_mean(&self, n: usize) -> f64 {
        let k = self.points.len().saturating_sub(n);
        let tail = &self.points[k..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|p| p.1).sum::<f64>() / tail.len() as f64
    }

    pub fn to_csv(&self) -> String {
        let mut s = format!("step,{}\n", self.name);
        for (st, v) in &self.points {
            s.push_str(&format!("{st},{v}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_accumulates() {
        let mut acc = 0.0;
        {
            let _t = Scoped::new(&mut acc);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(acc >= 0.004);
    }

    #[test]
    fn curve_tail_mean() {
        let mut c = Curve::new("loss");
        for i in 0..10 {
            c.push(i, i as f64);
        }
        assert_eq!(c.tail_mean(2), 8.5);
        assert_eq!(c.last(), Some(9.0));
        assert!(c.to_csv().contains("9,9"));
    }
}
