//! Fused AdamW update over a flat f32 shard.
//!
//! The paper trains BF16 mixed precision with FP32 master weights and FP32
//! optimizer states (16 bytes/param: 2P weights + 2P grads + 4P master +
//! 8P moments). On the CPU path weights are f32 throughout; the state
//! layout (m, v, master) and the update math match AdamW exactly:
//!
//! m ← β₁m + (1-β₁)g;  v ← β₂v + (1-β₂)g²
//! p ← p − lr·( m̂/(√v̂+ε) + wd·p )   with bias-corrected m̂, v̂.
//!
//! The decoupled weight decay is applied to all parameters (paper §2.1).
//!
//! The moment vectors are `Arc`-backed so the checkpoint path can capture
//! them in O(1) ([`AdamState::snapshot`]): the update loop mutates through
//! `Arc::make_mut`, which stays in-place while no snapshot handle is
//! alive and copies exactly once while a background checkpoint write is
//! still serializing (the snapshot stays intact — same copy-on-write
//! rules as [`crate::runtime::Tensor`], DESIGN.md §3).

use std::sync::Arc;

#[derive(Clone, Copy, Debug)]
pub struct AdamParams {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamParams {
    fn default() -> Self {
        // paper §2.1: beta1=0.9, beta2=0.99, eps=1e-8, wd=0.1
        AdamParams { beta1: 0.9, beta2: 0.99, eps: 1e-8, weight_decay: 0.1 }
    }
}

/// First/second moment state for one shard.
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Arc<Vec<f32>>,
    pub v: Arc<Vec<f32>>,
    pub step: u64,
}

impl AdamState {
    pub fn new(n: usize) -> AdamState {
        AdamState { m: Arc::new(vec![0.0; n]), v: Arc::new(vec![0.0; n]), step: 0 }
    }

    /// O(1) snapshot handles of the moment vectors (checkpoint capture).
    pub fn snapshot(&self) -> (Arc<Vec<f32>>, Arc<Vec<f32>>) {
        (Arc::clone(&self.m), Arc::clone(&self.v))
    }

    /// Replace the moment state (checkpoint restore). `step` is the
    /// number of optimizer steps already taken — it drives the bias
    /// correction, so a resumed run continues bit-identically.
    pub fn load(&mut self, m: Vec<f32>, v: Vec<f32>, step: u64) -> crate::Result<()> {
        if m.len() != self.m.len() || v.len() != self.v.len() {
            return Err(anyhow::anyhow!(
                "AdamState restore: moment lengths {}/{} do not match shard {}/{}",
                m.len(),
                v.len(),
                self.m.len(),
                self.v.len()
            ));
        }
        self.m = Arc::new(m);
        self.v = Arc::new(v);
        self.step = step;
        Ok(())
    }

    /// Bytes held by optimizer state (8 bytes/param) — what SO vs EPSO
    /// trades (paper Figure 6).
    pub fn bytes(&self) -> usize {
        self.m.len() * 8
    }

    /// One update step on `params` (master weights) with `grads`
    /// (already averaged & clipped via `grad_scale`). Hot path: plain
    /// indexed loop that LLVM auto-vectorizes.
    pub fn update(
        &mut self,
        hp: AdamParams,
        lr: f32,
        grad_scale: f32,
        params: &mut [f32],
        grads: &[f32],
    ) {
        assert_eq!(params.len(), self.m.len());
        self.begin_step();
        self.update_chunk(hp, lr, grad_scale, 0, params, grads);
    }

    /// Advance the step counter (drives bias correction) once per
    /// optimizer step. [`AdamState::update`] calls this itself; the
    /// pipelined sharded optimizer calls it once per segment and then
    /// [`AdamState::update_chunk`] per chunk.
    pub fn begin_step(&mut self) {
        self.step += 1;
    }

    /// Update a sub-range of the shard: `params`/`grads` are the chunk
    /// slices, `offset` is the chunk's start within the shard (it indexes
    /// `m`/`v`). Chunk-by-chunk application over a partition of the shard
    /// is bit-identical to one whole-shard [`AdamState::update`] — the
    /// loop body is elementwise and the bias correction reads the step
    /// counter bumped by [`AdamState::begin_step`].
    pub fn update_chunk(
        &mut self,
        hp: AdamParams,
        lr: f32,
        grad_scale: f32,
        offset: usize,
        params: &mut [f32],
        grads: &[f32],
    ) {
        assert_eq!(params.len(), grads.len());
        assert!(offset + params.len() <= self.m.len());
        let b1 = hp.beta1;
        let b2 = hp.beta2;
        let bc1 = 1.0 - b1.powi(self.step as i32);
        let bc2 = 1.0 - b2.powi(self.step as i32);
        let inv_bc1 = 1.0 / bc1;
        let inv_bc2 = 1.0 / bc2;
        // in-place while uniquely owned; one copy if a snapshot is alive
        let (m, v) = (Arc::make_mut(&mut self.m), Arc::make_mut(&mut self.v));
        for i in 0..params.len() {
            let g = grads[i] * grad_scale;
            let mi = b1 * m[offset + i] + (1.0 - b1) * g;
            let vi = b2 * v[offset + i] + (1.0 - b2) * g * g;
            m[offset + i] = mi;
            v[offset + i] = vi;
            let mhat = mi * inv_bc1;
            let vhat = vi * inv_bc2;
            params[i] -=
                lr * (mhat / (vhat.sqrt() + hp.eps) + hp.weight_decay * params[i]);
        }
    }
}

/// Global gradient-norm clipping factor: returns the scale s such that
/// ‖s·g‖ ≤ max_norm (paper: clip at 1.0, applied only after warmup).
pub fn clip_scale(grad_sumsq: f64, max_norm: f64) -> f32 {
    let norm = grad_sumsq.sqrt();
    if norm > max_norm && norm > 0.0 {
        (max_norm / norm) as f32
    } else {
        1.0
    }
}

pub fn sumsq(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scalar AdamW reference (independent transcription).
    fn reference_step(
        hp: AdamParams,
        lr: f32,
        p: f32,
        g: f32,
        m: f32,
        v: f32,
        t: u64,
    ) -> (f32, f32, f32) {
        let m2 = hp.beta1 * m + (1.0 - hp.beta1) * g;
        let v2 = hp.beta2 * v + (1.0 - hp.beta2) * g * g;
        let mhat = m2 / (1.0 - hp.beta1.powi(t as i32));
        let vhat = v2 / (1.0 - hp.beta2.powi(t as i32));
        let p2 = p - lr * (mhat / (vhat.sqrt() + hp.eps) + hp.weight_decay * p);
        (p2, m2, v2)
    }

    #[test]
    fn matches_scalar_reference() {
        let hp = AdamParams::default();
        let mut st = AdamState::new(3);
        let mut p = vec![1.0f32, -2.0, 0.5];
        let g = vec![0.1f32, -0.2, 0.0];
        let p0 = p.clone();
        st.update(hp, 1e-2, 1.0, &mut p, &g);
        for i in 0..3 {
            let (want, wm, wv) = reference_step(hp, 1e-2, p0[i], g[i], 0.0, 0.0, 1);
            assert!((p[i] - want).abs() < 1e-6, "{} vs {}", p[i], want);
            assert!((st.m[i] - wm).abs() < 1e-7);
            assert!((st.v[i] - wv).abs() < 1e-9);
        }
    }

    #[test]
    fn multiple_steps_track_reference() {
        let hp = AdamParams { weight_decay: 0.0, ..Default::default() };
        let mut st = AdamState::new(1);
        let mut p = vec![2.0f32];
        let (mut rp, mut rm, mut rv) = (2.0f32, 0.0f32, 0.0f32);
        for t in 1..=20u64 {
            let g = 0.3 * (t as f32).sin();
            st.update(hp, 5e-3, 1.0, &mut p, &[g]);
            let (a, b, c) = reference_step(hp, 5e-3, rp, g, rm, rv, t);
            rp = a;
            rm = b;
            rv = c;
            assert!((p[0] - rp).abs() < 1e-5, "step {t}");
        }
    }

    #[test]
    fn descends_a_quadratic() {
        // minimize (x-3)^2: grad = 2(x-3)
        let hp = AdamParams { weight_decay: 0.0, ..Default::default() };
        let mut st = AdamState::new(1);
        let mut p = vec![0.0f32];
        for _ in 0..800 {
            let g = 2.0 * (p[0] - 3.0);
            st.update(hp, 0.05, 1.0, &mut p, &[g]);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "{}", p[0]);
    }

    #[test]
    fn clip_scale_behaviour() {
        assert_eq!(clip_scale(0.25, 1.0), 1.0); // norm 0.5 < 1
        let s = clip_scale(4.0, 1.0); // norm 2
        assert!((s - 0.5).abs() < 1e-6);
        assert_eq!(clip_scale(0.0, 1.0), 1.0);
    }

    #[test]
    fn chunked_update_is_bit_identical_to_whole_shard() {
        let hp = AdamParams::default();
        let n = 23;
        let grads: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.37).sin()).collect();
        let mut whole = AdamState::new(n);
        let mut chunked = AdamState::new(n);
        let mut pw: Vec<f32> = (0..n).map(|i| 0.1 * i as f32 - 1.0).collect();
        let mut pc = pw.clone();
        for step in 0..4 {
            let scale = if step % 2 == 0 { 1.0 } else { 0.25 };
            whole.update(hp, 3e-3, scale, &mut pw, &grads);
            chunked.begin_step();
            let mut off = 0;
            for chunk in [5usize, 9, 2, 7] {
                chunked.update_chunk(
                    hp,
                    3e-3,
                    scale,
                    off,
                    &mut pc[off..off + chunk],
                    &grads[off..off + chunk],
                );
                off += chunk;
            }
            assert_eq!(off, n);
        }
        for i in 0..n {
            assert_eq!(pw[i].to_bits(), pc[i].to_bits(), "param {i}");
            assert_eq!(whole.m[i].to_bits(), chunked.m[i].to_bits(), "m {i}");
            assert_eq!(whole.v[i].to_bits(), chunked.v[i].to_bits(), "v {i}");
        }
    }

    #[test]
    fn snapshot_is_copy_on_write() {
        let hp = AdamParams::default();
        let mut st = AdamState::new(4);
        let mut p = vec![1.0f32; 4];
        st.update(hp, 1e-2, 1.0, &mut p, &[0.5; 4]);
        let (m_snap, v_snap) = st.snapshot();
        let (m1, v1) = (st.m[0], st.v[0]);
        // updating while the snapshot is alive copies; the snapshot is frozen
        st.update(hp, 1e-2, 1.0, &mut p, &[0.5; 4]);
        assert_eq!(m_snap[0].to_bits(), m1.to_bits());
        assert_eq!(v_snap[0].to_bits(), v1.to_bits());
        assert_ne!(st.m[0].to_bits(), m1.to_bits());
        // restore round-trips, including the bias-correction counter
        let mut st2 = AdamState::new(4);
        st2.load(m_snap.as_ref().clone(), v_snap.as_ref().clone(), 1).unwrap();
        assert_eq!(st2.step, 1);
        assert_eq!(st2.m[0].to_bits(), m1.to_bits());
        assert!(st2.load(vec![0.0; 3], vec![0.0; 4], 1).is_err(), "length gate");
    }

    #[test]
    fn grad_scale_is_applied() {
        let hp = AdamParams { weight_decay: 0.0, ..Default::default() };
        let mut a = AdamState::new(1);
        let mut b = AdamState::new(1);
        let mut pa = vec![1.0f32];
        let mut pb = vec![1.0f32];
        a.update(hp, 1e-3, 0.5, &mut pa, &[2.0]);
        b.update(hp, 1e-3, 1.0, &mut pb, &[1.0]);
        assert!((pa[0] - pb[0]).abs() < 1e-7);
    }
}
