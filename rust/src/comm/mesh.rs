//! N-D device mesh (DP × EP × PP) and its process groups.
//!
//! Mirrors the paper's placement: EP innermost (within a node, 12 tiles),
//! PP across nodes, DP across node groups. Rank numbering:
//! `rank = (dp * EP + ep) * PP + pp`.
//!
//! Groups exposed per rank:
//! - **dp group**  — ranks sharing (ep, pp): gradient sync + SO sharding
//! - **ep group**  — ranks sharing (dp, pp): Stage-1 token exchange
//! - **dpep group** — ranks sharing pp: EPSO's non-expert sharding domain
//! - **world**     — everything (barriers, health votes)

use super::group::{CommStats, Group};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub dp: usize,
    pub ep: usize,
    pub pp: usize,
}

impl Topology {
    pub fn dp_only(dp: usize) -> Topology {
        Topology { dp, ep: 1, pp: 1 }
    }

    pub fn world(&self) -> usize {
        self.dp * self.ep * self.pp
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MeshCoord {
    pub dp: usize,
    pub ep: usize,
    pub pp: usize,
}

pub struct Mesh {
    pub topo: Topology,
    /// indexed by ep * PP + pp
    dp_groups: Vec<Arc<Group>>,
    /// indexed by dp * PP + pp
    ep_groups: Vec<Arc<Group>>,
    /// indexed by pp
    dpep_groups: Vec<Arc<Group>>,
    world: Arc<Group>,
}

impl Mesh {
    pub fn new(topo: Topology) -> Arc<Mesh> {
        // stable labels per group: protocol-violation and stall reports
        // name the fabric they fired on (e.g. `dp[1]`, `world`)
        let dp_groups = (0..topo.ep * topo.pp)
            .map(|i| Group::new_labeled(topo.dp, &format!("dp[{i}]")))
            .collect();
        let ep_groups = (0..topo.dp * topo.pp)
            .map(|i| Group::new_labeled(topo.ep, &format!("ep[{i}]")))
            .collect();
        let dpep_groups = (0..topo.pp)
            .map(|i| Group::new_labeled(topo.dp * topo.ep, &format!("dpep[{i}]")))
            .collect();
        Arc::new(Mesh {
            topo,
            dp_groups,
            ep_groups,
            dpep_groups,
            world: Group::new_labeled(topo.world(), "world"),
        })
    }

    pub fn rank(&self, c: MeshCoord) -> usize {
        (c.dp * self.topo.ep + c.ep) * self.topo.pp + c.pp
    }

    pub fn coord(&self, rank: usize) -> MeshCoord {
        let pp = rank % self.topo.pp;
        let rest = rank / self.topo.pp;
        let ep = rest % self.topo.ep;
        let dp = rest / self.topo.ep;
        MeshCoord { dp, ep, pp }
    }

    /// (group, my index within it) for the data-parallel dimension.
    pub fn dp_group(&self, rank: usize) -> (&Arc<Group>, usize) {
        let c = self.coord(rank);
        (&self.dp_groups[c.ep * self.topo.pp + c.pp], c.dp)
    }

    /// (group, my index) for the expert-parallel dimension.
    pub fn ep_group(&self, rank: usize) -> (&Arc<Group>, usize) {
        let c = self.coord(rank);
        (&self.ep_groups[c.dp * self.topo.pp + c.pp], c.ep)
    }

    /// (group, my index) for the combined DP×EP domain (same pp stage).
    /// Index is `dp * EP + ep` — contiguous in dp-major order.
    pub fn dpep_group(&self, rank: usize) -> (&Arc<Group>, usize) {
        let c = self.coord(rank);
        (&self.dpep_groups[c.pp], c.dp * self.topo.ep + c.ep)
    }

    pub fn world_group(&self) -> &Arc<Group> {
        &self.world
    }

    /// Poison every group (used when a rank aborts so surviving ranks
    /// fail fast instead of hanging — paper §4 hard-failure semantics).
    pub fn poison_all(&self) {
        for g in self
            .dp_groups
            .iter()
            .chain(self.ep_groups.iter())
            .chain(self.dpep_groups.iter())
        {
            g.poison();
        }
        self.world.poison();
    }

    /// Aggregate traffic across every group of the mesh (dp, ep, dpep and
    /// world) — the bytes-moved number behind the perf gate's per-dtype
    /// column. Counters are at actual wire width (bf16 collectives move
    /// 2-byte words).
    pub fn traffic(&self) -> CommStats {
        let mut total = CommStats::default();
        for g in self
            .dp_groups
            .iter()
            .chain(self.ep_groups.iter())
            .chain(self.dpep_groups.iter())
            .chain(std::iter::once(&self.world))
        {
            let s = g.stats();
            total.ops += s.ops;
            total.bytes_in += s.bytes_in;
            total.bytes_out += s.bytes_out;
        }
        total
    }

    /// Pipeline neighbours (same dp, ep): (prev, next) ranks if any.
    pub fn pp_neighbours(&self, rank: usize) -> (Option<usize>, Option<usize>) {
        let c = self.coord(rank);
        let prev = (c.pp > 0).then(|| self.rank(MeshCoord { pp: c.pp - 1, ..c }));
        let next =
            (c.pp + 1 < self.topo.pp).then(|| self.rank(MeshCoord { pp: c.pp + 1, ..c }));
        (prev, next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_coord_roundtrip() {
        let m = Mesh::new(Topology { dp: 3, ep: 4, pp: 2 });
        for r in 0..24 {
            assert_eq!(m.rank(m.coord(r)), r);
        }
    }

    #[test]
    fn group_memberships_are_consistent() {
        let m = Mesh::new(Topology { dp: 2, ep: 2, pp: 2 });
        for r in 0..8 {
            let c = m.coord(r);
            let (dg, di) = m.dp_group(r);
            assert_eq!(dg.size(), 2);
            assert_eq!(di, c.dp);
            let (eg, ei) = m.ep_group(r);
            assert_eq!(eg.size(), 2);
            assert_eq!(ei, c.ep);
            let (xg, xi) = m.dpep_group(r);
            assert_eq!(xg.size(), 4);
            assert_eq!(xi, c.dp * 2 + c.ep);
        }
    }

    #[test]
    fn dp_groups_are_disjoint_by_ep_pp() {
        let m = Mesh::new(Topology { dp: 2, ep: 2, pp: 1 });
        let (g0, _) = m.dp_group(m.rank(MeshCoord { dp: 0, ep: 0, pp: 0 }));
        let (g1, _) = m.dp_group(m.rank(MeshCoord { dp: 0, ep: 1, pp: 0 }));
        assert!(!Arc::ptr_eq(g0, g1));
        let (g0b, _) = m.dp_group(m.rank(MeshCoord { dp: 1, ep: 0, pp: 0 }));
        assert!(Arc::ptr_eq(g0, g0b));
    }

    #[test]
    fn pp_neighbours_chain() {
        let m = Mesh::new(Topology { dp: 1, ep: 1, pp: 4 });
        assert_eq!(m.pp_neighbours(0), (None, Some(1)));
        assert_eq!(m.pp_neighbours(2), (Some(1), Some(3)));
        assert_eq!(m.pp_neighbours(3), (Some(2), None));
    }

    #[test]
    fn cross_thread_dp_allreduce_via_mesh() {
        use crate::comm::ReduceDtype;
        let m = Mesh::new(Topology { dp: 2, ep: 2, pp: 1 });
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    let (g, i) = m.dp_group(r);
                    g.allreduce(i, vec![m.coord(r).dp as f32], ReduceDtype::F32)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![1.0]); // 0 + 1
        }
    }
}
