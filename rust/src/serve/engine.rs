//! Decode engines: one batched greedy-decode step over token prefixes.
//!
//! The serving engine reuses the training artifacts unchanged, so decode
//! is windowed full recompute: every step re-runs the forward pass over
//! each request's whole prefix (EOS-padded to the artifact's fixed
//! `[batch, seq+1]` shape) and takes the argmax prediction at the
//! prefix's last position as the next token. Two engines cover the two
//! serving placements:
//!
//! * [`FusedDecoder`] (ep = 1) — the fused `eval_step` artifact with the
//!   full parameter vector resident per lane; its `preds` output is
//!   already the per-position argmax.
//! * [`EpDecoder`] (ep > 1) — the per-layer EP artifacts, running exactly
//!   the trainer's forward chain (`embed_fwd` → per layer `pre_fwd` →
//!   Stage-1 allgather exchange → `expert_fwd` → reduce-scatter →
//!   residual) and finishing with the serve-only `ep{ep}_head_fwd`
//!   artifact, which maps the final hidden states straight to argmax
//!   predictions (the training `head_fwdbwd` returns loss + cotangents,
//!   not predictions). Every rank of an EP group must call [`Decoder::step`]
//!   in lockstep — the scheduler guarantees that.
//!
//! Greedy argmax over a causal model makes each row's output independent
//! of whatever else shares the batch, so completions are a function of
//! (checkpoint, prompt) alone — the property the determinism tests and
//! the continuous-vs-static comparison lean on.

use crate::comm::{CollectiveOp, Group, Parts, Reduce, ReduceDtype};
use crate::config::ModelManifest;
use crate::coordinator::ep::exchange_allgather;
use crate::coordinator::{EpArts, EpLayout, EpParamSlices};
use crate::data::tokenizer::EOS;
use crate::runtime::{Engine, Tensor};
use crate::Result;
use std::path::PathBuf;
use std::sync::Arc;

pub(crate) enum Decoder {
    Fused(FusedDecoder),
    Ep(EpDecoder),
}

impl Decoder {
    /// One decode step: `rows[i]` is slot `i`'s token prefix (empty for
    /// an idle slot). Returns the next token per slot (EOS for idle
    /// slots). `rows.len()` must equal the artifact batch and every
    /// prefix must fit the sequence window.
    pub(crate) fn step(&self, engine: &Engine, rows: &[Vec<i32>]) -> Result<Vec<i32>> {
        match self {
            Decoder::Fused(d) => d.step(engine, rows),
            Decoder::Ep(d) => d.step(engine, rows),
        }
    }
}

/// Pack prefixes into the artifact's fixed `[b, s+1]` token block,
/// EOS-padded — the same packing `eval::run_suite` uses.
fn pack(rows: &[Vec<i32>], b: usize, s: usize) -> Tensor {
    debug_assert_eq!(rows.len(), b);
    let mut toks = vec![EOS as i32; b * (s + 1)];
    for (r, row) in rows.iter().enumerate() {
        debug_assert!(row.len() <= s, "prefix of {} exceeds the {s}-token window", row.len());
        toks[r * (s + 1)..r * (s + 1) + row.len()].copy_from_slice(row);
    }
    Tensor::i32(toks, vec![b, s + 1])
}

/// Pick each row's next token out of the `[b, s]` argmax grid: a prefix
/// of `L` tokens is continued by the prediction at position `L - 1`.
fn next_tokens(preds: &[i32], rows: &[Vec<i32>], s: usize) -> Vec<i32> {
    rows.iter()
        .enumerate()
        .map(|(r, row)| {
            if row.is_empty() {
                EOS as i32
            } else {
                preds[r * s + row.len() - 1]
            }
        })
        .collect()
}

pub(crate) struct FusedDecoder {
    key: String,
    art: PathBuf,
    /// full parameter vector — `Arc`-backed, shared across lanes
    params: Tensor,
    b: usize,
    s: usize,
}

impl FusedDecoder {
    pub(crate) fn new(mm: &ModelManifest, params: Tensor) -> Result<FusedDecoder> {
        Ok(FusedDecoder {
            key: format!("{}:eval_step", mm.name),
            art: mm.artifact_path("eval_step")?,
            params,
            b: mm.hyper.batch,
            s: mm.hyper.seq,
        })
    }

    fn step(&self, engine: &Engine, rows: &[Vec<i32>]) -> Result<Vec<i32>> {
        let toks = pack(rows, self.b, self.s);
        let outs = engine.exec(&self.key, self.art.clone(), vec![self.params.clone(), toks])?;
        // eval_step returns (nll [b,s], preds [b,s]); serving only wants
        // the argmax grid
        Ok(next_tokens(outs[1].as_i32()?, rows, self.s))
    }
}

pub(crate) struct EpDecoder {
    /// exec-cache key prefix (`<model>:<artifact>`)
    name: String,
    arts: EpArts,
    /// serve-only forward head: `(p_head, h) -> preds [b,s] i32`
    head_fwd: PathBuf,
    ps: EpParamSlices,
    group: Arc<Group>,
    ep: usize,
    ep_rank: usize,
    /// local experts per rank — the index-shift stride
    nr: usize,
    n_layers: usize,
    b: usize,
    s: usize,
    hid: usize,
    k: usize,
}

impl EpDecoder {
    pub(crate) fn new(
        mm: &ModelManifest,
        ep: usize,
        ep_rank: usize,
        full_params: &[f32],
        group: Arc<Group>,
    ) -> Result<EpDecoder> {
        let h = &mm.hyper;
        let layout = EpLayout::new(mm, ep, ep_rank);
        let local = layout.extract(full_params);
        Ok(EpDecoder {
            name: mm.name.clone(),
            arts: EpArts::load(mm, ep)?,
            head_fwd: mm.artifact_path(&format!("ep{ep}_head_fwd"))?,
            ps: EpParamSlices::new(&local, &layout),
            group,
            ep,
            ep_rank,
            nr: layout.n_local_experts,
            n_layers: h.n_layers,
            b: h.batch,
            s: h.seq,
            hid: h.hidden,
            k: h.top_k,
        })
    }

    fn step(&self, engine: &Engine, rows: &[Vec<i32>]) -> Result<Vec<i32>> {
        let (b, s, hid, k) = (self.b, self.s, self.hid, self.k);
        let t_local = b * s;
        let t_all = self.ep * t_local;
        // serving always computes in f32 (`validate_serve` pins the plan
        // dtype), so the exchange wire is f32 too
        let wire = ReduceDtype::F32;
        let exec = |key: &str, path: &std::path::Path, inputs: Vec<Tensor>| {
            engine.exec(&format!("{}:{key}", self.name), path.to_path_buf(), inputs)
        };

        let tokens_t = pack(rows, b, s);
        // forward chain, identical to the trainer's minus stashes/backward
        let mut hcur =
            exec("embed_fwd", &self.arts.embed_fwd, vec![self.ps.emb.clone(), tokens_t])?
                .remove(0);
        for l in 0..self.n_layers {
            let outs = exec("pre_fwd", &self.arts.pre_fwd, vec![
                self.ps.layer_ne[l].clone(),
                hcur,
            ])?;
            let mut it = outs.into_iter();
            let a = it.next().unwrap();
            let x2d = it.next().unwrap().into_f32()?;
            let w2d = it.next().unwrap().into_f32()?;
            let idx = it.next().unwrap().as_i32()?.to_vec();
            // ---- Stage 1: token exchange across the EP group ----
            let (x_all, w_all, idx_all) =
                exchange_allgather(&self.group, self.ep_rank, x2d, w2d, &idx, wire);
            let idx_shift: Vec<i32> =
                idx_all.iter().map(|&v| v - (self.ep_rank * self.nr) as i32).collect();
            let partial = exec("expert_fwd", &self.arts.expert_fwd, vec![
                self.ps.layer_e[l].clone(),
                Tensor::f32(x_all, vec![t_all, hid]),
                Tensor::f32(w_all, vec![t_all, k]),
                Tensor::i32(idx_shift, vec![t_all, k]),
            ])?
            .remove(0)
            .into_f32()?;
            let moe_local = self
                .group
                .run(
                    self.ep_rank,
                    CollectiveOp::ReduceScatter {
                        data: partial,
                        red: Reduce::Sum,
                        dt: wire,
                        parts: Parts::Even,
                    },
                )
                .unwrap_or_else(|f| panic!("{f}"))
                .values();
            let mut a_data = a.into_f32()?;
            for (av, mv) in a_data.iter_mut().zip(moe_local.iter()) {
                *av += *mv;
            }
            hcur = Tensor::f32(a_data, vec![b, s, hid]);
        }
        let preds =
            exec("head_fwd", &self.head_fwd, vec![self.ps.head.clone(), hcur])?.remove(0);
        Ok(next_tokens(preds.as_i32()?, rows, s))
    }
}
