//! Deterministic synthetic corpus — the OLMoE-Mix-0924 substitution.
//!
//! Documents are generated from a small probabilistic grammar with
//! learnable structure at several scales (so tiny models show a real,
//! declining loss curve, and the eval suite's probe tasks have signal):
//!
//! * a Zipfian word vocabulary with bigram structure ("language"),
//! * templated factual sentences ("the capital of X is Y" — consistent
//!   across the corpus, so models can memorize),
//! * arithmetic lines (`7+5=12`) and copy lines (`copy: abc -> abc`) that
//!   the eval suite later probes (Table 2 substitution).

use crate::util::prng::Prng;

const SUBJECTS: [&str; 12] = [
    "aurora", "ponte", "vecchio", "tile", "router", "expert", "token",
    "shard", "layer", "tensor", "pipeline", "node",
];
const VERBS: [&str; 8] =
    ["routes", "computes", "stores", "moves", "splits", "merges", "sends", "holds"];
const OBJECTS: [&str; 10] = [
    "gradients", "weights", "activations", "batches", "queries", "keys",
    "values", "caches", "counters", "buffers",
];
const PLACES: [&str; 8] =
    ["argonne", "chicago", "lemont", "illinois", "aurora", "alcf", "intel", "hpc"];

/// Deterministic fact table used by both the generator and the eval suite.
pub fn fact(i: usize) -> (String, String) {
    let a = SUBJECTS[i % SUBJECTS.len()];
    let b = PLACES[(i * 7 + 3) % PLACES.len()];
    (a.to_string(), b.to_string())
}

/// One synthetic document of roughly `target_len` characters.
pub fn document(rng: &mut Prng, target_len: usize) -> String {
    let mut s = String::new();
    while s.len() < target_len {
        match rng.below(10) {
            // factual template (memorizable; probed by eval)
            0 | 1 => {
                let i = rng.below(64);
                let (a, b) = fact(i);
                s.push_str(&format!("the home of {a} {i} is {b} . "));
            }
            // arithmetic (probed by eval)
            2 | 3 => {
                let a = rng.below(50);
                let b = rng.below(50);
                s.push_str(&format!("{a}+{b}={} . ", a + b));
            }
            // copy task (probed by eval)
            4 => {
                let n = 3 + rng.below(5);
                let w: String = (0..n)
                    .map(|_| (b'a' + rng.below(26) as u8) as char)
                    .collect();
                s.push_str(&format!("copy {w} -> {w} . "));
            }
            // bigram language
            _ => {
                let n = 4 + rng.below(8);
                let mut prev = rng.below(SUBJECTS.len());
                for _ in 0..n {
                    let subj = SUBJECTS[prev];
                    let verb = VERBS[(prev * 3 + 1) % VERBS.len()];
                    let obj = OBJECTS[(prev * 5 + 2) % OBJECTS.len()];
                    s.push_str(&format!("{subj} {verb} {obj} "));
                    prev = (prev + rng.below(3)) % SUBJECTS.len();
                }
                s.push_str(". ");
            }
        }
    }
    s
}

/// `n_files` data files of `docs_per_file` documents each — the
/// "hugging face dataset consists of data files" shape of paper §4.
pub fn data_files(seed: u64, n_files: usize, docs_per_file: usize) -> Vec<Vec<String>> {
    (0..n_files)
        .map(|f| {
            let mut rng = Prng::new(seed).fork(f as u64 + 1);
            (0..docs_per_file)
                .map(|_| {
                    let len = 200 + rng.below(400);
                    document(&mut rng, len)
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = data_files(9, 2, 3);
        let b = data_files(9, 2, 3);
        assert_eq!(a, b);
        let c = data_files(10, 2, 3);
        assert_ne!(a, c);
    }

    #[test]
    fn facts_are_stable() {
        assert_eq!(fact(5), fact(5));
        // used by eval: format must parse back
        let (a, b) = fact(3);
        assert!(!a.is_empty() && !b.is_empty());
    }

    #[test]
    fn documents_have_structure() {
        let mut rng = Prng::new(4);
        let d = document(&mut rng, 4000);
        assert!(d.len() >= 4000);
        assert!(d.contains("=") || d.contains("home of") || d.contains("copy"));
    }
}
