//! Rank-local parameter layout for expert parallelism.
//!
//! Global layout (manifest order): embed | per-layer [attn, norms, router,
//! gate, up, down] | final_norm | head. An EP rank keeps all non-expert
//! params and only its `NR = N/EP` expert slice, packed as
//! `[NE block || E block]`:
//!
//! NE block: [embed] | per-layer [wq wk wv wo norm1 norm2 router] | [final_norm | head]
//! E block:  per-layer [gate_local up_local down_local]
//!
//! These orders make every artifact input a contiguous local slice.
//!
//! [`EpLayout::for_stage`] restricts the layout to a pipeline stage's
//! layer range (embedding only on the first stage, final-norm/head only on
//! the last) — the parameter geometry of the hybrid PP×EP engine. The
//! whole-model layout of the EP engine is the single-stage special case.

use crate::config::ModelManifest;
use std::ops::Range;

#[derive(Clone, Debug)]
pub struct EpLayout {
    pub ep: usize,
    pub ep_rank: usize,
    pub n_local_experts: usize,
    /// global decoder layers covered by this layout
    pub layers: Range<usize>,
    pub ne_len: usize,
    pub e_len: usize,
    /// local range of the embedding table (empty unless the layout holds it)
    pub emb: Range<usize>,
    /// local range of each covered layer's non-expert params
    pub layer_ne: Vec<Range<usize>>,
    /// local range of [final_norm || head] (empty unless held)
    pub head: Range<usize>,
    /// local range of each covered layer's local expert params [gate|up|down]
    pub layer_e: Vec<Range<usize>>,
    /// copy plan: (global_offset, local_offset, len)
    copies: Vec<(usize, usize, usize)>,
}

impl EpLayout {
    /// Whole-model layout (the EP engine's view: one stage owning
    /// everything).
    pub fn new(mm: &ModelManifest, ep: usize, ep_rank: usize) -> EpLayout {
        EpLayout::for_stage(mm, ep, ep_rank, 0..mm.hyper.n_layers, true, true)
    }

    /// Layout restricted to a pipeline stage: `layers` is the stage's
    /// global layer range; `has_embed`/`has_head` mark the boundary
    /// stages.
    pub fn for_stage(
        mm: &ModelManifest,
        ep: usize,
        ep_rank: usize,
        layers: Range<usize>,
        has_embed: bool,
        has_head: bool,
    ) -> EpLayout {
        let h = &mm.hyper;
        assert!(h.n_experts % ep == 0, "EP must divide expert count");
        let nr = h.n_experts / ep;
        let mut copies = Vec::new();
        let mut local = 0usize;

        let push = |copies: &mut Vec<(usize, usize, usize)>,
                        local: &mut usize,
                        goff: usize,
                        len: usize| {
            copies.push((goff, *local, len));
            *local += len;
        };

        let by_name = |name: &str| {
            mm.params
                .iter()
                .find(|p| p.name == name)
                .unwrap_or_else(|| panic!("missing param {name}"))
        };

        // --- NE block ---
        let emb_start = local;
        if has_embed {
            let emb_spec = by_name("embed");
            push(&mut copies, &mut local, emb_spec.offset, emb_spec.numel);
        }
        let emb = emb_start..local;

        let mut layer_ne = Vec::with_capacity(layers.len());
        for l in layers.clone() {
            let start = local;
            for part in ["wq", "wk", "wv", "wo", "norm1", "norm2", "router"] {
                let s = by_name(&format!("layer{l}.{part}"));
                push(&mut copies, &mut local, s.offset, s.numel);
            }
            layer_ne.push(start..local);
        }

        let head_start = local;
        if has_head {
            for name in ["final_norm", "head"] {
                let s = by_name(name);
                push(&mut copies, &mut local, s.offset, s.numel);
            }
        }
        let head = head_start..local;
        let ne_len = local;

        // --- E block: local slice of each covered expert tensor ---
        let mut layer_e = Vec::with_capacity(layers.len());
        for l in layers.clone() {
            let start = local;
            for part in ["gate", "up", "down"] {
                let s = by_name(&format!("layer{l}.{part}"));
                let per_expert = s.numel / h.n_experts;
                let goff = s.offset + ep_rank * nr * per_expert;
                push(&mut copies, &mut local, goff, nr * per_expert);
            }
            layer_e.push(start..local);
        }
        let e_len = local - ne_len;

        EpLayout {
            ep,
            ep_rank,
            n_local_experts: nr,
            layers,
            ne_len,
            e_len,
            emb,
            layer_ne,
            head,
            layer_e,
            copies,
        }
    }

    pub fn local_len(&self) -> usize {
        self.ne_len + self.e_len
    }

    /// The copy plan as `(global_offset, local_offset, len)` runs — the
    /// form [`crate::ckpt::LocalMap::from_copies`] builds the rank's
    /// checkpoint map from.
    pub fn copy_runs(&self) -> &[(usize, usize, usize)] {
        &self.copies
    }

    /// Extract the rank-local vector from a global parameter vector.
    pub fn extract(&self, global: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.local_len()];
        for &(g, l, n) in &self.copies {
            out[l..l + n].copy_from_slice(&global[g..g + n]);
        }
        out
    }

    /// Scatter a rank-local vector back into a global vector (expert
    /// slices land in this rank's rows; NE overwrites).
    pub fn scatter(&self, local: &[f32], global: &mut [f32]) {
        for &(g, l, n) in &self.copies {
            global[g..g + n].copy_from_slice(&local[l..l + n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_partitions_params() {
        let Some(m) = crate::manifest_or_skip("ep_layout::layout_partitions_params") else {
            return;
        };
        let mm = m.config("mula-tiny").unwrap();
        let (e_total, ne_total) = mm.expert_param_counts();
        let ep = 2;
        let l0 = EpLayout::new(mm, ep, 0);
        let l1 = EpLayout::new(mm, ep, 1);
        assert_eq!(l0.ne_len, ne_total);
        assert_eq!(l0.e_len, e_total / ep);
        assert_eq!(l0.local_len(), l1.local_len());
        // extraction round-trips: scatter from both ranks rebuilds global
        let global: Vec<f32> = (0..mm.param_count).map(|i| i as f32).collect();
        let a = l0.extract(&global);
        let b = l1.extract(&global);
        let mut rebuilt = vec![-1.0f32; mm.param_count];
        l0.scatter(&a, &mut rebuilt);
        l1.scatter(&b, &mut rebuilt);
        assert_eq!(rebuilt, global, "EP slices + NE must cover everything");
        // NE block identical across ranks
        assert_eq!(a[..l0.ne_len], b[..l1.ne_len]);
        // expert blocks disjoint
        assert_ne!(a[l0.ne_len..], b[l1.ne_len..]);
    }

    #[test]
    fn stage_layouts_partition_params() {
        let Some(m) = crate::manifest_or_skip("ep_layout::stage_layouts_partition_params")
        else {
            return;
        };
        let mm = m.config("mula-tiny").unwrap();
        let n_layers = mm.hyper.n_layers;
        assert!(n_layers % 2 == 0, "test assumes an even layer count");
        let (ep, pp) = (2usize, 2usize);
        let lps = n_layers / pp;
        let global: Vec<f32> = (0..mm.param_count).map(|i| i as f32).collect();
        // every (stage, ep_rank) extracts its slice; scattering all of
        // them back must rebuild the full vector exactly once
        let mut rebuilt = vec![-1.0f32; mm.param_count];
        for stage in 0..pp {
            for r in 0..ep {
                let lay = EpLayout::for_stage(
                    mm,
                    ep,
                    r,
                    stage * lps..(stage + 1) * lps,
                    stage == 0,
                    stage == pp - 1,
                );
                assert_eq!(lay.layer_ne.len(), lps);
                assert_eq!(lay.layer_e.len(), lps);
                assert_eq!(lay.emb.is_empty(), stage != 0);
                assert_eq!(lay.head.is_empty(), stage != pp - 1);
                let local = lay.extract(&global);
                lay.scatter(&local, &mut rebuilt);
            }
        }
        assert_eq!(rebuilt, global, "stage slices must cover every param");
        // the two stages of one ep rank add up to the whole-model layout
        let whole = EpLayout::new(mm, ep, 0);
        let s0 = EpLayout::for_stage(mm, ep, 0, 0..lps, true, false);
        let s1 = EpLayout::for_stage(mm, ep, 0, lps..n_layers, false, true);
        assert_eq!(s0.local_len() + s1.local_len(), whole.local_len());
        assert_eq!(s0.ne_len + s1.ne_len, whole.ne_len);
        assert_eq!(s0.e_len + s1.e_len, whole.e_len);
    }

    #[test]
    fn artifact_slices_are_contiguous_and_sized() {
        let Some(m) = crate::manifest_or_skip("ep_layout::artifact_slices_are_contiguous_and_sized")
        else {
            return;
        };
        let mm = m.config("mula-tiny").unwrap();
        let h = &mm.hyper;
        let l = EpLayout::new(mm, 2, 1);
        // ep2_layer_pre_fwd expects 4h² + 2h + h*N params
        let want_ne = 4 * h.hidden * h.hidden + 2 * h.hidden + h.hidden * h.n_experts;
        for r in &l.layer_ne {
            assert_eq!(r.len(), want_ne);
        }
        // ep2_expert_fwd expects 3 * NR * hidden * intermediate
        let want_e = 3 * (h.n_experts / 2) * h.hidden * h.intermediate;
        for r in &l.layer_e {
            assert_eq!(r.len(), want_e);
        }
        assert_eq!(l.head.len(), h.hidden + h.hidden * h.vocab_size);
        assert_eq!(l.emb.len(), h.vocab_size * h.hidden);
    }
}
