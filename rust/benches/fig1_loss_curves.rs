//! Figure 1: (a) dense vs MoE training loss at iso-compute
//! (mula-mini-dense vs mula-mini — same active compute, MoE has 2x total
//! params); (b) loss vs model size for the MoE family to a fixed token
//! budget. Paper shape to match: MoE below dense at equal steps; larger
//! models lower.

use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec};
use optimus::data::{corpus, preprocess};
use optimus::util::bench::Report;

fn run(m: &Manifest, model: &str, steps: usize, data: &std::path::Path)
    -> optimus::Result<optimus::coordinator::TrainReport>
{
    let spec = JobSpec::new(model)
        .data_dir(data.to_path_buf())
        .topology(2, 1, 1)
        .steps(steps)
        .warmup_steps(steps / 8)
        .peak_lr(1.5e-3)
        .min_lr(1.5e-4)
        .engine_pool(2)
        .build()?;
    coordinator::train(m, &spec)
}

fn main() -> optimus::Result<()> {
    let m = Manifest::load(&optimus::artifacts_dir())?;
    let data_dir = std::env::temp_dir().join("optimus-fig1-data");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 8, 64), 192, 7, &data_dir, 2048)?;
    }

    // --- Fig 1a: dense vs MoE, iso-compute ---
    let steps = 14;
    let dense = run(&m, "mula-tiny-dense", steps, &data_dir)?;
    let moe = run(&m, "mula-tiny", steps, &data_dir)?;
    let mut a = Report::new(
        "Fig 1a: training loss, dense vs iso-compute MoE (mula-tiny scale)",
        &["step", "dense", "moe"],
    );
    for i in (0..steps).step_by(3).chain([steps - 1]) {
        a.row(&[
            i.to_string(),
            format!("{:.4}", dense.loss.points[i].1),
            format!("{:.4}", moe.loss.points[i].1),
        ]);
    }
    a.print();
    a.write_csv("fig1a_dense_vs_moe").ok();
    let d_end = dense.loss.tail_mean(5);
    let m_end = moe.loss.tail_mean(5);
    println!("final: dense {d_end:.4} vs moe {m_end:.4} — paper shape: moe <= dense");

    // --- Fig 1b: model scaling to a fixed token budget ---
    let mut b = Report::new(
        "Fig 1b: loss at fixed token budget vs model size (full sweep: OPTIMUS_BENCH_FULL=1)",
        &["model", "total params", "loss(tail)"],
    );
    // full sweep (mini/small/med) only when explicitly requested: their
    // interpret-mode MoE steps take minutes each on a single-core host
    let full = std::env::var("OPTIMUS_BENCH_FULL").is_ok();
    let sweep: &[(&str, usize)] = if full {
        &[("mula-tiny", 8), ("mula-mini", 8), ("mula-small", 8), ("mula-med", 8)]
    } else {
        &[("mula-tiny", 14), ("mula-tiny-dense", 14)]
    };
    for &(name, steps) in sweep {
        let r = run(&m, name, steps, &data_dir)?;
        let mm = m.config(name)?;
        b.row(&[
            name.into(),
            format!("{:.1}M", mm.param_count as f64 / 1e6),
            format!("{:.4}", r.loss.tail_mean(3)),
        ]);
    }
    b.print();
    b.write_csv("fig1b_model_scaling").ok();
    Ok(())
}
