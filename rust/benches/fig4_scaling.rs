//! Figure 4: (a) measured loss vs compute scale (global batch grows with
//! DP, mula-tiny); (b) Aurora-model scaling efficiency of Mula-220B-A10B
//! from 384 to 12288 tiles, with and without Forced Uniform Routing.

use optimus::cluster::{scaling_efficiency, step_time, Aurora, ParallelPlan};
use optimus::config::models::MULA_220B;
use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec};
use optimus::coordinator::pipeline::Schedule;
use optimus::data::{corpus, preprocess};
use optimus::util::bench::Report;

fn main() -> optimus::Result<()> {
    let m = Manifest::load(&optimus::artifacts_dir())?;
    let data_dir = std::env::temp_dir().join("optimus-fig4-data");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 6, 64), 64, 7, &data_dir, 2048)?;
    }

    let mut a = Report::new(
        "Fig 4a (measured analog): loss decreases with compute scale",
        &["dp", "tokens/step", "loss@18-20"],
    );
    for dp in [1usize, 2, 4] {
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data_dir.clone())
            .topology(dp, 1, 1)
            .steps(12)
            .warmup_steps(4)
            .peak_lr(2e-3)
            .engine_pool(dp.min(4))
            .build()?;
        let r = coordinator::train(&m, &spec)?;
        a.row(&[
            dp.to_string(),
            r.tokens_per_step.to_string(),
            format!("{:.4}", r.loss.tail_mean(2)),
        ]);
    }
    a.print();
    a.write_csv("fig4a_loss_vs_scale").ok();

    let hw = Aurora::default();
    let mut b = Report::new(
        "Fig 4b (modeled): Mula-220B-A10B weak-scaling efficiency",
        &["tiles", "regular", "FUR"],
    );
    for tiles in [384usize, 768, 1536, 3072, 6144, 12288] {
        b.row(&[
            tiles.to_string(),
            format!("{:.3}", scaling_efficiency(&MULA_220B, &hw, 384, tiles, false)),
            format!("{:.3}", scaling_efficiency(&MULA_220B, &hw, 384, tiles, true)),
        ]);
    }
    b.print();
    b.write_csv("fig4b_scaling_efficiency").ok();

    // step-time breakdown at the paper's 220B plan (sanity/bookkeeping)
    let plan = ParallelPlan {
        dp: 128, ep: 12, pp: 8, micro_batches: 16,
        schedule: Schedule::OneFOneB, tokens_per_tile: 4096, fur: false,
        wire_bytes: ParallelPlan::wire_bytes_for("bf16"),
    };
    let s = step_time(&MULA_220B, &hw, &plan, true);
    println!(
        "\nmodeled 220B step @12288 tiles: compute {:.2}s dp_comm {:.2}s \
         ep_comm {:.3}s bubble {:.2}s opt {:.3}s (total {:.2}s)",
        s.compute, s.dp_comm, s.ep_comm, s.pp_bubble, s.optimizer, s.total()
    );
    Ok(())
}
