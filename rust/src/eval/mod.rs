//! Synthetic benchmark suite — the lm-eval substitution (Table 2,
//! Figs 2-3; DESIGN.md §1).
//!
//! Probe tasks are built from the same generators as the training corpus,
//! so accuracies measure what the paper's benchmarks measure: whether the
//! model absorbed the corpus's structure. Tasks:
//!
//! * `fact_recall`  — "the home of {subj} {i} is ___" (consistent facts)
//! * `arithmetic`   — "{a}+{b}=___"
//! * `copy`         — "copy {w} -> ___"
//! * `bigram_lm`    — next-word accuracy on grammar sentences
//! * `held_out_ppl` — perplexity on unseen documents (reported as a
//!   bounded score 100·exp(-nll) for table-compatibility)

use crate::config::ModelManifest;
use crate::data::corpus;
use crate::data::tokenizer::{Tokenizer, EOS};
use crate::runtime::{Engine, Tensor};
use crate::util::prng::Prng;
use crate::Result;
use std::collections::BTreeMap;

pub const TASKS: [&str; 5] =
    ["fact_recall", "arithmetic", "copy", "bigram_lm", "held_out_ppl"];

/// One prompt/answer pair (token ids).
struct Case {
    prompt: Vec<u32>,
    answer: Vec<u32>,
}

fn cases_for(task: &str, n: usize, seed: u64) -> Vec<Case> {
    let tok = Tokenizer::new();
    let mut rng = Prng::new(seed ^ 0xE7A1);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let (prompt, answer) = match task {
            "fact_recall" => {
                let id = i % 64;
                let (a, b) = corpus::fact(id);
                (format!("the home of {a} {id} is "), format!("{b}"))
            }
            "arithmetic" => {
                let a = rng.below(50);
                let b = rng.below(50);
                (format!("{a}+{b}="), format!("{}", a + b))
            }
            "copy" => {
                let w: String = (0..4 + rng.below(4))
                    .map(|_| (b'a' + rng.below(26) as u8) as char)
                    .collect();
                (format!("copy {w} -> "), w)
            }
            "bigram_lm" => {
                // grammar: "{subj} {verb} {obj}" with deterministic
                // verb/obj per subject — predict the verb+object
                let subjects = ["aurora", "router", "expert", "pipeline"];
                let s = subjects[rng.below(subjects.len())];
                let mut d = corpus::document(&mut rng, 40);
                if let Some(p) = d.find(s) {
                    d.truncate(p);
                }
                (format!("{s} "), String::new())
            }
            "held_out_ppl" => {
                let mut r2 = Prng::new(0xDEAD + i as u64); // never in corpus seeds
                (corpus::document(&mut r2, 120), String::new())
            }
            _ => unreachable!(),
        };
        out.push(Case { prompt: tok.encode(&prompt), answer: tok.encode(&answer) });
    }
    out
}

/// Run the suite against a parameter tensor via the `eval_step` artifact.
/// `params` is `Arc`-backed: every batch submission is a refcount bump,
/// not a copy of the full model. Returns task → score in [0, 100].
pub fn run_suite(
    engine: &Engine,
    mm: &ModelManifest,
    params: &Tensor,
    cases_per_task: usize,
) -> Result<BTreeMap<String, f64>> {
    let (b, s) = (mm.hyper.batch, mm.hyper.seq);
    let art = mm.artifact_path("eval_step")?;
    let mut scores = BTreeMap::new();
    for task in TASKS {
        let cases = cases_for(task, cases_per_task, 7);
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut nll_sum = 0.0f64;
        let mut nll_n = 0usize;
        // pack cases into batches of b rows
        for chunk in cases.chunks(b) {
            let mut toks = vec![EOS as i32; b * (s + 1)];
            let mut answer_spans = Vec::with_capacity(chunk.len());
            for (r, case) in chunk.iter().enumerate() {
                let mut row: Vec<u32> = case.prompt.clone();
                let astart = row.len();
                row.extend_from_slice(&case.answer);
                row.truncate(s + 1);
                for (j, t) in row.iter().enumerate() {
                    toks[r * (s + 1) + j] = *t as i32;
                }
                answer_spans.push((astart, row.len().min(astart + case.answer.len())));
            }
            let outs = engine.exec(
                &format!("{}:eval_step", mm.name),
                art.clone(),
                vec![params.clone(), Tensor::i32(toks.clone(), vec![b, s + 1])],
            )?;
            let nll = outs[0].as_f32()?;
            let preds = outs[1].as_i32()?;
            for (r, case) in chunk.iter().enumerate() {
                let (a0, a1) = answer_spans[r];
                if task == "held_out_ppl" || task == "bigram_lm" {
                    // perplexity over the prompt tokens
                    let upto = case.prompt.len().min(s);
                    for j in 1..upto {
                        nll_sum += nll[r * s + j - 1] as f64;
                        nll_n += 1;
                    }
                    continue;
                }
                // answer-span token accuracy: pred at position j-1
                // predicts token j
                let mut all_ok = a1 > a0;
                for j in a0..a1 {
                    if j == 0 || j > s {
                        continue;
                    }
                    let want = toks[r * (s + 1) + j];
                    let got = preds[r * s + j - 1];
                    if want != got {
                        all_ok = false;
                    }
                }
                total += 1;
                if all_ok {
                    correct += 1;
                }
            }
        }
        let score = if task == "held_out_ppl" || task == "bigram_lm" {
            // bounded score: 100 * exp(-nll) (unigram-random ≈ low)
            100.0 * (-(nll_sum / nll_n.max(1) as f64)).exp()
        } else {
            100.0 * correct as f64 / total.max(1) as f64
        };
        scores.insert(task.to_string(), score);
    }
    Ok(scores)
}

/// Macro-average of the task scores (Table 2's "Average" row).
pub fn average(scores: &BTreeMap<String, f64>) -> f64 {
    scores.values().sum::<f64>() / scores.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_have_prompts_and_deterministic_facts() {
        for task in TASKS {
            let c = cases_for(task, 8, 1);
            assert_eq!(c.len(), 8);
            assert!(c.iter().all(|x| !x.prompt.is_empty()));
        }
        let a = cases_for("fact_recall", 4, 1);
        let b = cases_for("fact_recall", 4, 1);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn random_params_score_near_zero_on_probes() {
        let Some(m) = crate::manifest_or_skip("eval::random_params_score_near_zero_on_probes")
        else {
            return;
        };
        let mm = m.config("mula-tiny").unwrap();
        let engine = Engine::new().unwrap();
        let params = Tensor::f32(
            crate::coordinator::init_global_params(mm, 3),
            vec![mm.param_count],
        );
        let scores = run_suite(&engine, mm, &params, 8).unwrap();
        assert_eq!(scores.len(), TASKS.len());
        // an untrained byte model almost never emits a full correct answer
        assert!(scores["fact_recall"] < 40.0, "{scores:?}");
        assert!(scores["copy"] < 40.0, "{scores:?}");
        for v in scores.values() {
            assert!((0.0..=100.0).contains(v));
        }
    }
}
