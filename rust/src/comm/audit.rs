//! Collective **protocol auditor**: fail fast on rendezvous misuse.
//!
//! The rendezvous in [`super::Group`] assumes SPMD discipline — every
//! member issues the *same* collectives on a group in the *same* program
//! order. At scale the violation mode is not a crash but a silent
//! corruption (two different ops zipped into one reduction) or a hang
//! (one rank off by a round). The auditor turns both into an immediate,
//! attributable failure:
//!
//! * every deposit carries an [`OpDesc`] (op kind, payload length, wire
//!   dtype) — built exactly once per issued op by
//!   [`CollectiveOp::desc`](super::CollectiveOp::desc), so the auditor
//!   checks the very descriptor the program stated rather than one
//!   reconstructed per method;
//! * the **first arrival of a round pins** the round's descriptor;
//! * any mismatching later arrival fails the whole group with a stable
//!   `collective protocol violated [order|shape|dtype]` error
//!   ([`crate::ft::checks::PROTOCOL`]), poisoning the group so compliant
//!   peers unblock instead of waiting forever;
//! * the auditor also remembers each member's **last deposited op**, so
//!   the deadlock watchdog's `[stall]` dump can report
//!   `rank 0 last seen at reduce_scatter round 17` for every peer.
//!
//! Classification: `order`/`shape`/`dtype` are deterministic program
//! bugs → [`FailureKind::Config`](crate::ft::FailureKind) (relaunching
//! replays the same program order); `stall` → `Hard` (the dominant cause
//! is a dead peer, which a relaunch on a buffer node fixes).

use crate::ft::checks;
use std::fmt;

/// Which collective a member deposited into the round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Allreduce,
    AllreduceMax,
    ReduceScatter,
    Allgather,
    All2All,
    /// root consistency is part of the protocol: two members disagreeing
    /// on the broadcast root is an order violation
    Broadcast { root: usize },
    Barrier,
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpKind::Allreduce => write!(f, "allreduce"),
            OpKind::AllreduceMax => write!(f, "allreduce_max"),
            OpKind::ReduceScatter => write!(f, "reduce_scatter"),
            OpKind::Allgather => write!(f, "allgather"),
            OpKind::All2All => write!(f, "all2all"),
            OpKind::Broadcast { root } => write!(f, "broadcast(root={root})"),
            OpKind::Barrier => write!(f, "barrier"),
        }
    }
}

/// Element width a contribution travels at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireDtype {
    F32,
    Bf16,
}

impl fmt::Display for WireDtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireDtype::F32 => write!(f, "f32"),
            WireDtype::Bf16 => write!(f, "bf16"),
        }
    }
}

/// One member's deposit descriptor for a rendezvous round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpDesc {
    pub kind: OpKind,
    /// element count, for ops whose members must contribute equal
    /// lengths (allreduce / reduce_scatter: a mismatch would silently
    /// truncate the elementwise zip). `None` for ragged-legal ops
    /// (allgather, all2all) and broadcast (non-roots deposit empty).
    pub len: Option<usize>,
    pub dtype: WireDtype,
}

impl fmt::Display for OpDesc {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.len {
            Some(n) => write!(f, "{} (len {n}, {})", self.kind, self.dtype),
            None => write!(f, "{} ({})", self.kind, self.dtype),
        }
    }
}

/// A failed collective, as seen by one member. The `Display` strings are
/// the crate's stable failure contract — tests assert them and
/// [`crate::ft::classify`] routes on them.
#[derive(Debug)]
pub enum CommFault {
    /// this member (or a peer in the same round) broke the protocol
    Violated {
        /// registered check name under [`checks::PROTOCOL`]:
        /// `order` / `shape` / `dtype` / `stall`
        check: &'static str,
        detail: String,
    },
    /// a peer rank died (or violated the protocol first); the group is
    /// poisoned and every pending/future collective on it fails
    Poisoned,
}

impl fmt::Display for CommFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommFault::Violated { check, detail } => {
                write!(f, "{}", checks::msg(checks::PROTOCOL, check, detail))
            }
            CommFault::Poisoned => write!(f, "comm group poisoned: a peer rank failed"),
        }
    }
}

impl std::error::Error for CommFault {}

/// Per-round protocol state, embedded in the group's `RoundState` (so it
/// is guarded by the same mutex as the deposits it audits).
pub(super) struct Audit {
    /// the active round's descriptor and the rank that pinned it
    pinned: Option<(OpDesc, usize)>,
    /// each member's last deposited op and its round — survives round
    /// resets; this is what the `[stall]` dump prints
    last: Vec<Option<(OpDesc, u64)>>,
}

impl Audit {
    pub(super) fn new(size: usize) -> Audit {
        Audit { pinned: None, last: (0..size).map(|_| None).collect() }
    }

    /// Record `rank`'s deposit for `round` and verify it against the
    /// round's pinned descriptor (pinning it if `rank` arrived first).
    pub(super) fn check(&mut self, rank: usize, round: u64, desc: OpDesc) -> Result<(), CommFault> {
        // record first: even a violating deposit is "last seen" evidence
        // for whoever dumps the table afterwards
        self.last[rank] = Some((desc, round));
        let Some((pinned, pinner)) = self.pinned else {
            self.pinned = Some((desc, rank));
            return Ok(());
        };
        let blame = |check, what: &str| CommFault::Violated {
            check,
            detail: format!(
                "rank {rank} deposited {desc} into round {round}, but rank {pinner} \
                 pinned the round to {pinned} — {what}"
            ),
        };
        if desc.kind != pinned.kind {
            return Err(blame("order", "members disagree on which collective this round is"));
        }
        if desc.dtype != pinned.dtype {
            return Err(blame("dtype", "members disagree on the wire dtype"));
        }
        if let (Some(a), Some(b)) = (desc.len, pinned.len) {
            if a != b {
                return Err(blame(
                    "shape",
                    "equal-contribution op with mismatched payload lengths",
                ));
            }
        }
        Ok(())
    }

    /// The active round has fully drained; the next round pins afresh.
    pub(super) fn round_drained(&mut self) {
        self.pinned = None;
    }

    /// Per-rank last-op table for the watchdog dump, one line per member.
    pub(super) fn table(&self, group: &str) -> String {
        let mut out = String::new();
        for (r, seen) in self.last.iter().enumerate() {
            match seen {
                Some((desc, round)) => out.push_str(&format!(
                    "  rank {r} last seen at {desc} round {round} on group `{group}`\n"
                )),
                None => out.push_str(&format!(
                    "  rank {r} never deposited on group `{group}`\n"
                )),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ar(len: usize, dtype: WireDtype) -> OpDesc {
        OpDesc { kind: OpKind::Allreduce, len: Some(len), dtype }
    }

    #[test]
    fn first_arrival_pins_matching_members_pass() {
        let mut a = Audit::new(3);
        a.check(1, 0, ar(8, WireDtype::F32)).unwrap();
        a.check(0, 0, ar(8, WireDtype::F32)).unwrap();
        a.check(2, 0, ar(8, WireDtype::F32)).unwrap();
        a.round_drained();
        // next round re-pins: a different (consistent) op is fine
        let ag = OpDesc { kind: OpKind::Allgather, len: None, dtype: WireDtype::F32 };
        a.check(0, 1, ag).unwrap();
        a.check(1, 1, ag).unwrap();
    }

    #[test]
    fn kind_mismatch_is_an_order_violation() {
        let mut a = Audit::new(2);
        a.check(0, 4, ar(8, WireDtype::F32)).unwrap();
        let e = a
            .check(1, 4, OpDesc { kind: OpKind::Allgather, len: None, dtype: WireDtype::F32 })
            .unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("collective protocol violated [order]"), "{msg}");
        assert!(msg.contains("rank 1") && msg.contains("rank 0"), "{msg}");
        assert!(msg.contains("allgather") && msg.contains("allreduce"), "{msg}");
    }

    #[test]
    fn len_mismatch_is_a_shape_violation_only_for_equal_contribution_ops() {
        let mut a = Audit::new(2);
        a.check(0, 0, ar(8, WireDtype::F32)).unwrap();
        let e = a.check(1, 0, ar(9, WireDtype::F32)).unwrap_err();
        assert!(e.to_string().contains("collective protocol violated [shape]"), "{e}");
        // ragged allgather: len is None, never compared
        let mut a = Audit::new(2);
        let ag = |l| OpDesc { kind: OpKind::Allgather, len: l, dtype: WireDtype::F32 };
        a.check(0, 0, ag(None)).unwrap();
        a.check(1, 0, ag(None)).unwrap();
    }

    #[test]
    fn dtype_mismatch_is_a_dtype_violation() {
        let mut a = Audit::new(2);
        a.check(0, 0, ar(8, WireDtype::F32)).unwrap();
        let e = a.check(1, 0, ar(8, WireDtype::Bf16)).unwrap_err();
        assert!(e.to_string().contains("collective protocol violated [dtype]"), "{e}");
    }

    #[test]
    fn broadcast_root_disagreement_is_an_order_violation() {
        let mut a = Audit::new(2);
        let bc = |root| OpDesc { kind: OpKind::Broadcast { root }, len: None, dtype: WireDtype::F32 };
        a.check(0, 0, bc(0)).unwrap();
        let e = a.check(1, 0, bc(1)).unwrap_err();
        assert!(e.to_string().contains("[order]"), "{e}");
    }

    #[test]
    fn last_op_table_reports_stragglers() {
        let mut a = Audit::new(3);
        a.check(0, 17, OpDesc { kind: OpKind::ReduceScatter, len: Some(4), dtype: WireDtype::F32 })
            .unwrap();
        let t = a.table("dp[0]");
        assert!(t.contains("rank 0 last seen at reduce_scatter (len 4, f32) round 17"), "{t}");
        assert!(t.contains("rank 1 never deposited"), "{t}");
        assert!(t.contains("rank 2 never deposited"), "{t}");
    }
}
