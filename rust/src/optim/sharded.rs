//! Sharded optimizers: SO (ZeRO-1 style) and the paper's **EPSO** (§3.2).
//!
//! The local parameter vector of a rank is split into *segments*, each
//! synchronized and sharded over a process group:
//!
//! * **SO** (baseline): every segment shards over the **DP group** only.
//!   With EP, non-expert optimizer states are therefore replicated EP
//!   times (the inefficiency Figure 6 shows).
//! * **EPSO**: expert segments shard over **DP** (their replication
//!   domain), non-expert segments shard over **DP×EP** — optimizer states
//!   are never replicated, shards shrink, the optimizer step gets faster
//!   (Table 3, 1.07-1.36×).
//!
//! Step = reduce-scatter(grads) → global-norm clip → AdamW on owned shard
//! → allgather(params), per segment. Gradient reduction optionally rounds
//! through bf16 (paper §2.1 recipe).
//!
//! With [`ShardedOptimizer::with_overlap`] the step runs as a **software
//! pipeline** at `chunk`-element granularity on a per-rank
//! [`CommRuntime`] lane: reduce-scatter of chunk *k+1* is in flight while
//! chunk *k* is staged, and during the update phase AdamW on chunk *k*
//! overlaps the allgather of chunk *k−1*. The global-norm clip is folded
//! in via a *deferred scale* — gradients are never pre-scaled; the scale
//! reaches AdamW as `grad_scale` after the norm allreduce (and when
//! clipping is off that allreduce itself is deferred past the update
//! pipeline). Chunking never moves shard boundaries and every per-element
//! operation is unchanged, so the pipelined step is **bit-identical** to
//! the serial one (property-tested below; DESIGN.md §6 has the argument).

use super::adamw::{clip_scale, sumsq, AdamParams, AdamState};
use crate::comm::{
    CollectiveOp, CollectiveOut, CommHandle, CommRuntime, Group, Parts, Reduce, ReduceDtype,
};
use crate::runtime::{Dtype, Tensor};
use crate::util::{bf16s_to_f32s, f32s_to_bf16s, shard_ranges};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardingMode {
    /// standard sharded optimizer: shard over DP only
    So,
    /// EP-aware: non-expert over DP×EP, expert over DP
    Epso,
}

/// One contiguous segment of the rank-local parameter vector.
pub struct SegmentSpec {
    /// offset in the local parameter vector
    pub local_offset: usize,
    pub len: usize,
    /// group that replicates this segment (gradient sync + shard domain)
    pub group: Arc<Group>,
    pub group_rank: usize,
    /// multiplicity correction for the global grad-norm: 1/(number of
    /// times this segment's shards are counted across the world)
    pub norm_weight: f64,
}

struct Segment {
    spec: SegmentSpec,
    /// owned shard range within the segment
    shard: (usize, usize),
    state: AdamState,
    /// staging for the post-reduce shard gradient
    shard_grad: Vec<f32>,
    /// f32 master copy of the owned shard — populated only on the bf16
    /// mixed-precision path (paper §2.1: bf16 weights, fp32 master +
    /// moments). Empty on the f32 path and never checkpointed: a resume
    /// re-derives it from the bf16 params, which is exactly the
    /// loss-trajectory tolerance contract of mixed precision.
    master: Vec<f32>,
}

/// Per-rank sharded optimizer instance.
pub struct ShardedOptimizer {
    segments: Vec<Segment>,
    /// group spanning every contributor to the global grad norm (the
    /// full DP×EP domain of the pp stage, independent of sharding mode)
    norm_group: Arc<Group>,
    norm_rank: usize,
    pub hp: AdamParams,
    pub reduce_dtype: ReduceDtype,
    pub max_grad_norm: f64,
    /// time spent in the local AdamW update (the component EPSO speeds up)
    pub update_secs: f64,
    /// time spent in collectives. Serial step: end-to-end collective
    /// time. Pipelined step: *exposed* comm only — time the rank thread
    /// actually blocked on a [`CommHandle`]
    pub comm_secs: f64,
    /// comm time hidden behind compute by the overlap pipeline (lane busy
    /// time minus exposed waits). Concurrent with `update_secs`, so it is
    /// informational and never part of a wall-clock sum
    pub overlap_secs: f64,
    /// pipeline chunk length in elements (overlap mode)
    chunk: usize,
    /// per-rank async comm lane; `Some` ⇔ the pipelined step is active
    rt: Option<CommRuntime>,
}

impl ShardedOptimizer {
    pub fn new(
        specs: Vec<SegmentSpec>,
        norm_group: Arc<Group>,
        norm_rank: usize,
        hp: AdamParams,
        reduce_dtype: ReduceDtype,
        max_grad_norm: f64,
    ) -> ShardedOptimizer {
        let segments = specs
            .into_iter()
            .map(|spec| {
                let shard = shard_ranges(spec.len, spec.group.size())[spec.group_rank];
                Segment {
                    shard,
                    state: AdamState::new(shard.1),
                    shard_grad: vec![0.0; shard.1],
                    master: Vec::new(),
                    spec,
                }
            })
            .collect();
        ShardedOptimizer {
            segments,
            norm_group,
            norm_rank,
            hp,
            reduce_dtype,
            max_grad_norm,
            update_secs: 0.0,
            comm_secs: 0.0,
            overlap_secs: 0.0,
            chunk: 0,
            rt: None,
        }
    }

    /// Enable the pipelined step (paper §3.2 overlap): collectives run on
    /// a dedicated comm lane at `chunk`-element granularity while the
    /// rank thread computes. Bit-identical to the serial step. `label`
    /// names the worker thread (`comm-<label>`). `on = false` is a no-op
    /// so call sites can thread the plan knob through unconditionally.
    pub fn with_overlap(mut self, on: bool, chunk: usize, label: &str) -> ShardedOptimizer {
        if on {
            assert!(chunk > 0, "overlap chunk must be > 0 (plan validation enforces this)");
            self.chunk = chunk;
            self.rt = Some(CommRuntime::new(label));
        }
        self
    }

    /// Whether the pipelined (overlapped) step is active.
    pub fn overlapped(&self) -> bool {
        self.rt.is_some()
    }

    /// Collectives completed on the comm lane (0 on the serial path) — a
    /// falsifiable liveness signal that the pipelined step actually ran,
    /// used by the overlap acceptance tests.
    pub fn lane_ops(&self) -> u64 {
        self.rt.as_ref().map(|rt| rt.completed_ops()).unwrap_or(0)
    }

    /// Optimizer-state bytes held by this rank — the quantity EPSO shrinks
    /// (paper Figure 6). On the bf16 path this includes the f32 master
    /// shard (12 bytes/sharded param instead of 8).
    pub fn state_bytes(&self) -> usize {
        self.segments
            .iter()
            .map(|s| s.state.bytes() + s.master.len() * 4)
            .sum()
    }

    /// Per-segment persistent state as O(1) `Arc` handles — what the
    /// checkpoint path captures at a step boundary
    /// ([`crate::ckpt::capture_rank_state`]). Serialization happens later
    /// on the writer thread; the next `step` copy-on-writes past any
    /// still-alive snapshot.
    pub fn export_state(&self) -> Vec<SegmentState> {
        self.segments
            .iter()
            .map(|s| {
                let (ss, sl) = s.shard;
                let (m, v) = s.state.snapshot();
                SegmentState {
                    local_start: s.spec.local_offset + ss,
                    len: sl,
                    m,
                    v,
                    step: s.state.step,
                }
            })
            .collect()
    }

    /// `(local_start, len)` of each segment's owned shard within the
    /// rank-local parameter vector, in segment order — the geometry the
    /// elastic restore path re-slices a checkpoint through.
    pub fn shard_extents(&self) -> Vec<(usize, usize)> {
        self.segments
            .iter()
            .map(|s| (s.spec.local_offset + s.shard.0, s.shard.1))
            .collect()
    }

    /// Restore one segment's moments (checkpoint resume). `step` is the
    /// count of optimizer steps already taken — the AdamW bias-correction
    /// counter a resumed run continues from.
    pub fn import_state(
        &mut self,
        idx: usize,
        m: Vec<f32>,
        v: Vec<f32>,
        step: u64,
    ) -> crate::Result<()> {
        let n = self.segments.len();
        let seg = self
            .segments
            .get_mut(idx)
            .ok_or_else(|| anyhow::anyhow!("import_state: no segment {idx} (have {n})"))?;
        seg.state.load(m, v, step)
    }

    /// Owned shard sizes (diagnostics / tests).
    pub fn shard_lens(&self) -> Vec<usize> {
        self.segments.iter().map(|s| s.shard.1).collect()
    }

    /// One optimizer step. `params`/`grads` are the rank-local vectors;
    /// `clip` enables global-norm clipping (paper: only after warmup).
    /// Returns the global gradient norm (pre-clip). Dispatches to the
    /// pipelined step when [`ShardedOptimizer::with_overlap`] armed it;
    /// both paths produce bit-identical parameters.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32, clip: bool) -> f64 {
        if self.rt.is_some() {
            self.step_pipelined(params, grads, lr, clip)
        } else {
            self.step_serial(params, grads, lr, clip)
        }
    }

    /// Dtype-dispatching step over a parameter [`Tensor`]. `F32` tensors
    /// take the exact same path as [`ShardedOptimizer::step`]
    /// (bit-identical to before this entry point existed); `Bf16` tensors
    /// take the mixed-precision path: bf16 gradient wires, f32 master
    /// weights + moments, bf16 parameter allgather. Plan validation
    /// rejects bf16 + overlap, so the bf16 path is always serial.
    pub fn step_tensor(
        &mut self,
        params: &mut Tensor,
        grads: &[f32],
        lr: f32,
        clip: bool,
    ) -> crate::Result<f64> {
        match params.dtype() {
            Dtype::F32 => Ok(self.step(params.as_f32_mut()?, grads, lr, clip)),
            Dtype::Bf16 => {
                if self.rt.is_some() {
                    return Err(anyhow::anyhow!(
                        "bf16 params cannot use the overlapped optimizer step \
                         (plan validation rejects dtype=bf16 with overlap=on)"
                    ));
                }
                Ok(self.step_bf16(params.as_bf16_mut()?, grads, lr, clip))
            }
        }
    }

    /// The mixed-precision serial step (paper §2.1): same four phases as
    /// [`ShardedOptimizer::step_serial`], with half-width wires where
    /// precision allows it —
    ///
    /// 1. reduce-scatter grads at **bf16** wire width (2 bytes/elem on
    ///    the fabric, values rounded to nearest-even before summing);
    /// 2. global grad norm in f32 (one scalar — never worth rounding);
    /// 3. AdamW on the **f32 master** shard. The master is seeded lazily
    ///    by decoding the bf16 params on the first mixed step (and again
    ///    after a checkpoint resume — masters are derived state, never
    ///    saved), then carries full precision across steps so tiny
    ///    updates don't vanish in bf16's 8 mantissa bits;
    /// 4. allgather the bf16-encoded master shards (half-width again)
    ///    back into the bf16 parameter vector.
    fn step_bf16(&mut self, params: &mut [u16], grads: &[f32], lr: f32, clip: bool) -> f64 {
        // Phase 1: reduce-scatter each segment's grads at bf16 width.
        let t0 = std::time::Instant::now();
        for seg in self.segments.iter_mut() {
            let g = grads[seg.spec.local_offset..seg.spec.local_offset + seg.spec.len].to_vec();
            let reduced = seg
                .spec
                .group
                .run(
                    seg.spec.group_rank,
                    CollectiveOp::ReduceScatter {
                        data: g,
                        red: Reduce::Mean,
                        dt: ReduceDtype::Bf16,
                        parts: Parts::Ragged,
                    },
                )
                .unwrap_or_else(|f| panic!("{f}"))
                .values();
            debug_assert_eq!(reduced.len(), seg.shard.1);
            seg.shard_grad.copy_from_slice(&reduced);
        }
        // Phase 2: global grad norm, full precision.
        let mut local_sumsq = 0.0f64;
        for seg in &self.segments {
            local_sumsq += sumsq(&seg.shard_grad) * seg.spec.norm_weight;
        }
        let total = self
            .norm_group
            .run(
                self.norm_rank,
                CollectiveOp::Allreduce {
                    data: vec![local_sumsq as f32],
                    red: Reduce::Sum,
                    dt: ReduceDtype::F32,
                },
            )
            .unwrap_or_else(|f| panic!("{f}"))
            .values()[0] as f64;
        self.comm_secs += t0.elapsed().as_secs_f64();

        let scale = if clip { clip_scale(total, self.max_grad_norm) } else { 1.0 };

        // Phase 3: AdamW on the f32 master shard.
        let t1 = std::time::Instant::now();
        for seg in self.segments.iter_mut() {
            let (s, l) = seg.shard;
            let base = seg.spec.local_offset + s;
            if seg.master.len() != l {
                // first mixed step (or post-resume): seed from bf16 params
                seg.master = bf16s_to_f32s(&params[base..base + l]);
            }
            let grads_shard = seg.shard_grad.clone();
            seg.state.update(self.hp, lr, scale, &mut seg.master, &grads_shard);
        }
        self.update_secs += t1.elapsed().as_secs_f64();

        // Phase 4: allgather bf16-encoded master shards.
        let t2 = std::time::Instant::now();
        for seg in self.segments.iter_mut() {
            let mine = f32s_to_bf16s(&seg.master);
            let full = seg
                .spec
                .group
                .run(seg.spec.group_rank, CollectiveOp::AllgatherBits { data: mine })
                .unwrap_or_else(|f| panic!("{f}"))
                .bits();
            debug_assert_eq!(full.len(), seg.spec.len);
            params[seg.spec.local_offset..seg.spec.local_offset + seg.spec.len]
                .copy_from_slice(&full);
        }
        self.comm_secs += t2.elapsed().as_secs_f64();
        total.sqrt()
    }

    /// The baseline strictly-serial step: reduce-scatter all segments →
    /// norm → AdamW all shards → allgather all segments.
    fn step_serial(&mut self, params: &mut [f32], grads: &[f32], lr: f32, clip: bool) -> f64 {
        // Phase 1: reduce-scatter each segment's grads over its group.
        let t0 = std::time::Instant::now();
        for seg in self.segments.iter_mut() {
            let g = grads[seg.spec.local_offset..seg.spec.local_offset + seg.spec.len].to_vec();
            let reduced = seg
                .spec
                .group
                .run(
                    seg.spec.group_rank,
                    CollectiveOp::ReduceScatter {
                        data: g,
                        red: Reduce::Mean,
                        dt: self.reduce_dtype,
                        parts: Parts::Ragged,
                    },
                )
                .unwrap_or_else(|f| panic!("{f}"))
                .values();
            debug_assert_eq!(reduced.len(), seg.shard.1);
            seg.shard_grad.copy_from_slice(&reduced);
        }
        // Phase 2: global grad norm (sum of owned-shard sumsq, weighted by
        // multiplicity, allreduced over the widest group).
        let mut local_sumsq = 0.0f64;
        for seg in &self.segments {
            local_sumsq += sumsq(&seg.shard_grad) * seg.spec.norm_weight;
        }
        let total = self
            .norm_group
            .run(
                self.norm_rank,
                CollectiveOp::Allreduce {
                    data: vec![local_sumsq as f32],
                    red: Reduce::Sum,
                    dt: ReduceDtype::F32,
                },
            )
            .unwrap_or_else(|f| panic!("{f}"))
            .values()[0] as f64;
        self.comm_secs += t0.elapsed().as_secs_f64();

        let scale = if clip { clip_scale(total, self.max_grad_norm) } else { 1.0 };

        // Phase 3: AdamW on owned shards (the timed "optimizer component"
        // of Table 3).
        let t1 = std::time::Instant::now();
        for seg in self.segments.iter_mut() {
            let (s, l) = seg.shard;
            let base = seg.spec.local_offset + s;
            let grads_shard = seg.shard_grad.clone();
            seg.state.update(self.hp, lr, scale, &mut params[base..base + l], &grads_shard);
        }
        self.update_secs += t1.elapsed().as_secs_f64();

        // Phase 4: allgather updated shards back to full segments.
        let t2 = std::time::Instant::now();
        for seg in self.segments.iter_mut() {
            let (s, l) = seg.shard;
            let base = seg.spec.local_offset + s;
            let mine = params[base..base + l].to_vec();
            let full = seg
                .spec
                .group
                .run(
                    seg.spec.group_rank,
                    CollectiveOp::Allgather { data: mine, dt: ReduceDtype::F32 },
                )
                .unwrap_or_else(|f| panic!("{f}"))
                .values();
            debug_assert_eq!(full.len(), seg.spec.len);
            params[seg.spec.local_offset..seg.spec.local_offset + seg.spec.len]
                .copy_from_slice(&full);
        }
        self.comm_secs += t2.elapsed().as_secs_f64();
        total.sqrt()
    }

    /// The three-stage pipelined step over a per-rank [`CommRuntime`]
    /// lane, at `self.chunk`-element granularity:
    ///
    /// 1. **reduce** — every segment's gradient chunks are submitted as
    ///    nonblocking allreduces in program order; the rank thread drains
    ///    them FIFO, staging chunk *k* (shard-intersection copy + mean
    ///    scale) while chunk *k+1* is still on the wire;
    /// 2. **norm** — per-segment sumsq in segment order (identical f64
    ///    accumulation to the serial path) feeds a nonblocking norm
    ///    allreduce; with clipping the rank thread waits for it here
    ///    (AdamW needs the scale), without clipping the wait itself is
    ///    deferred to the end of the step;
    /// 3. **update** — AdamW on chunk *k* of the owned shard overlaps the
    ///    allgather of chunk *k−1* (bounded in-flight depth), the clip
    ///    folded in as AdamW's `grad_scale` — the *deferred scale*.
    ///
    /// Bit-identity with the serial step: chunking never moves shard
    /// boundaries, every collective is elementwise-identical to its
    /// whole-segment form (this fabric's reduce-scatter *is* allreduce +
    /// slice), the sumsq accumulation order is unchanged, and chunked
    /// AdamW is [`AdamState::update_chunk`] over a partition of the same
    /// shard. Asserted by `pipelined_matches_serial_bitwise` below.
    fn step_pipelined(&mut self, params: &mut [f32], grads: &[f32], lr: f32, clip: bool) -> f64 {
        let hp = self.hp;
        let dt = self.reduce_dtype;
        let max_norm = self.max_grad_norm;
        let chunk = self.chunk.max(1);
        let norm_rank = self.norm_rank;
        let norm_group = Arc::clone(&self.norm_group);
        let mut exposed = 0.0f64; // rank thread blocked on comm
        let mut update_secs = 0.0f64;

        let rt = self.rt.as_ref().expect("pipelined step without a comm lane");
        let busy0 = rt.busy_secs();
        let segments = &mut self.segments;

        // ---- stage 1: chunked reduce-scatter, pipelined ----
        // bounded in-flight depth (like the gather stage) so the queued
        // gradient copies never exceed a few chunks per rank, instead of
        // materializing a full extra gradient vector up front
        let descs: Vec<(usize, usize, usize)> = segments
            .iter()
            .enumerate()
            .flat_map(|(si, seg)| {
                chunk_ranges(seg.spec.len, chunk)
                    .into_iter()
                    .map(move |(cs, cl)| (si, cs, cl))
            })
            .collect();
        let mut rs_q: VecDeque<PendingRs> = VecDeque::new();
        for (si, cs, cl) in descs {
            let handle = {
                let seg = &segments[si];
                let base = seg.spec.local_offset + cs;
                Arc::clone(&seg.spec.group).start(
                    rt,
                    seg.spec.group_rank,
                    CollectiveOp::Allreduce {
                        data: grads[base..base + cl].to_vec(),
                        red: Reduce::Sum,
                        dt,
                    },
                )
            };
            rs_q.push_back(PendingRs { seg_idx: si, start: cs, len: cl, handle });
            while rs_q.len() > 2 {
                let p = rs_q.pop_front().unwrap();
                exposed += drain_reduce_chunk(segments, p);
            }
        }
        while let Some(p) = rs_q.pop_front() {
            exposed += drain_reduce_chunk(segments, p);
        }

        // ---- stage 2: global grad norm with a deferred wait ----
        let mut local_sumsq = 0.0f64;
        for seg in segments.iter() {
            local_sumsq += sumsq(&seg.shard_grad) * seg.spec.norm_weight;
        }
        let mut norm_h = Some(Arc::clone(&norm_group).start(
            rt,
            norm_rank,
            CollectiveOp::Allreduce {
                data: vec![local_sumsq as f32],
                red: Reduce::Sum,
                dt: ReduceDtype::F32,
            },
        ));
        let mut total = 0.0f64;
        let scale = if clip {
            let t = Instant::now();
            total = norm_h.take().unwrap().wait().values()[0] as f64;
            exposed += t.elapsed().as_secs_f64();
            clip_scale(total, max_norm)
        } else {
            1.0
        };

        // ---- stage 3: AdamW on chunk k ‖ allgather of chunk k−1 ----
        let mut ag_q: VecDeque<PendingAg> = VecDeque::new();
        for si in 0..segments.len() {
            let (len, gsize, grank) = {
                let s = &segments[si];
                (s.spec.len, s.spec.group.size(), s.spec.group_rank)
            };
            if len == 0 {
                continue;
            }
            // the uniform ZeRO shard slot: every rank walks the same
            // chunk grid over [0, per) so collectives line up, even when
            // trailing shards are short or empty (ragged allgather)
            let per = len.div_ceil(gsize);
            segments[si].state.begin_step();
            for (cs, slot) in chunk_ranges(per, chunk) {
                let handle = {
                    let seg = &mut segments[si];
                    let (ss, sl) = seg.shard;
                    let lo = cs.min(sl);
                    let hi = (cs + slot).min(sl);
                    let mine: Vec<f32> = if lo < hi {
                        let base = seg.spec.local_offset + ss + lo;
                        let t = Instant::now();
                        let (state, sg) = (&mut seg.state, &seg.shard_grad);
                        state.update_chunk(
                            hp,
                            lr,
                            scale,
                            lo,
                            &mut params[base..base + (hi - lo)],
                            &sg[lo..hi],
                        );
                        update_secs += t.elapsed().as_secs_f64();
                        params[base..base + (hi - lo)].to_vec()
                    } else {
                        Vec::new()
                    };
                    Arc::clone(&seg.spec.group).start(
                        rt,
                        grank,
                        CollectiveOp::Allgather { data: mine, dt: ReduceDtype::F32 },
                    )
                };
                ag_q.push_back(PendingAg { seg_idx: si, chunk_start: cs, slot_len: slot, handle });
                // bounded in-flight depth keeps memory flat while chunk k
                // computes over chunk k−1's gather
                while ag_q.len() > 2 {
                    let p = ag_q.pop_front().unwrap();
                    exposed += drain_allgather_chunk(segments, params, p);
                }
            }
        }
        while let Some(p) = ag_q.pop_front() {
            exposed += drain_allgather_chunk(segments, params, p);
        }

        // deferred norm wait (no-clip steps): the lane ran it between the
        // reduce and gather ops; this just collects the buffered result
        if let Some(h) = norm_h {
            let t = Instant::now();
            total = h.wait().values()[0] as f64;
            exposed += t.elapsed().as_secs_f64();
        }

        let busy1 = rt.busy_secs();
        self.comm_secs += exposed;
        self.update_secs += update_secs;
        self.overlap_secs += (busy1 - busy0 - exposed).max(0.0);
        total.sqrt()
    }
}

/// One segment's persistent optimizer state, exported as O(1) `Arc`
/// handles for the zero-copy snapshot path.
pub struct SegmentState {
    /// absolute start of the owned shard within the rank-local parameter
    /// vector
    pub local_start: usize,
    pub len: usize,
    pub m: Arc<Vec<f32>>,
    pub v: Arc<Vec<f32>>,
    /// optimizer steps taken (the AdamW bias-correction counter)
    pub step: u64,
}

/// One in-flight chunked gradient allreduce (pipelined step, stage 1).
struct PendingRs {
    seg_idx: usize,
    /// chunk start within the segment
    start: usize,
    len: usize,
    handle: CommHandle<CollectiveOut>,
}

/// Wait one reduced chunk and stage its intersection with the owned
/// shard into `shard_grad` (mean scale applied, exactly as
/// `reduce_scatter_mean` does). Returns the seconds spent blocked.
fn drain_reduce_chunk(segments: &mut [Segment], p: PendingRs) -> f64 {
    let t = Instant::now();
    let summed = p.handle.wait().values();
    let waited = t.elapsed().as_secs_f64();
    let seg = &mut segments[p.seg_idx];
    let (ss, sl) = seg.shard;
    let inv = 1.0 / seg.spec.group.size() as f32;
    // intersection of this chunk with the owned shard
    let lo = p.start.max(ss);
    let hi = (p.start + p.len).min(ss + sl);
    if lo < hi {
        for (dst, src) in seg.shard_grad[lo - ss..hi - ss]
            .iter_mut()
            .zip(summed[lo - p.start..hi - p.start].iter())
        {
            *dst = *src * inv;
        }
    }
    waited
}

/// One in-flight allgather of a shard-slot chunk (pipelined step).
struct PendingAg {
    seg_idx: usize,
    /// chunk start within the uniform shard slot `[0, per)`
    chunk_start: usize,
    /// chunk length within the slot grid
    slot_len: usize,
    handle: CommHandle<CollectiveOut>,
}

/// Chunk `[0, n)` into `chunk`-element ranges (the last may be short).
fn chunk_ranges(n: usize, chunk: usize) -> Vec<(usize, usize)> {
    let mut v = Vec::with_capacity(n / chunk.max(1) + 1);
    let mut s = 0;
    while s < n {
        let l = chunk.min(n - s);
        v.push((s, l));
        s += l;
    }
    v
}

/// Wait one gathered chunk and scatter each rank's ragged piece to its
/// place in the segment (rank r's piece lands at `shard_start(r) +
/// chunk_start`). Returns the seconds spent blocked on the handle.
fn drain_allgather_chunk(segments: &[Segment], params: &mut [f32], p: PendingAg) -> f64 {
    let t = Instant::now();
    let gathered = p.handle.wait().values();
    let waited = t.elapsed().as_secs_f64();
    let seg = &segments[p.seg_idx];
    let ranges = shard_ranges(seg.spec.len, seg.spec.group.size());
    let mut off = 0usize;
    for (rs, rl) in ranges {
        let hi = (p.chunk_start + p.slot_len).min(rl);
        if hi > p.chunk_start {
            let n = hi - p.chunk_start;
            let dst = seg.spec.local_offset + rs + p.chunk_start;
            params[dst..dst + n].copy_from_slice(&gathered[off..off + n]);
            off += n;
        }
    }
    debug_assert_eq!(off, gathered.len(), "ragged gather pieces must tile the chunk");
    waited
}

/// Rank-local `[non-expert(ne_len) || expert(e_len)]` segment lengths.
/// Computed per pipeline stage by
/// [`crate::coordinator::ParallelismPlan::materialized`] and handed to
/// [`plan_segments`] — the plan, not the trainer, owns the layout.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SegmentLayout {
    pub ne_len: usize,
    pub e_len: usize,
}

/// Plan-driven [`SegmentSpec`] construction for a rank whose local params
/// are `[non_expert(ne_len) || expert(e_len)]` — the stage's segment
/// layout plus the stage-local process groups fully determine the
/// sharding.
///
/// * `dp_group`   — ranks replicating the expert block (same ep coord)
/// * `dpep_group` — all ranks of the pp stage (replicate the NE block)
/// * `ep` — EP degree (for SO's norm multiplicity of the NE block)
#[allow(clippy::too_many_arguments)]
pub fn plan_segments(
    mode: ShardingMode,
    layout: SegmentLayout,
    dp_group: &Arc<Group>,
    dp_rank: usize,
    dpep_group: &Arc<Group>,
    dpep_rank: usize,
    ep: usize,
) -> Vec<SegmentSpec> {
    let SegmentLayout { ne_len, e_len } = layout;
    let mut v = Vec::new();
    match mode {
        ShardingMode::So => {
            // everything shards over DP; NE shards exist once per ep rank
            // -> their sumsq is counted ep times in the world sum
            if ne_len > 0 {
                v.push(SegmentSpec {
                    local_offset: 0,
                    len: ne_len,
                    group: Arc::clone(dp_group),
                    group_rank: dp_rank,
                    norm_weight: 1.0 / ep as f64,
                });
            }
            if e_len > 0 {
                v.push(SegmentSpec {
                    local_offset: ne_len,
                    len: e_len,
                    group: Arc::clone(dp_group),
                    group_rank: dp_rank,
                    norm_weight: 1.0,
                });
            }
        }
        ShardingMode::Epso => {
            if ne_len > 0 {
                v.push(SegmentSpec {
                    local_offset: 0,
                    len: ne_len,
                    group: Arc::clone(dpep_group),
                    group_rank: dpep_rank,
                    norm_weight: 1.0,
                });
            }
            if e_len > 0 {
                v.push(SegmentSpec {
                    local_offset: ne_len,
                    len: e_len,
                    group: Arc::clone(dp_group),
                    group_rank: dp_rank,
                    norm_weight: 1.0,
                });
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Mesh, Topology};

    /// Toy-problem run on a 2×2 DP×EP mesh with a parameterized segment
    /// layout; `overlap = Some(chunk)` arms the pipelined step. Returns
    /// per-rank final params plus shard lens / state bytes of rank 0.
    #[allow(clippy::too_many_arguments)]
    fn run_layout(
        mode: ShardingMode,
        ne_len: usize,
        e_len: usize,
        steps: usize,
        dt: ReduceDtype,
        clip: bool,
        overlap: Option<usize>,
    ) -> (Vec<Vec<f32>>, Vec<usize>, usize) {
        let topo = Topology::grid(2, 2, 1);
        let mesh = Mesh::new(topo);
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let mesh = Arc::clone(&mesh);
                std::thread::spawn(move || {
                    let c = mesh.coord(r);
                    let (dpg, dpr) = mesh.dp_group(r);
                    let (xg, xr) = mesh.dpep_group(r);
                    let segs = plan_segments(
                        mode, SegmentLayout { ne_len, e_len }, dpg, dpr, xg, xr, 2,
                    );
                    let mut opt = ShardedOptimizer::new(
                        segs,
                        Arc::clone(xg),
                        xr,
                        AdamParams { weight_decay: 0.0, ..Default::default() },
                        dt,
                        1.0,
                    )
                    .with_overlap(overlap.is_some(), overlap.unwrap_or(0).max(1), &format!("t{r}"));
                    // NE params replicated everywhere; expert params differ
                    // by ep coord (two expert groups)
                    let mut params: Vec<f32> = (0..ne_len + e_len)
                        .map(|i| {
                            if i < ne_len {
                                0.5 + i as f32 * 0.01
                            } else {
                                (c.ep as f32 + 1.0) * (1.0 + i as f32 * 0.01)
                            }
                        })
                        .collect();
                    for step in 0..steps {
                        // deterministic grads: NE grads equal across the
                        // dpep group after averaging; expert grads differ
                        // per dp but match across dp after mean.
                        let grads: Vec<f32> = (0..ne_len + e_len)
                            .map(|i| {
                                let base = (i as f32 * 0.1 + step as f32 * 0.01).sin();
                                base + c.dp as f32 * 0.001
                            })
                            .collect();
                        opt.step(&mut params, &grads, 1e-2, clip);
                    }
                    (params, opt.shard_lens(), opt.state_bytes())
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let params: Vec<Vec<f32>> = results.iter().map(|r| r.0.clone()).collect();
        let lens = results[0].1.clone();
        let bytes = results[0].2;
        (params, lens, bytes)
    }

    /// The original fixed layout (odd NE length exercises ragged shards).
    fn run_mode(mode: ShardingMode, steps: usize) -> (Vec<Vec<f32>>, Vec<usize>, usize) {
        run_layout(mode, 13, 8, steps, ReduceDtype::F32, true, None)
    }

    #[test]
    fn so_and_epso_agree_numerically() {
        let (p_so, lens_so, bytes_so) = run_mode(ShardingMode::So, 6);
        let (p_epso, lens_epso, bytes_epso) = run_mode(ShardingMode::Epso, 6);
        for (a, b) in p_so.iter().zip(p_epso.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x - y).abs() < 2e-5, "{x} vs {y}");
            }
        }
        // EPSO NE shard is EP(=2)x smaller: SO NE shard ceil(13/2)=7,
        // EPSO ceil(13/4)=4
        assert_eq!(lens_so[0], 7);
        assert_eq!(lens_epso[0], 4);
        assert!(bytes_epso < bytes_so, "{bytes_epso} vs {bytes_so}");
    }

    #[test]
    fn replicas_stay_in_sync() {
        let (p, _, _) = run_mode(ShardingMode::Epso, 4);
        // ranks 0,1 share ep=0? rank layout: rank = (dp*EP + ep)*PP
        // rank0=(0,0) rank1=(0,1) rank2=(1,0) rank3=(1,1)
        // NE block identical on all; expert block identical across dp
        for r in 1..4 {
            assert_eq!(p[0][..13], p[r][..13], "NE desynced on rank {r}");
        }
        assert_eq!(p[0][13..], p[2][13..], "experts desynced across dp");
        assert_eq!(p[1][13..], p[3][13..]);
        assert_ne!(p[0][13..21], p[1][13..21], "distinct expert groups should differ");
    }

    #[test]
    fn pipelined_matches_serial_bitwise() {
        // the tentpole invariant: across random segment layouts, chunk
        // sizes, reduce dtypes, clip settings and both sharding modes,
        // the overlapped step is a pure scheduling change — every rank's
        // final parameters are bit-identical to the serial step's
        crate::util::proptest::run_cases(6, |g| {
            let ne_len = g.range(1, 40);
            let e_len = if g.bool() { g.range(1, 32) } else { 0 };
            let chunk = g.range(1, 24);
            let steps = g.range(1, 4);
            let mode = *g.choose(&[ShardingMode::So, ShardingMode::Epso]);
            let dt = *g.choose(&[ReduceDtype::F32, ReduceDtype::Bf16]);
            let clip = g.bool();
            let (serial, _, _) = run_layout(mode, ne_len, e_len, steps, dt, clip, None);
            let (piped, _, _) = run_layout(mode, ne_len, e_len, steps, dt, clip, Some(chunk));
            for (rank, (a, b)) in serial.iter().zip(piped.iter()).enumerate() {
                assert_eq!(a.len(), b.len());
                for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "rank {rank} param {i}: serial {x} vs pipelined {y} \
                         (ne={ne_len} e={e_len} chunk={chunk} mode={mode:?} clip={clip})"
                    );
                }
            }
        });
    }

    #[test]
    fn overlap_accounts_exposed_and_hidden_comm() {
        // one overlapped run: counters populated, lane actually used
        let topo = Topology::grid(2, 1, 1);
        let mesh = Mesh::new(topo);
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let mesh = Arc::clone(&mesh);
                std::thread::spawn(move || {
                    let (dpg, dpr) = mesh.dp_group(r);
                    let (xg, xr) = mesh.dpep_group(r);
                    let segs = plan_segments(
                        ShardingMode::So,
                        SegmentLayout { ne_len: 64, e_len: 0 },
                        dpg,
                        dpr,
                        xg,
                        xr,
                        1,
                    );
                    let mut opt = ShardedOptimizer::new(
                        segs,
                        Arc::clone(mesh.world_group()),
                        r,
                        AdamParams::default(),
                        ReduceDtype::F32,
                        1.0,
                    )
                    .with_overlap(true, 16, &format!("acct{r}"));
                    assert!(opt.overlapped());
                    let mut params = vec![0.1f32; 64];
                    let grads = vec![0.5f32; 64];
                    let gn = opt.step(&mut params, &grads, 1e-3, true);
                    assert!(gn.is_finite() && gn > 0.0);
                    (opt.comm_secs, opt.overlap_secs, opt.lane_ops())
                })
            })
            .collect();
        for h in handles {
            let (comm, overlap, lane_ops) = h.join().unwrap();
            assert!(comm >= 0.0 && overlap >= 0.0, "{comm} {overlap}");
            // falsifiable liveness: 64 elems / 16-chunk = 4 reduce ops,
            // 1 norm, shard slot 32 / 16-chunk = 2 gather ops
            assert_eq!(lane_ops, 7, "pipelined step did not use the lane");
        }
    }

    /// Mixed-precision run on a 2-rank DP group via [`ShardedOptimizer::
    /// step_tensor`] over a bf16 tensor. Returns per-rank final bf16
    /// storage bits plus rank 0's state bytes.
    fn run_bf16(ne_len: usize, steps: usize) -> (Vec<Vec<u16>>, usize) {
        let topo = Topology::grid(2, 1, 1);
        let mesh = Mesh::new(topo);
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let mesh = Arc::clone(&mesh);
                std::thread::spawn(move || {
                    let (dpg, dpr) = mesh.dp_group(r);
                    let segs = plan_segments(
                        ShardingMode::So,
                        SegmentLayout { ne_len, e_len: 0 },
                        dpg,
                        dpr,
                        mesh.world_group(),
                        r,
                        1,
                    );
                    let mut opt = ShardedOptimizer::new(
                        segs,
                        Arc::clone(mesh.world_group()),
                        r,
                        AdamParams { weight_decay: 0.0, ..Default::default() },
                        ReduceDtype::Bf16,
                        1.0,
                    );
                    let init: Vec<f32> = (0..ne_len).map(|i| 0.5 + i as f32 * 0.01).collect();
                    let mut params =
                        Tensor::from_f32(Dtype::Bf16, init, vec![ne_len]);
                    for step in 0..steps {
                        let grads: Vec<f32> = (0..ne_len)
                            .map(|i| (i as f32 * 0.1 + step as f32 * 0.01).sin() + r as f32 * 0.001)
                            .collect();
                        let norm = opt.step_tensor(&mut params, &grads, 1e-2, true).unwrap();
                        assert!(norm.is_finite());
                    }
                    (params.as_bf16().unwrap().to_vec(), opt.state_bytes())
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let bytes = results[0].1;
        (results.into_iter().map(|r| r.0).collect(), bytes)
    }

    #[test]
    fn bf16_master_step_keeps_replicas_bitwise_synced() {
        let (p, _) = run_bf16(13, 5);
        assert_eq!(p[0], p[1], "bf16 replicas desynced");
    }

    #[test]
    fn bf16_master_path_tracks_f32_within_tolerance() {
        // same toy problem, f32 vs bf16 mixed precision: trajectories
        // agree within bf16's relative precision (the PR's tolerance
        // contract where bit-identity legitimately ends)
        let steps = 5;
        let (f32_runs, _, _) =
            run_layout(ShardingMode::So, 13, 0, steps, ReduceDtype::F32, true, None);
        let (bf16_runs, _) = run_bf16(13, steps);
        // run_layout uses a 2x2 mesh; its dp grads match run_bf16's for
        // the same dp coord, and ne-only layouts make ep coords identical
        let a = &f32_runs[0];
        let b = bf16s_to_f32s(&bf16_runs[0]);
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(
                (x - y).abs() <= 0.01 * x.abs().max(1.0),
                "param {i}: f32 {x} vs bf16 {y}"
            );
        }
    }

    #[test]
    fn bf16_master_grows_state_bytes() {
        // 2-way shard of 13 params: ceil(13/2)=7 owned -> 7*(8+4) bytes
        let (_, bytes) = run_bf16(13, 1);
        assert_eq!(bytes, 7 * 12, "f32 master must be counted in state bytes");
    }

    #[test]
    fn clipping_bounds_update() {
        let g = crate::comm::Group::new(1);
        let segs = vec![SegmentSpec {
            local_offset: 0,
            len: 4,
            group: g,
            group_rank: 0,
            norm_weight: 1.0,
        }];
        let mut opt = ShardedOptimizer::new(
            segs,
            crate::comm::Group::new(1),
            0,
            AdamParams { weight_decay: 0.0, ..Default::default() },
            ReduceDtype::F32,
            1.0,
        );
        let mut p = vec![0.0f32; 4];
        let huge = vec![1e6f32; 4];
        let norm = opt.step(&mut p, &huge, 1e-3, true);
        assert!(norm > 1e6);
        // post-clip effective grads have norm 1 -> bounded first step
        for v in &p {
            assert!(v.abs() < 2e-3, "{v}");
        }
    }
}
