//! Data pipeline (paper §4 "Data preprocessing" + DESIGN.md §7):
//! tokenize → shuffle → shard offline, then a deterministic **streaming**
//! read path — epoch-aware blockwise shuffle, an elastic-resume-safe
//! token cursor, and a per-rank background prefetcher — over mmap'd lazy
//! shard loading, so every rank reads contiguous memory with "bare
//! minimal overhead".
//!
//! - [`tokenizer`]  — byte-level tokenizer (+EOS), document framing
//! - [`corpus`]     — deterministic synthetic corpus generator (the
//!   OLMoE-Mix substitution; see DESIGN.md §1)
//! - [`preprocess`] — offline pipeline producing `.oshard` files
//! - [`dataset`]    — mmap shard reader + batch-consumption geometry
//!   ([`BatchPlan`])
//! - [`shuffle`]    — seeded, epoch-aware blockwise [`ShuffledIndex`]
//! - [`stream`]     — [`TokenStream`] (budget-enforced shuffled reads)
//!   and the [`TokenCursor`] resume contract
//! - [`prefetch`]   — bounded-queue background batch producer per rank

pub mod corpus;
pub mod dataset;
pub mod prefetch;
pub mod preprocess;
pub mod shuffle;
pub mod stream;
pub mod tokenizer;

pub use dataset::{BatchPlan, Dataset};
pub use prefetch::Prefetcher;
pub use preprocess::{preprocess, PreprocessStats};
pub use shuffle::{ShuffledIndex, SHUFFLE_BLOCK};
pub use stream::{TokenCursor, TokenStream};
pub use tokenizer::Tokenizer;
