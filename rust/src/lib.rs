//! # Optimus-RS
//!
//! Reproduction of *"Scalable Pretraining of Large Mixture of Experts
//! Language Models on Aurora Super Computer"* as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! This crate is Layer 3: the distributed-training coordinator (the paper's
//! **Optimus** library). Python/JAX/Pallas exist only at build time
//! (`make artifacts`); at runtime this crate loads the AOT-lowered HLO-text
//! artifacts through PJRT and owns everything else: the multi-rank runtime,
//! collectives, DP/EP/PP orchestration, the sharded optimizers (SO and the
//! paper's EP-aware EPSO), the data pipeline, checkpointing, and the
//! reliability features of paper §4.
//!
//! Module map (see `rust/DESIGN.md` for the full inventory):
//! - [`runtime`]  — PJRT executor pool: load + execute HLO artifacts
//! - [`comm`]     — in-process collectives over an N-D device mesh
//! - [`config`]   — manifest (param layout / artifacts) + run configs
//! - [`coordinator`] — `JobSpec`/`ParallelismPlan` API, rank-execution
//!   harness, DP/EP/PP/PP×EP engines, pipeline schedules, EP token
//!   exchange
//! - [`optim`]    — AdamW, sharded optimizer (SO), EPSO (paper §3.2)
//! - [`data`]     — tokenize → shuffle → shard pipeline + deterministic
//!   shuffled streaming (epoch-aware blockwise shuffle, elastic-resume
//!   token cursor, per-rank prefetch) over the mmap loader
//! - [`ckpt`]     — sharded `TrainState`/`Checkpointer` with async
//!   zero-copy snapshots, two-phase commit, topology-elastic reshard (§4)
//! - [`ft`]       — hard/soft node-failure handling with buffer nodes (§4)
//! - [`serve`]    — `optimus serve`: expert-parallel inference on the
//!   training mesh (continuous batching, paged KV cache, open-loop
//!   traffic generator)
//! - [`cluster`]  — Aurora analytic performance model (Fig 4b)
//! - [`eval`]     — synthetic benchmark suite (Table 2, Figs 2-3)
//! - [`metrics`]  — step timers, loss logs, CSV emitters
//! - [`analysis`] — `optimus lint`: repo-specific invariant lint (check
//!   string registry/coverage, named threads, lock discipline)
//! - [`util`]     — PRNG, JSON, CLI, micro-bench + property-test harnesses

pub mod analysis;
pub mod ckpt;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod ft;
pub mod metrics;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifacts directory (overridable for tests).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("OPTIMUS_ARTIFACTS") {
        return d.into();
    }
    // crate root/artifacts — works from `cargo test`, benches and examples
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}

/// Artifact manifest for tests that need built HLO artifacts, or `None`
/// (with a SKIP note on stderr) when `artifacts/` hasn't been built — so
/// `cargo test -q` gives signal on a fresh clone instead of a wall of
/// unwrap panics. Build artifacts with:
/// `python python/compile/aot.py --out rust/artifacts`.
pub fn manifest_or_skip(test: &str) -> Option<config::Manifest> {
    match config::Manifest::load(&artifacts_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!(
                "SKIP {test}: artifacts not built ({e:#}); \
                 run `python python/compile/aot.py --out rust/artifacts`"
            );
            None
        }
    }
}
