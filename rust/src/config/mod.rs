//! Model/run configuration: the artifact manifest written by
//! `python/compile/aot.py` (flat parameter layout + artifact inventory)
//! and the training run configuration (paper §2.1 recipe, scaled down).

pub mod models;

use crate::util::json::Json;
use crate::Result;
use anyhow::{anyhow, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One parameter tensor in the flat layout (mirrors model.param_specs).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub numel: usize,
    /// EPSO's grouping key (paper §3.2): expert params shard over DP,
    /// non-expert params shard over DP×EP.
    pub is_expert: bool,
    /// owning decoder layer, -1 for embed/final_norm/head
    pub layer: i64,
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model hyperparameters (manifest `hyper` block).
#[derive(Clone, Debug)]
pub struct Hyper {
    pub n_layers: usize,
    pub hidden: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub intermediate: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub vocab_size: usize,
    pub context: usize,
    pub batch: usize,
    pub seq: usize,
    pub aux_coef: f64,
}

impl Hyper {
    pub fn is_moe(&self) -> bool {
        self.n_experts > 0
    }
}

/// Everything the coordinator knows about one model config.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub params: Vec<ParamSpec>,
    pub param_count: usize,
    pub hyper: Hyper,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub pp_degrees: Vec<usize>,
    pub ep_degrees: Vec<usize>,
    pub dir: PathBuf,
}

impl ModelManifest {
    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("config `{}` has no artifact `{name}`", self.name))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Ranges (offset, numel) of expert vs non-expert params — the two
    /// EPSO groups. Order follows the flat layout.
    pub fn expert_split(&self) -> (Vec<(usize, usize)>, Vec<(usize, usize)>) {
        let mut e = Vec::new();
        let mut ne = Vec::new();
        for p in &self.params {
            if p.is_expert {
                e.push((p.offset, p.numel));
            } else {
                ne.push((p.offset, p.numel));
            }
        }
        (e, ne)
    }

    /// Total expert / non-expert parameter counts.
    pub fn expert_param_counts(&self) -> (usize, usize) {
        let (e, ne) = self.expert_split();
        (
            e.iter().map(|x| x.1).sum(),
            ne.iter().map(|x| x.1).sum(),
        )
    }
}

/// Paper-scale config (projection-only; Table 1).
#[derive(Clone, Debug)]
pub struct PaperConfig {
    pub name: String,
    pub n_layers: usize,
    pub hidden: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub intermediate: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub vocab_size: usize,
    pub context: usize,
    pub param_count: usize,
    pub active_param_count: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub configs: BTreeMap<String, ModelManifest>,
    pub paper: BTreeMap<String, PaperConfig>,
}

fn tensor_specs(j: &Json) -> Vec<TensorSpec> {
    j.as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|t| TensorSpec {
            shape: t
                .req("shape")
                .as_arr()
                .unwrap()
                .iter()
                .map(|d| d.as_usize().unwrap())
                .collect(),
            dtype: t.req("dtype").as_str().unwrap().to_string(),
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let mut configs = BTreeMap::new();
        for (name, c) in j.req("configs").as_obj().unwrap() {
            let params = c
                .req("params")
                .as_arr()
                .unwrap()
                .iter()
                .map(|p| ParamSpec {
                    name: p.req("name").as_str().unwrap().into(),
                    shape: p
                        .req("shape")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|d| d.as_usize().unwrap())
                        .collect(),
                    offset: p.req("offset").as_usize().unwrap(),
                    numel: p.req("numel").as_usize().unwrap(),
                    is_expert: p.req("is_expert").as_bool().unwrap(),
                    layer: p.req("layer").as_i64().unwrap(),
                })
                .collect();
            let h = c.req("hyper");
            let hyper = Hyper {
                n_layers: h.req("n_layers").as_usize().unwrap(),
                hidden: h.req("hidden").as_usize().unwrap(),
                n_heads: h.req("n_heads").as_usize().unwrap(),
                head_dim: h.req("head_dim").as_usize().unwrap(),
                intermediate: h.req("intermediate").as_usize().unwrap(),
                n_experts: h.req("n_experts").as_usize().unwrap(),
                top_k: h.req("top_k").as_usize().unwrap(),
                vocab_size: h.req("vocab_size").as_usize().unwrap(),
                context: h.req("context").as_usize().unwrap(),
                batch: h.req("batch").as_usize().unwrap(),
                seq: h.req("seq").as_usize().unwrap(),
                aux_coef: h.req("aux_coef").as_f64().unwrap(),
            };
            let artifacts = c
                .req("artifacts")
                .as_obj()
                .unwrap()
                .iter()
                .map(|(an, a)| {
                    (
                        an.clone(),
                        ArtifactInfo {
                            file: a.req("file").as_str().unwrap().into(),
                            inputs: tensor_specs(a.req("inputs")),
                            outputs: tensor_specs(a.req("outputs")),
                        },
                    )
                })
                .collect();
            let degrees = |key: &str| {
                c.get(key)
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default()
            };
            configs.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    params,
                    param_count: c.req("param_count").as_usize().unwrap(),
                    hyper,
                    artifacts,
                    pp_degrees: degrees("pp"),
                    ep_degrees: degrees("ep"),
                    dir: dir.to_path_buf(),
                },
            );
        }
        let mut paper = BTreeMap::new();
        if let Some(pc) = j.get("paper_configs").and_then(|p| p.as_obj()) {
            for (name, c) in pc {
                paper.insert(
                    name.clone(),
                    PaperConfig {
                        name: name.clone(),
                        n_layers: c.req("n_layers").as_usize().unwrap(),
                        hidden: c.req("hidden").as_usize().unwrap(),
                        n_heads: c.req("n_heads").as_usize().unwrap(),
                        head_dim: c.req("head_dim").as_usize().unwrap(),
                        intermediate: c.req("intermediate").as_usize().unwrap(),
                        n_experts: c.req("n_experts").as_usize().unwrap(),
                        top_k: c.req("top_k").as_usize().unwrap(),
                        vocab_size: c.req("vocab_size").as_usize().unwrap(),
                        context: c.req("context").as_usize().unwrap(),
                        param_count: c.req("param_count").as_usize().unwrap(),
                        active_param_count: c.req("active_param_count").as_usize().unwrap(),
                    },
                );
            }
        }
        Ok(Manifest { configs, paper })
    }

    pub fn config(&self, name: &str) -> Result<&ModelManifest> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("unknown model config `{name}` (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()))
    }
}

/// Training run configuration — the paper §2.1 recipe, scaled down.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub steps: usize,
    pub warmup_steps: usize,
    pub peak_lr: f64,
    pub min_lr: f64,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub grad_clip: f64,
    /// clip only after warmup (paper: "apply clipping only after the
    /// warmup steps")
    pub clip_after_warmup_only: bool,
    /// bf16 round-trip on gradient reduction (paper: bfloat16 gradient
    /// reduction instead of float32)
    pub bf16_grad_reduce: bool,
    pub seed: u64,
    /// seed of the epoch-aware blockwise data shuffle (`--data-seed`):
    /// the training data order is reproducible from this value alone,
    /// independently of `seed` (parameter init / model PRNG streams)
    pub data_seed: u64,
    pub log_every: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        // paper: peak 4e-4, min 4e-5, warmup 2500 (scaled), cosine decay,
        // wd 0.1 on all params, AdamW (0.9, 0.99, 1e-8), clip 1.0.
        RunConfig {
            steps: 200,
            warmup_steps: 20,
            peak_lr: 4e-4,
            min_lr: 4e-5,
            weight_decay: 0.1,
            beta1: 0.9,
            beta2: 0.99,
            eps: 1e-8,
            grad_clip: 1.0,
            clip_after_warmup_only: true,
            bf16_grad_reduce: true,
            seed: 1234,
            data_seed: 7,
            log_every: 10,
        }
    }
}

impl RunConfig {
    /// Linear warmup to peak, then cosine decay to min (paper §2.1).
    pub fn lr_at(&self, step: usize) -> f64 {
        if step < self.warmup_steps {
            return self.peak_lr * (step + 1) as f64 / self.warmup_steps as f64;
        }
        let t = (step - self.warmup_steps) as f64
            / (self.steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let t = t.min(1.0);
        self.min_lr
            + 0.5 * (self.peak_lr - self.min_lr) * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let rc = RunConfig { steps: 100, warmup_steps: 10, ..Default::default() };
        assert!(rc.lr_at(0) < rc.lr_at(5));
        assert!((rc.lr_at(9) - rc.peak_lr).abs() / rc.peak_lr < 0.11);
        assert!(rc.lr_at(50) < rc.peak_lr);
        assert!((rc.lr_at(99) - rc.min_lr) / rc.min_lr < 0.05);
        // monotone decay after warmup
        for s in 10..99 {
            assert!(rc.lr_at(s) >= rc.lr_at(s + 1));
        }
    }
}
