//! Tiny CLI argument parser (`clap` is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and free
//! positional arguments; typed getters with defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.into(), v.into());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.into(), v);
                } else {
                    out.flags.insert(rest.into(), "true".into());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    pub fn str_or(&self, k: &str, default: &str) -> String {
        self.get(k).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, k: &str, default: usize) -> usize {
        self.get(k)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{k} wants an integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, k: &str, default: f64) -> f64 {
        self.get(k)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{k} wants a number, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, k: &str, default: bool) -> bool {
        self.get(k)
            .map(|v| matches!(v, "true" | "1" | "yes" | "on"))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn basics() {
        let a = parse("train --steps 100 --lr=0.1 --fur");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f64_or("lr", 0.0), 0.1);
        assert!(a.bool_or("fur", false));
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn bool_flag_followed_by_flag() {
        let a = parse("--a --b 3 tail");
        assert!(a.bool_or("a", false));
        assert_eq!(a.usize_or("b", 0), 3);
        assert_eq!(a.positional, vec!["tail"]);
    }

    #[test]
    fn flag_value_pairs() {
        let a = parse("--name mula-tiny --dp 4");
        assert_eq!(a.str_or("name", ""), "mula-tiny");
        assert_eq!(a.usize_or("dp", 1), 4);
    }
}
