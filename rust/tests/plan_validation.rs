//! Preflight validation: every invalid `ParallelismPlan` fails in the
//! single table-driven `validate` pass — with a stable error string that
//! `ft::classify` labels as a non-relaunchable `Config` failure — *before*
//! any rank thread spawns (witnessed by a hook that records whether any
//! training step ever ran).
//!
//! These tests hand-build a synthetic `ModelManifest`, so they run without
//! HLO artifacts (no `manifest_or_skip`).

use optimus::comm::Topology;
use optimus::config::{Hyper, Manifest, ModelManifest};
use optimus::coordinator::{self, JobSpec, ParallelismPlan, StepHook};
use optimus::data::{corpus, preprocess, Dataset};
use optimus::ft::{classify, FailureKind};
use optimus::optim::ShardingMode;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

fn data_dir() -> PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("optimus-pv-data-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let files = corpus::data_files(42, 2, 8);
        preprocess::preprocess(&files, 64, 7, &dir, 128).unwrap();
        dir
    })
    .clone()
}

/// Synthetic manifest: internally consistent hyperparameters, EP=2 and
/// PP=2 "built", no artifact files (validation never touches them).
fn tiny_mm(seq: usize) -> ModelManifest {
    ModelManifest {
        name: "synthetic".into(),
        params: Vec::new(),
        param_count: 0,
        hyper: Hyper {
            n_layers: 4,
            hidden: 8,
            n_heads: 2,
            head_dim: 4,
            intermediate: 16,
            n_experts: 4,
            top_k: 2,
            vocab_size: 32,
            context: 64,
            batch: 2,
            seq,
            aux_coef: 0.01,
        },
        artifacts: BTreeMap::new(),
        pp_degrees: vec![2],
        ep_degrees: vec![2],
        dir: PathBuf::from("/nonexistent"),
    }
}

/// The table the issue calls for: every invalid plan, its expected check
/// tag, and a salient fragment of its message.
#[test]
fn every_invalid_plan_fails_with_a_stable_classifiable_error() {
    let ds = Dataset::open(&data_dir()).unwrap();
    let mm = tiny_mm(16);
    let mm_long_seq = tiny_mm(128); // seq + 1 > data context (64)

    struct Case {
        name: &'static str,
        plan: ParallelismPlan,
        mm: ModelManifest,
        tag: &'static str,
        fragment: &'static str,
    }
    let plan = ParallelismPlan::new;

    let cases = vec![
        Case {
            name: "zero axis",
            plan: plan(Topology::grid(0, 1, 1)),
            mm: mm.clone(),
            tag: "plan validation failed [topology]",
            fragment: "every mesh axis must be >= 1",
        },
        Case {
            name: "node size does not divide world",
            plan: plan(Topology::grid(2, 2, 1).with_node_size(3)),
            mm: mm.clone(),
            tag: "plan validation failed [topology]",
            fragment: "node_size=3 must divide the world size",
        },
        Case {
            name: "node size of zero",
            plan: plan(Topology::dp_only(2).with_node_size(0)),
            mm: mm.clone(),
            tag: "plan validation failed [topology]",
            fragment: "node_size must be >= 1",
        },
        Case {
            name: "dp*ep*pp != world",
            plan: {
                let mut p = plan(Topology::grid(2, 2, 1));
                p.expected_world = Some(8);
                p
            },
            mm: mm.clone(),
            tag: "plan validation failed [world-size]",
            fragment: "does not equal the requested world size 8",
        },
        Case {
            name: "micro_batches = 0",
            plan: {
                let mut p = plan(Topology::grid(1, 1, 2));
                p.micro_batches = 0;
                p
            },
            mm: mm.clone(),
            tag: "plan validation failed [micro-batches]",
            fragment: "must be in 1..=64",
        },
        Case {
            name: "micro_batches > 64",
            plan: {
                let mut p = plan(Topology::grid(1, 1, 2));
                p.micro_batches = 65;
                p
            },
            mm: mm.clone(),
            tag: "plan validation failed [micro-batches]",
            fragment: "got 65",
        },
        Case {
            name: "explicit EPSO at ep=1",
            plan: {
                let mut p = plan(Topology::dp_only(4));
                p.mode = ShardingMode::Epso;
                p.mode_explicit = true;
                p
            },
            mm: mm.clone(),
            tag: "plan validation failed [sharding]",
            fragment: "EPSO requires ep > 1",
        },
        Case {
            name: "overlap with zero chunk",
            plan: {
                let mut p = plan(Topology::dp_only(2));
                p.overlap = true;
                p.overlap_chunk = 0;
                p
            },
            mm: mm.clone(),
            tag: "plan validation failed [overlap]",
            fragment: "positive overlap_chunk",
        },
        Case {
            name: "checkpoint keep below the dual guarantee",
            plan: {
                let mut p = plan(Topology::dp_only(2));
                p.ckpt.dir = Some(PathBuf::from("/tmp/pv-ck"));
                p.ckpt.keep = 1;
                p
            },
            mm: mm.clone(),
            tag: "plan validation failed [checkpoint]",
            fragment: "keep must be >= 2",
        },
        Case {
            name: "checkpoint interval of zero",
            plan: {
                let mut p = plan(Topology::dp_only(2));
                p.ckpt.dir = Some(PathBuf::from("/tmp/pv-ck"));
                p.ckpt.every = 0;
                p
            },
            mm: mm.clone(),
            tag: "plan validation failed [checkpoint]",
            fragment: "interval must be >= 1",
        },
        Case {
            name: "missing PP artifacts for degree",
            plan: plan(Topology::grid(1, 1, 4)),
            mm: mm.clone(),
            tag: "plan validation failed [pp-artifacts]",
            fragment: "no PP=4 stage artifacts",
        },
        Case {
            name: "missing EP artifacts for degree",
            plan: plan(Topology::grid(1, 4, 1)),
            mm: mm.clone(),
            tag: "plan validation failed [ep-artifacts]",
            fragment: "no EP=4 artifacts",
        },
        Case {
            name: "hybrid needs the EP degree built",
            plan: plan(Topology::grid(1, 4, 2)),
            mm: mm.clone(),
            tag: "plan validation failed [ep-artifacts]",
            fragment: "no EP=4 artifacts",
        },
        Case {
            name: "ep does not divide experts",
            plan: plan(Topology::grid(1, 3, 1)),
            mm: mm.clone(),
            tag: "plan validation failed [expert-split]",
            fragment: "ep=3 does not divide n_experts=4",
        },
        Case {
            name: "pp does not divide layers",
            plan: plan(Topology::grid(1, 1, 2)),
            mm: {
                let mut m = mm.clone();
                m.hyper.n_layers = 5;
                m
            },
            tag: "plan validation failed [layer-split]",
            fragment: "pp=2 does not divide n_layers=5",
        },
        Case {
            name: "seq + 1 > data context",
            plan: plan(Topology::dp_only(2)),
            mm: mm_long_seq,
            tag: "plan validation failed [data-context]",
            fragment: "data context 64 < model seq+1 = 129",
        },
    ];

    for c in &cases {
        let err = c.plan.validate(&c.mm, &ds).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("plan validation failed"),
            "{}: unstable prefix: `{msg}`",
            c.name
        );
        assert!(msg.contains(c.tag), "{}: wrong check tag: `{msg}`", c.name);
        assert!(msg.contains(c.fragment), "{}: `{msg}`", c.name);
        // the launcher must classify it as non-relaunchable
        assert_eq!(classify(&err), FailureKind::Config, "{}: `{msg}`", c.name);
    }

    // valid plans for everything the synthetic manifest supports
    for topo in [
        Topology::dp_only(2),
        Topology::grid(1, 2, 1),
        Topology::grid(1, 1, 2),
        Topology::grid(2, 2, 2),
        // hierarchical collectives: any node_size dividing the world
        Topology::grid(2, 2, 1).with_node_size(2),
        Topology::grid(2, 2, 2).with_node_size(4),
    ] {
        plan(topo).validate(&mm, &ds).unwrap();
    }
}

/// The `[data]` instance-budget check is enforced by the harness (the
/// only place that sees the real resume cursor), but still *before* any
/// rank thread spawns, with a stable classifiable string. A fresh run's
/// demand is steps × instances_per_step; a run whose demand fits passes
/// the check and proceeds (to fail later on the synthetic manifest's
/// missing artifacts — NOT a `[data]` error).
#[test]
fn data_budget_overrun_fails_before_any_rank_runs() {
    let mut configs = BTreeMap::new();
    configs.insert("synthetic".to_string(), tiny_mm(16));
    let manifest = Manifest { configs, paper: BTreeMap::new() };

    // 200 steps × (dp2 × batch2) = 800 instances > tiny dataset × 1 epoch
    let stepped = Arc::new(AtomicBool::new(false));
    let spec = JobSpec::new("synthetic")
        .data_dir(data_dir())
        .topology(2, 1, 1)
        .steps(200)
        .data_epochs(1)
        .hook(Arc::new(StepWitness(stepped.clone())))
        .build()
        .unwrap();
    let err = coordinator::train(&manifest, &spec).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("plan validation failed [data]"), "{msg}");
    assert!(msg.contains("raise --epochs"), "{msg}");
    assert_eq!(classify(&err), FailureKind::Config);
    assert!(!stepped.load(Ordering::SeqCst), "a rank stepped past a blown data budget");

    // a demand the epoch budget covers sails past the [data] check: the
    // run then dies on the synthetic manifest's absent artifacts instead
    let spec = JobSpec::new("synthetic")
        .data_dir(data_dir())
        .topology(2, 1, 1)
        .steps(10) // 10 × 4 = 40 instances < one epoch
        .data_epochs(1)
        .build()
        .unwrap();
    let err = coordinator::train(&manifest, &spec).unwrap_err();
    let msg = format!("{err:#}");
    assert!(!msg.contains("plan validation failed [data]"), "{msg}");

    // unbounded budget (the default) never trips, whatever the demand
    let spec = JobSpec::new("synthetic")
        .data_dir(data_dir())
        .topology(2, 1, 1)
        .steps(1_000_000)
        .build()
        .unwrap();
    let err = coordinator::train(&manifest, &spec).unwrap_err();
    let msg = format!("{err:#}");
    assert!(!msg.contains("plan validation failed [data]"), "{msg}");
}

#[test]
fn serve_plan_validation_fires_with_stable_strings() {
    let mm = tiny_mm(16);
    let plan = ParallelismPlan::new;
    // pp > 1 has no decode engine
    let e = plan(Topology::grid(1, 2, 2)).validate_serve(&mm).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("plan validation failed [serve]"), "{msg}");
    assert!(msg.contains("pp=2"), "{msg}");
    assert_eq!(classify(&e), FailureKind::Config);
    // overlap is a training-only knob
    let mut p = plan(Topology::grid(2, 2, 1));
    p.overlap = true;
    let msg = format!("{:#}", p.validate_serve(&mm).unwrap_err());
    assert!(msg.contains("plan validation failed [serve]"), "{msg}");
    // bf16 serving plans are rejected (the decode engine computes in f32;
    // a bf16 *checkpoint* is instead rejected at load with the
    // `checkpoint resume failed [dtype]` string — see tests/serve.rs)
    let mut p = plan(Topology::grid(1, 2, 1));
    p.dtype = optimus::runtime::Dtype::Bf16;
    let msg = format!("{:#}", p.validate_serve(&mm).unwrap_err());
    assert!(msg.contains("plan validation failed [serve]"), "{msg}");
    // the ordinary spec+model tables still run underneath
    let msg = format!(
        "{:#}",
        plan(Topology::grid(1, 4, 1)).validate_serve(&mm).unwrap_err()
    );
    assert!(msg.contains("plan validation failed [ep-artifacts]"), "{msg}");
    // ep-only, dp×ep and plain-dp placements all serve
    plan(Topology::grid(1, 2, 1)).validate_serve(&mm).unwrap();
    plan(Topology::grid(2, 2, 1)).validate_serve(&mm).unwrap();
    plan(Topology::dp_only(2)).validate_serve(&mm).unwrap();
}

#[test]
fn batch_plan_geometry_matches_the_engines() {
    // one source of truth for instances/step: the [data] check, the
    // token cursor and `optimus plans` all read this
    let mm = tiny_mm(16); // batch = 2
    let ips = |dp, ep, pp| {
        ParallelismPlan::new(Topology::grid(dp, ep, pp))
            .batch_plan(&mm)
            .instances_per_step()
    };
    assert_eq!(ips(4, 1, 1), 8); // DP: dp × batch
    assert_eq!(ips(2, 2, 1), 8); // EP: world × batch
    assert_eq!(ips(2, 1, 2), 8); // PP: dp × batch × micro_batches (2)
    assert_eq!(ips(2, 2, 2), 16); // PP×EP: dp·ep × batch × micro_batches
}

/// Hook that records whether any training step ever executed.
struct StepWitness(Arc<AtomicBool>);
impl StepHook for StepWitness {
    fn on_step(&self, _r: usize, _s: usize, _l: f32, _p: &mut [f32]) -> optimus::Result<()> {
        self.0.store(true, Ordering::SeqCst);
        Ok(())
    }
}

#[test]
fn train_rejects_invalid_plans_before_any_rank_runs() {
    // a full train() call with an invalid plan must fail in the preflight
    // — no rank thread ever reaches a step (the witness hook stays unset)
    let mut configs = BTreeMap::new();
    configs.insert("synthetic".to_string(), tiny_mm(16));
    let manifest = Manifest { configs, paper: BTreeMap::new() };

    let stepped = Arc::new(AtomicBool::new(false));
    let spec = JobSpec::new("synthetic")
        .data_dir(data_dir())
        .topology(1, 4, 1) // EP=4 is not built in the synthetic manifest
        .steps(3)
        .hook(Arc::new(StepWitness(stepped.clone())))
        .build()
        .unwrap();
    let err = coordinator::train(&manifest, &spec).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("plan validation failed [ep-artifacts]"), "{msg}");
    assert_eq!(classify(&err), FailureKind::Config);
    assert!(
        !stepped.load(Ordering::SeqCst),
        "a rank executed a step despite an invalid plan"
    );
}

#[test]
fn builder_runs_the_same_spec_checks_early() {
    // the builder rejects plan-level invalidity at build() time with the
    // same stable strings train() would produce
    let e = JobSpec::new("m")
        .data_dir(data_dir())
        .topology(1, 1, 2)
        .micro_batches(0)
        .build()
        .unwrap_err();
    assert!(format!("{e:#}").contains("plan validation failed [micro-batches]"));
    assert_eq!(classify(&e), FailureKind::Config);
}

#[test]
fn enumerate_feeds_validate_for_sweeps() {
    // sweep tooling contract: enumerate lists every factorization; each
    // one either validates or fails with a classifiable config error
    let ds = Dataset::open(&data_dir()).unwrap();
    let mm = tiny_mm(16);
    let topos = ParallelismPlan::enumerate(8);
    assert!(topos.iter().all(|t| t.world() == 8));
    let mut runnable = 0;
    for t in topos {
        match ParallelismPlan::new(t).validate(&mm, &ds) {
            Ok(()) => runnable += 1,
            Err(e) => assert_eq!(classify(&e), FailureKind::Config),
        }
    }
    // runnable with EP=2/PP=2 built (the hybrid frees pp from needing
    // stage artifacts, so dp1·ep2·pp4 qualifies via 4 one-layer stages):
    // (8,1,1) (4,2,1) (4,1,2) (2,2,2) (1,2,4)
    assert_eq!(runnable, 5);
}
