//! Expert-parallel engine: Algorithm 1 with Stage 1 in Rust.
//!
//! Per layer and step, each EP rank:
//!   1. runs `ep_layer_pre_fwd` (attention + router) on its local tokens,
//!   2. exchanges tokens/weights/indices across the EP group (allgather —
//!      the paper's choice — or all2all, ablation),
//!   3. runs `ep_expert_fwd` (Pallas stages 2-5) over its local experts,
//!   4. reduce-scatters the partial outputs (line 116) and adds the
//!      residual.
//! The backward pass mirrors it: allgather d(moe_out) (line "allgather on
//! the gradients"), `ep_expert_bwd`, reduce-scatter dx/dw, then
//! `ep_layer_pre_bwd` recomputes the attention half from the stashed layer
//! input (SAC).
//!
//! Gradient/optimizer sharding is where SO vs EPSO differ (§3.2):
//! * SO: NE grads allreduced over EP (to stay correct), then sharded over
//!   DP only — NE optimizer states replicated EP times;
//! * EPSO: NE grads reduce-scattered over the whole DP×EP group.
//!
//! Expert gradients come back from `ep_expert_bwd` as a **sum** over every
//! EP peer's tokens (each peer's cotangents ride in through the gathered
//! `d_moe`); they are scaled by `1/EP` before the optimizer so all engines
//! share the DP convention — the mean gradient of the global batch. The
//! PP×EP hybrid engine relies on the same convention.
//!
//! Scaffolding (spawn/join/poison/broadcast/curves/report) lives in the
//! shared [`harness`](super::harness). Parameter slices handed to the
//! artifacts are materialized once per step and shared between the
//! forward and backward passes (the parameters only change at the
//! optimizer step), halving the seed's host-side copy volume; the full
//! local vector is never cloned inside the step.

use super::clip_now;
use super::ep::{exchange_all2all, exchange_allgather, fur_indices, EpComm};
use super::ep_layout::EpLayout;
use super::harness::{
    CkptView, LossDomain, RankCtx, RankFinish, RankTrainer, ReportParts, StepOutcome,
};
use super::plan::ParallelismPlan;
use crate::ckpt::LocalMap;
use crate::comm::{CollectiveOp, Group, Parts, Reduce, ReduceDtype};
use crate::config::ModelManifest;
use crate::metrics::{Scoped, StepBreakdown};
use crate::optim::sharded::{plan_segments, ShardedOptimizer};
use crate::runtime::{Dtype, Tensor};
use crate::Result;
use std::sync::Arc;

/// Per-layer EP artifact paths (shared with the PP×EP hybrid engine,
/// which runs the same artifacts per pipeline stage, and with the serving
/// engine's [`crate::serve`] expert-parallel decoder, which runs the
/// forward half of them).
pub(crate) struct Arts {
    pub(crate) embed_fwd: std::path::PathBuf,
    pub(crate) embed_bwd: std::path::PathBuf,
    pub(crate) pre_fwd: std::path::PathBuf,
    pub(crate) pre_bwd: std::path::PathBuf,
    pub(crate) expert_fwd: std::path::PathBuf,
    pub(crate) expert_bwd: std::path::PathBuf,
    pub(crate) head: std::path::PathBuf,
}

impl Arts {
    pub(crate) fn load(mm: &ModelManifest, ep: usize) -> Result<Arts> {
        let p = |n: &str| mm.artifact_path(&format!("ep{ep}_{n}"));
        Ok(Arts {
            embed_fwd: p("embed_fwd")?,
            embed_bwd: p("embed_bwd")?,
            pre_fwd: p("layer_pre_fwd")?,
            pre_bwd: p("layer_pre_bwd")?,
            expert_fwd: p("expert_fwd")?,
            expert_bwd: p("expert_bwd")?,
            head: p("head_fwdbwd")?,
        })
    }
}

/// Per-step parameter slices (shared by fwd and bwd — params are constant
/// within a step). Cloning one of these into an exec call is an Arc bump.
/// Layer slices are indexed by the layout's *local* layer index.
pub(crate) struct ParamSlices {
    pub(crate) emb: Tensor,
    pub(crate) head: Tensor,
    pub(crate) layer_ne: Vec<Tensor>,
    pub(crate) layer_e: Vec<Tensor>,
}

impl ParamSlices {
    pub(crate) fn new(params: &[f32], layout: &EpLayout) -> ParamSlices {
        let t = |r: &std::ops::Range<usize>| Tensor::f32(params[r.clone()].to_vec(), vec![r.len()]);
        ParamSlices {
            emb: t(&layout.emb),
            head: t(&layout.head),
            layer_ne: layout.layer_ne.iter().map(&t).collect(),
            layer_e: layout.layer_e.iter().map(&t).collect(),
        }
    }
}

pub(super) struct EpTrainer {
    layout: EpLayout,
    /// the layout's copy plan as a checkpoint map (local→global runs)
    map: LocalMap,
    arts: Arts,
    /// `Arc`-backed so a checkpoint snapshot is an O(1) handle capture
    params: Tensor,
    opt: ShardedOptimizer,
    ep_group: Arc<Group>,
    ep_rank: usize,
    /// this rank keeps participating in the final expert gather
    gathers_at_finish: bool,
    data_rank: usize,
    loss_dom: LossDomain,
}

impl RankTrainer for EpTrainer {
    const LABEL: &'static str = "ep";
    type Shared = ();

    fn shared(_mm: &ModelManifest, _plan: &ParallelismPlan) -> Result<Arc<()>> {
        Ok(Arc::new(()))
    }

    fn setup(ctx: &RankCtx, _shared: &Arc<()>, global_params: Vec<f32>) -> Result<EpTrainer> {
        let rank = ctx.rank;
        let ep = ctx.plan.topo.ep;
        let c = ctx.mesh.coord(rank);
        let layout = EpLayout::new(&ctx.mm, ep, c.ep);
        let arts = Arts::load(&ctx.mm, ep)?;
        let (ep_group, ep_rank) = ctx.mesh.ep_group(rank);
        let (dp_group, dp_rank) = ctx.mesh.dp_group(rank);
        let (dpep_group, dpep_rank) = ctx.mesh.dpep_group(rank);

        // every rank extracts its local view from the broadcast global
        let params = layout.extract(&global_params);
        drop(global_params);

        let stage = &ctx.plan.stages[0];
        debug_assert_eq!(stage.seg.ne_len, layout.ne_len);
        debug_assert_eq!(stage.seg.e_len, layout.e_len);
        let segs = plan_segments(
            ctx.plan.mode,
            stage.seg,
            dp_group,
            dp_rank,
            dpep_group,
            dpep_rank,
            ep,
        );
        let opt = ctx.sharded_optimizer(segs, &format!("ep{rank}"));
        let map = LocalMap::from_copies(layout.copy_runs())?;
        let local_len = layout.local_len();
        Ok(EpTrainer {
            ep_group: Arc::clone(ep_group),
            ep_rank,
            gathers_at_finish: c.dp == 0,
            data_rank: c.dp * ep + c.ep,
            layout,
            map,
            arts,
            // resident precision follows the plan dtype (one RNE round
            // here for bf16; masters in the optimizer stay f32)
            params: Tensor::from_f32(ctx.plan.dtype, params, vec![local_len]),
            opt,
            loss_dom: LossDomain {
                group: Arc::clone(ctx.mesh.world_group()),
                group_rank: rank,
                record: rank == 0,
            },
        })
    }

    fn step(
        &mut self,
        ctx: &RankCtx,
        step: usize,
        breakdown: &mut StepBreakdown,
    ) -> Result<StepOutcome> {
        let mm = &ctx.mm;
        let h = &mm.hyper;
        let ep = ctx.plan.topo.ep;
        let layout = &self.layout;
        let arts = &self.arts;
        let (ep_group, ep_rank) = (&self.ep_group, self.ep_rank);
        let nr = layout.n_local_experts;
        let (b, s) = (h.batch, h.seq);
        let t_local = b * s;
        let t_all = ep * t_local;
        let k = h.top_k;
        let hid = h.hidden;
        // activation-wire width follows the plan dtype; the standalone
        // `--bf16-grad-reduce` ablation knob deliberately only narrows
        // gradient reduction, never activation exchanges
        let wire = match ctx.plan.dtype {
            Dtype::Bf16 => ReduceDtype::Bf16,
            Dtype::F32 => ReduceDtype::F32,
        };

        let exec = |key: &str, path: &std::path::Path, inputs: Vec<Tensor>| {
            ctx.engine
                .exec(&format!("{}:{key}", mm.name), path.to_path_buf(), inputs)
        };

        let tokens_t = ctx.fetch_tokens(step, self.data_rank, 0, breakdown)?;
        // parameter slices for this step, shared by fwd and bwd; the
        // artifacts are lowered in f32, so a bf16-resident vector
        // decodes once per step (exactly) before slicing
        let ps = match self.params.dtype() {
            Dtype::F32 => ParamSlices::new(self.params.as_f32()?, layout),
            Dtype::Bf16 => ParamSlices::new(&self.params.to_f32_vec()?, layout),
        };

        // ---------------- forward ----------------
        let mut hcur = {
            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
            exec("embed_fwd", &arts.embed_fwd, vec![ps.emb.clone(), tokens_t.clone()])?
                .remove(0)
        };
        // stashes for backward (SAC: inputs only)
        let mut stash_h: Vec<Tensor> = Vec::with_capacity(h.n_layers);
        let mut stash_x: Vec<Tensor> = Vec::with_capacity(h.n_layers);
        let mut stash_w: Vec<Tensor> = Vec::with_capacity(h.n_layers);
        let mut stash_i: Vec<Tensor> = Vec::with_capacity(h.n_layers);
        let mut aux_total = 0.0f32;

        for l in 0..h.n_layers {
            stash_h.push(hcur.clone());
            let outs = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                exec("pre_fwd", &arts.pre_fwd, vec![ps.layer_ne[l].clone(), hcur])?
            };
            let mut it = outs.into_iter();
            let a = it.next().unwrap();
            let x2d = it.next().unwrap().into_f32()?;
            let w2d = it.next().unwrap().into_f32()?;
            let idx = it.next().unwrap();
            let aux = it.next().unwrap().scalar()?;
            aux_total += aux;
            let mut idx = idx.as_i32()?.to_vec();
            if ctx.spec.fur {
                idx = fur_indices(t_local, k, h.n_experts);
            }
            // ---- Stage 1: token exchange across EP ----
            let (x_all, w_all, idx_all) = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                match ctx.plan.ep_comm {
                    EpComm::Allgather => {
                        exchange_allgather(ep_group, ep_rank, x2d, w2d, &idx, wire)
                    }
                    EpComm::All2All => exchange_all2all(
                        ep_group, ep_rank, ep, nr, hid, x2d, w2d, &idx, wire,
                    ),
                }
            };
            // shift indices so local experts occupy [0, NR)
            let idx_shift: Vec<i32> = idx_all
                .iter()
                .map(|&v| v - (ep_rank * nr) as i32)
                .collect();
            let x_all = Tensor::f32(x_all, vec![t_all, hid]);
            let w_all = Tensor::f32(w_all, vec![t_all, k]);
            let idx_shift = Tensor::i32(idx_shift, vec![t_all, k]);
            // ---- Stages 2-5 (Pallas) ----
            let partial = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                exec("expert_fwd", &arts.expert_fwd, vec![
                    ps.layer_e[l].clone(),
                    x_all.clone(),
                    w_all.clone(),
                    idx_shift.clone(),
                ])?
                .remove(0)
                .into_f32()?
            };
            // ---- line 116: reduce-scatter of partial outputs ----
            let moe_local = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                ep_group
                    .run(
                        ep_rank,
                        CollectiveOp::ReduceScatter {
                            data: partial,
                            red: Reduce::Sum,
                            dt: wire,
                            parts: Parts::Even,
                        },
                    )
                    .unwrap_or_else(|f| panic!("{f}"))
                    .values()
            };
            // residual: h = a + moe_out
            let mut a_data = a.into_f32()?;
            for (av, mv) in a_data.iter_mut().zip(moe_local.iter()) {
                *av += *mv;
            }
            hcur = Tensor::f32(a_data, vec![b, s, hid]);
            stash_x.push(x_all);
            stash_w.push(w_all);
            stash_i.push(idx_shift);
        }

        // ---- head + loss ----
        let outs = {
            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
            exec("head", &arts.head, vec![ps.head.clone(), hcur, tokens_t.clone()])?
        };
        let loss = outs[0].scalar()?;
        let mut dh = outs[1].clone().into_f32()?;
        let dp_head = outs[2].as_f32()?.to_vec();
        if !loss.is_finite() {
            return Err(ctx.non_finite(step));
        }

        // ---------------- backward ----------------
        let mut grads = vec![0.0f32; layout.local_len()];
        grads[layout.head.clone()].copy_from_slice(&dp_head);

        for l in (0..h.n_layers).rev() {
            // d(out) = dh: residual gives d_a = dh and d(moe_out) = dh
            let d_moe_full = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                ep_group
                    .run(ep_rank, CollectiveOp::Allgather { data: dh.clone(), dt: wire })
                    .unwrap_or_else(|f| panic!("{f}"))
                    .values()
            };
            let outs = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                exec("expert_bwd", &arts.expert_bwd, vec![
                    ps.layer_e[l].clone(),
                    stash_x[l].clone(),
                    stash_w[l].clone(),
                    stash_i[l].clone(),
                    Tensor::f32(d_moe_full, vec![t_all, hid]),
                ])?
            };
            let dx_partial = outs[0].as_f32()?.to_vec();
            let dw_partial = outs[1].as_f32()?.to_vec();
            let dpe = outs[2].as_f32()?;
            grads[layout.layer_e[l].clone()].copy_from_slice(dpe);
            let (dx_local, dw_local) = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                let rs = |data: Vec<f32>| {
                    ep_group
                        .run(
                            ep_rank,
                            CollectiveOp::ReduceScatter {
                                data,
                                red: Reduce::Sum,
                                dt: wire,
                                parts: Parts::Even,
                            },
                        )
                        .unwrap_or_else(|f| panic!("{f}"))
                        .values()
                };
                (rs(dx_partial), rs(dw_partial))
            };
            let outs = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                exec("pre_bwd", &arts.pre_bwd, vec![
                    ps.layer_ne[l].clone(),
                    stash_h[l].clone(),
                    Tensor::f32(dh.clone(), vec![b, s, hid]),
                    Tensor::f32(dx_local, vec![t_local, hid]),
                    Tensor::f32(dw_local, vec![t_local, k]),
                ])?
            };
            dh = outs[0].as_f32()?.to_vec();
            grads[layout.layer_ne[l].clone()].copy_from_slice(outs[1].as_f32()?);
        }
        // embedding backward
        let outs = {
            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
            exec("embed_bwd", &arts.embed_bwd, vec![
                ps.emb.clone(),
                tokens_t.clone(),
                Tensor::f32(dh, vec![b, s, hid]),
            ])?
        };
        grads[layout.emb.clone()].copy_from_slice(outs[0].as_f32()?);

        // ---- SO correctness step: NE grads must average over EP too ----
        if ctx.plan.mode == crate::optim::ShardingMode::So && ep > 1 {
            let _t = Scoped::new(&mut breakdown.comm_secs);
            let ne = grads[..layout.ne_len].to_vec();
            let avg = ep_group
                .run(
                    ep_rank,
                    CollectiveOp::Allreduce {
                        data: ne,
                        red: Reduce::Mean,
                        dt: ctx.spec.reduce_dtype(),
                    },
                )
                .unwrap_or_else(|f| panic!("{f}"))
                .values();
            grads[..layout.ne_len].copy_from_slice(&avg);
        }

        // expert_bwd sums cotangents over every EP peer's tokens; scale by
        // 1/EP so expert grads follow the same mean-over-global-batch
        // convention as DP (NE grads get their mean from the optimizer's
        // reduce-scatter over the DP×EP group)
        if ep > 1 {
            let inv = 1.0 / ep as f32;
            for g in grads[layout.ne_len..].iter_mut() {
                *g *= inv;
            }
        }

        let lr = ctx.spec.run.lr_at(step) as f32;
        let gn = self
            .opt
            .step_tensor(&mut self.params, &grads, lr, clip_now(&ctx.spec.run, step))?;
        let _ = aux_total;
        Ok(StepOutcome { loss, grad_norm: gn })
    }

    fn params_mut(&mut self) -> Result<&mut [f32]> {
        Ok(self.params.as_f32_mut()?.as_mut_slice())
    }

    fn ckpt_view(&mut self) -> CkptView<'_> {
        CkptView { params: &self.params, map: &self.map, opt: &mut self.opt }
    }

    fn loss_domain(&self) -> Option<&LossDomain> {
        Some(&self.loss_dom)
    }

    fn finish(self, ctx: &RankCtx) -> Result<RankFinish> {
        // reassemble rank 0's global view: rank 0 holds ep=0 experts;
        // sibling ep ranks contribute theirs via the ep-group allgather
        if ctx.rank == 0 {
            let mm = &ctx.mm;
            let ep = ctx.plan.topo.ep;
            let mut final_params = vec![0.0f32; mm.param_count];
            // into_f32 moves the buffer when no snapshot handle is still
            // alive (the steady state) instead of copying the shard
            let local = self.params.into_f32()?;
            // lint: rank-uniform the gathers_at_finish legs below put every sibling of rank 0's ep group into this same allgather round
            let all_locals = self
                .ep_group
                .run(
                    self.ep_rank,
                    CollectiveOp::Allgather { data: local, dt: ReduceDtype::F32 },
                )
                .unwrap_or_else(|f| panic!("{f}"))
                .values();
            for (r, chunk) in all_locals.chunks(self.layout.local_len()).enumerate() {
                let lay_r = EpLayout::new(mm, ep, r);
                lay_r.scatter(chunk, &mut final_params);
            }
            return Ok(RankFinish::Report(Box::new(ReportParts {
                final_params: Tensor::f32(final_params, vec![mm.param_count]),
                opt_state_bytes: self.opt.state_bytes(),
                optimizer_update_secs: self.opt.update_secs,
                optimizer_comm_secs: self.opt.comm_secs,
                optimizer_overlap_secs: self.opt.overlap_secs,
                optimizer_lane_ops: self.opt.lane_ops(),
            })));
        }
        // non-zero ranks of rank 0's ep group must still rendezvous
        if self.gathers_at_finish {
            let local = self.params.into_f32()?;
            // lint: rank-uniform set exactly for the siblings of rank 0's ep group, matching the reporting rank's gather above
            self.ep_group
                .run(
                    self.ep_rank,
                    CollectiveOp::Allgather { data: local, dt: ReduceDtype::F32 },
                )
                .unwrap_or_else(|f| panic!("{f}"))
                .values();
        }
        Ok(RankFinish::None)
    }
}
