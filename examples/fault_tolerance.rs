//! Reliability features live (paper §4): a hard node failure at step 6
//! and a soft (NaN) failure at step 4 of the relaunched run, both
//! recovered automatically from buffer nodes + dual checkpoints.
//!
//! Run: `cargo run --release --example fault_tolerance`

use optimus::ckpt::DualCheckpointer;
use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec, StepHook};
use optimus::data::{corpus, preprocess};
use optimus::ft::{CkptHook, HardKillHook, Launcher, NanInjectHook};
use std::sync::Arc;

struct Chain(Vec<Arc<dyn StepHook>>);
impl StepHook for Chain {
    fn on_step(&self, r: usize, s: usize, l: f32, p: &mut [f32]) -> optimus::Result<()> {
        self.0.iter().try_for_each(|h| h.on_step(r, s, l, p))
    }
}

fn main() -> optimus::Result<()> {
    let data_dir = std::env::temp_dir().join("optimus-ft-demo-data");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 3, 16), 64, 7, &data_dir, 256)?;
    }
    let ckroot = std::env::temp_dir().join("optimus-ft-demo-ckpt");
    let _ = std::fs::remove_dir_all(&ckroot);

    let manifest = Manifest::load(&optimus::artifacts_dir())?;
    let hard = Arc::new(HardKillHook::once(1, 6));
    let soft = Arc::new(NanInjectHook::once(0, 4));
    // 2 active "nodes" + 2 buffer nodes
    let launcher = Launcher::new(2, 2);

    let report = launcher.run(|attempt, nodes| {
        println!("\n=== attempt {attempt} on nodes {nodes:?} ===");
        let mut spec = JobSpec::new("mula-tiny")
            .data_dir(data_dir.clone())
            .topology(2, 1, 1)
            .steps(12)
            .warmup_steps(2)
            .build()?;
        let dual = DualCheckpointer::new(&ckroot);
        if let Some(c) = dual.load_latest() {
            // resharding guard: the recorded plan must match ours
            c.ensure_plan(&spec.fingerprint())?;
            println!("resuming from checkpoint at step {}", c.step);
        }
        spec.hook = Arc::new(Chain(vec![
            hard.clone(),
            soft.clone(),
            Arc::new(CkptHook {
                every: 3,
                dual: DualCheckpointer::new(&ckroot),
                plan: Some(spec.fingerprint()),
            }),
        ]));
        coordinator::train(&manifest, &spec)
    })?;

    println!(
        "\nrecovered after {} relaunch(es); {} buffer nodes left; failed: {:?}",
        launcher.relaunches.load(std::sync::atomic::Ordering::Relaxed),
        launcher.pool.buffer_len(),
        launcher.pool.failed_nodes(),
    );
    println!("final loss: {:.4}", report.loss.last().unwrap());
    let latest = DualCheckpointer::new(&ckroot).load_latest().unwrap();
    println!("latest valid checkpoint: step {}", latest.step);
    Ok(())
}
