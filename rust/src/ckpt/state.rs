//! The `TrainState` / `StatePart` registry: every stateful component of a
//! training rank — parameter segments, per-segment AdamW moment shards,
//! step/metrics scalars, PRNG streams — exports named, typed parts, and
//! the [`Checkpointer`](super::Checkpointer) persists exactly the shards
//! this rank owns (the paper's DP-scattered checkpoint writes).
//!
//! Capture is **zero-copy and O(1) in element count**: every `F32`
//! payload is an `Arc` clone of a live buffer (the rank's parameter
//! [`Tensor`], the optimizer's moment vectors) plus a run list describing
//! which slices to persist and where those slices live in the *global*
//! flat parameter coordinate system. Serialization happens later — on the
//! Checkpointer's background writer — while training continues on a
//! copy-on-write view (see DESIGN.md §3: a mutation while the snapshot
//! handle is alive copies once; the snapshot stays intact).
//!
//! Global coordinates are what make resume **topology-elastic**: a shard
//! saved under one `ParallelismPlan` records `(global_start, len)` runs,
//! so any other plan can re-slice the union through its own segment
//! layouts (see [`super::reshard`]).

use crate::optim::sharded::ShardedOptimizer;
use crate::runtime::Tensor;
use crate::Result;
use anyhow::anyhow;

/// One contiguous run tying a slice of a rank-local vector to its
/// position in the global flat parameter vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GlobalRun {
    /// start within the rank-local vector the payload tensor indexes
    pub local_start: usize,
    /// start within the global flat parameter vector
    pub global_start: usize,
    pub len: usize,
}

/// Ordered runs tiling a rank-local parameter vector `[0, local_len)` —
/// the rank's local→global index map. Identity for DP; the EP/PP engines
/// build it from their layouts' copy plans.
#[derive(Clone, Debug, Default)]
pub struct LocalMap {
    runs: Vec<GlobalRun>,
    local_len: usize,
}

impl LocalMap {
    /// The DP map: local index == global index.
    pub fn identity(len: usize) -> LocalMap {
        LocalMap {
            runs: vec![GlobalRun { local_start: 0, global_start: 0, len }],
            local_len: len,
        }
    }

    /// Build from `(global_offset, local_offset, len)` copy runs (the
    /// form the engine layouts keep). Runs must tile `[0, local_len)`
    /// exactly — a gap or overlap is a layout bug, not a recoverable
    /// condition.
    pub fn from_copies(copies: &[(usize, usize, usize)]) -> Result<LocalMap> {
        let mut runs: Vec<GlobalRun> = copies
            .iter()
            .map(|&(g, l, n)| GlobalRun { local_start: l, global_start: g, len: n })
            .collect();
        runs.sort_by_key(|r| r.local_start);
        let mut pos = 0usize;
        for r in &runs {
            if r.local_start != pos {
                return Err(anyhow!(
                    "local map runs must tile the local vector: expected a run at {pos}, \
                     found one at {}",
                    r.local_start
                ));
            }
            pos += r.len;
        }
        Ok(LocalMap { runs, local_len: pos })
    }

    pub fn local_len(&self) -> usize {
        self.local_len
    }

    /// Project a local range onto global runs (the intersections, in
    /// local order). `local_start`s in the result stay absolute local
    /// coordinates.
    pub fn project(&self, start: usize, len: usize) -> Vec<GlobalRun> {
        let end = start + len;
        let mut out = Vec::new();
        for r in &self.runs {
            let lo = r.local_start.max(start);
            let hi = (r.local_start + r.len).min(end);
            if lo < hi {
                out.push(GlobalRun {
                    local_start: lo,
                    global_start: r.global_start + (lo - r.local_start),
                    len: hi - lo,
                });
            }
        }
        out
    }
}

/// Typed payload of one state part.
pub enum PartPayload {
    /// `Arc`-backed tensor plus the runs to persist out of it
    /// (`local_start` indexes the tensor). Capturing one is an `Arc`
    /// bump, never a data copy.
    F32 { tensor: Tensor, runs: Vec<GlobalRun> },
    /// bf16 parameter shards, persisted as raw 2-byte storage words —
    /// the mixed-precision run's half-width checkpoint payload. Same
    /// zero-copy capture discipline as `F32`.
    Bf16 { tensor: Tensor, runs: Vec<GlobalRun> },
    U64(u64),
    F64(f64),
}

/// One named, typed piece of a rank's persistent state.
pub struct StatePart {
    pub name: String,
    pub payload: PartPayload,
}

impl StatePart {
    /// Component key: the part name up to the first `.`
    /// (`"adam_m.s0"` → `"adam_m"`, `"params.s1"` → `"params"`).
    pub fn component(name: &str) -> &str {
        name.split('.').next().unwrap_or(name)
    }
}

/// Everything one rank hands the [`Checkpointer`](super::Checkpointer)
/// for one snapshot.
#[derive(Default)]
pub struct TrainState {
    pub parts: Vec<StatePart>,
}

impl TrainState {
    pub fn push_f32(&mut self, name: impl Into<String>, tensor: Tensor, runs: Vec<GlobalRun>) {
        self.parts.push(StatePart {
            name: name.into(),
            payload: PartPayload::F32 { tensor, runs },
        });
    }

    pub fn push_bf16(&mut self, name: impl Into<String>, tensor: Tensor, runs: Vec<GlobalRun>) {
        self.parts.push(StatePart {
            name: name.into(),
            payload: PartPayload::Bf16 { tensor, runs },
        });
    }

    pub fn push_u64(&mut self, name: impl Into<String>, v: u64) {
        self.parts.push(StatePart { name: name.into(), payload: PartPayload::U64(v) });
    }

    pub fn push_f64(&mut self, name: impl Into<String>, v: f64) {
        self.parts.push(StatePart { name: name.into(), payload: PartPayload::F64(v) });
    }
}

/// Capture a rank's persistent training state in O(1): the parameter
/// shards this rank *owns* per the optimizer's segment layout — the
/// paper's DP-scattered writes — and the per-segment AdamW moment
/// shards, all as `Arc` handles. `map` is the rank's local→global
/// parameter map; serialization happens later on the writer thread.
pub fn capture_rank_state(
    params: &Tensor,
    map: &LocalMap,
    opt: &ShardedOptimizer,
) -> Result<TrainState> {
    if params.len() != map.local_len() {
        return Err(anyhow!(
            "snapshot capture: params len {} does not match the local map len {}",
            params.len(),
            map.local_len()
        ));
    }
    let mut st = TrainState::default();
    for (i, seg) in opt.export_state().into_iter().enumerate() {
        // params: this rank persists exactly its owned shard of the
        // segment; after the optimizer's allgather every replica holds
        // the owner's bytes, so the union over ranks is exact. A bf16
        // run persists the raw 2-byte storage words (half-width payload;
        // the f32 masters are derived state and never saved — resume
        // re-seeds them from these params, the tolerance contract)
        let runs = map.project(seg.local_start, seg.len);
        match params.dtype() {
            crate::runtime::Dtype::Bf16 => {
                st.push_bf16(format!("params.s{i}"), params.clone(), runs.clone())
            }
            crate::runtime::Dtype::F32 => {
                st.push_f32(format!("params.s{i}"), params.clone(), runs.clone())
            }
        }
        // moments: same global geometry, but the m/v vectors are
        // shard-local — rebase the run starts onto [0, len)
        let rebased: Vec<GlobalRun> = runs
            .iter()
            .map(|r| GlobalRun { local_start: r.local_start - seg.local_start, ..*r })
            .collect();
        st.push_f32(format!("adam_m.s{i}"), Tensor::f32_shared(seg.m), rebased.clone());
        st.push_f32(format!("adam_v.s{i}"), Tensor::f32_shared(seg.v), rebased);
        st.push_u64(format!("adam_t.s{i}"), seg.step);
    }
    Ok(st)
}

/// Restore a rank's optimizer moments from a (possibly differently
/// sharded) resume source by re-slicing global runs through this rank's
/// map — the elastic half of the resume path. `step_counter` is the
/// number of optimizer steps already taken (saved step + 1), which
/// drives AdamW's bias correction.
pub fn restore_optimizer(
    opt: &mut ShardedOptimizer,
    map: &LocalMap,
    src: &super::reshard::ResumeState,
    step_counter: u64,
) -> Result<()> {
    for (i, (start, len)) in opt.shard_extents().into_iter().enumerate() {
        let runs: Vec<GlobalRun> = map
            .project(start, len)
            .into_iter()
            .map(|r| GlobalRun { local_start: r.local_start - start, ..r })
            .collect();
        let m = src.gather("adam_m", &runs, len)?;
        let v = src.gather("adam_v", &runs, len)?;
        opt.import_state(i, m, v, step_counter)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_and_projection() {
        let m = LocalMap::identity(100);
        assert_eq!(m.local_len(), 100);
        let p = m.project(10, 20);
        assert_eq!(p, vec![GlobalRun { local_start: 10, global_start: 10, len: 20 }]);
    }

    #[test]
    fn from_copies_projects_across_runs() {
        // local [0,10) -> global [40,50); local [10,30) -> global [0,20)
        let m = LocalMap::from_copies(&[(0, 10, 20), (40, 0, 10)]).unwrap();
        assert_eq!(m.local_len(), 30);
        // a range straddling both runs splits into two global runs
        let p = m.project(5, 10);
        assert_eq!(
            p,
            vec![
                GlobalRun { local_start: 5, global_start: 45, len: 5 },
                GlobalRun { local_start: 10, global_start: 0, len: 5 },
            ]
        );
        // empty projection of an out-of-range request
        assert!(m.project(30, 0).is_empty());
    }

    #[test]
    fn from_copies_rejects_gaps() {
        let e = LocalMap::from_copies(&[(0, 0, 10), (50, 15, 5)]).unwrap_err();
        assert!(e.to_string().contains("tile"), "{e}");
    }

    #[test]
    fn component_names() {
        assert_eq!(StatePart::component("params.s0"), "params");
        assert_eq!(StatePart::component("adam_m.s12"), "adam_m");
        assert_eq!(StatePart::component("params"), "params");
    }
}
