//! Ablations DESIGN.md calls out:
//! 1. EP Stage-1 exchange: allgather vs all2all (paper §3.1 Stage 1)
//! 2. PP schedule: gpipe vs 1f1b (activation memory + time)
//! 3. gradient-reduction dtype: bf16 vs f32 (paper §2.1 recipe)
//! 4. dual vs single checkpointing overhead

use optimus::ckpt::{Checkpoint, DualCheckpointer};
use optimus::config::Manifest;
use optimus::coordinator::pipeline::Schedule;
use optimus::coordinator::{self, ep::EpComm, JobSpec};
use optimus::data::{corpus, preprocess};
use optimus::util::bench::{bench, fmt_dur, Report};

fn main() -> optimus::Result<()> {
    let m = Manifest::load(&optimus::artifacts_dir())?;
    let data_dir = std::env::temp_dir().join("optimus-ablate-data");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 4, 32), 64, 7, &data_dir, 512)?;
    }

    // --- 1. EP exchange policy ---
    let mut t1 = Report::new(
        "Ablation: EP Stage-1 exchange (mula-tiny, EP=2, 8 steps)",
        &["policy", "loss@last", "step secs", "comm secs"],
    );
    for (policy, name) in [(EpComm::Allgather, "allgather"), (EpComm::All2All, "all2all")] {
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data_dir.clone())
            .topology(1, 2, 1)
            .steps(6)
            .ep_comm(policy)
            .build()?;
        let r = coordinator::train(&m, &spec)?;
        t1.row(&[
            name.into(),
            format!("{:.4}", r.loss.last().unwrap()),
            format!("{:.3}", r.mean_step_secs()),
            format!("{:.3}", r.breakdown.comm_secs),
        ]);
    }
    t1.print();
    t1.write_csv("ablation_ep_comm").ok();

    // --- 2. PP schedule ---
    let mut t2 = Report::new(
        "Ablation: PP schedule (mula-tiny, PP=2, 4 microbatches, 8 steps)",
        &["schedule", "loss@last", "step secs", "peak stashed acts (stage0)"],
    );
    for sched in [Schedule::GPipe, Schedule::OneFOneB] {
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data_dir.clone())
            .topology(1, 1, 2)
            .steps(6)
            .micro_batches(4)
            .schedule(sched)
            .build()?;
        let r = coordinator::train(&m, &spec)?;
        t2.row(&[
            sched.name().into(),
            format!("{:.4}", r.loss.last().unwrap()),
            format!("{:.3}", r.mean_step_secs()),
            sched.peak_in_flight(0, 2, 4).to_string(),
        ]);
    }
    t2.print();
    t2.write_csv("ablation_pp_schedule").ok();

    // --- 3. grad-reduce dtype ---
    let mut t3 = Report::new(
        "Ablation: gradient-reduction dtype (mula-tiny, DP=2, 12 steps)",
        &["dtype", "loss@last"],
    );
    for (bf16, name) in [(true, "bf16 (paper)"), (false, "f32")] {
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data_dir.clone())
            .topology(2, 1, 1)
            .steps(8)
            .bf16_grad_reduce(bf16)
            .build()?;
        let r = coordinator::train(&m, &spec)?;
        t3.row(&[name.into(), format!("{:.4}", r.loss.last().unwrap())]);
    }
    t3.print();
    t3.write_csv("ablation_grad_dtype").ok();

    // --- 4. checkpoint write cost: dual vs single slot ---
    let params = vec![0.5f32; 2_000_000];
    let moments = vec![0.1f32; 4_000_000];
    let root = std::env::temp_dir().join("optimus-ablate-ckpt");
    let _ = std::fs::remove_dir_all(&root);
    let dual = DualCheckpointer::new(&root);
    // the save API requires a recorded plan fingerprint
    let ck = Checkpoint {
        step: 1,
        params,
        moments,
        plan: Some("mula-tiny/dp2-ep1-pp1/so/1f1b/mb2/allgather".to_string()),
    };
    let s_dual = bench(1, 5, || {
        dual.save(&ck).unwrap();
    });
    let single_dir = root.join("single");
    let s_single = bench(1, 5, || {
        ck.write(&single_dir).unwrap();
    });
    let mut t4 = Report::new(
        "Ablation: checkpoint write cost (6M-f32 state)",
        &["strategy", "median write"],
    );
    t4.row(&["single slot".into(), fmt_dur(s_single.median)]);
    t4.row(&["dual (alternating)".into(), fmt_dur(s_dual.median)]);
    t4.print();
    t4.write_csv("ablation_ckpt").ok();
    let _ = std::fs::remove_dir_all(&root);
    Ok(())
}
