//! Shared rank-execution harness: the scaffolding every parallelism
//! engine (DP, EP, PP — and any future combination) runs on.
//!
//! The harness is the single owner of everything the paper's Optimus
//! trainer does identically regardless of topology:
//!
//! * rank thread spawning + naming (`<label>-rank-<r>`),
//! * join + error aggregation — the *root-cause* error returned by the
//!   failed rank wins over the panics of peers it took down,
//! * poison-on-failure: a dead rank poisons the mesh groups (and the
//!   trainer's shared fabric, e.g. PP's p2p channels) so peers fail fast
//!   instead of hanging (paper §4 hard-failure semantics),
//! * rank-0 model broadcast (paper §4 "model broadcasting"),
//! * the per-step driver loop: step fn → NaN guard → step hook → loss
//!   allreduce → curve recording → step timing,
//! * [`TrainReport`] assembly, including the [`StepBreakdown`]: trainers
//!   accumulate fwd/bwd, data and exchange-comm time during `step`; the
//!   optimizer's update/comm/overlap split is folded in exactly once from
//!   the optimizer's own counters at `finish` (the seed trainers each did
//!   this slightly differently — and DP double-booked it), and the PJRT
//!   executor queue-wait share is folded in from the pool counters as the
//!   per-rank average, so breakdown totals keep matching wall-clock.
//!
//! A parallelism engine implements [`RankTrainer`] and contains *only*
//! its genuinely distinct logic: the fused-artifact step (DP), the
//! per-layer Stage-1 exchange loop (EP), or the microbatch pipeline
//! schedule (PP). See DESIGN.md §4 for the trait contract.

use super::plan::ParallelismPlan;
use super::{init_global_params, JobSpec, StepHook as _, TrainReport};
use crate::ckpt::{
    capture_rank_state, restore_optimizer, Checkpointer, LocalMap, ResumeState, SavedCheckpoint,
};
use crate::comm::{CollectiveOp, Group, Mesh, Reduce, ReduceDtype};
use crate::config::ModelManifest;
use crate::data::{BatchPlan, Dataset, Prefetcher, TokenCursor, TokenStream};
use crate::ft::checks;
use crate::metrics::{Curve, Histogram, Scoped, StepBreakdown};
use crate::optim::sharded::{SegmentSpec, ShardedOptimizer};
use crate::runtime::{Engine, Tensor};
use crate::Result;
use anyhow::anyhow;
use std::cell::RefCell;
use std::sync::Arc;

/// Lifecycle of a rank's background batch prefetcher: spawned lazily on
/// the first fetch (so the engine's data rank is known), retired to
/// `Off` if a fetch ever falls outside the predicted sequence — from
/// then on the rank reads synchronously, which is always correct. `Off`
/// keeps the retired producer's hidden-assembly seconds so the
/// accounting survives retirement.
enum PrefetchSlot {
    Idle,
    Running(Prefetcher),
    Off(f64),
}

/// Everything a rank thread needs, cloned per rank before spawn.
pub struct RankCtx {
    pub rank: usize,
    pub mm: ModelManifest,
    pub engine: Engine,
    pub mesh: Arc<Mesh>,
    pub spec: JobSpec,
    /// the validated + materialized placement this run executes
    pub plan: Arc<ParallelismPlan>,
    /// batch-consumption geometry (`plan.batch_plan(mm)`)
    pub batches: BatchPlan,
    /// global data position: resume-safe mapping step → stream cursor
    pub cursor: TokenCursor,
    /// the run's shuffled, budget-enforced instance stream
    pub stream: Arc<TokenStream>,
    /// live sharded checkpointer (None when the plan's policy is off)
    pub ckpt: Option<Arc<Checkpointer>>,
    /// validated resume source (None for fresh runs)
    pub resume: Option<Arc<ResumeState>>,
    /// per-rank background batch producer (rank-thread-local)
    prefetch: RefCell<PrefetchSlot>,
    /// per-fetch prefetch-pop stall samples (rank-thread-local); merged
    /// into the report's world-wide `data_wait_hist` after the step loop
    data_wait_hist: RefCell<Histogram>,
}

impl RankCtx {
    /// The rank's sharded optimizer, built the one way every engine needs
    /// it: plan-driven segments, world-group grad-norm/clip domain, the
    /// run recipe's AdamW/reduction/clip settings, and the plan's
    /// `--overlap` knobs armed (`comm-<label>` names the lane worker).
    /// Engines construct through here so a new engine cannot forget to
    /// arm the overlap pipeline.
    pub fn sharded_optimizer(&self, segs: Vec<SegmentSpec>, label: &str) -> ShardedOptimizer {
        ShardedOptimizer::new(
            segs,
            Arc::clone(self.mesh.world_group()),
            self.rank,
            self.spec.adam(),
            self.spec.reduce_dtype(),
            self.spec.run.grad_clip,
        )
        .with_overlap(self.plan.overlap, self.plan.overlap_chunk, label)
    }

    /// Batch fetch: the `[b, s+1]` token tensor for
    /// (step, data_rank, microbatch), read from the shuffled stream at
    /// the cursor-derived position. With the plan's `prefetch` on, the
    /// batch comes off the rank's background producer (pop stall →
    /// `data_wait_secs`); otherwise — or when a fetch falls outside the
    /// producer's predicted sequence — it is assembled synchronously
    /// (→ `data_secs`).
    pub fn fetch_tokens(
        &self,
        step: usize,
        data_rank: usize,
        mb: usize,
        breakdown: &mut StepBreakdown,
    ) -> Result<Tensor> {
        let (b, s) = (self.mm.hyper.batch, self.mm.hyper.seq);
        let pos = self.cursor.at_step(step) + self.batches.offset(data_rank, mb) as u64;
        let mut toks: Option<Vec<i32>> = None;
        if self.plan.prefetch {
            let mut slot = self.prefetch.borrow_mut();
            if matches!(*slot, PrefetchSlot::Idle) {
                *slot = PrefetchSlot::Running(Prefetcher::spawn(
                    Arc::clone(&self.stream),
                    self.cursor,
                    self.batches,
                    data_rank,
                    b,
                    s,
                    self.spec.run.steps,
                    (step, mb),
                ));
            }
            let mut retire = None;
            if let PrefetchSlot::Running(p) = &mut *slot {
                let wait0 = breakdown.data_wait_secs;
                match p.fetch(step, data_rank, mb, &mut breakdown.data_wait_secs) {
                    Some(batch) => {
                        // one stall sample per queue pop: the delta the
                        // producer just added to the additive sum
                        self.data_wait_hist
                            .borrow_mut()
                            .record(breakdown.data_wait_secs - wait0);
                        toks = Some(batch?);
                    }
                    // out-of-pattern consumer: retire the producer (its
                    // hidden time survives in Off) and read
                    // synchronously for the rest of the run
                    None => retire = Some(p.busy_secs()),
                }
            }
            if let Some(busy) = retire {
                *slot = PrefetchSlot::Off(busy);
            }
        }
        let toks = match toks {
            Some(t) => t,
            None => {
                let _t = Scoped::new(&mut breakdown.data_secs);
                self.stream.batch_i32(pos, b, s)?
            }
        };
        if let Some(trace) = &self.spec.data_trace {
            let mut t = crate::util::lock(trace);
            for r in 0..b as u64 {
                t.push((pos + r, self.stream.map(pos + r)?.1 as u64));
            }
        }
        Ok(Tensor::i32(toks, vec![b, s + 1]))
    }

    /// Seconds this rank's prefetch producer spent assembling batches
    /// (hidden behind compute); 0 when prefetch never started. A retired
    /// producer's time is preserved by `Off`.
    fn data_prefetch_secs(&self) -> f64 {
        match &*self.prefetch.borrow() {
            PrefetchSlot::Running(p) => p.busy_secs(),
            PrefetchSlot::Off(busy) => *busy,
            PrefetchSlot::Idle => 0.0,
        }
    }

    /// The canonical rank-abort error for a non-finite loss. Trainers use
    /// it when they bail out mid-step; the harness uses it as the
    /// post-step backstop. The format is load-bearing: `crate::ft`
    /// classifies it as a *soft* failure and parses the rank out of it.
    pub fn non_finite(&self, step: usize) -> anyhow::Error {
        anyhow!("rank {}: non-finite loss at step {step}", self.rank)
    }
}

/// The rank's persistent state as the checkpoint path sees it: the
/// `Arc`-backed local parameter tensor, the rank-local→global parameter
/// map, and the sharded optimizer owning the moment shards. The harness
/// drives zero-copy snapshot capture and elastic restore through this
/// view; engines only describe *where* their state lives.
pub struct CkptView<'a> {
    pub params: &'a Tensor,
    pub map: &'a LocalMap,
    pub opt: &'a mut ShardedOptimizer,
}

/// What one training step produced on this rank.
pub struct StepOutcome {
    /// rank-local loss (last PP stage: microbatch mean; other PP stages
    /// report 0.0 and opt out of the loss domain below)
    pub loss: f32,
    /// global gradient norm from the sharded optimizer (pre-clip)
    pub grad_norm: f64,
}

/// Which group averages this rank's loss each step, and whether this rank
/// records the averaged curves. `None` ⇒ the rank neither contributes nor
/// records (e.g. non-last PP stages, which never see a loss).
pub struct LossDomain {
    pub group: Arc<Group>,
    pub group_rank: usize,
    pub record: bool,
}

/// Report ingredients only the reporting rank can supply. The optimizer
/// timing split comes from the optimizer's own counters so the harness can
/// fold it into the breakdown exactly once.
pub struct ReportParts {
    /// assembled full-model parameter vector (rank 0's view)
    pub final_params: Tensor,
    pub opt_state_bytes: usize,
    pub optimizer_update_secs: f64,
    /// exposed optimizer comm (rank thread blocked in collectives)
    pub optimizer_comm_secs: f64,
    /// optimizer comm hidden behind compute by the `--overlap` pipeline
    pub optimizer_overlap_secs: f64,
    /// collectives completed on the optimizer's comm lane (0 when serial)
    /// — the falsifiable signal that `--overlap` actually ran pipelined
    pub optimizer_lane_ops: u64,
}

/// Auxiliary per-rank payload merged into the report after join — e.g. a
/// non-last PP stage's parameters, scattered into `final_params` by
/// [`RankTrainer::merge_aux`].
pub struct AuxParams {
    pub tag: usize,
    pub params: Vec<f32>,
}

/// What a rank hands back when training ends.
pub enum RankFinish {
    Report(Box<ReportParts>),
    Aux(AuxParams),
    None,
}

/// One parallelism engine. `setup` → `step`× → `finish` runs inside a
/// rank thread the harness owns; associated functions configure the run
/// before any thread exists.
///
/// Contract (see DESIGN.md §4):
/// * exactly one rank must return [`RankFinish::Report`];
/// * `step` accumulates fwd/bwd, data and exchange-comm time into the
///   breakdown but must NOT time the optimizer — the harness folds the
///   optimizer's own `update_secs`/`comm_secs` in at finish;
/// * a rank that fails returns `Err` (never panics): the harness poisons
///   the mesh + shared fabric so peers unblock, and `train()` surfaces
///   the root-cause error, not a peer's panic;
/// * configuration validation does NOT live here — the single preflight
///   gate is [`ParallelismPlan::validate`], which `coordinator::train`
///   runs before anything spawns.
pub trait RankTrainer: Sized {
    /// Thread-name prefix ("dp" → `dp-rank-3`).
    const LABEL: &'static str;

    /// Cross-rank fabric built once before spawning (e.g. PP's [`crate::comm::P2p`]).
    type Shared: Send + Sync + 'static;

    fn shared(mm: &ModelManifest, plan: &ParallelismPlan) -> Result<Arc<Self::Shared>>;

    /// Unblock peers waiting on the shared fabric after a rank died.
    fn poison_shared(_shared: &Self::Shared) {}

    /// Build per-rank state. `global_params` is the full initial model
    /// vector every rank holds right after the rank-0 broadcast; the
    /// trainer extracts its local view (all of it for DP, the EP layout
    /// slice, the PP stage segment).
    fn setup(ctx: &RankCtx, shared: &Arc<Self::Shared>, global_params: Vec<f32>)
        -> Result<Self>;

    /// One optimizer step.
    fn step(
        &mut self,
        ctx: &RankCtx,
        step: usize,
        breakdown: &mut StepBreakdown,
    ) -> Result<StepOutcome>;

    /// Rank-local parameters, mutably — step hooks may rewrite them
    /// (checkpoint restore, NaN injection).
    fn params_mut(&mut self) -> Result<&mut [f32]>;

    /// Persistent-state view for checkpoint capture/restore (every
    /// engine's state is the same triple: params tensor, local→global
    /// map, sharded optimizer).
    fn ckpt_view(&mut self) -> CkptView<'_>;

    fn loss_domain(&self) -> Option<&LossDomain>;

    /// Tear down: final collectives + the rank's contribution to the
    /// report. Runs on every rank (so gather collectives can rendezvous).
    fn finish(self, ctx: &RankCtx) -> Result<RankFinish>;

    /// Merge auxiliary rank payloads into the assembled report (PP
    /// scatters non-last stage params into `final_params`).
    fn merge_aux(
        _mm: &ModelManifest,
        _plan: &ParallelismPlan,
        _report: &mut TrainReport,
        _aux: Vec<AuxParams>,
    ) -> Result<()> {
        Ok(())
    }
}

enum RankOut {
    Report(TrainReport),
    Aux(AuxParams),
    None,
}

/// Poisons the mesh + shared fabric on drop unless disarmed — so peers
/// unblock even when a rank *panics* (unwinds) rather than returning
/// `Err` through the normal path.
struct PoisonGuard<'a, S> {
    mesh: &'a Mesh,
    shared: &'a S,
    poison: fn(&S),
    armed: bool,
}

impl<S> Drop for PoisonGuard<'_, S> {
    fn drop(&mut self) {
        if self.armed {
            self.mesh.poison_all();
            (self.poison)(self.shared);
        }
    }
}

/// Run a [`RankTrainer`] over the full mesh: spawn one thread per rank,
/// drive the per-step loop, aggregate errors, assemble the report.
pub fn run<T: RankTrainer + 'static>(
    mm: &ModelManifest,
    ds: Arc<Dataset>,
    engine: Engine,
    mesh: Arc<Mesh>,
    spec: &JobSpec,
    plan: &Arc<ParallelismPlan>,
) -> Result<TrainReport> {
    let batches = plan.batch_plan(mm);
    let shared = T::shared(mm, plan)?;
    let world_n = plan.topo.world();

    // one source of placement truth: the spec carried into rank threads
    // holds the same materialized plan as ctx.plan, regardless of what
    // the caller's spec.plan contained
    let spec = {
        let mut s = spec.clone();
        s.plan = (**plan).clone();
        s
    };

    // sharded checkpointing + elastic auto-resume (paper §4): when the
    // plan's policy names a directory, attach the Checkpointer and — if a
    // committed checkpoint of this model exists there — resume from it,
    // resharding through this plan's layouts if the topology changed.
    // True mismatches fail here, before any rank thread spawns, with the
    // stable `checkpoint resume failed [<check>]` strings ft::classify
    // maps to a non-relaunchable Config failure.
    let (ckpt, resume) = match &plan.ckpt.dir {
        Some(dir) => {
            let mut resume = None;
            for saved in SavedCheckpoint::load_all(dir) {
                match ResumeState::open(&saved) {
                    Ok(rs) => {
                        // a true state mismatch (different model, short
                        // coverage) is not recoverable by falling back —
                        // propagate it
                        rs.validate(&spec.model, mm.param_count)?;
                        // the params must be saved in the dtype the plan
                        // runs — silent re-encoding at resume would shift
                        // the loss trajectory unrecorded
                        rs.validate_dtype(plan.dtype.as_str())?;
                        // the saved token cursor is only meaningful under
                        // the shuffle that consumed it: a different
                        // --data-seed would silently re-read and skip
                        // instances — the exact bug class the cursor
                        // exists to prevent. (Compared through the same
                        // f64 round-trip the manifest scalar takes.)
                        if let Some(saved_seed) = rs.data_seed() {
                            let want = spec.run.data_seed as f64 as u64;
                            if saved_seed != want {
                                return Err(checks::err(
                                    checks::RESUME,
                                    "data-seed",
                                    format!(
                                        "the checkpoint's token cursor was consumed \
                                         under --data-seed {saved_seed}, this job \
                                         shuffles with {}; resuming would re-read and \
                                         skip instances — pass --data-seed \
                                         {saved_seed} to continue the stream",
                                        spec.run.data_seed
                                    ),
                                ));
                            }
                        }
                        if rs.step() + 1 >= spec.run.steps {
                            // not an error: a relaunch after a final-step
                            // crash (or a re-run of a completed command)
                            // must still load — it just has nothing left
                            // to train
                            eprintln!(
                                "[ckpt] checkpoint at step {} meets the step budget \
                                 {} — resuming with zero steps left",
                                rs.step(),
                                spec.run.steps
                            );
                        }
                        resume = Some(Arc::new(rs));
                        break;
                    }
                    // corrupt shards: fall back to the next older slot
                    // (the dual guarantee)
                    Err(e) => eprintln!(
                        "[ckpt] skipping damaged checkpoint at step {}: {e:#}",
                        saved.step
                    ),
                }
            }
            let ck = Checkpointer::new(dir, &spec.fingerprint(), world_n, &plan.ckpt)?;
            (Some(ck), resume)
        }
        None => (None, None),
    };

    // --- the global token cursor (DESIGN.md §7): the resumed run
    // continues at exactly the instances-consumed-so-far the checkpoint
    // recorded, whatever geometry saved it. Same-topology resume lands on
    // the very positions the step-derived scheme produced (bit-identity
    // preserved); an elastic resume keeps consuming the next unseen
    // instance instead of re-deriving the position from the new
    // geometry's step product. Legacy checkpoints without the scalar fall
    // back to the step-derived position.
    let per_step = batches.instances_per_step() as u64;
    let cursor = match &resume {
        Some(r) => {
            let start_step = r.step() + 1;
            let base = r
                .data_cursor()
                .unwrap_or(start_step as u64 * per_step);
            TokenCursor { base, start_step, per_step }
        }
        None => TokenCursor::fresh(per_step),
    };
    // validated data budget: what the remaining steps are allowed to read
    let remaining = spec.run.steps.saturating_sub(cursor.start_step) as u64;
    let budget = cursor.base + remaining * per_step;
    let mut stream = TokenStream::new(Arc::clone(&ds), spec.run.data_seed, budget);
    if plan.data_epochs > 0 {
        // the [data] preflight re-checked against the REAL cursor: a
        // resumed run's demand counts what the checkpoint already
        // consumed, which the plan-level check (steps × per_step under
        // the NEW geometry) cannot see
        let have = ds.len() as u64 * plan.data_epochs as u64;
        if budget > have {
            return Err(checks::err(
                checks::PLAN,
                "data",
                format!(
                    "cursor {} + {remaining} steps × {per_step} instances/step needs \
                     {budget} total instances, but the dataset provides {} × {} epoch \
                     budget = {have}; raise --epochs, lower --steps, or preprocess \
                     more data",
                    cursor.base,
                    ds.len(),
                    plan.data_epochs
                ),
            ));
        }
        // epoch budget set ⇒ the logical stream truly ends there:
        // continuation targets EOS-pad at that wall (and only there)
        stream = stream.with_stream_end(have);
    }
    let stream = Arc::new(stream);

    let handles: Vec<_> = (0..world_n)
        .map(|rank| {
            let ctx = RankCtx {
                rank,
                mm: mm.clone(),
                engine: engine.clone(),
                mesh: Arc::clone(&mesh),
                spec: spec.clone(),
                plan: Arc::clone(plan),
                batches,
                cursor,
                stream: Arc::clone(&stream),
                ckpt: ckpt.clone(),
                resume: resume.clone(),
                prefetch: RefCell::new(PrefetchSlot::Idle),
                data_wait_hist: RefCell::new(Histogram::new()),
            };
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("{}-rank-{rank}", T::LABEL))
                .spawn(move || {
                    let mesh = Arc::clone(&ctx.mesh);
                    // dead node — by `Err` *or* panic — unblocks peers
                    // (paper §4 hard failure): the guard poisons on drop
                    // unless the rank finished cleanly
                    let mut guard = PoisonGuard {
                        mesh: mesh.as_ref(),
                        shared: shared.as_ref(),
                        poison: T::poison_shared,
                        armed: true,
                    };
                    let r = rank_loop::<T>(ctx, &shared);
                    guard.armed = r.is_err();
                    drop(guard);
                    r
                })
                .expect("spawn rank")
        })
        .collect();

    let mut report: Option<TrainReport> = None;
    let mut aux: Vec<AuxParams> = Vec::new();
    let mut first_err: Option<anyhow::Error> = None;
    let mut panicked = false;
    let mut panic_msgs: Vec<String> = Vec::new();
    for h in handles {
        match h.join() {
            Ok(Ok(RankOut::Report(r))) => report = Some(r),
            Ok(Ok(RankOut::Aux(a))) => aux.push(a),
            Ok(Ok(RankOut::None)) => {}
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            // panics are usually peers aborted by poisoning — prefer the
            // root-cause error returned by the rank that actually failed.
            // Keep non-poison payloads: a `collective protocol violated`
            // panic from a comm wrapper IS the root cause.
            Err(p) => {
                panicked = true;
                let msg = p
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()));
                match msg {
                    // collateral: peers killed by group/fabric poisoning
                    Some(m) if m.contains("poisoned") => {}
                    Some(m) => panic_msgs.push(m),
                    None => {}
                }
            }
        }
    }
    // drain the checkpoint writer before surfacing anything: trailing
    // snapshots commit (or a partial step stays staged-only), so when
    // train() returns — by Ok *or* Err — the newest valid checkpoint is
    // on disk and a relaunch can resume from it immediately
    let ckpt_err = ckpt.as_ref().and_then(|c| c.drain().err());
    if let Some(e) = first_err {
        return Err(e);
    }
    if panicked {
        // surface a protocol-violation payload first (it carries the
        // stable check string ft::classify routes on), then any other
        // captured payload, then the legacy generic line
        if let Some(m) = panic_msgs.iter().find(|m| m.contains(checks::PROTOCOL)) {
            return Err(anyhow!("{m}"));
        }
        if let Some(m) = panic_msgs.first() {
            return Err(anyhow!("rank thread panicked: {m}"));
        }
        return Err(anyhow!("a rank thread panicked without a root-cause error"));
    }
    if let Some(e) = ckpt_err {
        return Err(e);
    }
    let mut report = report.ok_or_else(|| anyhow!("no rank produced a report"))?;
    T::merge_aux(mm, plan, &mut report, aux)?;
    if let Some(ck) = &ckpt {
        let st = ck.stats();
        // hidden serialization time, attributed like queue_secs: the
        // writer is shared by the run, so the report carries the per-rank
        // share of the run total
        report.breakdown.snapshot_write_secs += st.write_secs / world_n as f64;
        report.ckpt_commits = st.commits;
        report.ckpt_bytes = st.bytes_written;
    }
    // whole-mesh collective traffic at actual wire width — the
    // bytes-moved signal the perf gate compares across dtypes, plus the
    // node-locality split the hierarchical collectives exist to improve
    let traffic = mesh.traffic();
    report.comm_bytes_in = traffic.bytes_in;
    report.comm_bytes_out = traffic.bytes_out;
    report.comm_intra_bytes = traffic.intra_bytes;
    report.comm_inter_bytes = traffic.inter_bytes;
    Ok(report)
}

fn rank_loop<T: RankTrainer>(ctx: RankCtx, shared: &Arc<T::Shared>) -> Result<RankOut> {
    let rank = ctx.rank;

    // --- model broadcasting (paper §4): only rank 0 materializes the
    // seed vector — a fresh init, or on resume the checkpoint's
    // reassembled global params. Every rank then extracts its local view
    // exactly as on a fresh start, which is what makes resume
    // plan-agnostic: the saving topology never appears here.
    let world = ctx.mesh.world_group();
    let global0 = if rank == 0 {
        let p = match &ctx.resume {
            Some(r) => r.assemble_params(ctx.mm.param_count)?,
            None => init_global_params(&ctx.mm, ctx.spec.run.seed),
        };
        // faults panic (not Err): a peer aborted by poisoning must stay a
        // filtered collateral panic so the root-cause rank's error wins
        world
            .run(rank, CollectiveOp::Broadcast { root: 0, data: p.clone() })
            .unwrap_or_else(|f| panic!("{f}"));
        p
    } else {
        world
            .run(rank, CollectiveOp::Broadcast { root: 0, data: Vec::new() })
            .unwrap_or_else(|f| panic!("{f}"))
            .values()
    };
    let mut trainer = T::setup(&ctx, shared, global0)?;
    let start_step = match &ctx.resume {
        Some(r) => {
            // moments re-sliced through this rank's local→global map
            // (the elastic reshard); the AdamW bias-correction counter
            // continues from the checkpoint's own scalar (falling back
            // to saved_step + 1 for files without one) — together with
            // the exact params this makes the resumed trajectory
            // bit-identical
            let t = r.adam_step().unwrap_or(r.step() as u64 + 1);
            let view = trainer.ckpt_view();
            restore_optimizer(view.opt, view.map, r, t)?;
            r.step() + 1
        }
        None => 0,
    };

    let mut loss_curve = Curve::new("loss");
    let mut gn_curve = Curve::new("grad_norm");
    let mut breakdown = StepBreakdown::default();
    // zero when the checkpoint already meets the step budget: the loop
    // body never runs and finish() reports the restored state
    let mut step_secs =
        Vec::with_capacity(ctx.spec.run.steps.saturating_sub(start_step));
    let mut last_loss = f64::NAN;
    // engine-pool counters are shared by every rank of the run: snapshot
    // now so the reporting rank can fold in this run's queue-wait delta
    let engine_stats0 = ctx.engine.stats();

    for step in start_step..ctx.spec.run.steps {
        let t_step = std::time::Instant::now();
        let out = trainer.step(&ctx, step, &mut breakdown)?;
        // soft-failure backstop (paper §4): a NaN loss aborts the rank
        // even if the trainer didn't bail out itself
        if !out.loss.is_finite() {
            return Err(ctx.non_finite(step));
        }
        if ctx.spec.hooked {
            // hooks observe (and may rewrite) the mutable f32 parameter
            // view; bf16 engines cannot provide one, so a hooked bf16
            // run fails here rather than silently dropping mutations
            ctx.spec.hook.on_step(rank, step, out.loss, trainer.params_mut()?)?;
        }
        if let Some(dom) = trainer.loss_domain() {
            // loss is rank-local; average across the domain for the curve
            let mean = dom
                .group
                .run(
                    dom.group_rank,
                    CollectiveOp::Allreduce {
                        data: vec![out.loss],
                        red: Reduce::Mean,
                        dt: ReduceDtype::F32,
                    },
                )
                .unwrap_or_else(|f| panic!("{f}"))
                .values()[0];
            if dom.record {
                last_loss = mean as f64;
                loss_curve.push(step, mean as f64);
                gn_curve.push(step, out.grad_norm);
            }
        }
        // snapshot at the step boundary: the training thread blocks only
        // for the O(1) Arc capture (+ inline write when the policy is
        // synchronous); every rank reaches this point after the same
        // step, so the union of submissions is a consistent cut. A rank
        // that died this step never submits and the step never commits.
        if let Some(ck) = &ctx.ckpt {
            if ctx.plan.ckpt.due(step) {
                let t = std::time::Instant::now();
                let view = trainer.ckpt_view();
                let mut snap = capture_rank_state(view.params, view.map, view.opt)?;
                snap.push_u64("prng.seed", ctx.spec.run.seed);
                // the global token cursor: instances consumed once this
                // step is done — the resume point for ANY geometry —
                // plus the shuffle seed the cursor is only valid under
                snap.push_u64("data.cursor", ctx.cursor.at_step(step + 1));
                snap.push_u64("data.seed", ctx.spec.run.data_seed);
                if last_loss.is_finite() {
                    snap.push_f64("metrics.loss", last_loss);
                }
                ck.submit(step, rank, snap)?;
                breakdown.snapshot_secs += t.elapsed().as_secs_f64();
            }
        }
        step_secs.push(t_step.elapsed().as_secs_f64());
    }

    // hidden batch-assembly time from this rank's prefetch producer,
    // folded once after the step loop (mirrors the optimizer split)
    breakdown.data_prefetch_secs += ctx.data_prefetch_secs();

    // world-wide data-wait distribution: histogram state is nothing but
    // bucket counts + a sum, so one Sum allreduce of 65 floats gives every
    // rank the identical global distribution. Every rank reaches this
    // point right after its step loop, so the op slots into the same
    // protocol position world-wide (the comm auditor sees one more
    // uniform round, never a divergent order).
    let data_wait_hist = {
        let local = ctx.data_wait_hist.borrow();
        let mut wire = local.counts_f32_wire();
        wire.push(local.sum() as f32);
        drop(local);
        let merged = world
            .run(
                rank,
                CollectiveOp::Allreduce { data: wire, red: Reduce::Sum, dt: ReduceDtype::F32 },
            )
            .unwrap_or_else(|f| panic!("{f}"))
            .values();
        Histogram::from_wire(&merged[..64], merged[64] as f64)
    };

    match trainer.finish(&ctx)? {
        RankFinish::Report(parts) => {
            let mut parts = *parts;
            // report contract: `final_params` is always f32 — eval and
            // the legacy checkpoint writer consume it at full width; a
            // bf16 engine's params decode exactly here
            if parts.final_params.dtype() == crate::runtime::Dtype::Bf16 {
                parts.final_params =
                    Tensor::f32(parts.final_params.to_f32_vec()?, vec![ctx.mm.param_count]);
            }
            // breakdown assembly: the optimizer's update/comm/overlap
            // split comes from its own counters, folded in exactly once
            breakdown.optimizer_secs += parts.optimizer_update_secs;
            breakdown.comm_secs += parts.optimizer_comm_secs;
            breakdown.overlap_secs += parts.optimizer_overlap_secs;
            // PJRT queue wait: the pool counters span all ranks, so the
            // report records the per-rank average of this run's delta —
            // an estimate of this rank's share (see StepBreakdown docs)
            let engine_stats1 = ctx.engine.stats();
            breakdown.queue_secs += (engine_stats1.queue_secs - engine_stats0.queue_secs)
                .max(0.0)
                / ctx.plan.topo.world() as f64;
            // run-level data consumption: total instances through the
            // end of the step budget (including pre-resume consumption)
            let instances_consumed = ctx.cursor.at_step(ctx.spec.run.steps);
            Ok(RankOut::Report(TrainReport {
                loss: loss_curve,
                grad_norm: gn_curve,
                breakdown,
                data_wait_hist,
                step_secs,
                tokens_per_step: ctx.batches.instances_per_step() * ctx.mm.hyper.seq,
                instances_consumed,
                epochs_consumed: instances_consumed as f64
                    / ctx.stream.epoch_len().max(1) as f64,
                final_params: parts.final_params,
                opt_state_bytes: parts.opt_state_bytes,
                optimizer_update_secs: parts.optimizer_update_secs,
                optimizer_comm_secs: parts.optimizer_comm_secs,
                optimizer_overlap_secs: parts.optimizer_overlap_secs,
                optimizer_lane_ops: parts.optimizer_lane_ops,
                // run-level quantities: harness::run folds these in from
                // the Checkpointer's stats and the mesh traffic counters
                ckpt_commits: 0,
                comm_bytes_in: 0,
                comm_bytes_out: 0,
                comm_intra_bytes: 0,
                comm_inter_bytes: 0,
                ckpt_bytes: 0,
            }))
        }
        RankFinish::Aux(a) => Ok(RankOut::Aux(a)),
        RankFinish::None => Ok(RankOut::None),
    }
}
