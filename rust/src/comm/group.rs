//! A process group: rendezvous collectives among `size` participants.
//!
//! The public surface is one typed descriptor, [`CollectiveOp`], executed
//! via [`Group::run`] (blocking) or [`Group::start`] (on a
//! [`CommRuntime`] lane). Flat and hierarchical execution are
//! interchangeable strategies behind that single surface: a group built
//! with node placement (see [`Group::new_on_nodes`]) runs the
//! reduction-shaped ops in three phases — intra-node reduce over a
//! node-local subgroup, inter-node exchange over a leaders subgroup,
//! intra-node broadcast back — while a flat group (or a
//! hierarchy-ineligible op) runs one world-wide rendezvous. DESIGN.md §6
//! has the phase diagram and the op contract.
//!
//! Each rendezvous is two-phase, guarded by a mutex+condvar: all members
//! deposit their contribution; the last arrival computes the result;
//! everyone picks up their share; the last departure resets the slot for
//! the next round. Rounds are strictly ordered per group, which matches
//! the deterministic program order of collectives in SPMD training.
//!
//! Two guards make protocol misuse fail fast instead of hanging or
//! silently corrupting (DESIGN.md §12):
//!
//! * every deposit carries the op's [`OpDesc`] (built once by
//!   [`CollectiveOp::desc`]) checked by the round's
//!   [`Audit`](super::audit) — the first arrival pins the round, any
//!   mismatching member fails the group with a stable
//!   `collective protocol violated [order|shape|dtype]` error;
//! * a **deadlock watchdog**: condvar waits are bounded by a configurable
//!   stall timeout ([`Group::set_stall_timeout`], default
//!   `OPTIMUS_STALL_TIMEOUT_SECS` or 180 s); on expiry the waiter dumps
//!   the per-rank last-op table and fails with
//!   `collective protocol violated [stall]`.
//!
//! The sync primitives come from [`super::lsync`], so `--cfg loom` builds
//! model-check the whole rendezvous state machine (`tests/loom_models.rs`).

use super::audit::{Audit, CommFault, OpDesc, OpKind, WireDtype};
use super::lsync::{AtomicBool, Condvar, Mutex, MutexGuard};
use super::runtime::{CommHandle, CommRuntime};
use crate::util::{bf16s_to_f32s, f32s_to_bf16s};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Gradient-reduction dtype (paper §2.1 trains with bfloat16 gradient
/// reduction; f32 is the ablation baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceDtype {
    F32,
    Bf16,
}

impl From<ReduceDtype> for WireDtype {
    fn from(dt: ReduceDtype) -> WireDtype {
        match dt {
            ReduceDtype::F32 => WireDtype::F32,
            ReduceDtype::Bf16 => WireDtype::Bf16,
        }
    }
}

/// Reduction applied by [`CollectiveOp::Allreduce`] /
/// [`CollectiveOp::ReduceScatter`]. `Mean` divides the elementwise sum
/// by the **parent** group size (so a hierarchical mean matches the flat
/// one); `Max` is hierarchy-ineligible and always runs flat.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduce {
    Sum,
    Mean,
    Max,
}

/// How [`CollectiveOp::ReduceScatter`] splits the reduced vector across
/// ranks: `Ragged` uses the ZeRO-style contiguous ranges of
/// [`crate::util::shard_ranges`] (length need not divide evenly), `Even`
/// asserts divisibility and hands rank r the r-th `1/size` slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parts {
    Ragged,
    Even,
}

/// One collective, fully described: what travels, how it is combined,
/// and at what wire width. This is the single surface the engines, the
/// sharded optimizer, and the tests speak — the auditor consumes the
/// same descriptor (via [`CollectiveOp::desc`]) that execution does, so
/// a protocol violation names exactly the op the program issued.
#[derive(Clone, Debug)]
pub enum CollectiveOp {
    /// Elementwise reduction; every rank receives the full result.
    Allreduce { data: Vec<f32>, red: Reduce, dt: ReduceDtype },
    /// Elementwise sum (optionally mean-scaled); rank r receives its
    /// `parts`-defined slice. `red` must be `Sum` or `Mean`.
    ReduceScatter { data: Vec<f32>, red: Reduce, dt: ReduceDtype, parts: Parts },
    /// Concatenation of every rank's (equal-length or ragged)
    /// contribution, in rank order. `dt: Bf16` rounds once (RNE) onto a
    /// 2-byte wire and decodes exactly on pickup.
    Allgather { data: Vec<f32>, dt: ReduceDtype },
    /// Allgather of raw bf16 storage bits — the mixed-precision
    /// optimizer's param wire; no f32 decode anywhere.
    AllgatherBits { data: Vec<u16> },
    /// `parts[d]` goes to rank d; returns the buffers destined to the
    /// caller, in source order.
    All2All { parts: Vec<Vec<f32>> },
    /// `data` from `root` to everyone; non-root `data` is ignored.
    Broadcast { root: usize, data: Vec<f32> },
    Barrier,
}

impl CollectiveOp {
    /// The audit descriptor for this op — built once per issue, checked
    /// against every peer's deposit by the protocol auditor. `Sum` vs
    /// `Mean` is deliberately not part of the contract (the scale is a
    /// local post-step), matching the wire format, which is identical.
    pub fn desc(&self) -> OpDesc {
        match self {
            CollectiveOp::Allreduce { data, red: Reduce::Max, dt } => OpDesc {
                kind: OpKind::AllreduceMax,
                len: Some(data.len()),
                dtype: (*dt).into(),
            },
            CollectiveOp::Allreduce { data, dt, .. } => OpDesc {
                kind: OpKind::Allreduce,
                len: Some(data.len()),
                dtype: (*dt).into(),
            },
            CollectiveOp::ReduceScatter { data, dt, .. } => OpDesc {
                kind: OpKind::ReduceScatter,
                len: Some(data.len()),
                dtype: (*dt).into(),
            },
            CollectiveOp::Allgather { dt, .. } => {
                // ragged contributions are legal: len is not part of the
                // contract
                OpDesc { kind: OpKind::Allgather, len: None, dtype: (*dt).into() }
            }
            CollectiveOp::AllgatherBits { .. } => {
                OpDesc { kind: OpKind::Allgather, len: None, dtype: WireDtype::Bf16 }
            }
            CollectiveOp::All2All { .. } => {
                OpDesc { kind: OpKind::All2All, len: None, dtype: WireDtype::F32 }
            }
            CollectiveOp::Broadcast { root, .. } => OpDesc {
                kind: OpKind::Broadcast { root: *root },
                len: None,
                dtype: WireDtype::F32,
            },
            CollectiveOp::Barrier => {
                OpDesc { kind: OpKind::Barrier, len: Some(0), dtype: WireDtype::F32 }
            }
        }
    }
}

/// What [`Group::run`] hands back; variant follows the op. The accessors
/// panic on a mismatch — reaching for `.values()` of a barrier is a
/// program bug, not a runtime condition.
#[derive(Debug)]
pub enum CollectiveOut {
    Values(Vec<f32>),
    Bits(Vec<u16>),
    Buckets(Vec<Vec<f32>>),
    Unit,
}

impl CollectiveOut {
    pub fn values(self) -> Vec<f32> {
        match self {
            CollectiveOut::Values(v) => v,
            other => panic!("expected CollectiveOut::Values, got {other:?}"),
        }
    }

    pub fn bits(self) -> Vec<u16> {
        match self {
            CollectiveOut::Bits(v) => v,
            other => panic!("expected CollectiveOut::Bits, got {other:?}"),
        }
    }

    pub fn buckets(self) -> Vec<Vec<f32>> {
        match self {
            CollectiveOut::Buckets(v) => v,
            other => panic!("expected CollectiveOut::Buckets, got {other:?}"),
        }
    }
}

/// What actually travels the simulated fabric: 4-byte f32 words or 2-byte
/// bf16 words. A bf16 collective deposits and publishes `Bf16` frames, so
/// wire-byte accounting (and the perf gate's bytes-moved column) sees the
/// real half-width payload instead of rounded values in f32 buffers.
#[derive(Clone, Debug)]
enum Wire {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
}

impl Wire {
    fn encode(data: Vec<f32>, dt: ReduceDtype) -> Wire {
        match dt {
            ReduceDtype::F32 => Wire::F32(data),
            ReduceDtype::Bf16 => Wire::Bf16(f32s_to_bf16s(&data)),
        }
    }

    fn empty(dtype: WireDtype) -> Wire {
        match dtype {
            WireDtype::F32 => Wire::F32(Vec::new()),
            WireDtype::Bf16 => Wire::Bf16(Vec::new()),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Wire::F32(v) => v.len() * 4,
            Wire::Bf16(v) => v.len() * 2,
        }
    }

    /// Decode to f32 values (exact for bf16 frames).
    fn into_f32(self) -> Vec<f32> {
        match self {
            Wire::F32(v) => v,
            Wire::Bf16(v) => bf16s_to_f32s(&v),
        }
    }
}

/// A round's published result. The publisher (last arrival) decodes a
/// bf16 wire to f32 **once**, under the lock, so the N members picking
/// the result up share one decode instead of each re-decoding the full
/// payload behind the `Arc`.
struct Published {
    wire: Wire,
    /// f32 view of a bf16 `wire`; `None` for f32 wires (the wire *is*
    /// the view) and for ops whose consumers want raw storage bits
    /// (`AllgatherBits`)
    decoded: Option<Vec<f32>>,
}

impl Published {
    fn as_f32(&self) -> &[f32] {
        match (&self.wire, &self.decoded) {
            (Wire::F32(v), _) => v,
            (Wire::Bf16(_), Some(d)) => d,
            (Wire::Bf16(_), None) => {
                unreachable!("bf16 result published without a decode for an f32 consumer")
            }
        }
    }

    /// Owned f32 copy regardless of decode state (the hierarchy's
    /// broadcast phase publishes without a shared decode).
    fn to_f32(&self) -> Vec<f32> {
        match (&self.wire, &self.decoded) {
            (Wire::F32(v), _) => v.clone(),
            (Wire::Bf16(_), Some(d)) => d.clone(),
            (Wire::Bf16(v), None) => bf16s_to_f32s(v),
        }
    }

    /// Owned bf16 storage bits (re-rounds an f32 wire, which only a
    /// mixed-dtype combine could produce).
    fn to_bits(&self) -> Vec<u16> {
        match &self.wire {
            Wire::Bf16(v) => v.clone(),
            Wire::F32(v) => f32s_to_bf16s(v),
        }
    }
}

struct RoundState {
    round: u64,
    arrived: usize,
    departed: usize,
    contribs: Vec<Option<Wire>>,
    /// full result (allreduce/allgather) — members slice their share
    result: Option<Arc<Published>>,
    /// protocol auditor, under the same lock as the deposits it audits
    audit: Audit,
}

/// Byte/operation counters for calibration of the cluster model.
/// `intra_bytes` / `inter_bytes` split the total wire traffic
/// (`bytes_in + bytes_out`, including hierarchy subgroups) by fabric:
/// node-local (Xe-Link-priced) vs node-crossing (Slingshot-priced).
#[derive(Default, Debug, Clone)]
pub struct CommStats {
    pub ops: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub intra_bytes: u64,
    pub inter_bytes: u64,
}

impl CommStats {
    /// Fold another group's counters into this accumulator (the mesh's
    /// traffic sum and the harness's report both aggregate this way).
    pub fn absorb(&mut self, o: &CommStats) {
        self.ops += o.ops;
        self.bytes_in += o.bytes_in;
        self.bytes_out += o.bytes_out;
        self.intra_bytes += o.intra_bytes;
        self.inter_bytes += o.inter_bytes;
    }
}

/// Two-level execution plan for a group whose members span several
/// nodes: one node-local subgroup per contiguous node run, a leaders
/// subgroup linking slot-0 members across nodes, and each member's
/// `(node index, slot within node)` placement.
pub(super) struct Hier {
    intra: Vec<Arc<Group>>,
    leaders: Arc<Group>,
    place: Vec<(usize, usize)>,
}

pub struct Group {
    size: usize,
    /// shown in every violation / stall / dump message ("dp[0]", "world")
    label: String,
    /// every member of this group lives on one node (its traffic is
    /// Xe-Link-priced); hierarchy subgroups set this for their intra
    /// legs, and the mesh sets it for groups fully contained in a node
    intra_node: bool,
    /// three-phase plan when the members span >1 node with ≥2 sharing
    /// one; `None` ⇒ every op runs the flat single-level rendezvous
    hier: Option<Hier>,
    state: Mutex<RoundState>,
    cv: Condvar,
    ops: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    /// bf16 result decodes performed by publishers — exactly one per
    /// decoded round, never one per member (asserted in tests)
    decodes: AtomicU64,
    /// deadlock-watchdog limit for one condvar wait, in milliseconds
    stall_timeout_ms: AtomicU64,
    /// set when a member died or violated the protocol: all waiting and
    /// future members fail instead of blocking forever (a dead node hangs
    /// its peers; the launcher classifies the resulting abort)
    poisoned: AtomicBool,
}

fn default_stall_ms() -> u64 {
    static MS: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
    *MS.get_or_init(|| {
        std::env::var("OPTIMUS_STALL_TIMEOUT_SECS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(|s| (s * 1000).max(1))
            .unwrap_or(180_000)
    })
}

/// Can this op run the three-phase hierarchy? Sum-shaped reductions and
/// gathers decompose exactly (fixed order: members within a node, then
/// nodes); max/all2all/broadcast/barrier stay on the flat path.
fn hier_eligible(op: &CollectiveOp) -> bool {
    matches!(
        op,
        CollectiveOp::Allreduce { red: Reduce::Sum | Reduce::Mean, .. }
            | CollectiveOp::ReduceScatter { .. }
            | CollectiveOp::Allgather { .. }
            | CollectiveOp::AllgatherBits { .. }
    )
}

impl Group {
    pub fn new(size: usize) -> Arc<Group> {
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let id = NEXT.fetch_add(1, Ordering::Relaxed);
        Group::new_labeled(size, &format!("g{id}"))
    }

    /// Group with a stable `label` (the mesh names its groups `dp[i]` /
    /// `ep[i]` / `dpep[i]` / `world`) used in protocol-violation and
    /// stall messages. Flat: no node placement, traffic inter-node-priced.
    pub fn new_labeled(size: usize, label: &str) -> Arc<Group> {
        Group::with_parts(size, label, false, None)
    }

    fn with_parts(
        size: usize,
        label: &str,
        intra_node: bool,
        hier: Option<Hier>,
    ) -> Arc<Group> {
        assert!(size > 0);
        Arc::new(Group {
            size,
            label: label.to_string(),
            intra_node,
            hier,
            state: Mutex::new(RoundState {
                round: 0,
                arrived: 0,
                departed: 0,
                contribs: (0..size).map(|_| None).collect(),
                result: None,
                audit: Audit::new(size),
            }),
            cv: Condvar::new(),
            ops: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            decodes: AtomicU64::new(0),
            stall_timeout_ms: AtomicU64::new(default_stall_ms()),
            poisoned: AtomicBool::new(false),
        })
    }

    /// Group with node placement: `nodes[i]` is the node hosting member
    /// i. When the members span several nodes as contiguous runs and at
    /// least one node holds ≥2 of them, the group gets a two-level
    /// hierarchy (`{label}/node[j]` intra subgroups + `{label}/leaders`)
    /// and the sum/gather collectives run three-phase; otherwise it
    /// degenerates to the flat group, with `intra_node` set when the
    /// whole group shares one node. Non-contiguous placements (a node id
    /// recurring after a different one) also fall back flat — the
    /// hierarchy's concat order must equal member order.
    pub(super) fn new_on_nodes(size: usize, label: &str, nodes: &[usize]) -> Arc<Group> {
        assert_eq!(nodes.len(), size);
        // contiguous runs of equal node ids, in member order
        let mut runs: Vec<(usize, usize)> = Vec::new(); // (start, len)
        let mut contiguous = true;
        for (i, n) in nodes.iter().enumerate() {
            match runs.last_mut() {
                Some((s, l)) if nodes[*s] == *n => *l += 1,
                _ => {
                    if runs.iter().any(|(s, _)| nodes[*s] == *n) {
                        contiguous = false;
                        break;
                    }
                    runs.push((i, 1));
                }
            }
        }
        if !contiguous {
            return Group::with_parts(size, label, false, None);
        }
        if runs.len() == 1 {
            // whole group on one node: flat, Xe-Link-priced
            return Group::with_parts(size, label, true, None);
        }
        if runs.iter().all(|(_, l)| *l == 1) {
            // one member per node (node_size=1 or a fully strided group):
            // the hierarchy would be pure overhead
            return Group::with_parts(size, label, false, None);
        }
        let intra: Vec<Arc<Group>> = runs
            .iter()
            .enumerate()
            .map(|(j, (_, l))| Group::with_parts(*l, &format!("{label}/node[{j}]"), true, None))
            .collect();
        let leaders =
            Group::with_parts(runs.len(), &format!("{label}/leaders"), false, None);
        let mut place = vec![(0, 0); size];
        for (j, (s, l)) in runs.iter().enumerate() {
            for k in 0..*l {
                place[s + k] = (j, k);
            }
        }
        Group::with_parts(size, label, false, Some(Hier { intra, leaders, place }))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// Whether sum/gather collectives on this group run the three-phase
    /// hierarchy (diagnostics; the execution strategy is otherwise
    /// invisible through [`Group::run`]).
    pub fn is_hierarchical(&self) -> bool {
        self.hier.is_some()
    }

    /// Watchdog limit for a single collective wait, forwarded to the
    /// hierarchy subgroups. Waits exceeding it poison the group and fail
    /// with `collective protocol violated [stall]` plus a per-rank
    /// last-op dump. Default: `OPTIMUS_STALL_TIMEOUT_SECS` (env) or 180 s.
    pub fn set_stall_timeout(&self, d: std::time::Duration) {
        self.stall_timeout_ms
            .store((d.as_millis() as u64).max(1), Ordering::Relaxed);
        if let Some(h) = &self.hier {
            for g in &h.intra {
                g.set_stall_timeout(d);
            }
            h.leaders.set_stall_timeout(d);
        }
    }

    /// Mark the group dead (a member rank failed). Wakes all waiters —
    /// including those parked in a hierarchy subgroup — which fail out
    /// of their collectives.
    pub fn poison(&self) {
        {
            let _guard = self.state.lock().unwrap();
            self.poison_locked();
        }
        if let Some(h) = &self.hier {
            for g in &h.intra {
                g.poison();
            }
            h.leaders.poison();
        }
    }

    /// Poison while already holding the state lock (a locked `poison()`
    /// would deadlock on itself). Subgroups are NOT reached from here —
    /// the unlocked [`Group::poison`] handles the fan-out.
    fn poison_locked(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Both-direction traffic counters at actual wire width: `bytes_in`
    /// is what this group's members deposited onto the fabric, `bytes_out`
    /// what they picked up (the published result, per member). Hierarchy
    /// subgroup traffic is folded in, split into `intra_bytes` (node-local
    /// legs) vs `inter_bytes` (node-crossing legs) — the measurable win
    /// the cluster model prices.
    pub fn stats(&self) -> CommStats {
        let bytes_in = self.bytes_in.load(Ordering::Relaxed);
        let bytes_out = self.bytes_out.load(Ordering::Relaxed);
        let own = bytes_in + bytes_out;
        let mut s = CommStats {
            ops: self.ops.load(Ordering::Relaxed),
            bytes_in,
            bytes_out,
            intra_bytes: if self.intra_node { own } else { 0 },
            inter_bytes: if self.intra_node { 0 } else { own },
        };
        if let Some(h) = &self.hier {
            for g in &h.intra {
                s.absorb(&g.stats());
            }
            s.absorb(&h.leaders.stats());
        }
        s
    }

    fn account_in(&self, bytes: usize) {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn account_out(&self, bytes: usize) {
        self.bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    #[cfg(not(loom))]
    fn deadline(&self) -> std::time::Instant {
        std::time::Instant::now()
            + std::time::Duration::from_millis(self.stall_timeout_ms.load(Ordering::Relaxed))
    }

    // loom has no clock; the watchdog is compiled out of the model and
    // the deadline degenerates to a unit value threaded through the waits
    #[cfg(loom)]
    fn deadline(&self) {}

    /// One bounded condvar wait. Returns the re-acquired guard, or the
    /// fault that ends this member's collective: `Poisoned` when a peer
    /// died, `[stall]` when the watchdog deadline expired with the round
    /// still incomplete (which also poisons the group so every peer
    /// unblocks).
    #[cfg(not(loom))]
    fn wait_step<'a>(
        &self,
        st: MutexGuard<'a, RoundState>,
        deadline: std::time::Instant,
        rank: usize,
        desc: &OpDesc,
    ) -> Result<MutexGuard<'a, RoundState>, CommFault> {
        // check *before* waiting: the poison notify fires under the state
        // lock, so a flag set before this member parked would otherwise be
        // a lost wakeup (the watchdog would eventually fire, but the peer
        // death is the root cause, not a stall)
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        let now = std::time::Instant::now();
        if now >= deadline {
            let fault = self.stall_fault(&st, rank, desc);
            self.poison_locked();
            return Err(fault);
        }
        let (g, _timed_out) = self.cv.wait_timeout(st, deadline - now).unwrap();
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        Ok(g)
    }

    #[cfg(loom)]
    fn wait_step<'a>(
        &self,
        st: MutexGuard<'a, RoundState>,
        _deadline: (),
        _rank: usize,
        _desc: &OpDesc,
    ) -> Result<MutexGuard<'a, RoundState>, CommFault> {
        // pre-wait poison check: same lost-wakeup guard as the std build
        // (loom's model checker is what caught the missing check)
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        let g = self.cv.wait(st).unwrap();
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        Ok(g)
    }

    /// The watchdog fired: build the per-rank last-op dump, e.g.
    /// `rank 3 waiting on allreduce round 17 ... rank 0 last seen at
    /// reduce_scatter round 17`.
    #[cfg(not(loom))]
    fn stall_fault(&self, st: &RoundState, rank: usize, desc: &OpDesc) -> CommFault {
        let secs = self.stall_timeout_ms.load(Ordering::Relaxed) as f64 / 1e3;
        CommFault::Violated {
            check: "stall",
            detail: format!(
                "rank {rank} waiting on {desc} round {} on group `{}` made no progress \
                 for {secs:.1}s; per-rank last deposits:\n{}",
                st.round,
                self.label,
                st.audit.table(&self.label)
            ),
        }
    }

    /// Core rendezvous: deposit `mine` under `desc`, the last arrival
    /// runs `combine` over all contributions (and decodes a bf16 result
    /// once when `decode` is set), everyone receives the shared result.
    ///
    /// Rounds are strictly ordered: an early finisher re-entering for
    /// round r+1 parks until round r has fully drained (a departure
    /// requires the result to be set, and the reset only happens after
    /// all `size` departures — so deposits can never leak across rounds).
    ///
    /// Fails fast instead of hanging: the auditor rejects descriptor
    /// mismatches, the watchdog bounds every wait, and a failure from
    /// either poisons the group so all peers unblock.
    fn rendezvous<F>(
        &self,
        rank: usize,
        desc: OpDesc,
        mine: Wire,
        decode: bool,
        combine: F,
    ) -> Result<Arc<Published>, CommFault>
    where
        F: FnOnce(&mut Vec<Option<Wire>>) -> Wire,
    {
        assert!(rank < self.size);
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        self.account_in(mine.bytes());
        let deadline = self.deadline();
        let mut st = self.state.lock().unwrap();
        // Previous round still draining (result published but not all
        // members have departed): wait for the reset.
        while st.result.is_some() {
            st = self.wait_step(st, deadline, rank, &desc)?;
        }
        let my_round = st.round;
        if let Err(fault) = st.audit.check(rank, my_round, desc) {
            // the round can never complete coherently — fail the whole
            // group so compliant peers unblock with `Poisoned` instead of
            // waiting on a deposit that will not come
            self.poison_locked();
            return Err(fault);
        }
        debug_assert!(
            st.contribs[rank].is_none(),
            "rank {rank} deposited twice in one round"
        );
        st.contribs[rank] = Some(mine);
        st.arrived += 1;
        if st.arrived == self.size {
            let wire = combine(&mut st.contribs);
            let decoded = match (&wire, decode) {
                (Wire::Bf16(v), true) => {
                    self.decodes.fetch_add(1, Ordering::Relaxed);
                    Some(bf16s_to_f32s(v))
                }
                _ => None,
            };
            st.result = Some(Arc::new(Published { wire, decoded }));
            self.cv.notify_all();
        } else {
            while !(st.result.is_some() && st.round == my_round) {
                st = self.wait_step(st, deadline, rank, &desc)?;
            }
        }
        let out = Arc::clone(st.result.as_ref().unwrap());
        self.account_out(out.wire.bytes());
        st.departed += 1;
        if st.departed == self.size {
            st.arrived = 0;
            st.departed = 0;
            st.result = None;
            st.round += 1;
            for c in st.contribs.iter_mut() {
                *c = None;
            }
            st.audit.round_drained();
            self.cv.notify_all();
        }
        Ok(out)
    }

    /// Shared sum rendezvous behind allreduce and reduce-scatter —
    /// `desc` is the issuing op's descriptor, so a reduce_scatter meeting
    /// an allreduce is an `[order]` violation, not a silent zip. The sum
    /// runs in f32 after an exact decode, in member order (fixed, for
    /// deterministic results), and the result is re-encoded at wire width.
    fn sum_rendezvous(
        &self,
        rank: usize,
        desc: OpDesc,
        mine: Vec<f32>,
        dt: ReduceDtype,
    ) -> Result<Arc<Published>, CommFault> {
        self.rendezvous(rank, desc, Wire::encode(mine, dt), true, |contribs| {
            let mut acc = contribs[0].take().unwrap().into_f32();
            for c in contribs.iter_mut().skip(1) {
                let c = c.take().unwrap().into_f32();
                for (a, b) in acc.iter_mut().zip(c.iter()) {
                    *a += *b;
                }
            }
            Wire::encode(acc, dt)
        })
    }

    /// Broadcast at an explicit wire dtype — phase 3 of the hierarchy
    /// (and the flat Broadcast op). Non-roots deposit an empty frame, so
    /// only the root's payload crosses the wire.
    fn bcast_wire(
        &self,
        rank: usize,
        root: usize,
        mine: Option<Wire>,
        dtype: WireDtype,
        decode: bool,
    ) -> Result<Arc<Published>, CommFault> {
        let payload = mine.unwrap_or_else(|| Wire::empty(dtype));
        let desc = OpDesc { kind: OpKind::Broadcast { root }, len: None, dtype };
        self.rendezvous(rank, desc, payload, decode, |contribs| {
            contribs[root].take().unwrap()
        })
    }

    /// Execute `op` as this group's member `rank`, blocking until every
    /// member has run the matching call. THE collective entry point:
    /// flat or hierarchical is an implementation detail chosen per group
    /// and per op (see [`hier_eligible`]); results are identical either
    /// way for exactly-representable data, and deterministic always.
    pub fn run(&self, rank: usize, op: CollectiveOp) -> Result<CollectiveOut, CommFault> {
        assert!(rank < self.size, "rank {rank} out of range for group of {}", self.size);
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        if self.hier.is_some() && hier_eligible(&op) {
            return self.run_hier(rank, op);
        }
        self.run_flat(rank, op)
    }

    /// Nonblocking [`Group::run`]: submits onto a [`CommRuntime`] lane
    /// and returns a [`CommHandle`] future; a fault panics on the lane
    /// (the harness's poison-on-panic contract). The caller must
    /// preserve program order: every member issues the same collectives
    /// on a group in the same order, whether via a lane or inline —
    /// lanes are FIFO, so submitting in program order is sufficient.
    pub fn start(
        self: Arc<Self>,
        rt: &CommRuntime,
        rank: usize,
        op: CollectiveOp,
    ) -> CommHandle<CollectiveOut> {
        rt.submit(move || self.run(rank, op).unwrap_or_else(|f| panic!("{f}")))
    }

    /// One world-wide rendezvous (the single-level path).
    fn run_flat(&self, rank: usize, op: CollectiveOp) -> Result<CollectiveOut, CommFault> {
        let desc = op.desc();
        match op {
            CollectiveOp::Allreduce { data, red: Reduce::Max, .. } => {
                let res = self.rendezvous(rank, desc, Wire::F32(data), true, |contribs| {
                    let mut acc = contribs[0].take().unwrap().into_f32();
                    for c in contribs.iter_mut().skip(1) {
                        let c = c.take().unwrap().into_f32();
                        for (a, b) in acc.iter_mut().zip(c.iter()) {
                            *a = a.max(*b);
                        }
                    }
                    Wire::F32(acc)
                })?;
                Ok(CollectiveOut::Values(res.as_f32().to_vec()))
            }
            CollectiveOp::Allreduce { data, red, dt } => {
                let res = self.sum_rendezvous(rank, desc, data, dt)?;
                let mut out = res.as_f32().to_vec();
                if red == Reduce::Mean {
                    let inv = 1.0 / self.size as f32;
                    for v in out.iter_mut() {
                        *v *= inv;
                    }
                }
                Ok(CollectiveOut::Values(out))
            }
            CollectiveOp::ReduceScatter { data, red, dt, parts } => {
                let n = data.len();
                let (s, l) = self.scatter_range(rank, n, parts);
                let summed = self.sum_rendezvous(rank, desc, data, dt)?;
                let mut out = summed.as_f32()[s..s + l].to_vec();
                self.scatter_scale(&mut out, red);
                Ok(CollectiveOut::Values(out))
            }
            CollectiveOp::Allgather { data, dt } => match dt {
                ReduceDtype::F32 => {
                    let res = self.rendezvous(rank, desc, Wire::F32(data), true, |contribs| {
                        let mut out = Vec::new();
                        for c in contribs.iter_mut() {
                            out.extend_from_slice(&c.take().unwrap().into_f32());
                        }
                        Wire::F32(out)
                    })?;
                    Ok(CollectiveOut::Values(res.as_f32().to_vec()))
                }
                ReduceDtype::Bf16 => {
                    // round once (RNE) onto the 2-byte wire, decode once
                    // on publish — half the traffic the byte counters see
                    let bits = f32s_to_bf16s(&data);
                    let res = self.gather_bits_rendezvous(rank, desc, bits, true)?;
                    Ok(CollectiveOut::Values(res.as_f32().to_vec()))
                }
            },
            CollectiveOp::AllgatherBits { data } => {
                // consumers want the raw bits: skip the f32 decode entirely
                let res = self.gather_bits_rendezvous(rank, desc, data, false)?;
                Ok(CollectiveOut::Bits(res.to_bits()))
            }
            CollectiveOp::All2All { parts } => {
                assert_eq!(parts.len(), self.size);
                // flatten with a length header per destination
                let mut flat = Vec::new();
                for d in parts.iter() {
                    flat.push(d.len() as f32);
                }
                for d in parts.iter() {
                    flat.extend_from_slice(d);
                }
                let size = self.size;
                let all = self.rendezvous(rank, desc, Wire::F32(flat), true, |contribs| {
                    // concatenate everyone's flattened frame, with a
                    // per-source offset directory at the front
                    let mut out = Vec::new();
                    let frames: Vec<Vec<f32>> =
                        contribs.iter_mut().map(|c| c.take().unwrap().into_f32()).collect();
                    out.push(frames.len() as f32);
                    let mut off = Vec::new();
                    let mut pos = 1.0 + frames.len() as f32;
                    for f in &frames {
                        off.push(pos);
                        pos += f.len() as f32;
                    }
                    out.extend_from_slice(&off);
                    for f in &frames {
                        out.extend_from_slice(f);
                    }
                    Wire::F32(out)
                })?;
                // decode: for each source frame, pick the chunk destined to us
                let all = all.as_f32();
                let nsrc = all[0] as usize;
                let mut result = Vec::with_capacity(nsrc);
                for s in 0..nsrc {
                    let fstart = all[1 + s] as usize;
                    let sizes: Vec<usize> =
                        (0..size).map(|d| all[fstart + d] as usize).collect();
                    let mut chunk_start = fstart + size;
                    for d in 0..rank {
                        chunk_start += sizes[d];
                    }
                    result.push(all[chunk_start..chunk_start + sizes[rank]].to_vec());
                }
                Ok(CollectiveOut::Buckets(result))
            }
            CollectiveOp::Broadcast { root, data } => {
                // non-root payloads never touch the wire, so the length
                // is not part of the contract — but the *root* is:
                // members disagreeing on the root fail with `[order]`
                let mine = (rank == root).then(|| Wire::F32(data));
                let res = self.bcast_wire(rank, root, mine, WireDtype::F32, false)?;
                Ok(CollectiveOut::Values(res.to_f32()))
            }
            CollectiveOp::Barrier => {
                self.rendezvous(rank, desc, Wire::F32(Vec::new()), true, |_| {
                    Wire::F32(Vec::new())
                })?;
                Ok(CollectiveOut::Unit)
            }
        }
    }

    /// Allgather of bf16 frames under `desc` (values-typed and
    /// bits-typed gathers share this wire path).
    fn gather_bits_rendezvous(
        &self,
        rank: usize,
        desc: OpDesc,
        bits: Vec<u16>,
        decode: bool,
    ) -> Result<Arc<Published>, CommFault> {
        self.rendezvous(rank, desc, Wire::Bf16(bits), decode, |contribs| {
            let mut out = Vec::new();
            for c in contribs.iter_mut() {
                match c.take().unwrap() {
                    Wire::Bf16(v) => out.extend_from_slice(&v),
                    Wire::F32(v) => out.extend(f32s_to_bf16s(&v)),
                }
            }
            Wire::Bf16(out)
        })
    }

    fn scatter_range(&self, rank: usize, n: usize, parts: Parts) -> (usize, usize) {
        match parts {
            Parts::Ragged => crate::util::shard_ranges(n, self.size)[rank],
            Parts::Even => {
                assert_eq!(n % self.size, 0, "even reduce-scatter needs divisible length");
                let per = n / self.size;
                (rank * per, per)
            }
        }
    }

    /// Post-reduce local scale for a scattered shard. `Mean` divides by
    /// the parent size even on the hierarchical path.
    fn scatter_scale(&self, out: &mut [f32], red: Reduce) {
        match red {
            Reduce::Sum => {}
            Reduce::Mean => {
                let inv = 1.0 / self.size as f32;
                for v in out.iter_mut() {
                    *v *= inv;
                }
            }
            Reduce::Max => unreachable!("reduce-scatter does not support Max"),
        }
    }

    /// Three-phase execution: (1) the op's intra-node leg on this
    /// member's `{label}/node[j]` subgroup, (2) the inter-node leg on
    /// `{label}/leaders` (slot-0 members only), (3) an intra-node
    /// broadcast of the full result from slot 0. Any phase fault poisons
    /// the whole family — parent and every subgroup — so members parked
    /// in *other* phases (or other nodes) unblock with `Poisoned`
    /// instead of riding their own watchdogs.
    fn run_hier(&self, rank: usize, op: CollectiveOp) -> Result<CollectiveOut, CommFault> {
        let h = self.hier.as_ref().expect("run_hier without a hierarchy");
        let res = self.run_hier_inner(h, rank, op);
        if res.is_err() {
            self.poison();
        }
        res
    }

    fn run_hier_inner(
        &self,
        h: &Hier,
        rank: usize,
        op: CollectiveOp,
    ) -> Result<CollectiveOut, CommFault> {
        let (node, slot) = h.place[rank];
        match op {
            CollectiveOp::Allreduce { data, red, dt } => {
                let mut out = self.hier_sum(h, node, slot, data, dt)?;
                if red == Reduce::Mean {
                    let inv = 1.0 / self.size as f32;
                    for v in out.iter_mut() {
                        *v *= inv;
                    }
                }
                Ok(CollectiveOut::Values(out))
            }
            CollectiveOp::ReduceScatter { data, red, dt, parts } => {
                let n = data.len();
                let (s, l) = self.scatter_range(rank, n, parts);
                let total = self.hier_sum(h, node, slot, data, dt)?;
                let mut out = total[s..s + l].to_vec();
                self.scatter_scale(&mut out, red);
                Ok(CollectiveOut::Values(out))
            }
            CollectiveOp::Allgather { data, dt } => match dt {
                ReduceDtype::F32 => {
                    let intra = &h.intra[node];
                    let node_cat = intra
                        .run(slot, CollectiveOp::Allgather { data, dt })?
                        .values();
                    let full = if slot == 0 {
                        // lint: rank-uniform leaders is the slot-0 subgroup: every node's slot 0 takes this arm, the rest wait on the bcast below
                        let full = h
                            .leaders
                            .run(node, CollectiveOp::Allgather { data: node_cat, dt })?
                            .values();
                        intra.bcast_wire(slot, 0, Some(Wire::F32(full)), WireDtype::F32, false)?
                    } else {
                        intra.bcast_wire(slot, 0, None, WireDtype::F32, false)?
                    };
                    Ok(CollectiveOut::Values(full.to_f32()))
                }
                ReduceDtype::Bf16 => {
                    let bits = f32s_to_bf16s(&data);
                    let full = self.hier_gather_bits(h, node, slot, bits)?;
                    Ok(CollectiveOut::Values(bf16s_to_f32s(&full)))
                }
            },
            CollectiveOp::AllgatherBits { data } => {
                Ok(CollectiveOut::Bits(self.hier_gather_bits(h, node, slot, data)?))
            }
            _ => unreachable!("op is not hierarchy-eligible"),
        }
    }

    /// Hierarchical elementwise sum of the full vector: intra-node sum
    /// (members in slot order), leaders sum (nodes in node order),
    /// intra-node broadcast back at wire width. The order is fixed, so
    /// repeated runs are bitwise identical; node_size=1 builds no
    /// hierarchy at all, so that case is the flat path verbatim.
    fn hier_sum(
        &self,
        h: &Hier,
        node: usize,
        slot: usize,
        data: Vec<f32>,
        dt: ReduceDtype,
    ) -> Result<Vec<f32>, CommFault> {
        let intra = &h.intra[node];
        let partial = intra
            .run(slot, CollectiveOp::Allreduce { data, red: Reduce::Sum, dt })?
            .values();
        let full = if slot == 0 {
            // lint: rank-uniform leaders is the slot-0 subgroup: every node's slot 0 takes this arm, the rest wait on the bcast below
            let total = h
                .leaders
                .run(node, CollectiveOp::Allreduce { data: partial, red: Reduce::Sum, dt })?
                .values();
            // re-encoding a decoded bf16 total is an exact roundtrip, so
            // the broadcast leg moves the same half-width frames
            intra.bcast_wire(slot, 0, Some(Wire::encode(total, dt)), dt.into(), true)?
        } else {
            intra.bcast_wire(slot, 0, None, dt.into(), true)?
        };
        Ok(full.to_f32())
    }

    /// Hierarchical bf16-bits allgather: node-local concat, leaders
    /// concat (node runs are contiguous in member order, so the result
    /// is the member-order concat), bits broadcast back.
    fn hier_gather_bits(
        &self,
        h: &Hier,
        node: usize,
        slot: usize,
        bits: Vec<u16>,
    ) -> Result<Vec<u16>, CommFault> {
        let intra = &h.intra[node];
        let node_cat = intra.run(slot, CollectiveOp::AllgatherBits { data: bits })?.bits();
        let full = if slot == 0 {
            // lint: rank-uniform leaders is the slot-0 subgroup: every node's slot 0 takes this arm, the rest wait on the bcast below
            let full = h
                .leaders
                .run(node, CollectiveOp::AllgatherBits { data: node_cat })?
                .bits();
            intra.bcast_wire(slot, 0, Some(Wire::Bf16(full)), WireDtype::Bf16, false)?
        } else {
            intra.bcast_wire(slot, 0, None, WireDtype::Bf16, false)?
        };
        Ok(full.to_bits())
    }

    /// Allgather for i32 payloads (routing indices) — transported as f32
    /// bit patterns to reuse the same fabric. A typed convenience over
    /// [`Group::run`], not part of the deprecated sprawl.
    pub fn allgather_i32(&self, rank: usize, mine: &[i32]) -> Vec<i32> {
        let enc: Vec<f32> = mine.iter().map(|v| f32::from_bits(*v as u32)).collect();
        self.run(rank, CollectiveOp::Allgather { data: enc, dt: ReduceDtype::F32 })
            .unwrap_or_else(|f| panic!("{f}"))
            .values()
            .into_iter()
            .map(|v| v.to_bits() as i32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_ranks<F, T>(n: usize, f: F) -> Vec<T>
    where
        F: Fn(usize) -> T + Send + Sync + 'static + Clone,
        T: Send + 'static,
    {
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let f = f.clone();
                std::thread::spawn(move || f(r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn allreduce(g: &Group, r: usize, data: Vec<f32>, dt: ReduceDtype) -> Vec<f32> {
        g.run(r, CollectiveOp::Allreduce { data, red: Reduce::Sum, dt })
            .unwrap()
            .values()
    }

    #[test]
    fn allreduce_sums() {
        let g = Group::new(4);
        let outs =
            spawn_ranks(4, move |r| allreduce(&g, r, vec![r as f32, 1.0], ReduceDtype::F32));
        for o in outs {
            assert_eq!(o, vec![6.0, 4.0]);
        }
    }

    #[test]
    fn reduce_scatter_then_allgather_is_mean() {
        let g = Group::new(3);
        let n = 10; // not divisible by 3: ragged shards
        let outs = spawn_ranks(3, move |r| {
            let mine: Vec<f32> = (0..n).map(|i| (i + r) as f32).collect();
            let shard = g
                .run(
                    r,
                    CollectiveOp::ReduceScatter {
                        data: mine,
                        red: Reduce::Mean,
                        dt: ReduceDtype::F32,
                        parts: Parts::Ragged,
                    },
                )
                .unwrap()
                .values();
            let out = g
                .run(r, CollectiveOp::Allgather { data: shard, dt: ReduceDtype::F32 })
                .unwrap()
                .values();
            assert_eq!(out.len(), n);
            out
        });
        let want: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        for o in outs {
            assert_eq!(o, want);
        }
    }

    #[test]
    fn allgather_concats_in_rank_order() {
        let g = Group::new(3);
        let outs = spawn_ranks(3, move |r| {
            g.run(
                r,
                CollectiveOp::Allgather { data: vec![r as f32; r + 1], dt: ReduceDtype::F32 },
            )
            .unwrap()
            .values()
        });
        for o in outs {
            assert_eq!(o, vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn all2all_routes_chunks() {
        let g = Group::new(2);
        let outs = spawn_ranks(2, move |r| {
            // rank r sends [r*10+d] to rank d
            let parts: Vec<Vec<f32>> = (0..2).map(|d| vec![(r * 10 + d) as f32]).collect();
            g.run(r, CollectiveOp::All2All { parts }).unwrap().buckets()
        });
        assert_eq!(outs[0], vec![vec![0.0], vec![10.0]]);
        assert_eq!(outs[1], vec![vec![1.0], vec![11.0]]);
    }

    #[test]
    fn broadcast_from_root() {
        let g = Group::new(4);
        let outs = spawn_ranks(4, move |r| {
            let mine = if r == 2 { vec![9.0, 8.0] } else { vec![] };
            g.run(r, CollectiveOp::Broadcast { root: 2, data: mine }).unwrap().values()
        });
        for o in outs {
            assert_eq!(o, vec![9.0, 8.0]);
        }
    }

    #[test]
    fn repeated_rounds_stay_ordered() {
        let g = Group::new(3);
        let outs = spawn_ranks(3, move |r| {
            let mut acc = Vec::new();
            for round in 0..50 {
                let o = allreduce(&g, r, vec![round as f32], ReduceDtype::F32);
                acc.push(o[0]);
            }
            acc
        });
        for o in outs {
            for (round, v) in o.iter().enumerate() {
                assert_eq!(*v, 3.0 * round as f32);
            }
        }
    }

    #[test]
    fn bf16_reduction_rounds() {
        let g = Group::new(2);
        let outs =
            spawn_ranks(2, move |r| allreduce(&g, r, vec![1.0009765625f32], ReduceDtype::Bf16));
        for o in outs {
            // bf16(1.0009765625) = 1.0 -> sum 2.0
            assert_eq!(o, vec![2.0]);
        }
    }

    #[test]
    fn traffic_accounting_tracks_wire_width_both_directions() {
        // f32: 8 elems × 4 B deposited and picked up per rank
        let g = Group::new(2);
        let gs = Arc::clone(&g);
        spawn_ranks(2, move |r| allreduce(&g, r, vec![1.0f32; 8], ReduceDtype::F32));
        let st = gs.stats();
        assert_eq!(st.ops, 2);
        assert_eq!(st.bytes_in, 2 * 8 * 4);
        assert_eq!(st.bytes_out, 2 * 8 * 4);
        // a flat group is inter-node-priced end to end
        assert_eq!(st.inter_bytes, st.bytes_in + st.bytes_out);
        assert_eq!(st.intra_bytes, 0);
        // bf16: the same collective moves exactly half the bytes each way
        let g = Group::new(2);
        let gs = Arc::clone(&g);
        spawn_ranks(2, move |r| allreduce(&g, r, vec![1.0f32; 8], ReduceDtype::Bf16));
        let st = gs.stats();
        assert_eq!(st.bytes_in, 2 * 8 * 2);
        assert_eq!(st.bytes_out, 2 * 8 * 2);
    }

    #[test]
    fn bf16_allgather_concats_storage_bits() {
        use crate::util::{bf16_to_f32, f32_to_bf16};
        let g = Group::new(2);
        let gs = Arc::clone(&g);
        let outs = spawn_ranks(2, move |r| {
            let mine = vec![f32_to_bf16(r as f32 + 0.5); 2];
            g.run(r, CollectiveOp::AllgatherBits { data: mine }).unwrap().bits()
        });
        for o in outs {
            let vals: Vec<f32> = o.iter().map(|&b| bf16_to_f32(b)).collect();
            assert_eq!(vals, vec![0.5, 0.5, 1.5, 1.5]);
        }
        // 4 elems × 2 B out per rank
        assert_eq!(gs.stats().bytes_out, 2 * 4 * 2);
    }

    #[test]
    fn async_collectives_match_blocking_results() {
        // each rank drives its own lane; two in-flight collectives per
        // rank, submitted in the same program order everywhere
        let g = Group::new(3);
        let outs = spawn_ranks(3, move |r| {
            let rt = CommRuntime::new(&format!("t{r}"));
            let h1 = g.clone().start(
                &rt,
                r,
                CollectiveOp::Allreduce {
                    data: vec![r as f32, 1.0],
                    red: Reduce::Sum,
                    dt: ReduceDtype::F32,
                },
            );
            let h2 = g.clone().start(
                &rt,
                r,
                CollectiveOp::Allgather { data: vec![r as f32], dt: ReduceDtype::F32 },
            );
            (h1.wait().values(), h2.wait().values())
        });
        for (ar, ag) in outs {
            assert_eq!(ar, vec![3.0, 3.0]);
            assert_eq!(ag, vec![0.0, 1.0, 2.0]);
        }
    }

    #[test]
    fn async_reduce_scatter_matches_blocking() {
        let g = Group::new(2);
        let n = 7; // ragged shards
        let outs = spawn_ranks(2, move |r| {
            let op = |data: Vec<f32>| CollectiveOp::ReduceScatter {
                data,
                red: Reduce::Mean,
                dt: ReduceDtype::F32,
                parts: Parts::Ragged,
            };
            let mine: Vec<f32> = (0..n).map(|i| (i + r) as f32).collect();
            let blocking = g.run(r, op(mine.clone())).unwrap().values();
            let rt = CommRuntime::new(&format!("rs{r}"));
            let async_ = g.clone().start(&rt, r, op(mine)).wait().values();
            (blocking, async_)
        });
        for (b, a) in outs {
            assert_eq!(b, a);
        }
    }

    #[test]
    fn i32_allgather_roundtrips() {
        let g = Group::new(2);
        let outs =
            spawn_ranks(2, move |r| g.allgather_i32(r, &[r as i32 * 100 - 5, i32::MAX]));
        for o in outs {
            assert_eq!(o, vec![-5, i32::MAX, 95, i32::MAX]);
        }
    }

    // -- protocol auditor + watchdog ------------------------------------

    #[test]
    fn mismatched_program_order_fails_fast_with_order_violation() {
        // rank 0 issues allreduce, rank 1 issues allgather on the same
        // group and round: whoever arrives second violates; the other
        // member unblocks via poisoning — nobody hangs
        let g = Group::new_labeled(2, "t-order");
        let errs = spawn_ranks(2, move |r| {
            if r == 0 {
                g.run(
                    0,
                    CollectiveOp::Allreduce {
                        data: vec![1.0, 2.0],
                        red: Reduce::Sum,
                        dt: ReduceDtype::F32,
                    },
                )
                .unwrap_err()
            } else {
                g.run(1, CollectiveOp::Allgather { data: vec![3.0], dt: ReduceDtype::F32 })
                    .unwrap_err()
            }
        });
        let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("collective protocol violated [order]")),
            "{msgs:?}"
        );
        for m in &msgs {
            assert!(
                m.contains("collective protocol violated [order]")
                    || m.contains("comm group poisoned"),
                "{m}"
            );
        }
        // the violation names both ops and the group label
        let v = msgs.iter().find(|m| m.contains("[order]")).unwrap();
        assert!(v.contains("allreduce") && v.contains("allgather"), "{v}");
    }

    #[test]
    fn mismatched_payload_length_is_a_shape_violation() {
        // an allreduce zip would silently truncate to the shorter vector —
        // the auditor rejects the round instead
        let g = Group::new_labeled(2, "t-shape");
        let errs = spawn_ranks(2, move |r| {
            let mine = vec![1.0f32; if r == 0 { 8 } else { 9 }];
            g.run(
                r,
                CollectiveOp::Allreduce { data: mine, red: Reduce::Sum, dt: ReduceDtype::F32 },
            )
            .unwrap_err()
        });
        let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("collective protocol violated [shape]")),
            "{msgs:?}"
        );
    }

    #[test]
    fn mismatched_wire_dtype_is_a_dtype_violation() {
        let g = Group::new_labeled(2, "t-dtype");
        let errs = spawn_ranks(2, move |r| {
            let dt = if r == 0 { ReduceDtype::F32 } else { ReduceDtype::Bf16 };
            g.run(r, CollectiveOp::Allreduce { data: vec![1.0, 2.0], red: Reduce::Sum, dt })
                .unwrap_err()
        });
        let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("collective protocol violated [dtype]")),
            "{msgs:?}"
        );
    }

    #[test]
    fn watchdog_stall_dumps_per_rank_last_ops() {
        // rank 1 never shows up: rank 0's wait must end in a [stall]
        // failure carrying the per-rank table, not hang forever
        let g = Group::new_labeled(2, "t-stall");
        g.set_stall_timeout(std::time::Duration::from_millis(50));
        let e = g
            .run(
                0,
                CollectiveOp::Allreduce {
                    data: vec![1.0],
                    red: Reduce::Sum,
                    dt: ReduceDtype::F32,
                },
            )
            .unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("collective protocol violated [stall]"), "{msg}");
        assert!(msg.contains("rank 0 waiting on allreduce"), "{msg}");
        assert!(msg.contains("rank 1 never deposited"), "{msg}");
        assert!(msg.contains("t-stall"), "{msg}");
        // the stall poisoned the group: a late peer fails immediately
        // instead of waiting on a round that already died
        let late = g
            .run(
                1,
                CollectiveOp::Allreduce {
                    data: vec![1.0],
                    red: Reduce::Sum,
                    dt: ReduceDtype::F32,
                },
            )
            .unwrap_err();
        assert!(late.to_string().contains("comm group poisoned"), "{late}");
    }

    #[test]
    fn bf16_result_is_decoded_once_per_round_not_per_member() {
        let g = Group::new(3);
        let gs = Arc::clone(&g);
        let outs =
            spawn_ranks(3, move |r| allreduce(&g, r, vec![r as f32, 1.0], ReduceDtype::Bf16));
        for o in outs {
            assert_eq!(o, vec![3.0, 3.0]);
        }
        // 3 members picked the result up, but the publisher decoded once
        assert_eq!(gs.decodes.load(Ordering::Relaxed), 1);
        // raw-bits allgather skips the decode entirely
        let g = Group::new(2);
        let gs = Arc::clone(&g);
        spawn_ranks(2, move |r| {
            g.run(r, CollectiveOp::AllgatherBits { data: vec![0x3f80; 2] }).unwrap().bits()
        });
        assert_eq!(gs.decodes.load(Ordering::Relaxed), 0);
    }

    // -- hierarchical execution -----------------------------------------

    /// 4 members on 2 nodes of 2: the smallest real hierarchy.
    fn hier4() -> Arc<Group> {
        let g = Group::new_on_nodes(4, "h4", &[0, 0, 1, 1]);
        assert!(g.is_hierarchical());
        g
    }

    #[test]
    fn hierarchical_allreduce_matches_flat() {
        for dt in [ReduceDtype::F32, ReduceDtype::Bf16] {
            let flat = Group::new(4);
            let hier = hier4();
            let f = Arc::clone(&flat);
            let h = Arc::clone(&hier);
            // small integers: exact in f32 and bf16, so flat and
            // hierarchical sums agree bitwise despite reassociation
            let outs = spawn_ranks(4, move |r| {
                let mine: Vec<f32> = (0..6).map(|i| (r * 7 + i) as f32).collect();
                (allreduce(&f, r, mine.clone(), dt), allreduce(&h, r, mine, dt))
            });
            for (flat_out, hier_out) in outs {
                assert_eq!(flat_out, hier_out, "{dt:?}");
            }
        }
    }

    #[test]
    fn hierarchical_reduce_scatter_and_allgather_match_flat() {
        for dt in [ReduceDtype::F32, ReduceDtype::Bf16] {
            let flat = Group::new(4);
            let hier = hier4();
            let f = Arc::clone(&flat);
            let h = Arc::clone(&hier);
            let n = 10; // ragged
            let outs = spawn_ranks(4, move |r| {
                let mine: Vec<f32> = (0..n).map(|i| ((i + r) % 16) as f32).collect();
                let rs = |g: &Group| {
                    g.run(
                        r,
                        CollectiveOp::ReduceScatter {
                            data: mine.clone(),
                            red: Reduce::Mean,
                            dt,
                            parts: Parts::Ragged,
                        },
                    )
                    .unwrap()
                    .values()
                };
                let shard_f = rs(&f);
                let shard_h = rs(&h);
                assert_eq!(shard_f, shard_h, "{dt:?}");
                let ag = |g: &Group| {
                    g.run(r, CollectiveOp::Allgather { data: shard_f.clone(), dt })
                        .unwrap()
                        .values()
                };
                (ag(&f), ag(&h))
            });
            for (flat_out, hier_out) in outs {
                assert_eq!(flat_out, hier_out, "{dt:?}");
            }
        }
    }

    #[test]
    fn hierarchical_bits_allgather_matches_flat() {
        let flat = Group::new(4);
        let hier = hier4();
        let f = Arc::clone(&flat);
        let h = Arc::clone(&hier);
        let outs = spawn_ranks(4, move |r| {
            let mine = vec![0x3f80u16 + r as u16; 3];
            let bits = |g: &Group| {
                g.run(r, CollectiveOp::AllgatherBits { data: mine.clone() }).unwrap().bits()
            };
            (bits(&f), bits(&h))
        });
        for (flat_out, hier_out) in outs {
            assert_eq!(flat_out, hier_out);
        }
    }

    #[test]
    fn hierarchical_runs_are_deterministic() {
        // non-representable data: the reassociated sum may differ from
        // flat, but two hierarchical runs must agree bitwise (fixed
        // member-then-node reduction order)
        let hier = hier4();
        let outs = spawn_ranks(4, move |r| {
            let mine: Vec<f32> = (0..8).map(|i| 0.1f32 * (r * 8 + i) as f32).collect();
            let a = allreduce(&hier, r, mine.clone(), ReduceDtype::F32);
            let b = allreduce(&hier, r, mine, ReduceDtype::F32);
            (a, b)
        });
        for (a, b) in outs {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn hierarchical_traffic_splits_intra_from_inter() {
        let flat = Group::new(4);
        let hier = hier4();
        let f = Arc::clone(&flat);
        let h = Arc::clone(&hier);
        spawn_ranks(4, move |r| {
            let mine = vec![1.0f32; 8];
            allreduce(&f, r, mine.clone(), ReduceDtype::F32);
            allreduce(&h, r, mine, ReduceDtype::F32);
        });
        let fs = flat.stats();
        let hs = hier.stats();
        // flat: every byte is inter-node-priced
        assert_eq!(fs.intra_bytes, 0);
        assert_eq!(fs.inter_bytes, fs.bytes_in + fs.bytes_out);
        // hierarchical: only the 2-leader exchange crosses nodes — with
        // 2 nodes of 2 that is at most half the flat inter traffic
        assert!(hs.intra_bytes > 0, "{hs:?}");
        assert!(hs.inter_bytes > 0, "{hs:?}");
        assert!(
            hs.inter_bytes * 2 <= fs.inter_bytes,
            "hier moved {} inter bytes, flat {}",
            hs.inter_bytes,
            fs.inter_bytes
        );
    }

    #[test]
    fn single_node_and_strided_placements_stay_flat() {
        // whole group on one node: flat execution, Xe-Link-priced
        let g = Group::new_on_nodes(2, "one-node", &[3, 3]);
        assert!(!g.is_hierarchical());
        let gs = Arc::clone(&g);
        spawn_ranks(2, move |r| allreduce(&g, r, vec![1.0f32; 4], ReduceDtype::F32));
        let st = gs.stats();
        assert_eq!(st.intra_bytes, st.bytes_in + st.bytes_out);
        assert_eq!(st.inter_bytes, 0);
        // one member per node (node_size=1): flat and inter-priced
        let g = Group::new_on_nodes(2, "spread", &[0, 1]);
        assert!(!g.is_hierarchical());
        assert_eq!(g.stats().intra_bytes, 0);
        // a node id recurring non-contiguously cannot keep member order
        // through the hierarchy: falls back flat
        let g = Group::new_on_nodes(3, "striped", &[0, 1, 0]);
        assert!(!g.is_hierarchical());
    }

    #[test]
    fn hierarchical_stall_poisons_the_whole_family() {
        // rank 1 (node 0, slot 1) never shows up: its intra subgroup
        // stalls, and the resulting fault must poison the parent and the
        // other node's subgroup so every member unblocks
        let g = Group::new_on_nodes(4, "h-dead", &[0, 0, 1, 1]);
        g.set_stall_timeout(std::time::Duration::from_millis(100));
        let errs = spawn_ranks(3, move |i| {
            let r = [0, 2, 3][i]; // rank 1 is dead
            g.run(
                r,
                CollectiveOp::Allreduce {
                    data: vec![1.0],
                    red: Reduce::Sum,
                    dt: ReduceDtype::F32,
                },
            )
            .unwrap_err()
        });
        let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("collective protocol violated [stall]")),
            "{msgs:?}"
        );
        for m in &msgs {
            assert!(
                m.contains("[stall]") || m.contains("comm group poisoned"),
                "{m}"
            );
        }
        // the stall names the subgroup that starved (the dead rank's
        // node leg, or the leaders leg waiting on its leader) — either
        // way attributable to this group's hierarchy at a glance
        let v = msgs.iter().find(|m| m.contains("[stall]")).unwrap();
        assert!(v.contains("h-dead/"), "{v}");
    }

    #[test]
    fn poisoning_the_parent_reaches_the_subgroups() {
        let g = Group::new_on_nodes(4, "h-poison", &[0, 0, 1, 1]);
        g.poison();
        // a member entering any phase fails immediately instead of
        // waiting on peers that will never come
        let e = g
            .run(
                0,
                CollectiveOp::Allreduce {
                    data: vec![1.0],
                    red: Reduce::Sum,
                    dt: ReduceDtype::F32,
                },
            )
            .unwrap_err();
        assert!(matches!(e, CommFault::Poisoned), "{e}");
    }
}
