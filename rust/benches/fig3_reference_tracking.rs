//! Figure 3: our model's benchmark progression tracks an independently
//! trained reference of the same architecture (the paper compares
//! Mula-7B-A1B against Allen AI's OLMoE-1B-7B-0924 checkpoints; here the
//! "reference" is a second run with an independent seed — the claim being
//! reproduced is *tracking*, i.e. same-architecture runs on the same data
//! follow the same score trajectory).

use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec, StepHook};
use optimus::data::{corpus, preprocess};
use optimus::eval;
use optimus::runtime::Engine;
use optimus::util::bench::Report;
use std::sync::{Arc, Mutex};

struct SnapHook {
    every: usize,
    snaps: Mutex<Vec<(usize, Vec<f32>)>>,
}
impl StepHook for SnapHook {
    fn on_step(&self, r: usize, s: usize, _l: f32, p: &mut [f32]) -> optimus::Result<()> {
        if r == 0 && s % self.every == 0 {
            self.snaps.lock().unwrap().push((s, p.to_vec()));
        }
        Ok(())
    }
}

fn main() -> optimus::Result<()> {
    let m = Manifest::load(&optimus::artifacts_dir())?;
    let data_dir = std::env::temp_dir().join("optimus-fig3-data");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 6, 48), 64, 7, &data_dir, 2048)?;
    }
    let engine = Engine::new_pool(2)?;
    let mm = m.config("mula-tiny")?;

    let mut traj = Vec::new();
    for seed in [1234u64, 777] {
        let snaps = Arc::new(SnapHook { every: 8, snaps: Mutex::new(Vec::new()) });
        let spec = JobSpec::new("mula-tiny")
            .data_dir(data_dir.clone())
            .topology(2, 1, 1)
            .steps(24)
            .warmup_steps(5)
            .peak_lr(3e-3)
            .seed(seed)
            .hook(snaps.clone())
            .build()?;
        coordinator::train(&m, &spec)?;
        let mut pts = Vec::new();
        for (s, params) in snaps.snaps.lock().unwrap().iter() {
            let pt = optimus::runtime::Tensor::f32(params.clone(), vec![mm.param_count]);
            let scores = eval::run_suite(&engine, mm, &pt, 8)?;
            pts.push((*s, eval::average(&scores)));
        }
        traj.push(pts);
    }
    let mut t = Report::new(
        "Fig 3: ours vs independently-seeded reference run (same arch+data)",
        &["step", "ours", "reference", "|gap|"],
    );
    let mut max_gap = 0.0f64;
    for (a, b) in traj[0].iter().zip(traj[1].iter()) {
        let gap = (a.1 - b.1).abs();
        max_gap = max_gap.max(gap);
        t.row(&[
            a.0.to_string(),
            format!("{:.1}", a.1),
            format!("{:.1}", b.1),
            format!("{:.1}", gap),
        ]);
    }
    t.print();
    t.write_csv("fig3_reference_tracking").ok();
    println!("max score gap {max_gap:.1} — tracking = small gap throughout");
    Ok(())
}
