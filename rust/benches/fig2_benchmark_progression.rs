//! Figure 2: benchmark-score progression during training, dense vs MoE
//! (the paper's lm-eval progression, substituted by the synthetic suite).
//! Shape to match: scores improve with tokens; MoE >= dense late in
//! training at iso-compute.

use optimus::config::Manifest;
use optimus::coordinator::{self, JobSpec, StepHook};
use optimus::data::{corpus, preprocess};
use optimus::eval;
use optimus::runtime::Engine;
use optimus::util::bench::Report;
use std::sync::{Arc, Mutex};

/// Hook that snapshots parameters every `every` steps (rank 0).
struct SnapHook {
    every: usize,
    snaps: Mutex<Vec<(usize, Vec<f32>)>>,
}
impl StepHook for SnapHook {
    fn on_step(&self, r: usize, s: usize, _l: f32, p: &mut [f32]) -> optimus::Result<()> {
        if r == 0 && (s % self.every == 0 || s == 0) {
            self.snaps.lock().unwrap().push((s, p.to_vec()));
        }
        Ok(())
    }
}

fn main() -> optimus::Result<()> {
    let m = Manifest::load(&optimus::artifacts_dir())?;
    let data_dir = std::env::temp_dir().join("optimus-fig2-data");
    if !data_dir.exists() {
        preprocess::preprocess(&corpus::data_files(42, 6, 48), 64, 7, &data_dir, 2048)?;
    }
    let engine = Engine::new_pool(2)?;
    let steps = 24;
    let every = 8;

    let mut table = Report::new(
        "Fig 2: synthetic-suite average during training (dense vs MoE)",
        &["step", "mula-tiny-dense", "mula-tiny (MoE)"],
    );
    let mut curves = Vec::new();
    for model in ["mula-tiny-dense", "mula-tiny"] {
        let snaps = Arc::new(SnapHook { every, snaps: Mutex::new(Vec::new()) });
        let spec = JobSpec::new(model)
            .data_dir(data_dir.clone())
            .topology(2, 1, 1)
            .steps(steps)
            .warmup_steps(5)
            .peak_lr(3e-3)
            .hook(snaps.clone())
            .build()?;
        coordinator::train(&m, &spec)?;
        let mm = m.config(model)?;
        let mut pts = Vec::new();
        for (s, params) in snaps.snaps.lock().unwrap().iter() {
            let pt = optimus::runtime::Tensor::f32(params.clone(), vec![mm.param_count]);
            let scores = eval::run_suite(&engine, mm, &pt, 8)?;
            pts.push((*s, eval::average(&scores)));
        }
        curves.push(pts);
    }
    for i in 0..curves[0].len().min(curves[1].len()) {
        table.row(&[
            curves[0][i].0.to_string(),
            format!("{:.1}", curves[0][i].1),
            format!("{:.1}", curves[1][i].1),
        ]);
    }
    table.print();
    table.write_csv("fig2_progression").ok();
    Ok(())
}
