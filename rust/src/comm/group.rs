//! A process group: rendezvous collectives among `size` participants.
//!
//! Each collective is a two-phase rendezvous guarded by a mutex+condvar:
//! all members deposit their contribution; the last arrival computes the
//! result; everyone picks up their share; the last departure resets the
//! slot for the next round. Rounds are strictly ordered per group, which
//! matches the deterministic program order of collectives in SPMD
//! training.
//!
//! Two guards make protocol misuse fail fast instead of hanging or
//! silently corrupting (DESIGN.md §12):
//!
//! * every deposit carries an [`OpDesc`] checked by the round's
//!   [`Audit`](super::audit) — the first arrival pins the round, any
//!   mismatching member fails the group with a stable
//!   `collective protocol violated [order|shape|dtype]` error;
//! * a **deadlock watchdog**: condvar waits are bounded by a configurable
//!   stall timeout ([`Group::set_stall_timeout`], default
//!   `OPTIMUS_STALL_TIMEOUT_SECS` or 180 s); on expiry the waiter dumps
//!   the per-rank last-op table and fails with
//!   `collective protocol violated [stall]`.
//!
//! The sync primitives come from [`super::lsync`], so `--cfg loom` builds
//! model-check the whole rendezvous state machine (`tests/loom_models.rs`).

use super::audit::{Audit, CommFault, OpDesc, OpKind, WireDtype};
use super::lsync::{AtomicBool, Condvar, Mutex, MutexGuard};
use super::runtime::{CommHandle, CommRuntime};
use crate::util::{bf16s_to_f32s, f32s_to_bf16s};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Gradient-reduction dtype (paper §2.1 trains with bfloat16 gradient
/// reduction; f32 is the ablation baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceDtype {
    F32,
    Bf16,
}

impl From<ReduceDtype> for WireDtype {
    fn from(dt: ReduceDtype) -> WireDtype {
        match dt {
            ReduceDtype::F32 => WireDtype::F32,
            ReduceDtype::Bf16 => WireDtype::Bf16,
        }
    }
}

/// What actually travels the simulated fabric: 4-byte f32 words or 2-byte
/// bf16 words. A bf16 collective deposits and publishes `Bf16` frames, so
/// wire-byte accounting (and the perf gate's bytes-moved column) sees the
/// real half-width payload instead of rounded values in f32 buffers.
#[derive(Clone, Debug)]
enum Wire {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
}

impl Wire {
    fn encode(data: Vec<f32>, dt: ReduceDtype) -> Wire {
        match dt {
            ReduceDtype::F32 => Wire::F32(data),
            ReduceDtype::Bf16 => Wire::Bf16(f32s_to_bf16s(&data)),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Wire::F32(v) => v.len() * 4,
            Wire::Bf16(v) => v.len() * 2,
        }
    }

    /// Decode to f32 values (exact for bf16 frames).
    fn into_f32(self) -> Vec<f32> {
        match self {
            Wire::F32(v) => v,
            Wire::Bf16(v) => bf16s_to_f32s(&v),
        }
    }
}

/// A round's published result. The publisher (last arrival) decodes a
/// bf16 wire to f32 **once**, under the lock, so the N members picking
/// the result up share one decode instead of each re-decoding the full
/// payload behind the `Arc`.
struct Published {
    wire: Wire,
    /// f32 view of a bf16 `wire`; `None` for f32 wires (the wire *is*
    /// the view) and for ops whose consumers want raw storage bits
    /// (`allgather_bf16`)
    decoded: Option<Vec<f32>>,
}

impl Published {
    fn as_f32(&self) -> &[f32] {
        match (&self.wire, &self.decoded) {
            (Wire::F32(v), _) => v,
            (Wire::Bf16(_), Some(d)) => d,
            (Wire::Bf16(_), None) => {
                unreachable!("bf16 result published without a decode for an f32 consumer")
            }
        }
    }
}

struct RoundState {
    round: u64,
    arrived: usize,
    departed: usize,
    contribs: Vec<Option<Wire>>,
    /// full result (allreduce/allgather) — members slice their share
    result: Option<Arc<Published>>,
    /// protocol auditor, under the same lock as the deposits it audits
    audit: Audit,
}

/// Byte/operation counters for calibration of the cluster model.
#[derive(Default, Debug, Clone)]
pub struct CommStats {
    pub ops: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

pub struct Group {
    size: usize,
    /// shown in every violation / stall / dump message ("dp[0]", "world")
    label: String,
    state: Mutex<RoundState>,
    cv: Condvar,
    ops: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    /// bf16 result decodes performed by publishers — exactly one per
    /// decoded round, never one per member (asserted in tests)
    decodes: AtomicU64,
    /// deadlock-watchdog limit for one condvar wait, in milliseconds
    stall_timeout_ms: AtomicU64,
    /// set when a member died or violated the protocol: all waiting and
    /// future members fail instead of blocking forever (a dead node hangs
    /// its peers; the launcher classifies the resulting abort)
    poisoned: AtomicBool,
}

fn default_stall_ms() -> u64 {
    static MS: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
    *MS.get_or_init(|| {
        std::env::var("OPTIMUS_STALL_TIMEOUT_SECS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(|s| (s * 1000).max(1))
            .unwrap_or(180_000)
    })
}

impl Group {
    pub fn new(size: usize) -> Arc<Group> {
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let id = NEXT.fetch_add(1, Ordering::Relaxed);
        Group::new_labeled(size, &format!("g{id}"))
    }

    /// Group with a stable `label` (the mesh names its groups `dp[i]` /
    /// `ep[i]` / `dpep[i]` / `world`) used in protocol-violation and
    /// stall messages.
    pub fn new_labeled(size: usize, label: &str) -> Arc<Group> {
        assert!(size > 0);
        Arc::new(Group {
            size,
            label: label.to_string(),
            state: Mutex::new(RoundState {
                round: 0,
                arrived: 0,
                departed: 0,
                contribs: (0..size).map(|_| None).collect(),
                result: None,
                audit: Audit::new(size),
            }),
            cv: Condvar::new(),
            ops: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            decodes: AtomicU64::new(0),
            stall_timeout_ms: AtomicU64::new(default_stall_ms()),
            poisoned: AtomicBool::new(false),
        })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// Watchdog limit for a single collective wait. Waits exceeding it
    /// poison the group and fail with
    /// `collective protocol violated [stall]` plus a per-rank last-op
    /// dump. Default: `OPTIMUS_STALL_TIMEOUT_SECS` (env) or 180 s.
    pub fn set_stall_timeout(&self, d: std::time::Duration) {
        self.stall_timeout_ms
            .store((d.as_millis() as u64).max(1), Ordering::Relaxed);
    }

    /// Mark the group dead (a member rank failed). Wakes all waiters,
    /// which fail out of their collectives.
    pub fn poison(&self) {
        let _guard = self.state.lock().unwrap();
        self.poison_locked();
    }

    /// Poison while already holding the state lock (a locked `poison()`
    /// would deadlock on itself).
    fn poison_locked(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Both-direction traffic counters at actual wire width: `bytes_in`
    /// is what this group's members deposited onto the fabric, `bytes_out`
    /// what they picked up (the published result, per member).
    pub fn stats(&self) -> CommStats {
        CommStats {
            ops: self.ops.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }

    fn account_in(&self, bytes: usize) {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    fn account_out(&self, bytes: usize) {
        self.bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    #[cfg(not(loom))]
    fn deadline(&self) -> std::time::Instant {
        std::time::Instant::now()
            + std::time::Duration::from_millis(self.stall_timeout_ms.load(Ordering::Relaxed))
    }

    // loom has no clock; the watchdog is compiled out of the model and
    // the deadline degenerates to a unit value threaded through the waits
    #[cfg(loom)]
    fn deadline(&self) {}

    /// One bounded condvar wait. Returns the re-acquired guard, or the
    /// fault that ends this member's collective: `Poisoned` when a peer
    /// died, `[stall]` when the watchdog deadline expired with the round
    /// still incomplete (which also poisons the group so every peer
    /// unblocks).
    #[cfg(not(loom))]
    fn wait_step<'a>(
        &self,
        st: MutexGuard<'a, RoundState>,
        deadline: std::time::Instant,
        rank: usize,
        desc: &OpDesc,
    ) -> Result<MutexGuard<'a, RoundState>, CommFault> {
        // check *before* waiting: the poison notify fires under the state
        // lock, so a flag set before this member parked would otherwise be
        // a lost wakeup (the watchdog would eventually fire, but the peer
        // death is the root cause, not a stall)
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        let now = std::time::Instant::now();
        if now >= deadline {
            let fault = self.stall_fault(&st, rank, desc);
            self.poison_locked();
            return Err(fault);
        }
        let (g, _timed_out) = self.cv.wait_timeout(st, deadline - now).unwrap();
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        Ok(g)
    }

    #[cfg(loom)]
    fn wait_step<'a>(
        &self,
        st: MutexGuard<'a, RoundState>,
        _deadline: (),
        _rank: usize,
        _desc: &OpDesc,
    ) -> Result<MutexGuard<'a, RoundState>, CommFault> {
        // pre-wait poison check: same lost-wakeup guard as the std build
        // (loom's model checker is what caught the missing check)
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        let g = self.cv.wait(st).unwrap();
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        Ok(g)
    }

    /// The watchdog fired: build the per-rank last-op dump, e.g.
    /// `rank 3 waiting on allreduce round 17 ... rank 0 last seen at
    /// reduce_scatter round 17`.
    #[cfg(not(loom))]
    fn stall_fault(&self, st: &RoundState, rank: usize, desc: &OpDesc) -> CommFault {
        let secs = self.stall_timeout_ms.load(Ordering::Relaxed) as f64 / 1e3;
        CommFault::Violated {
            check: "stall",
            detail: format!(
                "rank {rank} waiting on {desc} round {} on group `{}` made no progress \
                 for {secs:.1}s; per-rank last deposits:\n{}",
                st.round,
                self.label,
                st.audit.table(&self.label)
            ),
        }
    }

    /// Core rendezvous: deposit `mine` under `desc`, the last arrival
    /// runs `combine` over all contributions (and decodes a bf16 result
    /// once when `decode` is set), everyone receives the shared result.
    ///
    /// Rounds are strictly ordered: an early finisher re-entering for
    /// round r+1 parks until round r has fully drained (a departure
    /// requires the result to be set, and the reset only happens after
    /// all `size` departures — so deposits can never leak across rounds).
    ///
    /// Fails fast instead of hanging: the auditor rejects descriptor
    /// mismatches, the watchdog bounds every wait, and a failure from
    /// either poisons the group so all peers unblock.
    fn rendezvous<F>(
        &self,
        rank: usize,
        desc: OpDesc,
        mine: Wire,
        decode: bool,
        combine: F,
    ) -> Result<Arc<Published>, CommFault>
    where
        F: FnOnce(&mut Vec<Option<Wire>>) -> Wire,
    {
        assert!(rank < self.size);
        if self.is_poisoned() {
            return Err(CommFault::Poisoned);
        }
        self.account_in(mine.bytes());
        let deadline = self.deadline();
        let mut st = self.state.lock().unwrap();
        // Previous round still draining (result published but not all
        // members have departed): wait for the reset.
        while st.result.is_some() {
            st = self.wait_step(st, deadline, rank, &desc)?;
        }
        let my_round = st.round;
        if let Err(fault) = st.audit.check(rank, my_round, desc) {
            // the round can never complete coherently — fail the whole
            // group so compliant peers unblock with `Poisoned` instead of
            // waiting on a deposit that will not come
            self.poison_locked();
            return Err(fault);
        }
        debug_assert!(
            st.contribs[rank].is_none(),
            "rank {rank} deposited twice in one round"
        );
        st.contribs[rank] = Some(mine);
        st.arrived += 1;
        if st.arrived == self.size {
            let wire = combine(&mut st.contribs);
            let decoded = match (&wire, decode) {
                (Wire::Bf16(v), true) => {
                    self.decodes.fetch_add(1, Ordering::Relaxed);
                    Some(bf16s_to_f32s(v))
                }
                _ => None,
            };
            st.result = Some(Arc::new(Published { wire, decoded }));
            self.cv.notify_all();
        } else {
            while !(st.result.is_some() && st.round == my_round) {
                st = self.wait_step(st, deadline, rank, &desc)?;
            }
        }
        let out = Arc::clone(st.result.as_ref().unwrap());
        self.account_out(out.wire.bytes());
        st.departed += 1;
        if st.departed == self.size {
            st.arrived = 0;
            st.departed = 0;
            st.result = None;
            st.round += 1;
            for c in st.contribs.iter_mut() {
                *c = None;
            }
            st.audit.round_drained();
            self.cv.notify_all();
        }
        Ok(out)
    }

    /// Shared sum rendezvous behind `allreduce` and the reduce-scatter
    /// family — parameterized by [`OpKind`] so each public collective
    /// carries its own descriptor (a reduce_scatter meeting an allreduce
    /// is an `[order]` violation, not a silent zip).
    fn sum_rendezvous(
        &self,
        rank: usize,
        mine: Vec<f32>,
        dt: ReduceDtype,
        kind: OpKind,
    ) -> Result<Arc<Published>, CommFault> {
        let desc = OpDesc { kind, len: Some(mine.len()), dtype: dt.into() };
        self.rendezvous(rank, desc, Wire::encode(mine, dt), true, |contribs| {
            let mut acc = contribs[0].take().unwrap().into_f32();
            for c in contribs.iter_mut().skip(1) {
                let c = c.take().unwrap().into_f32();
                for (a, b) in acc.iter_mut().zip(c.iter()) {
                    *a += *b;
                }
            }
            Wire::encode(acc, dt)
        })
    }

    /// Sum-allreduce. Under `ReduceDtype::Bf16` the deposited frames and
    /// the published result are genuine 2-byte bf16 payloads (the paper's
    /// bf16 gradient reduction); the sum itself runs in f32 after an exact
    /// decode, so the values match the old round-then-sum-then-round
    /// simulation bit for bit while the wire moves half the bytes.
    pub fn allreduce(&self, rank: usize, mine: Vec<f32>, dt: ReduceDtype) -> Vec<f32> {
        self.allreduce_checked(rank, mine, dt).unwrap_or_else(|f| panic!("{f}"))
    }

    /// [`Group::allreduce`] returning the fault instead of panicking —
    /// for callers (and model checks) that handle protocol failures
    /// themselves.
    pub fn allreduce_checked(
        &self,
        rank: usize,
        mine: Vec<f32>,
        dt: ReduceDtype,
    ) -> Result<Vec<f32>, CommFault> {
        Ok(self.sum_rendezvous(rank, mine, dt, OpKind::Allreduce)?.as_f32().to_vec())
    }

    /// Mean-allreduce (gradient averaging across data-parallel ranks).
    pub fn allreduce_mean(&self, rank: usize, mine: Vec<f32>, dt: ReduceDtype) -> Vec<f32> {
        let n = self.size as f32;
        let mut out = self.allreduce(rank, mine, dt);
        for v in out.iter_mut() {
            *v /= n;
        }
        out
    }

    /// Reduce-scatter with mean: rank r receives shard r of the averaged
    /// sum, shards per [`crate::util::shard_ranges`]. Input length may not
    /// divide evenly; shards are ZeRO-style contiguous ranges.
    pub fn reduce_scatter_mean(
        &self,
        rank: usize,
        mine: Vec<f32>,
        dt: ReduceDtype,
    ) -> Vec<f32> {
        let n = mine.len();
        let ranges = crate::util::shard_ranges(n, self.size);
        let summed = self
            .sum_rendezvous(rank, mine, dt, OpKind::ReduceScatter)
            .unwrap_or_else(|f| panic!("{f}"));
        let (s, l) = ranges[rank];
        let inv = 1.0 / self.size as f32;
        summed.as_f32()[s..s + l].iter().map(|v| v * inv).collect()
    }

    /// Reduce-scatter with sum over equal `1/size` slices: rank r receives
    /// slice r of the elementwise sum (Algorithm 1 line 116 — partial
    /// expert outputs are *summed*, and each EP rank keeps its own token
    /// segment).
    pub fn reduce_scatter_sum_even(
        &self,
        rank: usize,
        mine: Vec<f32>,
        dt: ReduceDtype,
    ) -> Vec<f32> {
        let n = mine.len();
        assert_eq!(n % self.size, 0, "even reduce-scatter needs divisible length");
        let per = n / self.size;
        let summed = self
            .sum_rendezvous(rank, mine, dt, OpKind::ReduceScatter)
            .unwrap_or_else(|f| panic!("{f}"));
        summed.as_f32()[rank * per..(rank + 1) * per].to_vec()
    }

    /// Allgather: concatenation of every rank's (equal-length or ragged)
    /// contribution, in rank order.
    pub fn allgather(&self, rank: usize, mine: Vec<f32>) -> Vec<f32> {
        self.allgather_checked(rank, mine).unwrap_or_else(|f| panic!("{f}"))
    }

    /// [`Group::allgather`] returning the fault instead of panicking.
    pub fn allgather_checked(&self, rank: usize, mine: Vec<f32>) -> Result<Vec<f32>, CommFault> {
        // ragged contributions are legal: len is not part of the contract
        let desc = OpDesc { kind: OpKind::Allgather, len: None, dtype: WireDtype::F32 };
        let res = self.rendezvous(rank, desc, Wire::F32(mine), true, |contribs| {
            let mut out = Vec::new();
            for c in contribs.iter_mut() {
                out.extend_from_slice(&c.take().unwrap().into_f32());
            }
            Wire::F32(out)
        })?;
        Ok(res.as_f32().to_vec())
    }

    /// Allgather of bf16 storage bits: contributions travel and
    /// concatenate as 2-byte words (the mixed-precision optimizer's param
    /// allgather wire). Consumers want the raw bits, so the publisher
    /// skips the f32 decode entirely.
    pub fn allgather_bf16(&self, rank: usize, mine: Vec<u16>) -> Vec<u16> {
        let desc = OpDesc { kind: OpKind::Allgather, len: None, dtype: WireDtype::Bf16 };
        let res = self
            .rendezvous(rank, desc, Wire::Bf16(mine), false, |contribs| {
                let mut out = Vec::new();
                for c in contribs.iter_mut() {
                    match c.take().unwrap() {
                        Wire::Bf16(v) => out.extend_from_slice(&v),
                        Wire::F32(v) => out.extend(f32s_to_bf16s(&v)),
                    }
                }
                Wire::Bf16(out)
            })
            .unwrap_or_else(|f| panic!("{f}"));
        match &res.wire {
            Wire::Bf16(v) => v.clone(),
            Wire::F32(v) => f32s_to_bf16s(v),
        }
    }

    /// Allgather over f32 values with a dtype-selected wire: `Bf16`
    /// rounds once (RNE) into genuine 2-byte frames — half the traffic
    /// the byte counters see — and decodes exactly on pickup.
    pub fn allgather_values(&self, rank: usize, mine: Vec<f32>, dt: ReduceDtype) -> Vec<f32> {
        match dt {
            ReduceDtype::F32 => self.allgather(rank, mine),
            ReduceDtype::Bf16 => {
                bf16s_to_f32s(&self.allgather_bf16(rank, f32s_to_bf16s(&mine)))
            }
        }
    }

    /// Allgather for i32 payloads (routing indices) — transported as f32
    /// bit patterns to reuse the same fabric.
    pub fn allgather_i32(&self, rank: usize, mine: &[i32]) -> Vec<i32> {
        let enc: Vec<f32> = mine.iter().map(|v| f32::from_bits(*v as u32)).collect();
        self.allgather(rank, enc)
            .into_iter()
            .map(|v| v.to_bits() as i32)
            .collect()
    }

    /// Ragged-aware gather of variable-length shards followed by local
    /// concatenation — the inverse of `reduce_scatter_mean` (ZeRO param
    /// allgather).
    pub fn allgather_shards(&self, rank: usize, mine: Vec<f32>, total: usize) -> Vec<f32> {
        let out = self.allgather(rank, mine);
        debug_assert_eq!(out.len(), total);
        out
    }

    /// [`Group::allgather_shards`] over bf16 storage bits — the ZeRO param
    /// allgather at half wire width.
    pub fn allgather_shards_bf16(&self, rank: usize, mine: Vec<u16>, total: usize) -> Vec<u16> {
        let out = self.allgather_bf16(rank, mine);
        debug_assert_eq!(out.len(), total);
        out
    }

    /// All-to-all: `mine[d]` goes to rank d; returns the buffers destined
    /// to `rank`, in source order. Used by the EP `ep_comm=all2all`
    /// ablation (paper Stage 1 compares all2all vs allgather).
    pub fn all2all(&self, rank: usize, mine: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        assert_eq!(mine.len(), self.size);
        // flatten with a length header per destination
        let mut flat = Vec::new();
        for d in mine.iter() {
            flat.push(d.len() as f32);
        }
        for d in mine.iter() {
            flat.extend_from_slice(d);
        }
        let desc = OpDesc { kind: OpKind::All2All, len: None, dtype: WireDtype::F32 };
        let all = self
            .rendezvous(rank, desc, Wire::F32(flat), true, |contribs| {
                // concatenate everyone's flattened frame, with a per-source
                // offset directory at the front
                let mut out = Vec::new();
                let frames: Vec<Vec<f32>> =
                    contribs.iter_mut().map(|c| c.take().unwrap().into_f32()).collect();
                out.push(frames.len() as f32);
                let mut off = Vec::new();
                let mut pos = 1.0 + frames.len() as f32;
                for f in &frames {
                    off.push(pos);
                    pos += f.len() as f32;
                }
                out.extend_from_slice(&off);
                for f in &frames {
                    out.extend_from_slice(f);
                }
                Wire::F32(out)
            })
            .unwrap_or_else(|f| panic!("{f}"));
        // decode: for each source frame, pick the chunk destined to us
        let all = all.as_f32();
        let nsrc = all[0] as usize;
        let mut result = Vec::with_capacity(nsrc);
        for s in 0..nsrc {
            let fstart = all[1 + s] as usize;
            let sizes: Vec<usize> = (0..self.size)
                .map(|d| all[fstart + d] as usize)
                .collect();
            let mut chunk_start = fstart + self.size;
            for d in 0..rank {
                chunk_start += sizes[d];
            }
            result.push(all[chunk_start..chunk_start + sizes[rank]].to_vec());
        }
        result
    }

    /// Broadcast from `root` (model broadcasting, paper §4). Non-roots
    /// deposit an empty payload, so the length is not part of the
    /// contract — but the *root* is: members disagreeing on the root
    /// fail with `[order]`.
    pub fn broadcast(&self, rank: usize, root: usize, mine: Vec<f32>) -> Vec<f32> {
        let payload = if rank == root { mine } else { Vec::new() };
        let desc = OpDesc { kind: OpKind::Broadcast { root }, len: None, dtype: WireDtype::F32 };
        let res = self
            .rendezvous(rank, desc, Wire::F32(payload), true, |contribs| {
                contribs[root].take().unwrap()
            })
            .unwrap_or_else(|f| panic!("{f}"));
        res.as_f32().to_vec()
    }

    /// Barrier.
    pub fn barrier(&self, rank: usize) {
        self.barrier_checked(rank).unwrap_or_else(|f| panic!("{f}"))
    }

    /// [`Group::barrier`] returning the fault instead of panicking.
    pub fn barrier_checked(&self, rank: usize) -> Result<(), CommFault> {
        let desc = OpDesc { kind: OpKind::Barrier, len: Some(0), dtype: WireDtype::F32 };
        self.rendezvous(rank, desc, Wire::F32(Vec::new()), true, |_| Wire::F32(Vec::new()))?;
        Ok(())
    }

    // -- nonblocking variants -------------------------------------------
    //
    // Each submits the blocking collective onto a [`CommRuntime`] lane and
    // returns a [`CommHandle`] future. The caller must preserve program
    // order: every group member has to issue the same collectives on a
    // group in the same order, whether via a lane or inline — lanes are
    // FIFO, so submitting in program order is sufficient. The receivers
    // take `self: Arc<Self>` (clone the `Arc` at the call site) so the
    // group can move onto the worker thread.

    /// Nonblocking [`Group::allreduce`].
    pub fn allreduce_start(
        self: Arc<Self>,
        rt: &CommRuntime,
        rank: usize,
        mine: Vec<f32>,
        dt: ReduceDtype,
    ) -> CommHandle<Vec<f32>> {
        rt.submit(move || self.allreduce(rank, mine, dt))
    }

    /// Nonblocking [`Group::reduce_scatter_mean`].
    pub fn reduce_scatter_start(
        self: Arc<Self>,
        rt: &CommRuntime,
        rank: usize,
        mine: Vec<f32>,
        dt: ReduceDtype,
    ) -> CommHandle<Vec<f32>> {
        rt.submit(move || self.reduce_scatter_mean(rank, mine, dt))
    }

    /// Nonblocking [`Group::allgather`].
    pub fn allgather_start(
        self: Arc<Self>,
        rt: &CommRuntime,
        rank: usize,
        mine: Vec<f32>,
    ) -> CommHandle<Vec<f32>> {
        rt.submit(move || self.allgather(rank, mine))
    }

    /// Nonblocking [`Group::allgather_bf16`].
    pub fn allgather_bf16_start(
        self: Arc<Self>,
        rt: &CommRuntime,
        rank: usize,
        mine: Vec<u16>,
    ) -> CommHandle<Vec<u16>> {
        rt.submit(move || self.allgather_bf16(rank, mine))
    }

    /// Max-allreduce (used for global NaN/overflow voting in ft).
    pub fn allreduce_max(&self, rank: usize, mine: Vec<f32>) -> Vec<f32> {
        let desc =
            OpDesc { kind: OpKind::AllreduceMax, len: Some(mine.len()), dtype: WireDtype::F32 };
        let res = self
            .rendezvous(rank, desc, Wire::F32(mine), true, |contribs| {
                let mut acc = contribs[0].take().unwrap().into_f32();
                for c in contribs.iter_mut().skip(1) {
                    let c = c.take().unwrap().into_f32();
                    for (a, b) in acc.iter_mut().zip(c.iter()) {
                        *a = a.max(*b);
                    }
                }
                Wire::F32(acc)
            })
            .unwrap_or_else(|f| panic!("{f}"));
        res.as_f32().to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_ranks<F, T>(n: usize, f: F) -> Vec<T>
    where
        F: Fn(usize) -> T + Send + Sync + 'static + Clone,
        T: Send + 'static,
    {
        let handles: Vec<_> = (0..n)
            .map(|r| {
                let f = f.clone();
                std::thread::spawn(move || f(r))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn allreduce_sums() {
        let g = Group::new(4);
        let outs = spawn_ranks(4, move |r| {
            g.allreduce(r, vec![r as f32, 1.0], ReduceDtype::F32)
        });
        for o in outs {
            assert_eq!(o, vec![6.0, 4.0]);
        }
    }

    #[test]
    fn reduce_scatter_then_allgather_is_mean() {
        let g = Group::new(3);
        let n = 10; // not divisible by 3: ragged shards
        let outs = spawn_ranks(3, move |r| {
            let mine: Vec<f32> = (0..n).map(|i| (i + r) as f32).collect();
            let shard = g.reduce_scatter_mean(r, mine, ReduceDtype::F32);
            g.allgather_shards(r, shard, n)
        });
        let want: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        for o in outs {
            assert_eq!(o, want);
        }
    }

    #[test]
    fn allgather_concats_in_rank_order() {
        let g = Group::new(3);
        let outs = spawn_ranks(3, move |r| g.allgather(r, vec![r as f32; r + 1]));
        for o in outs {
            assert_eq!(o, vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn all2all_routes_chunks() {
        let g = Group::new(2);
        let outs = spawn_ranks(2, move |r| {
            // rank r sends [r*10+d] to rank d
            let mine: Vec<Vec<f32>> =
                (0..2).map(|d| vec![(r * 10 + d) as f32]).collect();
            g.all2all(r, mine)
        });
        assert_eq!(outs[0], vec![vec![0.0], vec![10.0]]);
        assert_eq!(outs[1], vec![vec![1.0], vec![11.0]]);
    }

    #[test]
    fn broadcast_from_root() {
        let g = Group::new(4);
        let outs = spawn_ranks(4, move |r| {
            let mine = if r == 2 { vec![9.0, 8.0] } else { vec![] };
            g.broadcast(r, 2, mine)
        });
        for o in outs {
            assert_eq!(o, vec![9.0, 8.0]);
        }
    }

    #[test]
    fn repeated_rounds_stay_ordered() {
        let g = Group::new(3);
        let outs = spawn_ranks(3, move |r| {
            let mut acc = Vec::new();
            for round in 0..50 {
                let o = g.allreduce(r, vec![round as f32], ReduceDtype::F32);
                acc.push(o[0]);
            }
            acc
        });
        for o in outs {
            for (round, v) in o.iter().enumerate() {
                assert_eq!(*v, 3.0 * round as f32);
            }
        }
    }

    #[test]
    fn bf16_reduction_rounds() {
        let g = Group::new(2);
        let outs = spawn_ranks(2, move |r| {
            g.allreduce(r, vec![1.0009765625f32], ReduceDtype::Bf16)
        });
        for o in outs {
            // bf16(1.0009765625) = 1.0 -> sum 2.0
            assert_eq!(o, vec![2.0]);
        }
    }

    #[test]
    fn traffic_accounting_tracks_wire_width_both_directions() {
        // f32: 8 elems × 4 B deposited and picked up per rank
        let g = Group::new(2);
        let gs = Arc::clone(&g);
        spawn_ranks(2, move |r| g.allreduce(r, vec![1.0f32; 8], ReduceDtype::F32));
        let st = gs.stats();
        assert_eq!(st.ops, 2);
        assert_eq!(st.bytes_in, 2 * 8 * 4);
        assert_eq!(st.bytes_out, 2 * 8 * 4);
        // bf16: the same collective moves exactly half the bytes each way
        let g = Group::new(2);
        let gs = Arc::clone(&g);
        spawn_ranks(2, move |r| g.allreduce(r, vec![1.0f32; 8], ReduceDtype::Bf16));
        let st = gs.stats();
        assert_eq!(st.bytes_in, 2 * 8 * 2);
        assert_eq!(st.bytes_out, 2 * 8 * 2);
    }

    #[test]
    fn bf16_allgather_concats_storage_bits() {
        use crate::util::{bf16_to_f32, f32_to_bf16};
        let g = Group::new(2);
        let gs = Arc::clone(&g);
        let outs = spawn_ranks(2, move |r| {
            let mine = vec![f32_to_bf16(r as f32 + 0.5); 2];
            g.allgather_bf16(r, mine)
        });
        for o in outs {
            let vals: Vec<f32> = o.iter().map(|&b| bf16_to_f32(b)).collect();
            assert_eq!(vals, vec![0.5, 0.5, 1.5, 1.5]);
        }
        // 4 elems × 2 B out per rank
        assert_eq!(gs.stats().bytes_out, 2 * 4 * 2);
    }

    #[test]
    fn async_collectives_match_blocking_results() {
        // each rank drives its own lane; two in-flight collectives per
        // rank, submitted in the same program order everywhere
        let g = Group::new(3);
        let outs = spawn_ranks(3, move |r| {
            let rt = CommRuntime::new(&format!("t{r}"));
            let h1 = g.clone().allreduce_start(
                &rt,
                r,
                vec![r as f32, 1.0],
                ReduceDtype::F32,
            );
            let h2 = g.clone().allgather_start(&rt, r, vec![r as f32]);
            (h1.wait(), h2.wait())
        });
        for (ar, ag) in outs {
            assert_eq!(ar, vec![3.0, 3.0]);
            assert_eq!(ag, vec![0.0, 1.0, 2.0]);
        }
    }

    #[test]
    fn async_reduce_scatter_matches_blocking() {
        let g = Group::new(2);
        let n = 7; // ragged shards
        let outs = spawn_ranks(2, move |r| {
            let mine: Vec<f32> = (0..n).map(|i| (i + r) as f32).collect();
            let blocking = g.reduce_scatter_mean(r, mine.clone(), ReduceDtype::F32);
            let rt = CommRuntime::new(&format!("rs{r}"));
            let async_ = g
                .clone()
                .reduce_scatter_start(&rt, r, mine, ReduceDtype::F32)
                .wait();
            (blocking, async_)
        });
        for (b, a) in outs {
            assert_eq!(b, a);
        }
    }

    #[test]
    fn i32_allgather_roundtrips() {
        let g = Group::new(2);
        let outs = spawn_ranks(2, move |r| {
            g.allgather_i32(r, &[r as i32 * 100 - 5, i32::MAX])
        });
        for o in outs {
            assert_eq!(o, vec![-5, i32::MAX, 95, i32::MAX]);
        }
    }

    // -- protocol auditor + watchdog ------------------------------------

    #[test]
    fn mismatched_program_order_fails_fast_with_order_violation() {
        // rank 0 issues allreduce, rank 1 issues allgather on the same
        // group and round: whoever arrives second violates; the other
        // member unblocks via poisoning — nobody hangs
        let g = Group::new_labeled(2, "t-order");
        let errs = spawn_ranks(2, move |r| {
            if r == 0 {
                g.allreduce_checked(0, vec![1.0, 2.0], ReduceDtype::F32).unwrap_err()
            } else {
                g.allgather_checked(1, vec![3.0]).unwrap_err()
            }
        });
        let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("collective protocol violated [order]")),
            "{msgs:?}"
        );
        for m in &msgs {
            assert!(
                m.contains("collective protocol violated [order]")
                    || m.contains("comm group poisoned"),
                "{m}"
            );
        }
        // the violation names both ops and the group label
        let v = msgs.iter().find(|m| m.contains("[order]")).unwrap();
        assert!(v.contains("allreduce") && v.contains("allgather"), "{v}");
    }

    #[test]
    fn mismatched_payload_length_is_a_shape_violation() {
        // an allreduce zip would silently truncate to the shorter vector —
        // the auditor rejects the round instead
        let g = Group::new_labeled(2, "t-shape");
        let errs = spawn_ranks(2, move |r| {
            let mine = vec![1.0f32; if r == 0 { 8 } else { 9 }];
            g.allreduce_checked(r, mine, ReduceDtype::F32).unwrap_err()
        });
        let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("collective protocol violated [shape]")),
            "{msgs:?}"
        );
    }

    #[test]
    fn mismatched_wire_dtype_is_a_dtype_violation() {
        let g = Group::new_labeled(2, "t-dtype");
        let errs = spawn_ranks(2, move |r| {
            let dt = if r == 0 { ReduceDtype::F32 } else { ReduceDtype::Bf16 };
            g.allreduce_checked(r, vec![1.0, 2.0], dt).unwrap_err()
        });
        let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("collective protocol violated [dtype]")),
            "{msgs:?}"
        );
    }

    #[test]
    fn watchdog_stall_dumps_per_rank_last_ops() {
        // rank 1 never shows up: rank 0's wait must end in a [stall]
        // failure carrying the per-rank table, not hang forever
        let g = Group::new_labeled(2, "t-stall");
        g.set_stall_timeout(std::time::Duration::from_millis(50));
        let e = g.allreduce_checked(0, vec![1.0], ReduceDtype::F32).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("collective protocol violated [stall]"), "{msg}");
        assert!(msg.contains("rank 0 waiting on allreduce"), "{msg}");
        assert!(msg.contains("rank 1 never deposited"), "{msg}");
        assert!(msg.contains("t-stall"), "{msg}");
        // the stall poisoned the group: a late peer fails immediately
        // instead of waiting on a round that already died
        let late = g.allreduce_checked(1, vec![1.0], ReduceDtype::F32).unwrap_err();
        assert!(late.to_string().contains("comm group poisoned"), "{late}");
    }

    #[test]
    fn bf16_result_is_decoded_once_per_round_not_per_member() {
        let g = Group::new(3);
        let gs = Arc::clone(&g);
        let outs = spawn_ranks(3, move |r| {
            g.allreduce(r, vec![r as f32, 1.0], ReduceDtype::Bf16)
        });
        for o in outs {
            assert_eq!(o, vec![3.0, 3.0]);
        }
        // 3 members picked the result up, but the publisher decoded once
        assert_eq!(gs.decodes.load(Ordering::Relaxed), 1);
        // raw-bits allgather skips the decode entirely
        let g = Group::new(2);
        let gs = Arc::clone(&g);
        spawn_ranks(2, move |r| g.allgather_bf16(r, vec![0x3f80; 2]));
        assert_eq!(gs.decodes.load(Ordering::Relaxed), 0);
    }
}
