//! In-process collective communication over an N-D device mesh.
//!
//! Substitution for OneCCL (see DESIGN.md §1): rank threads rendezvous on
//! shared state. The *semantics* — process groups, who contributes what,
//! reduce/scatter/gather layouts, bf16 reduction rounding — match the
//! paper's usage exactly; only the transport differs. Every operation also
//! accounts bytes moved so the cluster model can be calibrated against the
//! runnable scale.
//!
//! Collectives are issued as a typed [`CollectiveOp`] descriptor through
//! [`Group::run`] (blocking) or [`Group::start`] (nonblocking on a
//! per-rank [`CommRuntime`] lane — what the pipelined sharded optimizer
//! uses to hide communication behind compute): allreduce,
//! reduce_scatter, allgather (values or raw bf16 bits), all2all,
//! broadcast, barrier; plus point-to-point send/recv (pipeline
//! activations). Groups built with [`Topology::node_size`] > 1 execute
//! the sum/gather ops as a three-phase hierarchy (intra-node → leaders →
//! intra-node) behind the same surface, and their traffic counters split
//! intra-node from inter-node bytes.

pub mod audit;
mod group;
pub(crate) mod lsync;
mod mesh;
mod runtime;

pub use audit::{CommFault, OpDesc, OpKind, WireDtype};
pub use group::{CollectiveOp, CollectiveOut, CommStats, Group, Parts, Reduce, ReduceDtype};
pub use mesh::{Mesh, MeshCoord, Topology};
pub use runtime::{CommHandle, CommRuntime, LaneDropped};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Point-to-point channel fabric for pipeline send/recv. Channels are
/// keyed by (src, dst, tag).
pub struct P2p {
    n: usize,
    senders: Vec<Vec<Mutex<Vec<mpsc::Sender<P2pMsg>>>>>,
    receivers: Vec<Vec<Mutex<Vec<mpsc::Receiver<P2pMsg>>>>>,
    /// out-of-order stash per (src, dst): schedules may retire receives in
    /// a different order than sends (e.g. GPipe's reverse-order backward
    /// against the last stage's in-order cotangent sends)
    stash: Mutex<std::collections::HashMap<(usize, usize, usize, u64), Vec<f32>>>,
    /// set when a rank died: blocked receivers panic instead of waiting
    /// forever for a message the dead rank will never send (mirrors
    /// [`Group`] poisoning — paper §4 hard-failure semantics)
    poisoned: AtomicBool,
}

type P2pMsg = (u64, Vec<f32>);

impl P2p {
    pub fn new(n: usize, tags: usize) -> Arc<P2p> {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _src in 0..n {
            let mut srow = Vec::with_capacity(n);
            let mut rrow = Vec::with_capacity(n);
            for _dst in 0..n {
                let mut stags = Vec::with_capacity(tags);
                let mut rtags = Vec::with_capacity(tags);
                for _ in 0..tags {
                    let (tx, rx) = mpsc::channel();
                    stags.push(tx);
                    rtags.push(rx);
                }
                srow.push(Mutex::new(stags));
                rrow.push(Mutex::new(rtags));
            }
            senders.push(srow);
            receivers.push(rrow);
        }
        Arc::new(P2p {
            n,
            senders,
            receivers,
            stash: Mutex::new(Default::default()),
            poisoned: AtomicBool::new(false),
        })
    }

    /// Mark the fabric dead (a rank failed). Receivers blocked on a
    /// message from the dead rank panic out on their next poll.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
    }

    fn check_poison(&self) {
        if self.poisoned.load(Ordering::SeqCst) {
            panic!("p2p fabric poisoned: a peer rank failed");
        }
    }

    /// Send `data` from `src` to `dst` on `tag` with a sequence id for
    /// sanity checking.
    pub fn send(&self, src: usize, dst: usize, tag: usize, seq: u64, data: Vec<f32>) {
        assert!(src < self.n && dst < self.n);
        self.check_poison();
        let guard = self.senders[src][dst].lock().unwrap();
        guard[tag].send((seq, data)).expect("p2p receiver gone");
    }

    /// Blocking receive at `dst` from `src` on `tag` for a specific seq
    /// id; out-of-order arrivals are stashed until requested.
    pub fn recv(&self, src: usize, dst: usize, tag: usize, expect_seq: u64) -> Vec<f32> {
        if let Some(d) = self.stash.lock().unwrap().remove(&(src, dst, tag, expect_seq)) {
            return d;
        }
        let guard = self.receivers[src][dst].lock().unwrap();
        loop {
            self.check_poison();
            match guard[tag].recv_timeout(Duration::from_millis(20)) {
                Ok((seq, data)) => {
                    if seq == expect_seq {
                        return data;
                    }
                    self.stash.lock().unwrap().insert((src, dst, tag, seq), data);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => panic!("p2p sender gone"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_roundtrip() {
        let p = P2p::new(2, 2);
        let p2 = Arc::clone(&p);
        let h = std::thread::spawn(move || {
            p2.send(0, 1, 1, 7, vec![1.0, 2.0]);
        });
        let got = p.recv(0, 1, 1, 7);
        assert_eq!(got, vec![1.0, 2.0]);
        h.join().unwrap();
    }
}
