//! Central registry of the repo's **stable failure-check names**.
//!
//! Every fail-fast path that operators and tests key on emits a string of
//! the shape `"<domain> [<name>]: <detail>"` — e.g.
//! `plan validation failed [micro-batches]: ...`. Those tags are load
//! bearing three times over: [`super::classify`] routes them to a
//! relaunch decision, integration tests assert them, and runbooks grep
//! for them. Before this module each site hand-formatted its own
//! literal, so a typo silently produced an unclassifiable (and
//! un-greppable) failure.
//!
//! The registry makes the contract checkable:
//!
//! * producers build errors through [`err`] / [`tag`] (a `debug_assert`
//!   rejects unregistered names at test time);
//! * `optimus lint` (see [`crate::analysis`]) verifies that every
//!   `<domain> [<name>]` literal in the sources is registered here AND
//!   that every registered check is asserted by at least one test —
//!   a check nobody tests is a check that silently rots.

/// Domain prefix for parallelism-plan validation failures
/// ([`crate::coordinator::plan`]). Non-relaunchable: the job spec itself
/// is wrong.
pub const PLAN: &str = "plan validation failed";

/// Domain prefix for checkpoint-resume failures
/// ([`crate::ckpt`]). Non-relaunchable: retrying replays the same
/// on-disk state.
pub const RESUME: &str = "checkpoint resume failed";

/// Domain prefix for collective-protocol violations detected by the
/// comm auditor ([`crate::comm`]). Non-relaunchable for
/// `order`/`shape`/`dtype` (a program bug re-manifests identically);
/// `stall` stays relaunchable — the dominant cause is a dead peer.
pub const PROTOCOL: &str = "collective protocol violated";

/// Domain prefix for serving-engine startup failures
/// ([`crate::serve`]). Non-relaunchable: the serve configuration or the
/// checkpoint it points at is wrong, and a retry replays both.
pub const SERVE: &str = "serve startup failed";

/// Domain prefix for `optimus lint` findings ([`crate::analysis`]) —
/// one registered name per pass, so CI summaries and runbook greps key
/// on the same stable tags as every other failure domain.
/// Non-relaunchable: a lint finding is a source defect.
pub const LINT: &str = "lint invariant violated";

/// One registered check: a `(domain, name)` pair whose formatted tag is
/// `"<domain> [<name>]"`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CheckId {
    pub domain: &'static str,
    pub name: &'static str,
}

/// Every stable check the repo may emit. Adding a failure path means
/// adding a row here and a test asserting it — `optimus lint` enforces
/// both directions.
pub const CHECKS: &[CheckId] = &[
    // plan validation (coordinator/plan.rs spec checks)
    CheckId { domain: PLAN, name: "topology" },
    CheckId { domain: PLAN, name: "world-size" },
    CheckId { domain: PLAN, name: "micro-batches" },
    CheckId { domain: PLAN, name: "sharding" },
    CheckId { domain: PLAN, name: "schedule" },
    CheckId { domain: PLAN, name: "overlap" },
    CheckId { domain: PLAN, name: "checkpoint" },
    CheckId { domain: PLAN, name: "dtype" },
    // plan validation (model checks)
    CheckId { domain: PLAN, name: "layer-split" },
    CheckId { domain: PLAN, name: "expert-split" },
    CheckId { domain: PLAN, name: "pp-artifacts" },
    CheckId { domain: PLAN, name: "ep-artifacts" },
    // plan validation (data checks)
    CheckId { domain: PLAN, name: "data-context" },
    CheckId { domain: PLAN, name: "data" },
    // plan validation (serving plans — coordinator/plan.rs::validate_serve)
    CheckId { domain: PLAN, name: "serve" },
    // serving engine startup (serve/mod.rs)
    CheckId { domain: SERVE, name: "plan" },
    CheckId { domain: SERVE, name: "kv-oom" },
    CheckId { domain: SERVE, name: "ckpt" },
    // checkpoint resume (ckpt/reshard.rs + ckpt/checkpointer.rs)
    CheckId { domain: RESUME, name: "manifest" },
    CheckId { domain: RESUME, name: "checksum" },
    CheckId { domain: RESUME, name: "dtype" },
    CheckId { domain: RESUME, name: "model" },
    CheckId { domain: RESUME, name: "param-count" },
    CheckId { domain: RESUME, name: "coverage" },
    CheckId { domain: RESUME, name: "data-seed" },
    // collective protocol (comm/audit.rs)
    CheckId { domain: PROTOCOL, name: "order" },
    CheckId { domain: PROTOCOL, name: "shape" },
    CheckId { domain: PROTOCOL, name: "dtype" },
    CheckId { domain: PROTOCOL, name: "stall" },
    // static analysis passes (analysis/passes.rs::RULES, same order)
    CheckId { domain: LINT, name: "check-strings" },
    CheckId { domain: LINT, name: "check-coverage" },
    CheckId { domain: LINT, name: "named-spawn" },
    CheckId { domain: LINT, name: "lock-discipline" },
    CheckId { domain: LINT, name: "metrics-class" },
    CheckId { domain: LINT, name: "collective-divergence" },
    CheckId { domain: LINT, name: "collective-order" },
    CheckId { domain: LINT, name: "lock-order" },
    CheckId { domain: LINT, name: "poison-path" },
];

/// Is `(domain, name)` a registered check?
pub fn is_registered(domain: &str, name: &str) -> bool {
    CHECKS.iter().any(|c| c.domain == domain && c.name == name)
}

/// The stable tag `"<domain> [<name>]"` — what tests assert and
/// [`super::classify`] matches on.
pub fn tag(domain: &'static str, name: &'static str) -> String {
    debug_assert!(
        is_registered(domain, name),
        "unregistered check `{domain} [{name}]` — add it to ft::checks::CHECKS"
    );
    format!("{domain} [{name}]")
}

/// Full failure message `"<domain> [<name>]: <detail>"`.
pub fn msg(domain: &'static str, name: &'static str, detail: impl std::fmt::Display) -> String {
    format!("{}: {detail}", tag(domain, name))
}

/// Registered failure as an [`anyhow::Error`] — the one constructor the
/// plan/resume validators use, so the literal never drifts from the
/// registry.
pub fn err(domain: &'static str, name: &'static str, detail: impl std::fmt::Display) -> anyhow::Error {
    anyhow::anyhow!("{}", msg(domain, name, detail))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_no_duplicates() {
        for (i, a) in CHECKS.iter().enumerate() {
            for b in &CHECKS[i + 1..] {
                assert!(a != b, "duplicate check {a:?}");
            }
        }
    }

    #[test]
    fn tag_formats_the_stable_string() {
        assert_eq!(tag(PLAN, "micro-batches"), "plan validation failed [micro-batches]");
        assert_eq!(
            msg(PROTOCOL, "order", "rank 1 issued allgather"),
            "collective protocol violated [order]: rank 1 issued allgather"
        );
        let e = err(RESUME, "checksum", "shard r0.params.bin");
        assert!(format!("{e:#}").starts_with("checkpoint resume failed [checksum]"));
    }

    #[test]
    fn lookup_rejects_unknown_names() {
        assert!(is_registered(PLAN, "topology"));
        assert!(is_registered(PROTOCOL, "stall"));
        assert!(!is_registered(PLAN, "no-such-check"));
        assert!(!is_registered("made-up domain", "topology"));
    }
}
