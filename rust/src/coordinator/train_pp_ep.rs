//! Hybrid PP×EP engine: pipeline stages whose per-stage step runs the EP
//! per-layer Stage-1 exchange loop over the stage's EP subgroup.
//!
//! The mesh slice of pipeline stage `s` is a dp×ep grid. Each microbatch
//! flows through the stages exactly as in [`train_pp`](super): hidden
//! states forward on p2p tag 0, cotangents back on tag 1, last stage
//! fuses its backward into the forward op. Inside a stage, each layer
//! executes the [`train_ep`](super) loop — `ep_layer_pre_fwd`, Stage-1
//! token exchange across the stage's EP group, `ep_expert_fwd`,
//! reduce-scatter of partials — on the same per-layer EP artifacts, so no
//! dedicated PP×EP artifacts are needed; the plan only requires the EP
//! degree to be built.
//!
//! Placement comes entirely from the [`ParallelismPlan`]: the stage's
//! layer range and embed/head ownership select the rank-local
//! [`EpLayout`], and the plan's per-stage segment layout drives the
//! sharded optimizer — experts shard over the stage's DP group, non-expert
//! params over DP (SO) or the stage's DP×EP group (EPSO), with the
//! grad-norm/clip domain spanning the whole world so clipping sees the
//! same global norm as a DP run.
//!
//! Gradient convention matches DP and EP: microbatch-mean everywhere,
//! expert gradients additionally scaled by 1/EP (the gathered backward
//! sums every EP peer's token cotangents).

use super::clip_now;
use super::ep::{exchange_all2all, exchange_allgather, fur_indices, EpComm};
use super::ep_layout::EpLayout;
use super::harness::{
    AuxParams, CkptView, LossDomain, RankCtx, RankFinish, RankTrainer, ReportParts, StepOutcome,
};
use super::pipeline::{seq_id, PipeOp};
use super::plan::ParallelismPlan;
use super::train_ep::{Arts, ParamSlices};
use super::TrainReport;
use crate::ckpt::LocalMap;
use crate::comm::{CollectiveOp, Group, P2p, Parts, Reduce, ReduceDtype};
use crate::config::ModelManifest;
use crate::metrics::{Scoped, StepBreakdown};
use crate::optim::sharded::{plan_segments, ShardedOptimizer};
use crate::optim::ShardingMode;
use crate::runtime::{Dtype, Tensor};
use crate::util::bf16_round;
use crate::Result;
use std::sync::Arc;

/// Per-microbatch forward stash (SAC: layer inputs + Stage-1 exchange
/// products, everything the stage backward recomputes from).
struct MbStash {
    /// stage-0 token batch (needed for the embedding backward)
    tokens: Option<Tensor>,
    /// per local layer: `pre_fwd` input
    h_in: Vec<Tensor>,
    /// per local layer: gathered tokens / routing weights / shifted ids
    x_all: Vec<Tensor>,
    w_all: Vec<Tensor>,
    idx: Vec<Tensor>,
}

impl MbStash {
    fn new(n_layers: usize) -> MbStash {
        MbStash {
            tokens: None,
            h_in: Vec::with_capacity(n_layers),
            x_all: Vec::with_capacity(n_layers),
            w_all: Vec::with_capacity(n_layers),
            idx: Vec::with_capacity(n_layers),
        }
    }
}

pub(super) struct PpEpTrainer {
    layout: EpLayout,
    /// the stage layout's copy plan as a checkpoint map
    map: LocalMap,
    arts: Arts,
    /// `Arc`-backed so a checkpoint snapshot is an O(1) handle capture
    params: Tensor,
    opt: ShardedOptimizer,
    p2p: Arc<P2p>,
    ep_group: Arc<Group>,
    ep_rank: usize,
    stage: usize,
    first: bool,
    last: bool,
    dp_coord: usize,
    ep_coord: usize,
    data_rank: usize,
    prev: Option<usize>,
    next: Option<usize>,
    ops: Vec<PipeOp>,
    loss_dom: Option<LossDomain>,
}

impl PpEpTrainer {
    fn exec(
        &self,
        ctx: &RankCtx,
        key: &str,
        path: &std::path::Path,
        inputs: Vec<Tensor>,
    ) -> Result<Vec<Tensor>> {
        // same cache keys as the EP engine: the artifacts are identical
        // files, so stages share compiled executables
        ctx.engine
            .exec(&format!("{}:{key}", ctx.mm.name), path.to_path_buf(), inputs)
    }

    /// Activation-wire width for the stage's EP collectives — follows
    /// the plan dtype, exactly like the flat EP engine.
    fn wire(&self, ctx: &RankCtx) -> ReduceDtype {
        match ctx.plan.dtype {
            Dtype::Bf16 => ReduceDtype::Bf16,
            Dtype::F32 => ReduceDtype::F32,
        }
    }

    /// Forward through this stage's layers, stashing SAC inputs into `st`.
    fn fwd_through_layers(
        &self,
        ctx: &RankCtx,
        ps: &ParamSlices,
        mut hcur: Tensor,
        st: &mut MbStash,
        breakdown: &mut StepBreakdown,
    ) -> Result<Tensor> {
        let h = &ctx.mm.hyper;
        let ep = ctx.plan.topo.ep;
        let nr = self.layout.n_local_experts;
        let (b, s) = (h.batch, h.seq);
        let t_local = b * s;
        let t_all = ep * t_local;
        let k = h.top_k;
        let hid = h.hidden;
        let wire = self.wire(ctx);

        for l in 0..self.layout.layer_ne.len() {
            st.h_in.push(hcur.clone());
            let outs = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                self.exec(ctx, "pre_fwd", &self.arts.pre_fwd, vec![
                    ps.layer_ne[l].clone(),
                    hcur,
                ])?
            };
            let mut it = outs.into_iter();
            let a = it.next().unwrap();
            let x2d = it.next().unwrap().into_f32()?;
            let w2d = it.next().unwrap().into_f32()?;
            let idx_t = it.next().unwrap();
            let _aux = it.next().unwrap().scalar()?;
            let mut idx = idx_t.as_i32()?.to_vec();
            if ctx.spec.fur {
                idx = fur_indices(t_local, k, h.n_experts);
            }
            // ---- Stage 1: token exchange across the stage's EP group ----
            let (x_all, w_all, idx_all) = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                match ctx.plan.ep_comm {
                    EpComm::Allgather => {
                        exchange_allgather(&self.ep_group, self.ep_rank, x2d, w2d, &idx, wire)
                    }
                    EpComm::All2All => exchange_all2all(
                        &self.ep_group,
                        self.ep_rank,
                        ep,
                        nr,
                        hid,
                        x2d,
                        w2d,
                        &idx,
                        wire,
                    ),
                }
            };
            let idx_shift: Vec<i32> = idx_all
                .iter()
                .map(|&v| v - (self.ep_rank * nr) as i32)
                .collect();
            let x_all = Tensor::f32(x_all, vec![t_all, hid]);
            let w_all = Tensor::f32(w_all, vec![t_all, k]);
            let idx_shift = Tensor::i32(idx_shift, vec![t_all, k]);
            let partial = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                self.exec(ctx, "expert_fwd", &self.arts.expert_fwd, vec![
                    ps.layer_e[l].clone(),
                    x_all.clone(),
                    w_all.clone(),
                    idx_shift.clone(),
                ])?
                .remove(0)
                .into_f32()?
            };
            let moe_local = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                self.ep_group
                    .run(
                        self.ep_rank,
                        CollectiveOp::ReduceScatter {
                            data: partial,
                            red: Reduce::Sum,
                            dt: wire,
                            parts: Parts::Even,
                        },
                    )
                    .unwrap_or_else(|f| panic!("{f}"))
                    .values()
            };
            let mut a_data = a.into_f32()?;
            for (av, mv) in a_data.iter_mut().zip(moe_local.iter()) {
                *av += *mv;
            }
            hcur = Tensor::f32(a_data, vec![b, s, hid]);
            st.x_all.push(x_all);
            st.w_all.push(w_all);
            st.idx.push(idx_shift);
        }
        Ok(hcur)
    }

    /// Backward through this stage's layers (reverse order), accumulating
    /// into `grads`; returns the cotangent of the stage *input*.
    fn bwd_through_layers(
        &self,
        ctx: &RankCtx,
        ps: &ParamSlices,
        st: &MbStash,
        mut dh: Vec<f32>,
        grads: &mut [f32],
        breakdown: &mut StepBreakdown,
    ) -> Result<Vec<f32>> {
        let h = &ctx.mm.hyper;
        let ep = ctx.plan.topo.ep;
        let (b, s) = (h.batch, h.seq);
        let t_local = b * s;
        let t_all = ep * t_local;
        let k = h.top_k;
        let hid = h.hidden;
        let wire = self.wire(ctx);

        for l in (0..self.layout.layer_ne.len()).rev() {
            let d_moe_full = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                self.ep_group
                    .run(self.ep_rank, CollectiveOp::Allgather { data: dh.clone(), dt: wire })
                    .unwrap_or_else(|f| panic!("{f}"))
                    .values()
            };
            let outs = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                self.exec(ctx, "expert_bwd", &self.arts.expert_bwd, vec![
                    ps.layer_e[l].clone(),
                    st.x_all[l].clone(),
                    st.w_all[l].clone(),
                    st.idx[l].clone(),
                    Tensor::f32(d_moe_full, vec![t_all, hid]),
                ])?
            };
            let dx_partial = outs[0].as_f32()?.to_vec();
            let dw_partial = outs[1].as_f32()?.to_vec();
            for (g, d) in grads[self.layout.layer_e[l].clone()]
                .iter_mut()
                .zip(outs[2].as_f32()?)
            {
                *g += d;
            }
            let (dx_local, dw_local) = {
                let _t = Scoped::new(&mut breakdown.comm_secs);
                let rs = |data: Vec<f32>| {
                    self.ep_group
                        .run(
                            self.ep_rank,
                            CollectiveOp::ReduceScatter {
                                data,
                                red: Reduce::Sum,
                                dt: wire,
                                parts: Parts::Even,
                            },
                        )
                        .unwrap_or_else(|f| panic!("{f}"))
                        .values()
                };
                (rs(dx_partial), rs(dw_partial))
            };
            let outs = {
                let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                self.exec(ctx, "pre_bwd", &self.arts.pre_bwd, vec![
                    ps.layer_ne[l].clone(),
                    st.h_in[l].clone(),
                    Tensor::f32(dh.clone(), vec![b, s, hid]),
                    Tensor::f32(dx_local, vec![t_local, hid]),
                    Tensor::f32(dw_local, vec![t_local, k]),
                ])?
            };
            dh = outs[0].as_f32()?.to_vec();
            for (g, d) in grads[self.layout.layer_ne[l].clone()]
                .iter_mut()
                .zip(outs[1].as_f32()?)
            {
                *g += d;
            }
        }
        Ok(dh)
    }
}

impl RankTrainer for PpEpTrainer {
    const LABEL: &'static str = "ppep";
    type Shared = P2p;

    fn shared(_mm: &ModelManifest, plan: &ParallelismPlan) -> Result<Arc<P2p>> {
        // tag 0 = fwd activations, 1 = cotangents
        Ok(P2p::new(plan.topo.world(), 2))
    }

    fn poison_shared(shared: &P2p) {
        shared.poison();
    }

    fn setup(ctx: &RankCtx, shared: &Arc<P2p>, global_params: Vec<f32>) -> Result<PpEpTrainer> {
        let rank = ctx.rank;
        let mm = &ctx.mm;
        let topo = ctx.plan.topo;
        let (ep, pp) = (topo.ep, topo.pp);
        let c = ctx.mesh.coord(rank);
        let stage = c.pp;
        let sp = &ctx.plan.stages[stage];
        let layout =
            EpLayout::for_stage(mm, ep, c.ep, sp.layers.clone(), sp.has_embed, sp.has_head);
        debug_assert_eq!(layout.ne_len, sp.seg.ne_len);
        debug_assert_eq!(layout.e_len, sp.seg.e_len);
        debug_assert_eq!(layout.n_local_experts, sp.experts_per_rank);
        let arts = Arts::load(mm, ep)?;
        let (dp_group, dp_rank) = ctx.mesh.dp_group(rank);
        let (ep_group, ep_rank) = ctx.mesh.ep_group(rank);
        let (dpep_group, dpep_rank) = ctx.mesh.dpep_group(rank);
        let (prev, next) = ctx.mesh.pp_neighbours(rank);

        let params = layout.extract(&global_params);
        drop(global_params);

        let segs = plan_segments(
            ctx.plan.mode,
            sp.seg,
            dp_group,
            dp_rank,
            dpep_group,
            dpep_rank,
            ep,
        );
        let opt = ctx.sharded_optimizer(segs, &format!("ppep{rank}"));

        let last = stage == pp - 1;
        let map = LocalMap::from_copies(layout.copy_runs())?;
        let local_len = layout.local_len();
        Ok(PpEpTrainer {
            layout,
            map,
            arts,
            // resident precision follows the plan dtype (one RNE round
            // here for bf16; the optimizer's f32 masters carry state)
            params: Tensor::from_f32(ctx.plan.dtype, params, vec![local_len]),
            opt,
            p2p: Arc::clone(shared),
            ep_group: Arc::clone(ep_group),
            ep_rank,
            stage,
            first: stage == 0,
            last,
            dp_coord: c.dp,
            ep_coord: c.ep,
            data_rank: c.dp * ep + c.ep,
            prev,
            next,
            ops: ctx.plan.schedule.ops(stage, pp, ctx.plan.micro_batches),
            loss_dom: last.then(|| LossDomain {
                group: Arc::clone(dpep_group),
                group_rank: dpep_rank,
                record: c.dp == 0 && c.ep == 0,
            }),
        })
    }

    fn step(
        &mut self,
        ctx: &RankCtx,
        step: usize,
        breakdown: &mut StepBreakdown,
    ) -> Result<StepOutcome> {
        let rank = ctx.rank;
        let h = &ctx.mm.hyper;
        let ep = ctx.plan.topo.ep;
        let micro = ctx.plan.micro_batches;
        let (b, s) = (h.batch, h.seq);
        let hid = h.hidden;
        let n_local = self.layout.layer_ne.len();

        // artifacts are lowered in f32: a bf16-resident vector decodes
        // once per step (exactly) before slicing. Stage p2p payloads
        // value-round through bf16 in bf16 mode, like the PP engine.
        let ps = match self.params.dtype() {
            Dtype::F32 => ParamSlices::new(self.params.as_f32()?, &self.layout),
            Dtype::Bf16 => ParamSlices::new(&self.params.to_f32_vec()?, &self.layout),
        };
        let round = |mut v: Vec<f32>| {
            if ctx.plan.dtype == Dtype::Bf16 {
                for x in v.iter_mut() {
                    *x = bf16_round(*x);
                }
            }
            v
        };
        let mut grads = vec![0.0f32; self.layout.local_len()];
        let mut step_loss = 0.0f32;
        let mut stash: Vec<Option<MbStash>> = (0..micro).map(|_| None).collect();

        for op in &self.ops {
            match *op {
                PipeOp::Fwd { mb, .. } => {
                    let mut st = MbStash::new(n_local);
                    let h_in = if self.first {
                        let tokens = ctx.fetch_tokens(step, self.data_rank, mb, breakdown)?;
                        let h0 = {
                            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                            self.exec(ctx, "embed_fwd", &self.arts.embed_fwd, vec![
                                ps.emb.clone(),
                                tokens.clone(),
                            ])?
                            .remove(0)
                        };
                        st.tokens = Some(tokens);
                        h0
                    } else {
                        let hin = {
                            let _t = Scoped::new(&mut breakdown.comm_secs);
                            self.p2p
                                .recv(self.prev.unwrap(), rank, 0, seq_id(step, mb))
                        };
                        Tensor::f32(hin, vec![b, s, hid])
                    };
                    let hout = self.fwd_through_layers(ctx, &ps, h_in, &mut st, breakdown)?;
                    if self.last {
                        // head + fused stage backward (mirrors train_pp's
                        // last-stage behaviour: cotangent leaves at once)
                        let tokens = ctx.fetch_tokens(step, self.data_rank, mb, breakdown)?;
                        let outs = {
                            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                            self.exec(ctx, "head", &self.arts.head, vec![
                                ps.head.clone(),
                                hout,
                                tokens,
                            ])?
                        };
                        let loss = outs[0].scalar()?;
                        if !loss.is_finite() {
                            return Err(ctx.non_finite(step));
                        }
                        step_loss += loss;
                        let dh = outs[1].clone().into_f32()?;
                        for (g, d) in grads[self.layout.head.clone()]
                            .iter_mut()
                            .zip(outs[2].as_f32()?)
                        {
                            *g += d;
                        }
                        let dh_in =
                            self.bwd_through_layers(ctx, &ps, &st, dh, &mut grads, breakdown)?;
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        self.p2p
                            .send(rank, self.prev.unwrap(), 1, seq_id(step, mb), round(dh_in));
                    } else {
                        {
                            let _t = Scoped::new(&mut breakdown.comm_secs);
                            self.p2p.send(
                                rank,
                                self.next.unwrap(),
                                0,
                                seq_id(step, mb),
                                round(hout.into_f32()?),
                            );
                        }
                        stash[mb] = Some(st);
                    }
                }
                PipeOp::Bwd { mb, .. } => {
                    if self.last {
                        continue; // fused into Fwd above
                    }
                    let d_out = {
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        self.p2p
                            .recv(self.next.unwrap(), rank, 1, seq_id(step, mb))
                    };
                    let st = stash[mb].take().expect("bwd before fwd");
                    let dh_in =
                        self.bwd_through_layers(ctx, &ps, &st, d_out, &mut grads, breakdown)?;
                    if self.first {
                        let tokens = st.tokens.as_ref().unwrap();
                        let outs = {
                            let _t = Scoped::new(&mut breakdown.fwd_bwd_secs);
                            self.exec(ctx, "embed_bwd", &self.arts.embed_bwd, vec![
                                ps.emb.clone(),
                                tokens.clone(),
                                Tensor::f32(dh_in, vec![b, s, hid]),
                            ])?
                        };
                        for (g, d) in
                            grads[self.layout.emb.clone()].iter_mut().zip(outs[0].as_f32()?)
                        {
                            *g += d;
                        }
                    } else {
                        let _t = Scoped::new(&mut breakdown.comm_secs);
                        self.p2p
                            .send(rank, self.prev.unwrap(), 1, seq_id(step, mb), round(dh_in));
                    }
                }
            }
        }

        // ---- SO correctness step: NE grads must average over EP too ----
        if ctx.plan.mode == ShardingMode::So && ep > 1 {
            let _t = Scoped::new(&mut breakdown.comm_secs);
            let ne = grads[..self.layout.ne_len].to_vec();
            let avg = self
                .ep_group
                .run(
                    self.ep_rank,
                    CollectiveOp::Allreduce {
                        data: ne,
                        red: Reduce::Mean,
                        dt: ctx.spec.reduce_dtype(),
                    },
                )
                .unwrap_or_else(|f| panic!("{f}"))
                .values();
            grads[..self.layout.ne_len].copy_from_slice(&avg);
        }

        // microbatch mean everywhere; expert grads additionally divide by
        // EP (the gathered backward sums every EP peer's cotangents) so
        // all engines share the mean-over-global-batch convention
        let inv_mb = 1.0 / micro as f32;
        for g in grads[..self.layout.ne_len].iter_mut() {
            *g *= inv_mb;
        }
        let inv_e = inv_mb / ep as f32;
        for g in grads[self.layout.ne_len..].iter_mut() {
            *g *= inv_e;
        }

        let lr = ctx.spec.run.lr_at(step) as f32;
        let gn = self
            .opt
            .step_tensor(&mut self.params, &grads, lr, clip_now(&ctx.spec.run, step))?;
        Ok(StepOutcome { loss: step_loss / micro as f32, grad_norm: gn })
    }

    fn params_mut(&mut self) -> Result<&mut [f32]> {
        Ok(self.params.as_f32_mut()?.as_mut_slice())
    }

    fn ckpt_view(&mut self) -> CkptView<'_> {
        CkptView { params: &self.params, map: &self.map, opt: &mut self.opt }
    }

    fn loss_domain(&self) -> Option<&LossDomain> {
        self.loss_dom.as_ref()
    }

    fn finish(self, ctx: &RankCtx) -> Result<RankFinish> {
        // dp=0 plane reassembles the model: the (last-stage, ep=0) rank
        // seeds the report; every other (stage, ep) slice arrives as an
        // Aux payload and is scattered in by merge_aux — no collectives
        if self.dp_coord != 0 {
            return Ok(RankFinish::None);
        }
        if self.last && self.ep_coord == 0 {
            let mut final_params = vec![0.0f32; ctx.mm.param_count];
            self.layout.scatter(&self.params.to_f32_vec()?, &mut final_params);
            return Ok(RankFinish::Report(Box::new(ReportParts {
                final_params: Tensor::f32(final_params, vec![ctx.mm.param_count]),
                opt_state_bytes: self.opt.state_bytes(),
                optimizer_update_secs: self.opt.update_secs,
                optimizer_comm_secs: self.opt.comm_secs,
                optimizer_overlap_secs: self.opt.overlap_secs,
                optimizer_lane_ops: self.opt.lane_ops(),
            })));
        }
        Ok(RankFinish::Aux(AuxParams {
            tag: self.stage * ctx.plan.topo.ep + self.ep_coord,
            params: self.params.into_f32()?,
        }))
    }

    fn merge_aux(
        mm: &ModelManifest,
        plan: &ParallelismPlan,
        report: &mut TrainReport,
        aux: Vec<AuxParams>,
    ) -> Result<()> {
        let ep = plan.topo.ep;
        let global = report.final_params.as_f32_mut()?;
        for a in aux {
            let (stage, ep_rank) = (a.tag / ep, a.tag % ep);
            let sp = &plan.stages[stage];
            let lay =
                EpLayout::for_stage(mm, ep, ep_rank, sp.layers.clone(), sp.has_embed, sp.has_head);
            lay.scatter(&a.params, global);
        }
        Ok(())
    }
}
